//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no vendored registry,
//! so this crate re-implements the (small) slice of `anyhow` the workspace
//! uses: [`Error`] with a context chain, the [`Result`] alias, the
//! [`Context`] extension trait for `Result`/`Option`, and the `anyhow!`,
//! `bail!`, `ensure!` macros. Formatting matches `anyhow` where it matters:
//! `{e}` prints the outermost context, `{e:#}` the full chain joined by
//! `": "`, and `{e:?}` a `Caused by:` listing.
//!
//! Swapping in the real crate later is a one-line Cargo.toml change; no
//! source edits needed.

use std::fmt;

/// A string-backed error with a context chain (innermost cause first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.push(c.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let outer = self.chain.last().map(String::as_str).unwrap_or("");
        write!(f, "{outer}")?;
        if f.alternate() {
            for c in self.chain.iter().rev().skip(1) {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let outer = self.chain.last().map(String::as_str).unwrap_or("");
        write!(f, "{outer}")?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: a blanket conversion from any std error. Legal
// because `Error` itself deliberately does NOT implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`: attach context to `Result` errors / `None` options.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = anyhow!("root {}", 42).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_and_result_context() {
        let n: Option<u32> = None;
        let e = n.with_context(|| "missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("fmt").unwrap_err();
        assert_eq!(format!("{e}"), "fmt");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(5).unwrap_err()), "five");
        assert_eq!(format!("{}", f(50).unwrap_err()), "too big: 50");
    }
}
