//! The HTTP front-end's observable contract, over real loopback sockets:
//! `/metrics` values must equal ground truth (request counts, batch-fill
//! sum), bounded admission must demonstrably fire 429 under saturating
//! load while accepted requests keep a bounded p99, and a graceful drain
//! must answer every admitted in-flight request. Self-contained
//! (synthetic model + data; no `make artifacts`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use adaround::coordinator::{save_quantized, Method, Pipeline, PipelineConfig, QuantizedModel};
use adaround::data::synthetic_stripes;
use adaround::nn::Model;
use adaround::serve::{
    infer_body, BatchPolicy, Batcher, HttpClient, HttpConfig, HttpServer, ModelRegistry,
    ServeEngine,
};
use adaround::tensor::Tensor;
use adaround::util::{Json, Rng};

/// Tiny conv classifier (same shape as the pool-serving suite).
fn tiny_model(rng: &mut Rng) -> Model {
    let ir = r#"{"task":"cls","ir":[
      {"id":"in","op":"input","inputs":[]},
      {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":8,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
      {"id":"g1","op":"gpool","inputs":["c1"]},
      {"id":"d1","op":"dense","inputs":["g1"],"cin":8,"cout":3,"relu":false}
    ]}"#;
    let entry = Json::parse(ir).unwrap();
    let mut w = BTreeMap::new();
    let mut tensor = |shape: &[usize], std: f32, rng: &mut Rng| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, std)).collect())
    };
    w.insert("c1.w".into(), tensor(&[8, 3, 3, 3], 0.25, rng));
    w.insert("c1.b".into(), tensor(&[8], 0.05, rng));
    w.insert("d1.w".into(), tensor(&[3, 8], 0.4, rng));
    w.insert("d1.b".into(), tensor(&[3], 0.05, rng));
    Model::from_manifest("httpserve", &entry, w).unwrap()
}

fn quantize_8_8(model: &Model, calib: &Tensor) -> QuantizedModel {
    let cfg = PipelineConfig {
        method: Method::Nearest,
        bits: 8,
        per_channel: true,
        act_bits: Some(8),
        calib_n: calib.shape[0],
        ..Default::default()
    };
    Pipeline::new(model, cfg, None).quantize(calib, &mut Rng::new(7)).unwrap()
}

fn images_of(x: &Tensor) -> Vec<Tensor> {
    let per: usize = x.shape[1..].iter().product();
    (0..x.shape[0])
        .map(|i| Tensor::from_vec(&x.shape[1..], x.data[i * per..(i + 1) * per].to_vec()))
        .collect()
}

/// Build (model, qm, oracle rows per pool image, images).
fn fixture(seed: u64) -> (Model, QuantizedModel, Vec<Vec<f32>>, Vec<Tensor>) {
    let mut rng = Rng::new(seed);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(8, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib);
    let images = images_of(&val);
    let mut oracle_engine = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let oracle: Vec<Vec<f32>> = images
        .iter()
        .map(|img| {
            let mut shape = vec![1usize];
            shape.extend_from_slice(&img.shape);
            oracle_engine.forward(&Tensor::from_vec(&shape, img.data.clone())).data
        })
        .collect();
    (model, qm, oracle, images)
}

fn bind_server(
    model: &Model,
    qm: &QuantizedModel,
    policy: BatchPolicy,
    cfg: HttpConfig,
) -> HttpServer {
    let engine = ServeEngine::compile(model, qm, &[3, 16, 16]).unwrap();
    HttpServer::bind(Batcher::new(engine, policy), "127.0.0.1:0", cfg).unwrap()
}

/// Value of an exact metric line ("name v" or "name{labels} v").
fn metric(text: &str, series: &str) -> f64 {
    text.lines()
        .find(|l| {
            l.starts_with(series) && l.as_bytes().get(series.len()) == Some(&b' ')
        })
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("series '{series}' not found in:\n{text}"))
}

fn le_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[test]
fn metrics_match_ground_truth_request_count() {
    let (model, qm, oracle, images) = fixture(1001);
    let server = bind_server(
        &model,
        &qm,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        HttpConfig::default(),
    );
    let mut cli = HttpClient::connect(server.local_addr()).unwrap();
    let k = 12usize;
    for i in 0..k {
        let idx = i % images.len();
        let (code, body) = cli
            .request("POST", "/v1/infer", &[], &infer_body(&images[idx]))
            .unwrap();
        assert_eq!(code, 200, "request {i}");
        // exact bytes: response rows must match the oracle engine bit for bit
        assert_eq!(le_f32(&body), oracle[idx], "row {i} differs from oracle");
    }
    let (code, body) = cli.request("GET", "/metrics", &[], &[]).unwrap();
    assert_eq!(code, 200);
    let text = String::from_utf8(body).unwrap();
    // ground truth: exactly k requests were admitted, answered, and
    // batched — the integer-sum histogram makes the last check exact
    assert_eq!(metric(&text, "pallas_infer_requests_total"), k as f64);
    assert_eq!(metric(&text, "pallas_infer_responses_total"), k as f64);
    assert_eq!(metric(&text, "pallas_batch_fill_sum"), k as f64);
    assert_eq!(metric(&text, "pallas_infer_rejected_total{reason=\"queue_full\"}"), 0.0);
    assert_eq!(metric(&text, "pallas_inflight_requests"), 0.0);
    assert!(metric(&text, "pallas_http_responses_total{code=\"200\"}") >= k as f64);
    assert!(metric(&text, "pallas_service_time_seconds_count") == k as f64);
    assert!(metric(&text, "pallas_plan_weight_bytes") > 0.0);
    server.shutdown();
}

#[test]
fn healthz_reports_plan_and_drain_state() {
    let (model, qm, _, _) = fixture(1002);
    let server = bind_server(
        &model,
        &qm,
        BatchPolicy { shards: 2, ..Default::default() },
        HttpConfig::default(),
    );
    let mut cli = HttpClient::connect(server.local_addr()).unwrap();
    let (code, body) = cli.request("GET", "/healthz", &[], &[]).unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(j.get("status").and_then(|s| s.as_str()), Some("ok"));
    assert_eq!(j.get("shards").and_then(|s| s.as_f64()), Some(2.0));
    let id = j.get("plan_id").and_then(|s| s.as_str()).expect("plan_id present");
    assert_eq!(id.len(), 16, "plan id is 16 hex chars, got '{id}'");
    server.shutdown();
}

#[test]
fn saturating_load_fires_429_with_bounded_p99() {
    let (model, qm, oracle, images) = fixture(1003);
    // tiny budget + a long batching window: while a batch is collecting,
    // in-flight depth stays at the cap, so concurrent submitters must
    // see 429 deterministically
    let server = bind_server(
        &model,
        &qm,
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(30),
            shards: 1,
            depth_budget: 4,
            ..Default::default()
        },
        HttpConfig::default(),
    );
    let addr = server.local_addr();
    let oks = AtomicUsize::new(0);
    let rejected = AtomicUsize::new(0);
    let retry_after_seen = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..8usize {
            let (oks, rejected, retry_after_seen) = (&oks, &rejected, &retry_after_seen);
            let (images, oracle) = (&images, &oracle);
            s.spawn(move || {
                let mut cli = HttpClient::connect(addr).unwrap();
                for i in 0..10usize {
                    let idx = (c * 10 + i) % images.len();
                    let (code, head, body) = cli
                        .request_full("POST", "/v1/infer", &[], &infer_body(&images[idx]))
                        .unwrap();
                    match code {
                        200 => {
                            assert_eq!(le_f32(&body), oracle[idx], "accepted row must be exact");
                            oks.fetch_add(1, Ordering::Relaxed);
                        }
                        429 => {
                            if head.header("retry-after").is_some() {
                                retry_after_seen.fetch_add(1, Ordering::Relaxed);
                            }
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        other => panic!("unexpected status {other}"),
                    }
                }
            });
        }
    });
    let (oks, rejected) = (oks.load(Ordering::Relaxed), rejected.load(Ordering::Relaxed));
    assert!(rejected > 0, "8 clients against budget 4 must see 429s");
    assert_eq!(oks + rejected, 80);
    assert_eq!(
        retry_after_seen.load(Ordering::Relaxed),
        rejected,
        "every 429 carries Retry-After"
    );
    // metrics agree with the client-side ground truth exactly
    let mut cli = HttpClient::connect(addr).unwrap();
    let (_, body) = cli.request("GET", "/metrics", &[], &[]).unwrap();
    let text = String::from_utf8(body).unwrap();
    assert_eq!(metric(&text, "pallas_infer_requests_total"), oks as f64);
    assert_eq!(metric(&text, "pallas_infer_responses_total"), oks as f64);
    assert_eq!(
        metric(&text, "pallas_infer_rejected_total{reason=\"queue_full\"}"),
        rejected as f64
    );
    assert_eq!(metric(&text, "pallas_admission_budget"), 4.0);
    // accepted requests stay bounded: within the histogram's finite range
    // (5s), not pushed into the overflow bucket by the rejected flood
    let p99 = metric(&text, "pallas_service_time_seconds_p99");
    assert!(p99.is_finite() && p99 <= 5.0, "accepted p99 {p99} out of range");
    server.shutdown();
}

#[test]
fn graceful_drain_loses_no_inflight_response() {
    let (model, qm, oracle, images) = fixture(1004);
    let server = bind_server(
        &model,
        &qm,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            shards: 2,
            depth_budget: 128,
            ..Default::default()
        },
        HttpConfig::default(),
    );
    let addr = server.local_addr();
    let metrics = std::sync::Arc::clone(server.metrics());
    let got_200 = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..4usize {
            let got_200 = &got_200;
            let (images, oracle) = (&images, &oracle);
            s.spawn(move || {
                let Ok(mut cli) = HttpClient::connect(addr) else { return };
                for i in 0..200usize {
                    let idx = (c + i * 4) % images.len();
                    match cli.request("POST", "/v1/infer", &[], &infer_body(&images[idx])) {
                        Ok((200, body)) => {
                            // an accepted request must get the full,
                            // correct response even mid-drain
                            assert_eq!(le_f32(&body), oracle[idx], "drained row must be exact");
                            got_200.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok((503, _)) => break, // draining: refused cleanly
                        Ok((other, _)) => panic!("unexpected status {other}"),
                        Err(_) => break, // connection closed by the drain
                    }
                }
            });
        }
        // let the clients get going, then drain under load
        std::thread::sleep(Duration::from_millis(30));
        server.shutdown();
    });
    let client_200s = got_200.load(Ordering::Relaxed) as u64;
    assert!(client_200s > 0, "some requests must complete before the drain");
    // zero loss, both ways: the batcher answered everything it admitted,
    // and every one of those answers reached a client as a 200
    assert_eq!(metrics.submitted.get(), metrics.responses.get());
    assert_eq!(metrics.responses.get(), client_200s);
    assert_eq!(metrics.inflight(), 0);
    assert!(metrics.draining());
}

#[test]
fn unknown_routes_and_bad_bodies() {
    let (model, qm, _, images) = fixture(1005);
    let server = bind_server(&model, &qm, BatchPolicy::default(), HttpConfig::default());
    let mut cli = HttpClient::connect(server.local_addr()).unwrap();
    let (code, _) = cli.request("GET", "/nope", &[], &[]).unwrap();
    assert_eq!(code, 404);
    let (code, head, _) = cli.request_full("DELETE", "/metrics", &[], &[]).unwrap();
    assert_eq!(code, 405);
    assert_eq!(head.header("allow"), Some("GET"));
    let (code, head, _) = cli.request_full("GET", "/v1/infer", &[], &[]).unwrap();
    assert_eq!(code, 405);
    assert_eq!(head.header("allow"), Some("POST"));
    // wrong byte count -> 400 at the HTTP layer (shape guard)
    let (code, _) = cli.request("POST", "/v1/infer", &[], &[0u8; 12]).unwrap();
    assert_eq!(code, 400);
    // JSON body with the wrong value count -> 400 too
    let (code, _) = cli
        .request(
            "POST",
            "/v1/infer",
            &[("Content-Type", "application/json")],
            b"[1, 2, 3]",
        )
        .unwrap();
    assert_eq!(code, 400);
    let (code, _) = cli.request("GET", "/", &[], &[]).unwrap();
    assert_eq!(code, 200);
    // the happy path still works on the same keep-alive connection
    let (code, _) = cli
        .request("POST", "/v1/infer", &[], &infer_body(&images[0]))
        .unwrap();
    assert_eq!(code, 200);
    server.shutdown();
}

/// Two models behind one server: `/v1/models/<id>/infer` routes by id,
/// `/v1/infer` aliases the default (first-registered) model, unknown ids
/// are 404, and both `/healthz` and `/metrics` expose per-model state.
#[test]
fn multi_model_routing_and_observability() {
    let (model_a, qm_a, oracle_a, images) = fixture(2001);
    let (model_b, qm_b, oracle_b, _) = fixture(2002);
    assert_ne!(oracle_a, oracle_b, "the two models must be distinguishable");
    let policy =
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() };
    let registry = ModelRegistry::builder()
        .register("alpha", ServeEngine::compile(&model_a, &qm_a, &[3, 16, 16]).unwrap(), policy)
        .unwrap()
        .register("beta", ServeEngine::compile(&model_b, &qm_b, &[3, 16, 16]).unwrap(), policy)
        .unwrap()
        .build()
        .unwrap();
    let server = HttpServer::bind_registry(registry, "127.0.0.1:0", HttpConfig::default()).unwrap();
    let mut cli = HttpClient::connect(server.local_addr()).unwrap();

    // routing: each id answers with its own model's exact rows
    let body0 = infer_body(&images[0]);
    let (code, body) = cli.request("POST", "/v1/models/alpha/infer", &[], &body0).unwrap();
    assert_eq!(code, 200);
    assert_eq!(le_f32(&body), oracle_a[0]);
    let (code, body) = cli.request("POST", "/v1/models/beta/infer", &[], &body0).unwrap();
    assert_eq!(code, 200);
    assert_eq!(le_f32(&body), oracle_b[0]);
    // the unprefixed route is the default (first-registered) model
    let (code, body) = cli.request("POST", "/v1/infer", &[], &body0).unwrap();
    assert_eq!(code, 200);
    assert_eq!(le_f32(&body), oracle_a[0]);
    let (code, _) = cli.request("POST", "/v1/models/nope/infer", &[], &body0).unwrap();
    assert_eq!(code, 404);
    let (code, head, _) = cli.request_full("GET", "/v1/models/alpha/infer", &[], &[]).unwrap();
    assert_eq!(code, 405);
    assert_eq!(head.header("allow"), Some("POST"));

    // listing
    let (code, body) = cli.request("GET", "/v1/models", &[], &[]).unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(j.get("default").and_then(|s| s.as_str()), Some("alpha"));
    let ids: Vec<String> = j
        .get("models")
        .and_then(|m| m.as_arr())
        .expect("models should be an array")
        .iter()
        .filter_map(|x| x.as_str().map(String::from))
        .collect();
    assert_eq!(ids, vec!["alpha".to_string(), "beta".to_string()]);

    // healthz: per-model block with generation 1 each
    let (_, body) = cli.request("GET", "/healthz", &[], &[]).unwrap();
    let j = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(j.get("default_model").and_then(|s| s.as_str()), Some("alpha"));
    let models = j.get("models").expect("healthz models object");
    for id in ["alpha", "beta"] {
        let m = models.get(id).unwrap_or_else(|| panic!("healthz missing model '{id}'"));
        assert_eq!(m.get("generation").and_then(|g| g.as_f64()), Some(1.0), "model {id}");
        assert_eq!(m.get("reloadable").and_then(|b| b.as_bool()), Some(false), "model {id}");
    }

    // metrics: the classic unlabeled block counts the DEFAULT model only
    // (2 requests: one /v1/models/alpha/infer + one /v1/infer), while the
    // labeled per-model series cover both
    let (_, body) = cli.request("GET", "/metrics", &[], &[]).unwrap();
    let text = String::from_utf8(body).unwrap();
    assert_eq!(metric(&text, "pallas_infer_requests_total"), 2.0);
    assert_eq!(metric(&text, "pallas_model_requests_total{model=\"alpha\"}"), 2.0);
    assert_eq!(metric(&text, "pallas_model_requests_total{model=\"beta\"}"), 1.0);
    assert_eq!(metric(&text, "pallas_model_generation{model=\"alpha\"}"), 1.0);
    assert_eq!(metric(&text, "pallas_model_generation{model=\"beta\"}"), 1.0);
    server.shutdown();
}

/// Hot-swap observed through the HTTP layer: a `.qtz`-backed model is
/// reloaded while the server runs; `/metrics` and `/healthz` report the
/// new generation and inference flips to the new weights — with zero
/// non-200 responses along the way (the CI smoke step's in-process twin).
#[test]
fn hot_swap_visible_through_http_with_no_errors() {
    let (model, qm_a, oracle_a, images) = fixture(2003);
    let (model_b, qm_b, _, _) = fixture(2004);
    // qm_b over model's arch: the second observable generation
    let mut oracle_engine = ServeEngine::compile(&model, &qm_b, &[3, 16, 16]).unwrap();
    let oracle_b: Vec<Vec<f32>> = images
        .iter()
        .map(|img| {
            let mut shape = vec![1usize];
            shape.extend_from_slice(&img.shape);
            oracle_engine.forward(&Tensor::from_vec(&shape, img.data.clone())).data
        })
        .collect();
    drop(model_b);
    assert_ne!(oracle_a, oracle_b);

    let path = std::env::temp_dir().join("http_hot_swap.qtz");
    save_quantized(&path, &qm_a).unwrap();
    let registry = ModelRegistry::builder()
        .register_qtz(
            "live",
            model.clone(),
            &path,
            &[3, 16, 16],
            BatchPolicy { max_wait: Duration::from_millis(1), shards: 2, ..Default::default() },
        )
        .unwrap()
        .build()
        .unwrap();
    let server = HttpServer::bind_registry(registry, "127.0.0.1:0", HttpConfig::default()).unwrap();
    let mut cli = HttpClient::connect(server.local_addr()).unwrap();
    let body0 = infer_body(&images[0]);

    let (code, body) = cli.request("POST", "/v1/models/live/infer", &[], &body0).unwrap();
    assert_eq!((code, le_f32(&body)), (200, oracle_a[0].clone()));

    save_quantized(&path, &qm_b).unwrap();
    assert_eq!(server.registry().expect("running").reload("live").unwrap(), 2);

    // every response during adoption is a 200 matching one generation,
    // and the new one arrives within the idle-recheck window
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let (code, body) = cli.request("POST", "/v1/models/live/infer", &[], &body0).unwrap();
        assert_eq!(code, 200, "no request may fail across a hot-swap");
        let row = le_f32(&body);
        assert!(row == oracle_a[0] || row == oracle_b[0], "torn response");
        if row == oracle_b[0] {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "generation 2 never served");
        std::thread::sleep(Duration::from_millis(10));
    }

    let (_, body) = cli.request("GET", "/metrics", &[], &[]).unwrap();
    let text = String::from_utf8(body).unwrap();
    assert_eq!(metric(&text, "pallas_model_generation{model=\"live\"}"), 2.0);
    assert_eq!(
        metric(&text, "pallas_model_reloads_total{model=\"live\",outcome=\"ok\"}"),
        1.0
    );
    assert!(
        text.contains("generation=\"2\""),
        "pallas_plan_info must carry the live generation label"
    );
    let (_, body) = cli.request("GET", "/healthz", &[], &[]).unwrap();
    let j = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(j.get("generation").and_then(|g| g.as_f64()), Some(2.0));
    server.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn bearer_auth_guards_infer_only() {
    let (model, qm, _, images) = fixture(1006);
    let cfg = HttpConfig { auth_token: Some("sekrit".to_string()), ..Default::default() };
    let server = bind_server(&model, &qm, BatchPolicy::default(), cfg);
    let mut cli = HttpClient::connect(server.local_addr()).unwrap();
    let body = infer_body(&images[0]);
    let (code, _) = cli.request("POST", "/v1/infer", &[], &body).unwrap();
    assert_eq!(code, 401, "no token");
    let (code, _) = cli
        .request("POST", "/v1/infer", &[("Authorization", "Bearer wrong")], &body)
        .unwrap();
    assert_eq!(code, 401, "wrong token");
    let (code, _) = cli
        .request("POST", "/v1/infer", &[("Authorization", "Bearer sekrit")], &body)
        .unwrap();
    assert_eq!(code, 200, "correct token");
    // probes and scrapers stay open
    let (code, _) = cli.request("GET", "/healthz", &[], &[]).unwrap();
    assert_eq!(code, 200);
    let (code, _) = cli.request("GET", "/metrics", &[], &[]).unwrap();
    assert_eq!(code, 200);
    server.shutdown();
}
