//! Perf invariants of the parallel compute core: the native optimizer's
//! inner loop must not touch the heap once its workspace exists.
//!
//! A counting global allocator wraps `System`; the loop runs with the
//! thread count forced to 1 (worker spawns legitimately allocate stacks —
//! the zero-allocation contract is about tensor traffic, and the serial
//! path exercises exactly the same buffers the parallel path reuses).
//!
//! This file deliberately holds a single #[test]: sibling tests in the
//! same binary would run concurrently and pollute the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use adaround::adaround::{Adam, LayerProblem, StepWorkspace};
use adaround::adaround::{gather_cols_into, AdaRoundConfig};
use adaround::quant::QuantGrid;
use adaround::tensor::{matmul, Tensor};
use adaround::util::parallel::with_threads;
use adaround::util::Rng;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn native_step_inner_loop_is_allocation_free() {
    let (rows, cols, batch, ncols) = (16usize, 64usize, 48usize, 256usize);
    let mut rng = Rng::new(1);
    let w = Tensor::from_vec(
        &[rows, cols],
        (0..rows * cols).map(|_| rng.normal_f32(0.0, 0.3)).collect(),
    );
    let grid = QuantGrid::per_tensor(0.05, 4);
    let bias: Vec<f32> = (0..rows).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let prob = LayerProblem::new(w, &grid, 0, bias, true);
    let x = Tensor::from_vec(
        &[cols, ncols],
        (0..cols * ncols).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
    );
    let t = matmul(&prob.w, &x);
    let cfg = AdaRoundConfig::default();

    with_threads(1, || {
        let mut v = prob.init_v();
        let mut adam = Adam::new(v.numel());
        let mut ws = StepWorkspace::new(rows, cols, batch);
        let mut xb = Tensor::zeros(&[cols, batch]);
        let mut tb = Tensor::zeros(&[rows, batch]);
        let mut pool: Vec<usize> = Vec::with_capacity(ncols);
        let mut srng = Rng::new(7);

        let iteration = |it: usize, ws: &mut StepWorkspace, v: &mut Tensor,
                             adam: &mut Adam, srng: &mut Rng,
                             xb: &mut Tensor, tb: &mut Tensor, pool: &mut Vec<usize>| {
            let (beta, reg_on) = cfg.beta.at(it, 400);
            let lam = if reg_on { cfg.lambda } else { 0.0 };
            let k = srng.sample_indices_into(ncols, batch, pool);
            gather_cols_into(&x, &pool[..k], xb);
            gather_cols_into(&t, &pool[..k], tb);
            prob.loss_grad_into(v, xb, tb, beta, lam, ws);
            adam.step(&mut v.data, &ws.grad, cfg.lr);
        };

        // warm up: first iterations may grow the index pool to capacity
        for it in 0..3 {
            iteration(it, &mut ws, &mut v, &mut adam, &mut srng, &mut xb, &mut tb, &mut pool);
        }
        let before = ALLOCS.load(Ordering::SeqCst);
        for it in 3..103 {
            iteration(it, &mut ws, &mut v, &mut adam, &mut srng, &mut xb, &mut tb, &mut pool);
        }
        let after = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "native optimizer inner loop allocated {} time(s) over 100 iterations",
            after - before
        );
    });
}
