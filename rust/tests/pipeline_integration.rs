//! End-to-end pipeline integration over the real artifacts: the paper's
//! headline claims as executable assertions.
//!
//! Requires `make artifacts` (skipped gracefully if absent).

use adaround::coordinator::{Method, Pipeline, PipelineConfig};
use adaround::eval::top1;
use adaround::nn::ForwardOptions;
use adaround::runtime::Runtime;
use adaround::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = adaround::artifacts_dir();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn fast_cfg(method: Method) -> PipelineConfig {
    PipelineConfig {
        method,
        bits: 2,
        calib_n: 96,
        col_budget: 768,
        adaround: adaround::adaround::AdaRoundConfig {
            iters: 250,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn adaround_recovers_nearest_collapse() {
    // THE paper claim: at a bit-width where nearest rounding destroys the
    // network, AdaRound recovers most of the FP32 accuracy.
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.load_model("micro18").unwrap();
    let (calib, _) = rt.manifest.load_dataset("calib_gabor").unwrap();
    let (vx, vy) = rt.manifest.load_dataset("val_gabor").unwrap();
    let vx = adaround::tensor::Tensor::from_vec(
        &[256, 3, 32, 32],
        vx.data[..256 * 3 * 1024].to_vec(),
    );
    let vy = adaround::tensor::IntTensor::from_vec(&[256], vy.data[..256].to_vec());

    let fp = top1(&model, &vx, &vy, &ForwardOptions::default(), 64);

    let near = Pipeline::new(&model, fast_cfg(Method::Nearest), Some(&rt))
        .quantize(&calib, &mut Rng::new(1))
        .unwrap();
    let acc_near = top1(&model, &vx, &vy, &near.opts(), 64);

    let ada = Pipeline::new(&model, fast_cfg(Method::AdaRound), Some(&rt))
        .quantize(&calib, &mut Rng::new(1))
        .unwrap();
    let acc_ada = top1(&model, &vx, &vy, &ada.opts(), 64);

    assert!(fp > 85.0, "fp32 sanity: {fp}");
    assert!(acc_near < fp - 30.0, "nearest should collapse at 2-bit: {acc_near} vs {fp}");
    assert!(
        acc_ada > acc_near + 30.0,
        "AdaRound should recover: nearest {acc_near} adaround {acc_ada}"
    );
    assert!(acc_ada > fp - 12.0, "AdaRound close to fp32: {acc_ada} vs {fp}");
}

#[test]
fn layer_stats_report_mse_improvement() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.load_model("micro18").unwrap();
    let (calib, _) = rt.manifest.load_dataset("calib_gabor").unwrap();
    let qm = Pipeline::new(&model, fast_cfg(Method::AdaRound), Some(&rt))
        .quantize(&calib, &mut Rng::new(2))
        .unwrap();
    assert_eq!(qm.stats.len(), model.quant_layers().len());
    // reconstruction must improve (or tie) on the large majority of layers
    let improved = qm
        .stats
        .iter()
        .filter(|s| s.mse_after <= s.mse_before * 1.001)
        .count();
    assert!(
        improved * 10 >= qm.stats.len() * 8,
        "only {improved}/{} layers improved",
        qm.stats.len()
    );
    // AdaRound must actually flip some roundings (Fig. 3)
    let any_flip = qm.stats.iter().any(|s| s.flipped_frac > 0.01);
    assert!(any_flip);
}

#[test]
fn activation_quantization_applies() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.load_model("micro18").unwrap();
    let (calib, _) = rt.manifest.load_dataset("calib_gabor").unwrap();
    let mut cfg = fast_cfg(Method::Nearest);
    cfg.bits = 8;
    cfg.act_bits = Some(8);
    let qm = Pipeline::new(&model, cfg, Some(&rt))
        .quantize(&calib, &mut Rng::new(3))
        .unwrap();
    let aq = qm.act_quant.as_ref().expect("act quant calibrated");
    assert!(!aq.is_empty());
    // 8/8 should be nearly lossless on the calibration data
    let (vx, vy) = rt.manifest.load_dataset("val_gabor").unwrap();
    let fp = top1(&model, &vx, &vy, &ForwardOptions::default(), 128);
    let q = top1(&model, &vx, &vy, &qm.opts(), 128);
    assert!(q > fp - 3.0, "8/8 should be ~lossless: {q} vs {fp}");
}

#[test]
fn first_layer_only_restricts_overrides() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.load_model("micro18").unwrap();
    let (calib, _) = rt.manifest.load_dataset("calib_gabor").unwrap();
    let mut cfg = fast_cfg(Method::Nearest);
    cfg.only_layers = Some(vec![model.quant_layers()[0].id.clone()]);
    let qm = Pipeline::new(&model, cfg, Some(&rt))
        .quantize(&calib, &mut Rng::new(4))
        .unwrap();
    assert_eq!(qm.weight_overrides.len(), 1);
    assert_eq!(qm.stats.len(), 1);
}

#[test]
fn grouped_conv_pipeline_works() {
    // micromobile has depthwise convs: per-group problems must compose
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.load_model("micromobile").unwrap();
    let (calib, _) = rt.manifest.load_dataset("calib_gabor").unwrap();
    let qm = Pipeline::new(&model, fast_cfg(Method::AdaRound), Some(&rt))
        .quantize(&calib, &mut Rng::new(5))
        .unwrap();
    // every quantizable node got an override of the right shape
    for node in model.quant_layers() {
        let ov = &qm.weight_overrides[&node.id];
        assert_eq!(ov.shape, model.weight(&node.id).shape);
    }
    let dw = qm.stats.iter().find(|s| s.groups > 1).expect("depthwise stat");
    assert!(dw.rows == 1, "depthwise rows-per-group must be 1");
}

#[test]
fn dfq_equalization_preserves_fp32_function() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.load_model("micromobile").unwrap();
    let (eq, n) = adaround::baselines::equalize_model(&model);
    assert!(n > 0, "no pairs equalized on micromobile");
    let eq_model = adaround::nn::Model { weights: eq, ..model.clone() };
    let (vx, vy) = rt.manifest.load_dataset("val_gabor").unwrap();
    let vx = adaround::tensor::Tensor::from_vec(
        &[128, 3, 32, 32],
        vx.data[..128 * 3 * 1024].to_vec(),
    );
    let vy = adaround::tensor::IntTensor::from_vec(&[128], vy.data[..128].to_vec());
    let a = top1(&model, &vx, &vy, &ForwardOptions::default(), 64);
    let b = top1(&eq_model, &vx, &vy, &ForwardOptions::default(), 64);
    assert!((a - b).abs() < 1.0, "CLE changed FP32 accuracy: {a} vs {b}");
}
