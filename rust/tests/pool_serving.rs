//! The pool + sharding determinism contract, end to end: serving results
//! and rounding decisions must be bit-identical for every
//! (`PALLAS_THREADS`, shard-count) combination, and the sharded batcher
//! must survive concurrent submitters and drain cleanly on shutdown.
//! Self-contained (synthetic model + data; no `make artifacts`).

use std::collections::BTreeMap;
use std::time::Duration;

use adaround::coordinator::{Method, Pipeline, PipelineConfig, QuantizedModel};
use adaround::data::synthetic_stripes;
use adaround::nn::Model;
use adaround::serve::{BatchPolicy, Batcher, ServeEngine};
use adaround::tensor::Tensor;
use adaround::util::parallel::with_threads;
use adaround::util::{Json, Rng};

/// Tiny conv classifier: conv(+relu), residual add, avgpool, gpool,
/// dense — every op class the engine lowers for classifiers.
fn tiny_model(rng: &mut Rng) -> Model {
    let ir = r#"{"task":"cls","ir":[
      {"id":"in","op":"input","inputs":[]},
      {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":8,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
      {"id":"c2","op":"conv","inputs":["c1"],"cin":8,"cout":8,
       "k":3,"stride":1,"pad":1,"groups":2,"relu":false},
      {"id":"a1","op":"add","inputs":["c2","c1"],"relu":true},
      {"id":"p1","op":"avgpool","inputs":["a1"],"k":2,"stride":2},
      {"id":"g1","op":"gpool","inputs":["p1"]},
      {"id":"d1","op":"dense","inputs":["g1"],"cin":8,"cout":3,"relu":false}
    ]}"#;
    let entry = Json::parse(ir).unwrap();
    let mut w = BTreeMap::new();
    let mut tensor = |shape: &[usize], std: f32, rng: &mut Rng| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, std)).collect())
    };
    w.insert("c1.w".into(), tensor(&[8, 3, 3, 3], 0.25, rng));
    w.insert("c1.b".into(), tensor(&[8], 0.05, rng));
    // groups=2: cin/g = 4 — exercises the flat two-level conv fan-out
    w.insert("c2.w".into(), tensor(&[8, 4, 3, 3], 0.12, rng));
    w.insert("c2.b".into(), tensor(&[8], 0.05, rng));
    w.insert("d1.w".into(), tensor(&[3, 8], 0.4, rng));
    w.insert("d1.b".into(), tensor(&[3], 0.05, rng));
    Model::from_manifest("poolserve", &entry, w).unwrap()
}

fn quantize_8_8(model: &Model, calib: &Tensor, method: Method) -> QuantizedModel {
    let cfg = PipelineConfig {
        method,
        bits: 8,
        per_channel: true,
        act_bits: Some(8),
        calib_n: calib.shape[0],
        ..Default::default()
    };
    Pipeline::new(model, cfg, None).quantize(calib, &mut Rng::new(7)).unwrap()
}

/// Split a [N,C,H,W] batch into per-image tensors.
fn images_of(x: &Tensor) -> Vec<Tensor> {
    let per: usize = x.shape[1..].iter().product();
    (0..x.shape[0])
        .map(|i| Tensor::from_vec(&x.shape[1..], x.data[i * per..(i + 1) * per].to_vec()))
        .collect()
}

#[test]
fn serving_bit_identical_across_threads_and_shards() {
    let mut rng = Rng::new(101);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(48, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(24, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib, Method::Nearest);
    let images = images_of(&val);

    let serve_all = |threads: usize, shards: usize| -> Vec<Vec<f32>> {
        with_threads(threads, || {
            let engine = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
            let batcher = Batcher::new(
                engine,
                BatchPolicy {
                    max_batch: 5, // forces several partial batches per run
                    max_wait: Duration::from_millis(2),
                    shards,
                    ..Default::default()
                },
            );
            let rxs: Vec<_> = images
                .iter()
                .map(|img| batcher.submit(img.clone()).expect("batcher alive"))
                .collect();
            let rows: Vec<Vec<f32>> = rxs
                .into_iter()
                .map(|rx| rx.recv().expect("response"))
                .collect();
            batcher.shutdown();
            rows
        })
    };

    let reference = serve_all(1, 1);
    assert_eq!(reference.len(), images.len());
    for threads in [1usize, 2, 8] {
        for shards in [1usize, 4] {
            let got = serve_all(threads, shards);
            assert_eq!(
                got, reference,
                "serving differs at threads={threads} shards={shards}"
            );
        }
    }
}

#[test]
fn rounding_masks_bit_identical_across_threads() {
    // stochastic rounding goes through the per-row rng forks of
    // util::parallel::par_map_rng — the rounding-side half of the pool
    // determinism contract
    let mut rng = Rng::new(202);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let run = |threads: usize| {
        with_threads(threads, || quantize_8_8(&model, &calib, Method::Stochastic))
    };
    let reference = run(1);
    for threads in [2usize, 8] {
        let got = run(threads);
        for (id, w) in &reference.weight_overrides {
            let other = got.weight_overrides.get(id).expect("same layer set");
            assert_eq!(
                w.data, other.data,
                "rounded weights for {id} differ at threads={threads}"
            );
        }
    }
}

#[test]
fn batcher_stress_concurrent_submitters_no_loss() {
    let mut rng = Rng::new(303);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(8, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib, Method::Nearest);
    let images = images_of(&val);

    // oracle rows per pool image (per-image outputs are batch-invariant)
    let mut oracle_engine = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let oracle: Vec<Vec<f32>> = images
        .iter()
        .map(|img| {
            let mut shape = vec![1usize];
            shape.extend_from_slice(&img.shape);
            let out = oracle_engine.forward(&Tensor::from_vec(&shape, img.data.clone()));
            out.data
        })
        .collect();

    let engine = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let batcher = Batcher::new(
        engine,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            shards: 4,
            ..Default::default()
        },
    );
    let n_clients = 6usize;
    let per_client = 40usize;
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let h = batcher.handle();
            let images = &images;
            let oracle = &oracle;
            s.spawn(move || {
                let mut pending = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let idx = (c * per_client + i) % images.len();
                    let rx = h.submit(images[idx].clone()).expect("batcher alive");
                    pending.push((idx, rx));
                }
                for (idx, rx) in pending {
                    let row = rx.recv().expect("no request may be lost");
                    assert_eq!(row, oracle[idx], "wrong answer for pool image {idx}");
                }
            });
        }
    });
    batcher.shutdown();
}

#[test]
fn shutdown_drains_in_flight_requests_without_loss() {
    let mut rng = Rng::new(404);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(4, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib, Method::Nearest);
    let images = images_of(&val);

    let engine = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let batcher = Batcher::new(
        engine,
        // long max_wait: shutdown must not wait out the batching window
        // per batch, it must just drain
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            shards: 2,
            ..Default::default()
        },
    );
    // flood the queue, then shut down immediately with most requests
    // still in flight
    let rxs: Vec<_> = (0..64)
        .map(|i| batcher.submit(images[i % images.len()].clone()).expect("batcher alive"))
        .collect();
    batcher.shutdown(); // blocks until the queue is drained
    let classes = 3usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let row = rx.recv().unwrap_or_else(|_| panic!("request {i} lost in shutdown"));
        assert_eq!(row.len(), classes);
    }
}
