//! The hot-swap contract, end to end: concurrent submitters racing
//! forced plan swaps must never see a torn batch or lose a request; old
//! generations must actually be freed once every shard adopts; a failed
//! reload must leave the old generation serving; the mtime watcher must
//! pick up a rewritten bundle. Self-contained (synthetic model + data;
//! no `make artifacts`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use std::collections::BTreeMap;

use adaround::coordinator::{save_quantized, Method, Pipeline, PipelineConfig, QuantizedModel};
use adaround::data::synthetic_stripes;
use adaround::nn::Model;
use adaround::serve::{
    compile_plan, BatchPolicy, Batcher, ModelRegistry, ServeEngine, SubmitError, SwapError,
};
use adaround::tensor::Tensor;
use adaround::util::parallel::with_threads;
use adaround::util::{Json, Rng};

/// Tiny conv classifier (conv+relu, residual add, avgpool, gpool,
/// dense); `seed` picks the weights, so two seeds give two models with
/// identical geometry and different outputs — the two distinguishable
/// generations every test here swaps between.
fn tiny_model(seed: u64) -> Model {
    let ir = r#"{"task":"cls","ir":[
      {"id":"in","op":"input","inputs":[]},
      {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":8,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
      {"id":"c2","op":"conv","inputs":["c1"],"cin":8,"cout":8,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":false},
      {"id":"a1","op":"add","inputs":["c2","c1"],"relu":true},
      {"id":"p1","op":"avgpool","inputs":["a1"],"k":2,"stride":2},
      {"id":"g1","op":"gpool","inputs":["p1"]},
      {"id":"d1","op":"dense","inputs":["g1"],"cin":8,"cout":3,"relu":false}
    ]}"#;
    let entry = Json::parse(ir).unwrap();
    let mut rng = Rng::new(seed);
    let mut w = BTreeMap::new();
    let mut tensor = |shape: &[usize], std: f32, rng: &mut Rng| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, std)).collect())
    };
    w.insert("c1.w".into(), tensor(&[8, 3, 3, 3], 0.25, &mut rng));
    w.insert("c1.b".into(), tensor(&[8], 0.05, &mut rng));
    w.insert("c2.w".into(), tensor(&[8, 8, 3, 3], 0.12, &mut rng));
    w.insert("c2.b".into(), tensor(&[8], 0.05, &mut rng));
    w.insert("d1.w".into(), tensor(&[3, 8], 0.4, &mut rng));
    w.insert("d1.b".into(), tensor(&[3], 0.05, &mut rng));
    Model::from_manifest("hotswap", &entry, w).unwrap()
}

fn quantize_8_8(model: &Model, calib: &Tensor) -> QuantizedModel {
    let cfg = PipelineConfig {
        method: Method::Nearest,
        bits: 8,
        per_channel: true,
        act_bits: Some(8),
        calib_n: calib.shape[0],
        ..Default::default()
    };
    Pipeline::new(model, cfg, None).quantize(calib, &mut Rng::new(7)).unwrap()
}

/// Split a [N,C,H,W] batch into per-image tensors.
fn images_of(x: &Tensor) -> Vec<Tensor> {
    let per: usize = x.shape[1..].iter().product();
    (0..x.shape[0])
        .map(|i| Tensor::from_vec(&x.shape[1..], x.data[i * per..(i + 1) * per].to_vec()))
        .collect()
}

/// Per-image oracle rows for one (arch, quantized-weights) pair: what a
/// single-engine forward answers for each pool image, batch-invariantly.
fn oracle_rows(model: &Model, qm: &QuantizedModel, images: &[Tensor]) -> Vec<Vec<f32>> {
    let mut engine = ServeEngine::compile(model, qm, &[3, 16, 16]).unwrap();
    images
        .iter()
        .map(|img| {
            let mut shape = vec![1usize];
            shape.extend_from_slice(&img.shape);
            engine.forward(&Tensor::from_vec(&shape, img.data.clone())).data
        })
        .collect()
}

/// Everything the swap tests share: one float arch, two quantized weight
/// sets over it (generation A and B), the image pool and both oracles.
struct SwapFixture {
    model: Model,
    qm_a: QuantizedModel,
    qm_b: QuantizedModel,
    images: Vec<Tensor>,
    oracle_a: Vec<Vec<f32>>,
    oracle_b: Vec<Vec<f32>>,
}

fn swap_fixture() -> SwapFixture {
    let mut rng = Rng::new(11);
    let model = tiny_model(1);
    let model_b = tiny_model(2);
    let (calib, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(8, 3, 16, &mut rng);
    let qm_a = quantize_8_8(&model, &calib);
    // qm_b carries model_b's rounded weights; compiled over `model`'s
    // arch they form the second, observably-different generation
    let qm_b = quantize_8_8(&model_b, &calib);
    let images = images_of(&val);
    let oracle_a = oracle_rows(&model, &qm_a, &images);
    let oracle_b = oracle_rows(&model, &qm_b, &images);
    assert_ne!(oracle_a, oracle_b, "the two generations must be distinguishable");
    SwapFixture { model, qm_a, qm_b, images, oracle_a, oracle_b }
}

/// Satellite 1, the core race: concurrent submitters vs repeated forced
/// hot-swaps between two plans with distinct oracle outputs. Every
/// response must bit-match exactly one generation's oracle (a batch is
/// never computed by a torn mix of weights) and no request may be lost —
/// across every (PALLAS_THREADS, shards) combination the acceptance
/// criteria name.
#[test]
fn swap_race_every_response_matches_exactly_one_generation() {
    let fx = swap_fixture();
    const SWAPS: usize = 6;
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 40;
    for threads in [1usize, 4] {
        for shards in [1usize, 4] {
            with_threads(threads, || {
                let engine = ServeEngine::compile(&fx.model, &fx.qm_a, &[3, 16, 16]).unwrap();
                let batcher = Batcher::new(
                    engine,
                    BatchPolicy {
                        max_batch: 4,
                        max_wait: Duration::from_millis(1),
                        shards,
                        depth_budget: 512, // no QueueFull noise in this test
                        ..Default::default()
                    },
                );
                let answered = AtomicUsize::new(0);
                std::thread::scope(|s| {
                    for c in 0..CLIENTS {
                        let h = batcher.handle();
                        let (fx, answered) = (&fx, &answered);
                        s.spawn(move || {
                            let mut pending = Vec::new();
                            for i in 0..PER_CLIENT {
                                let idx = (c * PER_CLIENT + i) % fx.images.len();
                                let rx = h.submit(fx.images[idx].clone()).expect("admitted");
                                pending.push((idx, rx));
                                // a sliding window keeps swaps landing
                                // while requests are still in flight
                                if pending.len() >= 8 {
                                    let (idx, rx) = pending.remove(0);
                                    let row = rx.recv().expect("request lost");
                                    assert!(
                                        row == fx.oracle_a[idx] || row == fx.oracle_b[idx],
                                        "image {idx}: response matches neither generation"
                                    );
                                    answered.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            for (idx, rx) in pending {
                                let row = rx.recv().expect("request lost");
                                assert!(
                                    row == fx.oracle_a[idx] || row == fx.oracle_b[idx],
                                    "image {idx}: response matches neither generation"
                                );
                                answered.fetch_add(1, Ordering::Relaxed);
                            }
                        });
                    }
                    // the swapper: alternate B, A, B, ... while traffic flows
                    let (batcher, fx) = (&batcher, &fx);
                    s.spawn(move || {
                        for k in 0..SWAPS {
                            std::thread::sleep(Duration::from_millis(3));
                            let qm = if k % 2 == 0 { &fx.qm_b } else { &fx.qm_a };
                            let plan = compile_plan(&fx.model, qm, &[3, 16, 16]).unwrap();
                            batcher.swap_plan(plan).expect("swap accepted");
                        }
                    });
                });
                assert_eq!(
                    answered.load(Ordering::Relaxed),
                    CLIENTS * PER_CLIENT,
                    "zero-loss violated at threads={threads} shards={shards}"
                );
                assert_eq!(batcher.generation(), 1 + SWAPS as u64);
                assert_eq!(batcher.metrics().generation.get(), 1 + SWAPS as i64);
                batcher.shutdown();
            });
        }
    }
}

/// After a swap, idle shards adopt within IDLE_RECHECK and the last
/// adopter drops the final reference: the old generation's weights are
/// actually freed, observed directly via `Arc::strong_count`.
#[test]
fn old_generation_is_freed_after_all_shards_adopt() {
    let fx = swap_fixture();
    let engine = ServeEngine::compile(&fx.model, &fx.qm_a, &[3, 16, 16]).unwrap();
    let batcher = Batcher::new(
        engine,
        BatchPolicy { shards: 2, max_wait: Duration::from_millis(1), ..Default::default() },
    );
    let old = batcher.plan(); // our probe reference to generation 1
    assert!(
        Arc::strong_count(&old) >= 4,
        "cell + 2 shard engines + probe should hold generation 1"
    );
    let plan_b = compile_plan(&fx.model, &fx.qm_b, &[3, 16, 16]).unwrap();
    assert_eq!(batcher.swap_plan(plan_b).unwrap(), 2);
    // no traffic at all: adoption must happen via the idle recheck
    let deadline = Instant::now() + Duration::from_secs(10);
    while Arc::strong_count(&old) > 1 {
        assert!(
            Instant::now() < deadline,
            "old generation still referenced ({} strong) after swap",
            Arc::strong_count(&old)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    // and the swapped-in generation actually answers
    let rx = batcher.submit(fx.images[0].clone()).expect("admitted");
    assert_eq!(rx.recv().expect("response"), fx.oracle_b[0]);
    batcher.shutdown();
}

/// A replacement plan with different input geometry is refused — the
/// invariant every outstanding `BatcherHandle` was validated against.
#[test]
fn swap_rejects_input_shape_mismatch() {
    let fx = swap_fixture();
    let engine = ServeEngine::compile(&fx.model, &fx.qm_a, &[3, 16, 16]).unwrap();
    let batcher = Batcher::new(engine, BatchPolicy::default());
    let small = compile_plan(&fx.model, &fx.qm_b, &[3, 8, 8]).unwrap();
    match batcher.swap_plan(small) {
        Err(SwapError::ShapeMismatch { got, want }) => {
            assert_eq!(got, vec![3, 8, 8]);
            assert_eq!(want, vec![3, 16, 16]);
        }
        other => panic!("expected ShapeMismatch, got {other:?}"),
    }
    assert_eq!(batcher.generation(), 1, "a rejected swap must not bump the generation");
    batcher.shutdown();
}

/// Poll traffic until the served answer for image 0 equals `want`
/// (adoption is asynchronous); every interim answer must still match one
/// of the two known generations.
fn await_served(registry: &ModelRegistry, id: &str, fx: &SwapFixture, want: &[Vec<f32>]) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let rx =
            registry.get(id).unwrap().handle().submit(fx.images[0].clone()).expect("admitted");
        let row = rx.recv().expect("response");
        assert!(
            row == fx.oracle_a[0] || row == fx.oracle_b[0],
            "response matches neither generation"
        );
        if row == want[0] {
            return;
        }
        assert!(Instant::now() < deadline, "new generation never served");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Satellite 2's serving half: a `.qtz`-backed registry hot-reloads a
/// rewritten bundle on demand, and a reload over a corrupted bundle
/// fails cleanly — counted in the metrics — while the previous
/// generation keeps answering.
#[test]
fn reload_swaps_bundle_and_failed_reload_keeps_serving() {
    let fx = swap_fixture();
    let path = std::env::temp_dir().join("registry_reload_test.qtz");
    save_quantized(&path, &fx.qm_a).unwrap();
    let registry = ModelRegistry::builder()
        .register_qtz(
            "m",
            fx.model.clone(),
            &path,
            &[3, 16, 16],
            BatchPolicy { shards: 2, max_wait: Duration::from_millis(1), ..Default::default() },
        )
        .unwrap()
        .build()
        .unwrap();
    assert_eq!(registry.default_id(), "m");
    let entry = registry.get("m").unwrap();
    assert!(entry.reloadable());
    assert_eq!(entry.stamp().generation, 1);
    await_served(&registry, "m", &fx, &fx.oracle_a);

    // rewrite the bundle -> manual reload -> generation 2 serves B
    save_quantized(&path, &fx.qm_b).unwrap();
    assert_eq!(registry.reload("m").unwrap(), 2);
    assert_eq!(entry.stamp().generation, 2);
    assert_eq!(entry.metrics().reloads_ok.get(), 1);
    await_served(&registry, "m", &fx, &fx.oracle_b);

    // corrupt the bundle -> reload fails -> generation 2 keeps serving
    std::fs::write(&path, b"QTZ1 definitely not a bundle").unwrap();
    assert!(registry.reload("m").is_err());
    assert_eq!(entry.metrics().reloads_failed.get(), 1);
    assert_eq!(entry.stamp().generation, 2, "failed reload must not bump the generation");
    let mut prom = String::new();
    entry.metrics().render_model_prometheus("m", &mut prom);
    assert!(prom.contains("pallas_model_reloads_total{model=\"m\",outcome=\"failed\"} 1"));
    await_served(&registry, "m", &fx, &fx.oracle_b);

    registry.shutdown();
    std::fs::remove_file(&path).ok();
}

/// The watcher path: build with `build_watched`, rewrite the bundle on
/// disk, and the mtime debounce reloads it with no explicit call.
#[test]
fn watcher_hot_swaps_a_rewritten_bundle() {
    let fx = swap_fixture();
    let path = std::env::temp_dir().join("registry_watch_test.qtz");
    save_quantized(&path, &fx.qm_a).unwrap();
    let registry = ModelRegistry::builder()
        .register_qtz(
            "w",
            fx.model.clone(),
            &path,
            &[3, 16, 16],
            BatchPolicy { shards: 1, max_wait: Duration::from_millis(1), ..Default::default() },
        )
        .unwrap()
        .build_watched(Duration::from_millis(50))
        .unwrap();
    assert!(registry.watching());
    let entry = registry.get("w").unwrap();
    await_served(&registry, "w", &fx, &fx.oracle_a);

    save_quantized(&path, &fx.qm_b).unwrap();
    let deadline = Instant::now() + Duration::from_secs(15);
    while entry.stamp().generation < 2 {
        assert!(Instant::now() < deadline, "watcher never picked up the rewritten bundle");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(entry.metrics().reloads_ok.get(), 1);
    await_served(&registry, "w", &fx, &fx.oracle_b);
    registry.shutdown();
    std::fs::remove_file(&path).ok();
}

/// Satellite 4 regression: shape validation happens BEFORE the admission
/// CAS, so a burst of malformed submits can neither consume in-flight
/// slots nor release ones it never took — the inflight gauge is
/// untouched and well-formed traffic still sees the full budget.
#[test]
fn bad_shape_burst_leaves_admission_state_untouched() {
    let fx = swap_fixture();
    let engine = ServeEngine::compile(&fx.model, &fx.qm_a, &[3, 16, 16]).unwrap();
    let batcher = Batcher::new(
        engine,
        // long max_wait + large max_batch: the two admitted requests
        // stay in flight while the burst runs
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(500),
            shards: 1,
            depth_budget: 2,
            ..Default::default()
        },
    );
    let m = Arc::clone(batcher.metrics());
    let rx1 = batcher.submit(fx.images[0].clone()).expect("first admitted");
    let rx2 = batcher.submit(fx.images[1].clone()).expect("second admitted");
    assert_eq!(m.inflight(), 2, "budget filled");

    for _ in 0..100 {
        match batcher.submit(Tensor::zeros(&[3, 8, 8])) {
            Err(SubmitError::BadShape { got, want }) => {
                assert_eq!((got, want), (3 * 8 * 8, 3 * 16 * 16));
            }
            other => panic!("bad-shape submit must fail with BadShape, got {other:?}"),
        }
        assert_eq!(m.inflight(), 2, "a bad-shape submit must not touch the inflight gauge");
    }
    assert_eq!(m.rejected_shape.get(), 100);
    assert_eq!(m.rejected_full.get(), 0, "bad shapes must be rejected before the CAS");

    // the budget is still genuinely full for well-formed traffic...
    match batcher.submit(fx.images[0].clone()) {
        Err(SubmitError::QueueFull { budget: 2 }) => {}
        Ok(_) => panic!("submit admitted past the budget"),
        Err(e) => panic!("expected QueueFull at budget 2, got {e:?}"),
    }
    // ...and the two admitted requests are answered untouched
    assert_eq!(rx1.recv().expect("response"), fx.oracle_a[0]);
    assert_eq!(rx2.recv().expect("response"), fx.oracle_a[1]);
    batcher.shutdown();
}
