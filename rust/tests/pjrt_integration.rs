//! Integration tests over the AOT artifacts: the PJRT runtime must load,
//! compile and execute the HLO step/qlinear artifacts, and the PJRT
//! AdaRound driver must agree with the pure-rust native driver (identical
//! math, fp roundoff aside).
//!
//! Requires `make artifacts` (skipped gracefully if absent).

use adaround::adaround::{
    AdaRoundConfig, LayerProblem, NativeOptimizer, PjrtOptimizer, RoundingOptimizer,
};
use adaround::quant::QuantGrid;
use adaround::runtime::{Runtime, StepState};
use adaround::tensor::{matmul, Tensor};
use adaround::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = adaround::artifacts_dir();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

/// A layer problem matching an existing artifact bucket (micro18 stem:
/// rows=8, cols=27, relu=true).
fn stem_problem(seed: u64) -> (LayerProblem, Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let (rows, cols, ncols) = (8usize, 27usize, 512usize);
    let w = Tensor::from_vec(
        &[rows, cols],
        (0..rows * cols).map(|_| rng.normal_f32(0.0, 0.3)).collect(),
    );
    let grid = QuantGrid::per_tensor(0.08, 4);
    let bias = (0..rows).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let prob = LayerProblem::new(w.clone(), &grid, 0, bias, true);
    let x = Tensor::from_vec(
        &[cols, ncols],
        (0..cols * ncols).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
    );
    let mut t = matmul(&w, &x);
    for r in 0..rows {
        let b = prob.bias[r];
        for v in &mut t.data[r * ncols..(r + 1) * ncols] {
            *v += b;
        }
    }
    (prob, x, t)
}

#[test]
fn step_exec_matches_native_single_step() {
    let Some(rt) = runtime() else { return };
    let (prob, x, t) = stem_problem(1);
    let exec = rt.step_exec(8, 27, true).expect("step exec");
    let batch = exec.batch;

    // same minibatch for both paths
    let xb = Tensor::from_vec(&[27, batch], x.data[..27 * batch].to_vec());
    let tb = {
        let mut out = Tensor::zeros(&[8, batch]);
        for r in 0..8 {
            out.data[r * batch..(r + 1) * batch]
                .copy_from_slice(&t.data[r * x.cols()..r * x.cols() + batch]);
        }
        out
    };
    let (beta, lam, lr) = (8.0f32, 0.01f32, 0.01f32);

    // native: one loss_grad + Adam step
    let v0 = prob.init_v();
    let (_, _, grad) = prob.loss_grad(&v0, &xb, &tb, beta, lam);
    let mut v_native = v0.clone();
    let mut adam = adaround::adaround::Adam::new(v_native.numel());
    adam.step(&mut v_native.data, &grad.data, lr);

    // pjrt: one artifact execution
    let s_col = Tensor::from_vec(&[8, 1], (0..8).map(|r| prob.s(r)).collect());
    let b_col = Tensor::from_vec(&[8, 1], prob.bias.clone());
    let mut state = StepState::new(v0);
    let (loss, mse) = exec
        .run(&mut state, &xb, &tb, &prob.w, &s_col, &b_col, beta, lam, lr, prob.n, prob.p)
        .expect("step run");
    assert!(loss.is_finite() && mse.is_finite());

    let max_err = state
        .v
        .data
        .iter()
        .zip(&v_native.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 2e-4, "V' disagreement native vs pjrt: {max_err}");
}

#[test]
fn pjrt_and_native_drivers_agree_on_rounding() {
    let Some(rt) = runtime() else { return };
    let (prob, x, t) = stem_problem(2);
    let cfg = AdaRoundConfig { iters: 150, batch: 192, ..Default::default() };
    let res_n = NativeOptimizer
        .optimize(&prob, &x, &t, &cfg, &mut Rng::new(9))
        .unwrap();
    let res_p = PjrtOptimizer::new(&rt)
        .optimize(&prob, &x, &t, &cfg, &mut Rng::new(9))
        .unwrap();
    // identical seeds + identical math => identical minibatches; fp
    // accumulation differences may flip h values sitting exactly at 0.5,
    // so allow a tiny disagreement margin
    let disagree = res_n
        .mask
        .data
        .iter()
        .zip(&res_p.mask.data)
        .filter(|(a, b)| (*a - *b).abs() > 0.5)
        .count();
    let frac = disagree as f64 / res_n.mask.numel() as f64;
    assert!(frac < 0.03, "mask disagreement {frac} ({disagree} weights)");
    assert!(res_p.mse_after <= res_p.mse_before * 1.01);
}

#[test]
fn qlinear_exec_matches_native_fake_quant() {
    let Some(rt) = runtime() else { return };
    // micro18 stem qlinear bucket: rows=8, cols=27, npos = 32*32*32
    let npos = 32 * 32 * 32;
    let Ok(exec) = rt.qlinear_exec(8, 27, npos) else {
        eprintln!("SKIP: no qlinear bucket");
        return;
    };
    let mut rng = Rng::new(3);
    let w = Tensor::from_vec(&[8, 27], (0..216).map(|_| rng.normal_f32(0.0, 0.3)).collect());
    let grid = QuantGrid::per_tensor(0.05, 4);
    let r = adaround::quant::nearest_mask(&w, &grid);
    let s = Tensor::full(&[8, 1], 0.05);
    let b = Tensor::from_vec(&[8, 1], (0..8).map(|_| rng.normal_f32(0.0, 0.1)).collect());
    let x = Tensor::from_vec(
        &[27, npos],
        (0..27 * npos).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
    );
    let y = exec.run(&w, &r, &s, &b, &x, -8.0, 7.0).expect("qlinear run");
    // native reference
    let wq = adaround::quant::fake_quant(&w, &r, &grid);
    let mut y_ref = matmul(&wq, &x);
    for row in 0..8 {
        for v in &mut y_ref.data[row * npos..(row + 1) * npos] {
            *v += b.data[row];
        }
    }
    let max_err = y
        .data
        .iter()
        .zip(&y_ref.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "qlinear disagreement {max_err}");
}

#[test]
fn manifest_covers_all_model_layer_buckets() {
    let Some(rt) = runtime() else { return };
    for name in rt.manifest.model_names() {
        let model = rt.manifest.load_model(&name).unwrap();
        for node in model.quant_layers() {
            let g = node.geom().unwrap();
            assert!(
                rt.manifest
                    .find_exec("adaround_step", g.rows, g.cols, g.relu)
                    .is_some(),
                "{name}/{}: no step bucket r{} c{} relu={}",
                node.id,
                g.rows,
                g.cols,
                g.relu
            );
        }
    }
}
