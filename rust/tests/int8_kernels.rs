//! Differential harness for the i8 GEMM micro-kernel layer: every
//! implementation (AVX-512 VNNI, AVX2, NEON, portable-packed, the
//! unpacked scalar reference) in every blocking config must be
//! **bit-for-bit identical** on every input — randomized shapes (K off
//! the block sizes, M/N = 1, grouped convs), i32-accumulator magnitude
//! edges, and requant zero-point corners. This is the contract that
//! makes `PALLAS_NO_SIMD`, `PALLAS_KERNEL`, `PALLAS_AUTOTUNE` and ISA
//! differences pure performance knobs: the serving stack's outputs
//! never depend on which kernel (or which autotuned config) ran.

use adaround::serve::ikernels::{conv2d_i8, dense_i8, Int8Workspace};
use adaround::serve::{ConvW, DenseW, Requant};
use adaround::tensor::int8::kernel::{
    cfg_count, gemm_conv4_packed_into, gemm_conv_packed_into, gemm_dense4_packed_into,
    gemm_dense_packed_into, GemmChoice, Kernel, PackedConv, PackedConv4, PackedDense,
    PackedDense4,
};
use adaround::tensor::int8::{gemm_i8_into, gemm_u8_bt_into};
use adaround::tensor::{Conv2dParams, I8Tensor, U8Tensor};
use adaround::util::parallel::with_threads;
use adaround::util::Rng;

/// Every (kernel, blocking config) pair runnable on this machine — the
/// full candidate space the autotuner picks from. The portable path
/// always runs; AVX2/AVX-512/NEON join when the CPU (and toolchain)
/// has them, and ISAs this machine lacks skip green by construction.
fn kernels() -> Vec<GemmChoice> {
    Kernel::all()
        .into_iter()
        .filter(|k| k.available())
        .flat_map(|k| (0..cfg_count(k)).map(move |cfg| GemmChoice::new(k, cfg)))
        .collect()
}

fn rnd_i8(n: usize, rng: &mut Rng) -> Vec<i8> {
    (0..n).map(|_| (rng.below(256) as i32 - 128) as i8).collect()
}

fn rnd_u8(n: usize, rng: &mut Rng) -> Vec<u8> {
    (0..n).map(|_| rng.below(256) as u8).collect()
}

/// Naive i64 oracle for C = A_i8 [m,k] · B_u8 [k,n].
fn naive_conv_gemm(a: &[i8], b: &[u8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i64;
            for t in 0..k {
                acc += a[i * k + t] as i64 * b[t * n + j] as i64;
            }
            c[i * n + j] = acc as i32;
        }
    }
    c
}

/// Naive i64 oracle for C = A_u8 [m,k] · W^T with W [n,k] i8.
fn naive_dense_gemm(a: &[u8], w: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i64;
            for t in 0..k {
                acc += a[i * k + t] as i64 * w[j * k + t] as i64;
            }
            c[i * n + j] = acc as i32;
        }
    }
    c
}

#[test]
fn conv_gemm_bit_identical_across_kernels() {
    // K even/odd/1, K < and > the j-tile, M = 1, N = 1, N off the 32-wide
    // tile, N exactly on it — the seams where a packed kernel can go wrong
    let shapes = [
        (1usize, 1usize, 1usize),
        (1, 2, 1),
        (2, 1, 3),
        (3, 7, 5),
        (4, 15, 33),
        (5, 16, 32),
        (8, 17, 100),
        (1, 33, 64),
        (16, 64, 31),
        (2, 3, 257),
        (6, 128, 96),
    ];
    let mut rng = Rng::new(401);
    for (m, k, n) in shapes {
        let a = rnd_i8(m * k, &mut rng);
        let b = rnd_u8(k * n, &mut rng);
        let want = naive_conv_gemm(&a, &b, m, k, n);
        // the unpacked scalar reference kernel
        let mut c_scalar = vec![0i32; m * n];
        gemm_i8_into(&a, &b, &mut c_scalar, m, k, n);
        assert_eq!(c_scalar, want, "scalar reference vs naive at {m}x{k}x{n}");
        let packed = PackedConv::pack(&a, m, k);
        assert!(packed.layout_ok());
        for kern in kernels() {
            let mut c = vec![-1i32; m * n]; // poison: kernel must overwrite
            gemm_conv_packed_into(kern, &packed.data, m, k, packed.kp, &b, &mut c, n);
            assert_eq!(c, want, "{} conv kernel at {m}x{k}x{n}", kern.label());
        }
    }
}

#[test]
fn dense_gemm_bit_identical_across_kernels() {
    // K and N straddling the 16-wide K block and the 4-row quad
    let shapes = [
        (1usize, 1usize, 1usize),
        (2, 16, 4),
        (3, 15, 5),
        (1, 16, 1),
        (4, 17, 8),
        (5, 31, 3),
        (2, 33, 9),
        (7, 64, 13),
        (3, 100, 2),
        (1, 129, 31),
    ];
    let mut rng = Rng::new(402);
    for (m, k, n) in shapes {
        let a = rnd_u8(m * k, &mut rng);
        let w = rnd_i8(n * k, &mut rng);
        let want = naive_dense_gemm(&a, &w, m, k, n);
        let mut c_scalar = vec![0i32; m * n];
        gemm_u8_bt_into(&a, &w, &mut c_scalar, m, k, n);
        assert_eq!(c_scalar, want, "scalar reference vs naive at {m}x{k}x{n}");
        let packed = PackedDense::pack(&w, n, k);
        assert!(packed.layout_ok());
        for kern in kernels() {
            let mut c = vec![-1i32; m * n];
            gemm_dense_packed_into(kern, &a, &packed, &mut c, m);
            assert_eq!(c, want, "{} dense kernel at {m}x{k}x{n}", kern.label());
        }
    }
}

#[test]
fn grouped_conv_kernels_and_threads_agree() {
    // groups > 1 with an ODD row count per group (og = 3), so the
    // group-boundary row slicing hands the kernel both 2-row tiles and a
    // 1-row tail inside every group
    let p = Conv2dParams { k: 3, stride: 1, pad: 1, groups: 4 };
    let (n, c, o, hw) = (4usize, 8usize, 12usize, 11usize);
    let cg = c / p.groups;
    let patch = cg * 9;
    let mut rng = Rng::new(403);
    let qin = U8Tensor::from_vec(
        &[n, c, hw, hw],
        (0..n * c * hw * hw).map(|_| rng.below(256) as u8).collect(),
    );
    let wi = I8Tensor::from_vec(&[o, cg, 3, 3], rnd_i8(o * patch, &mut rng));
    let wp = ConvW::W8(PackedConv::pack(&wi.data, o, patch));
    let bias_q: Vec<i32> = (0..o as i32).map(|v| v * 3 - 7).collect();
    let wsum: Vec<i32> = (0..o)
        .map(|oc| wi.data[oc * patch..(oc + 1) * patch].iter().map(|&z| z as i32).sum())
        .collect();
    let requant = vec![Requant::from_real(0.031); o];
    let run = |kern: GemmChoice, threads: usize| {
        with_threads(threads, || {
            let mut ws = Int8Workspace::new();
            conv2d_i8(&mut ws, kern, &qin, &wp, p, &bias_q, &wsum, &requant, 3, 5, true).data
        })
    };
    let base = run(GemmChoice::from(Kernel::Portable), 1);
    for kern in kernels() {
        for threads in [1usize, 4] {
            assert_eq!(
                run(kern, threads),
                base,
                "grouped conv differs for {} kernel, {threads} threads",
                kern.label()
            );
        }
    }
}

#[test]
fn accumulator_magnitude_edges_are_exact() {
    // all-i8::MIN weights x all-255 inputs at the largest K whose sum
    // still fits i32: acc = 65792 * (-32640) = -2_147_450_880, within
    // 32_768 of i32::MIN. Any kernel that saturates an intermediate (the
    // pmaddubsw i16 trap) or mis-widens breaks long before this point.
    let k = 65_792usize;
    let a_min = vec![i8::MIN; k];
    let b_max = vec![255u8; k];
    let want_min = -2_147_450_880i32;
    // ...and the positive mirror with +127 weights
    let a_max = vec![127i8; k];
    let want_max = 2_130_673_920i32;
    for (a, want) in [(&a_min, want_min), (&a_max, want_max)] {
        let mut c = vec![0i32; 1];
        gemm_i8_into(a, &b_max, &mut c, 1, k, 1);
        assert_eq!(c[0], want, "scalar conv reference");
        let packed = PackedConv::pack(a, 1, k);
        for kern in kernels() {
            let mut c = vec![0i32; 1];
            gemm_conv_packed_into(kern, &packed.data, 1, k, packed.kp, &b_max, &mut c, 1);
            assert_eq!(c[0], want, "{} conv kernel near i32 edge", kern.label());
        }
        let mut c = vec![0i32; 1];
        gemm_u8_bt_into(&b_max, a, &mut c, 1, k, 1);
        assert_eq!(c[0], want, "scalar dense reference");
        let packed = PackedDense::pack(a, 1, k);
        for kern in kernels() {
            let mut c = vec![0i32; 1];
            gemm_dense_packed_into(kern, &b_max, &packed, &mut c, 1);
            assert_eq!(c[0], want, "{} dense kernel near i32 edge", kern.label());
        }
    }
}

#[test]
fn requant_zero_point_corners() {
    // zero points at the u8 corners and midpoint, both sides of the
    // requant, on every kernel — checked against an inline scalar oracle
    // of the serving convention (zp_out + round(M*(acc - zp_in*wsum)),
    // clamped to [relu-floor, 255])
    let (n, c, o) = (3usize, 21usize, 5usize);
    let mut rng = Rng::new(405);
    let qin = U8Tensor::from_vec(&[n, c], rnd_u8(n * c, &mut rng));
    let w = rnd_i8(o * c, &mut rng);
    let packed = DenseW::W8(PackedDense::pack(&w, o, c));
    let bias_q = vec![11i32, -4, 0, 250, -99];
    let wsum: Vec<i32> =
        (0..o).map(|oc| w[oc * c..(oc + 1) * c].iter().map(|&z| z as i32).sum()).collect();
    let r = Requant::from_real(0.73);
    let requant = vec![r; o];
    for zp_in in [0i32, 128, 255] {
        for zp_out in [0i32, 128, 255] {
            for relu in [false, true] {
                let mut oracle = vec![0u8; n * o];
                for i in 0..n {
                    for oc in 0..o {
                        let mut acc = bias_q[oc] - zp_in * wsum[oc];
                        for cc in 0..c {
                            acc += qin.data[i * c + cc] as i32 * w[oc * c + cc] as i32;
                        }
                        let lo = if relu { zp_out } else { 0 };
                        oracle[i * o + oc] = (zp_out + r.apply(acc)).clamp(lo, 255) as u8;
                    }
                }
                for kern in kernels() {
                    let mut ws = Int8Workspace::new();
                    let got = dense_i8(
                        &mut ws, kern, &qin, &packed, &bias_q, &wsum, &requant, zp_in, zp_out,
                        relu,
                    );
                    assert_eq!(
                        got.data,
                        oracle,
                        "{} dense zp_in={zp_in} zp_out={zp_out} relu={relu}",
                        kern.label()
                    );
                }
            }
        }
    }
}

fn rnd_i4(n: usize, rng: &mut Rng) -> Vec<i8> {
    (0..n).map(|_| (rng.below(16) as i32 - 8) as i8).collect()
}

#[test]
fn conv4_gemm_bit_identical_across_kernels() {
    // same seam catalogue as the w8 test, but K odd shapes additionally
    // exercise the nibble tail (last packed byte half-used)
    let shapes = [
        (1usize, 1usize, 1usize),
        (1, 2, 1),
        (2, 1, 3),
        (3, 7, 5),
        (4, 15, 33),
        (5, 16, 32),
        (8, 17, 100),
        (1, 33, 64),
        (16, 64, 31),
        (2, 3, 257),
        (6, 128, 96),
    ];
    let mut rng = Rng::new(411);
    for (m, k, n) in shapes {
        let a = rnd_i4(m * k, &mut rng);
        let b = rnd_u8(k * n, &mut rng);
        // the oracle is the *w8 semantics over the same codes*: i4 is a
        // storage format, not a different arithmetic
        let want = naive_conv_gemm(&a, &b, m, k, n);
        let packed = PackedConv4::pack(&a, m, k);
        assert!(packed.layout_ok());
        for kern in kernels() {
            let mut c = vec![-1i32; m * n]; // poison: kernel must overwrite
            gemm_conv4_packed_into(kern, &packed.data, m, k, packed.kp, &b, &mut c, n);
            assert_eq!(c, want, "{} conv4 kernel at {m}x{k}x{n}", kern.label());
        }
    }
}

#[test]
fn dense4_gemm_bit_identical_across_kernels() {
    let shapes = [
        (1usize, 1usize, 1usize),
        (2, 16, 4),
        (3, 15, 5),
        (1, 16, 1),
        (4, 17, 8),
        (5, 31, 3),
        (2, 33, 9),
        (7, 64, 13),
        (3, 100, 2),
        (1, 129, 31),
    ];
    let mut rng = Rng::new(412);
    for (m, k, n) in shapes {
        let a = rnd_u8(m * k, &mut rng);
        let w = rnd_i4(n * k, &mut rng);
        let want = naive_dense_gemm(&a, &w, m, k, n);
        let packed = PackedDense4::pack(&w, n, k);
        assert!(packed.layout_ok());
        for kern in kernels() {
            let mut c = vec![-1i32; m * n];
            gemm_dense4_packed_into(kern, &a, &packed, &mut c, m);
            assert_eq!(c, want, "{} dense4 kernel at {m}x{k}x{n}", kern.label());
        }
    }
}

#[test]
fn int4_sign_extension_corners() {
    // the unpack seam: -8 (0b1000) and 7 (0b0111) in both nibble
    // positions, plus -1 (all ones) which a logical instead of
    // arithmetic shift would turn into +15. K odd so the tail nibble of
    // the last byte is the zero pad.
    let w: Vec<i8> = vec![-8, 7, -1, -8, 7, -1, -8];
    let k = w.len();
    let b = vec![255u8; k];
    let want = naive_conv_gemm(&w, &b, 1, k, 1);
    assert_eq!(want[0], (-8 + 7 - 1 - 8 + 7 - 1 - 8) * 255);
    let pc = PackedConv4::pack(&w, 1, k);
    let pd = PackedDense4::pack(&w, 1, k);
    for kern in kernels() {
        let mut c = vec![0i32; 1];
        gemm_conv4_packed_into(kern, &pc.data, 1, k, pc.kp, &b, &mut c, 1);
        assert_eq!(c, want, "{} conv4 sign corners", kern.label());
        let mut c = vec![0i32; 1];
        gemm_dense4_packed_into(kern, &b, &pd, &mut c, 1);
        assert_eq!(c, want, "{} dense4 sign corners", kern.label());
    }
}

#[test]
fn int4_accumulator_magnitude_edges_are_exact() {
    // all -8 weights x all-255 inputs at the largest K whose product sum
    // still fits i32: 1_052_688 * (-2040) = -2_147_483_520, within 128
    // of i32::MIN. The positive mirror with +7 weights lands at
    // 1_879_048_080. Saturating or mis-widened intermediates in the
    // nibble unpack break far before this magnitude.
    let k = 1_052_688usize;
    let b_max = vec![255u8; k];
    for (code, want) in [(-8i8, -2_147_483_520i32), (7, 1_879_048_080)] {
        let a = vec![code; k];
        let mut c = vec![0i32; 1];
        gemm_i8_into(&a, &b_max, &mut c, 1, k, 1);
        assert_eq!(c[0], want, "scalar reference at the i32 edge");
        let pc = PackedConv4::pack(&a, 1, k);
        let pd = PackedDense4::pack(&a, 1, k);
        for kern in kernels() {
            let mut c = vec![0i32; 1];
            gemm_conv4_packed_into(kern, &pc.data, 1, k, pc.kp, &b_max, &mut c, 1);
            assert_eq!(c[0], want, "{} conv4 kernel near i32 edge", kern.label());
            let mut c = vec![0i32; 1];
            gemm_dense4_packed_into(kern, &b_max, &pd, &mut c, 1);
            assert_eq!(c[0], want, "{} dense4 kernel near i32 edge", kern.label());
        }
    }
}

/// Layout corruption must fail loudly (debug_assert in the serve kernels),
/// not silently corrupt accumulators. Debug builds only — release strips
/// the check by design (the plan compiler is the only production packer).
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "PackedDense layout")]
fn corrupted_dense_pack_fails_loudly() {
    let (c, o) = (10usize, 3usize);
    let mut rng = Rng::new(406);
    let qin = U8Tensor::from_vec(&[1, c], rnd_u8(c, &mut rng));
    let w = rnd_i8(o * c, &mut rng);
    let mut packed = PackedDense::pack(&w, o, c);
    // scribble on a K-pad byte of row 0 (k=10 pads to kp=16)
    packed.data[10] = 1;
    let mut ws = Int8Workspace::new();
    let z = vec![0i32; o];
    let r = vec![Requant::from_real(1.0); o];
    dense_i8(&mut ws, Kernel::Portable, &qin, &DenseW::W8(packed), &z, &z, &r, 0, 0, false);
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "PackedConv layout")]
fn corrupted_conv_pack_fails_loudly() {
    let p = Conv2dParams { k: 1, stride: 1, pad: 0, groups: 1 };
    let (c, o) = (3usize, 2usize);
    let mut rng = Rng::new(407);
    let qin = U8Tensor::from_vec(&[1, c, 4, 4], rnd_u8(c * 16, &mut rng));
    let w = rnd_i8(o * c, &mut rng);
    let mut packed = PackedConv::pack(&w, o, c); // k=3 pads to kp=4
    packed.data[3] = 1;
    let mut ws = Int8Workspace::new();
    let z = vec![0i32; o];
    let r = vec![Requant::from_real(1.0); o];
    conv2d_i8(&mut ws, Kernel::Portable, &qin, &ConvW::W8(packed), p, &z, &z, &r, 0, 0, false);
}
