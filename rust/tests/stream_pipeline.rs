//! Streaming-calibration guarantees, end to end and artifact-free:
//!
//! 1. the streaming sampler's total prefix layer-forwards are O(L) on a
//!    deep synthetic model (the replay reference is O(L²)),
//! 2. the streaming pipeline reproduces the full-replay `QuantizedModel`
//!    **bit-identically** — weights, biases, scales, activation
//!    quantizers and per-layer stats — for Nearest, AdaRound and
//!    BiasCorr, including on a branchy Add/Concat graph,
//! 3. results are invariant across `PALLAS_THREADS` {1, 4}.

use adaround::adaround::AdaRoundConfig;
use adaround::coordinator::pipeline::CHUNK_IMGS;
use adaround::coordinator::{Method, Pipeline, PipelineConfig, QuantizedModel};
use adaround::data::{synthetic_stripes, synthetic_tokens};
use adaround::nn::graph::TRANSFORMER_VOCAB;
use adaround::nn::Model;
use adaround::tensor::Tensor;
use adaround::util::{parallel, Rng};

fn chain(depth: usize, branchy: bool) -> Model {
    Model::synthetic_chain(depth, 4, branchy, &mut Rng::new(33))
}

fn calib(n: usize) -> Tensor {
    synthetic_stripes(n, 3, 8, &mut Rng::new(44)).0
}

fn cfg(method: Method, replay: bool) -> PipelineConfig {
    PipelineConfig {
        method,
        bits: 3,
        calib_n: 80, // 2 chunks at CHUNK_IMGS = 64
        col_budget: 96,
        adaround: AdaRoundConfig { iters: 40, ..Default::default() },
        replay_sampler: replay,
        ..Default::default()
    }
}

fn quantize(model: &Model, c: &Tensor, cfg: PipelineConfig, threads: usize) -> QuantizedModel {
    parallel::with_threads(threads, || {
        Pipeline::new(model, cfg, None)
            .quantize(c, &mut Rng::new(1000))
            .expect("quantize")
    })
}

/// Bit-identity over everything the pipeline produces except wall-clock
/// (`secs`) and the instrumentation counter (which differs by design).
fn assert_identical(a: &QuantizedModel, b: &QuantizedModel, what: &str) {
    assert_eq!(a.weight_overrides, b.weight_overrides, "{what}: weight overrides");
    assert_eq!(a.bias_overrides, b.bias_overrides, "{what}: bias overrides");
    assert_eq!(a.scales, b.scales, "{what}: grid scales");
    match (&a.act_quant, &b.act_quant) {
        (None, None) => {}
        (Some(x), Some(y)) => {
            assert_eq!(x.len(), y.len(), "{what}: act-quant count");
            for (id, qa) in x {
                let qb = &y[id];
                assert_eq!(
                    (qa.min.to_bits(), qa.max.to_bits(), qa.bits),
                    (qb.min.to_bits(), qb.max.to_bits(), qb.bits),
                    "{what}: act quant {id}"
                );
            }
        }
        _ => panic!("{what}: act_quant presence differs"),
    }
    assert_eq!(a.stats.len(), b.stats.len(), "{what}: stats length");
    for (sa, sb) in a.stats.iter().zip(&b.stats) {
        assert_eq!(sa.id, sb.id, "{what}: stat order");
        let ga = (sa.rows, sa.cols, sa.groups);
        assert_eq!(ga, (sb.rows, sb.cols, sb.groups), "{what}: geometry {}", sa.id);
        let mb = (sa.mse_before.to_bits(), sb.mse_before.to_bits());
        assert_eq!(mb.0, mb.1, "{what}: mse_before {}", sa.id);
        assert_eq!(sa.mse_after.to_bits(), sb.mse_after.to_bits(), "{what}: mse_after {}", sa.id);
        assert_eq!(sa.flipped_frac.to_bits(), sb.flipped_frac.to_bits(), "{what}: flips {}", sa.id);
    }
}

#[test]
fn prefix_work_is_linear_in_depth() {
    let c = calib(80);
    let n_chunks = (80usize).div_ceil(CHUNK_IMGS) as u64; // = 2
    let mut streaming_counts = Vec::new();
    for depth in [6usize, 12] {
        let model = chain(depth, false);
        let l = model.quant_layers().len() as u64;
        let qm = quantize(&model, &c, cfg(Method::Nearest, false), 1);
        // each stream (FP32 + quantized prefix) executes each quantizable
        // node at most once per chunk — the O(L) contract, exactly
        assert!(
            qm.layer_execs <= 2 * n_chunks * l,
            "depth {depth}: {} layer-forwards exceeds the streaming bound {}",
            qm.layer_execs,
            2 * n_chunks * l
        );
        assert!(qm.layer_execs > 0, "instrumentation must count something");
        streaming_counts.push(qm.layer_execs);
    }
    // doubling the depth must (at most) double the prefix work
    assert!(
        streaming_counts[1] <= streaming_counts[0] * 5 / 2,
        "streaming forwards not linear: depth 6 -> {}, depth 12 -> {}",
        streaming_counts[0],
        streaming_counts[1]
    );

    // the replay reference on the deep model is quadratic — and the
    // streaming path beats it by a wide margin
    let model = chain(12, false);
    let l = model.quant_layers().len() as u64;
    let replay = quantize(&model, &c, cfg(Method::Nearest, true), 1);
    assert!(
        replay.layer_execs >= n_chunks * l * (l - 1) / 2,
        "replay count {} is not O(L²)?",
        replay.layer_execs
    );
    assert!(
        replay.layer_execs >= 3 * streaming_counts[1],
        "streaming ({}) should do several times fewer layer-forwards than replay ({})",
        streaming_counts[1],
        replay.layer_execs
    );
}

#[test]
fn streaming_matches_replay_bit_for_bit() {
    let model = chain(8, false);
    let c = calib(80);
    for method in [Method::Nearest, Method::AdaRound, Method::BiasCorr] {
        let s = quantize(&model, &c, cfg(method, false), 1);
        let r = quantize(&model, &c, cfg(method, true), 1);
        assert_identical(&s, &r, &format!("{method:?}"));
        assert!(
            r.layer_execs > s.layer_execs,
            "{method:?}: replay must do more prefix work ({} vs {})",
            r.layer_execs,
            s.layer_execs
        );
    }
}

#[test]
fn branchy_graph_matches_replay_with_act_quant() {
    // Add + Concat keep long-lived taps across frontiers; activation
    // quantization exercises the post-pipeline calibration pass too
    let model = chain(5, true);
    let c = calib(80);
    for method in [Method::Nearest, Method::BiasCorr] {
        let mut cs = cfg(method, false);
        cs.act_bits = Some(8);
        let mut cr = cfg(method, true);
        cr.act_bits = Some(8);
        let s = quantize(&model, &c, cs, 1);
        let r = quantize(&model, &c, cr, 1);
        assert!(s.act_quant.is_some(), "act quant requested");
        assert_identical(&s, &r, &format!("branchy {method:?}"));
    }
}

#[test]
fn streaming_is_thread_count_invariant() {
    let model = chain(6, true);
    let c = calib(80);
    for method in [Method::Nearest, Method::AdaRound, Method::BiasCorr] {
        let t1 = quantize(&model, &c, cfg(method, false), 1);
        let t4 = quantize(&model, &c, cfg(method, false), 4);
        assert_identical(&t1, &t4, &format!("{method:?} threads 1 vs 4"));
        assert_eq!(
            t1.layer_execs, t4.layer_execs,
            "{method:?}: even the forward count must not depend on threads"
        );
        // close the grid: replay at 4 threads equals streaming at 1
        let r4 = quantize(&model, &c, cfg(method, true), 4);
        assert_identical(&t1, &r4, &format!("{method:?} streaming/1 vs replay/4"));
    }
}

// ---- synthetic transformer: the branchy multi-consumer stress case ----
// Every attention block fans ln1 out to three consumers (q/k/v), feeds
// MatMul nodes two activation inputs each, and holds residual taps alive
// across the whole block — the hard case for the streaming store's
// last-consumer eviction and for input-index-aware tap wiring.

fn transformer() -> Model {
    Model::synthetic_transformer(2, 2, 8, 6, &mut Rng::new(5))
}

fn tokens(n: usize) -> Tensor {
    synthetic_tokens(n, 6, TRANSFORMER_VOCAB, &mut Rng::new(44))
}

#[test]
fn transformer_streaming_matches_replay_bit_for_bit() {
    let model = transformer();
    let c = tokens(80);
    for method in [Method::Nearest, Method::AdaRound, Method::AttentionRound] {
        let s = quantize(&model, &c, cfg(method, false), 1);
        let r = quantize(&model, &c, cfg(method, true), 1);
        // per-head grids: the Q/K/V projections must carry one scale per
        // head row-block (d_model 8, 2 heads -> 8 scales, 2 distinct max)
        let qs = &s.scales["b0.q"];
        assert_eq!(qs.len(), 8, "per-head grid is row-indexed over cout");
        assert!(qs[..4].iter().all(|&v| v == qs[0]), "head 0 shares one scale");
        assert!(qs[4..].iter().all(|&v| v == qs[4]), "head 1 shares one scale");
        assert_identical(&s, &r, &format!("transformer {method:?}"));
        assert!(
            r.layer_execs > s.layer_execs,
            "{method:?}: replay must do more prefix work on the transformer"
        );
    }
}

#[test]
fn transformer_thread_count_invariant() {
    let model = transformer();
    let c = tokens(80);
    for method in [Method::AdaRound, Method::AttentionRound] {
        let mut c1 = cfg(method, false);
        c1.act_bits = Some(8);
        let t1 = quantize(&model, &c, c1.clone(), 1);
        let t4 = quantize(&model, &c, c1, 4);
        assert_identical(&t1, &t4, &format!("transformer {method:?} threads 1 vs 4"));
        let mut cr = cfg(method, true);
        cr.act_bits = Some(8);
        let r4 = quantize(&model, &c, cr, 4);
        assert_identical(&t1, &r4, &format!("transformer {method:?} streaming/1 vs replay/4"));
    }
}

#[test]
fn transformer_prefix_work_is_linear() {
    let c = tokens(80);
    let n_chunks = (80usize).div_ceil(CHUNK_IMGS) as u64; // = 2
    let model = transformer();
    let l = model.quant_layers().len() as u64; // 13 at depth 2
    let qm = quantize(&model, &c, cfg(Method::Nearest, false), 1);
    assert!(
        qm.layer_execs <= 2 * n_chunks * l,
        "transformer streaming did {} dense executions, O(L) bound is {}",
        qm.layer_execs,
        2 * n_chunks * l
    );
    let replay = quantize(&model, &c, cfg(Method::Nearest, true), 1);
    assert!(
        replay.layer_execs > 2 * qm.layer_execs,
        "replay ({}) should redo the prefix per layer vs streaming ({})",
        replay.layer_execs,
        qm.layer_execs
    );
}

#[test]
fn transformer_segment_eviction_matches_whole_pass() {
    // forward the quantized transformer whole vs in segments cut INSIDE
    // an attention block, seeding each resume with exactly the liveness
    // set `live_at` promises — proves eviction keeps every value the
    // attention subgraph still needs (sm and v both feed b1.av)
    let model = transformer();
    let c = tokens(8);
    let qm = quantize(&model, &c, cfg(Method::AdaRound, false), 1);
    let opts = qm.opts();
    let whole = model.forward(&c, &opts);

    let cut = model.node_index("b1.av").expect("attention block node");
    let out_id = model.nodes.last().unwrap().id.clone();
    let want: std::collections::BTreeSet<String> = [out_id.clone()].into();
    let mut vals = std::collections::BTreeMap::new();
    vals.insert("in".to_string(), c.clone());
    model.forward_segment(&mut vals, 0..cut, &opts, &want);
    // the resume state is exactly the live set at the cut
    let live = model.live_at(cut);
    let held: std::collections::BTreeSet<String> = vals.keys().cloned().collect();
    assert_eq!(held, live, "segment state at b1.av != live_at");
    assert!(live.contains("b1.sm") && live.contains("b1.v"), "both MatMul inputs live");
    model.forward_segment(&mut vals, cut..model.nodes.len(), &opts, &want);
    let seg = vals.remove(&out_id).expect("segmented output");
    assert_eq!(whole.data, seg.data, "segmented forward must be bit-identical");
}

#[test]
fn only_layers_subset_streams_identically() {
    // layer selection skips overrides for unselected layers; the stream
    // still propagates through them with FP32 weights, like the replay
    let model = chain(6, false);
    let c = calib(80);
    let subset = vec!["c2".to_string(), "c5".to_string()];
    let mut cs = cfg(Method::Nearest, false);
    cs.only_layers = Some(subset.clone());
    let mut cr = cfg(Method::Nearest, true);
    cr.only_layers = Some(subset);
    let s = quantize(&model, &c, cs, 1);
    let r = quantize(&model, &c, cr, 1);
    assert_eq!(s.weight_overrides.len(), 2);
    assert_identical(&s, &r, "only-layers subset");
}
