//! Integer serving engine vs the f32 fake-quant simulation it mirrors,
//! plus the batched front-end and the quantize -> export -> serve loop.
//! Self-contained (synthetic model + data; no `make artifacts`).

use std::collections::BTreeMap;
use std::time::Duration;

use adaround::coordinator::{
    load_quantized, save_quantized, Method, Pipeline, PipelineConfig, QuantizedModel,
};
use adaround::data::synthetic_stripes;
use adaround::nn::Model;
use adaround::serve::{BatchPolicy, Batcher, ServeEngine};
use adaround::tensor::Tensor;
use adaround::util::{Json, Rng};

/// Tiny conv classifier exercising conv(+relu), residual add, avgpool,
/// gpool and dense — every op class the engine lowers for classifiers.
fn tiny_model(rng: &mut Rng) -> Model {
    let ir = r#"{"task":"cls","ir":[
      {"id":"in","op":"input","inputs":[]},
      {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":8,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
      {"id":"c2","op":"conv","inputs":["c1"],"cin":8,"cout":8,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":false},
      {"id":"a1","op":"add","inputs":["c2","c1"],"relu":true},
      {"id":"p1","op":"avgpool","inputs":["a1"],"k":2,"stride":2},
      {"id":"g1","op":"gpool","inputs":["p1"]},
      {"id":"d1","op":"dense","inputs":["g1"],"cin":8,"cout":2,"relu":false}
    ]}"#;
    let entry = Json::parse(ir).unwrap();
    let mut w = BTreeMap::new();
    let mut tensor = |shape: &[usize], std: f32, rng: &mut Rng| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, std)).collect())
    };
    w.insert("c1.w".into(), tensor(&[8, 3, 3, 3], 0.25, rng));
    w.insert("c1.b".into(), tensor(&[8], 0.05, rng));
    w.insert("c2.w".into(), tensor(&[8, 8, 3, 3], 0.12, rng));
    w.insert("c2.b".into(), tensor(&[8], 0.05, rng));
    w.insert("d1.w".into(), tensor(&[2, 8], 0.4, rng));
    w.insert("d1.b".into(), tensor(&[2], 0.05, rng));
    Model::from_manifest("tinyserve", &entry, w).unwrap()
}

fn quantize_8_8(model: &Model, calib: &Tensor, method: Method) -> QuantizedModel {
    let cfg = PipelineConfig {
        method,
        bits: 8,
        per_channel: true,
        act_bits: Some(8),
        calib_n: calib.shape[0],
        ..Default::default()
    };
    Pipeline::new(model, cfg, None).quantize(calib, &mut Rng::new(7)).unwrap()
}

/// The parity contract, asserted in two self-consistent halves:
/// 1. dequantized int8 logits track the fake-quant logits within a small
///    multiple of the output quantization step (the accumulated
///    requant-rounding tolerance), and
/// 2. argmax agrees on every sample whose fake-quant margin exceeds twice
///    the *observed* worst-case logit error — i.e. quantization noise may
///    only flip genuine near-ties.
fn assert_parity(logits_fq: &Tensor, logits_i8: &Tensor, pred_i8: &[usize], out_step: f32) {
    let mut max_err = 0.0f32;
    for (a, b) in logits_i8.data.iter().zip(&logits_fq.data) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err <= 16.0 * out_step,
        "logit drift {max_err} exceeds requant tolerance ({}x output step {out_step})",
        max_err / out_step
    );
    let pred_fq = logits_fq.argmax_rows();
    let mut clear = 0usize;
    for r in 0..logits_fq.rows() {
        let row = logits_fq.row(r);
        let best = row[pred_fq[r]];
        let second = row
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != pred_fq[r])
            .map(|(_, &v)| v)
            .fold(f32::NEG_INFINITY, f32::max);
        if best - second > 2.0 * max_err {
            clear += 1;
            assert_eq!(
                pred_fq[r], pred_i8[r],
                "argmax flip on sample {r} with margin {} > 2x max err {max_err}",
                best - second
            );
        }
    }
    // the margin filter must not be vacuous
    assert!(
        clear * 4 >= logits_fq.rows(),
        "only {clear}/{} samples had clear fake-quant margins",
        logits_fq.rows()
    );
}

#[test]
fn int8_engine_matches_fake_quant_argmax() {
    let mut rng = Rng::new(21);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(64, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(96, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib, Method::Nearest);
    let mut engine = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();

    let logits_fq = model.forward(&val, &qm.opts());
    let logits_i8 = engine.forward(&val);
    let pred_i8 = engine.classify(&val);
    assert_parity(&logits_fq, &logits_i8, &pred_i8, engine.out_q().scale);
}

#[test]
fn engine_output_identical_across_thread_counts() {
    use adaround::util::parallel::with_threads;
    let mut rng = Rng::new(31);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(48, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib, Method::Nearest);
    let run = |threads: usize| {
        with_threads(threads, || {
            let mut engine = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
            engine.forward_quantized(&val).data
        })
    };
    assert_eq!(run(1), run(4), "integer engine differs across thread counts");
}

#[test]
fn export_then_serve_without_float_model_weights() {
    // the deployment loop: quantize -> save .qtz v2 -> load in a "server"
    // that never sees the original float weights -> identical predictions
    let mut rng = Rng::new(41);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(64, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(64, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib, Method::Nearest);
    let path = std::env::temp_dir().join("serve_roundtrip_v2.qtz");
    save_quantized(&path, &qm).unwrap();

    // v2 bundles carry i8 codes for every quantized layer
    let raw = adaround::io::read_qtz(&path).unwrap();
    for id in ["c1", "c2", "d1"] {
        assert!(raw.contains_key(&format!("i8:{id}")), "no i8 weights for {id}");
        assert!(raw.contains_key(&format!("scale:{id}")), "no scales for {id}");
        assert!(!raw.contains_key(&format!("w:{id}")), "float weights leaked for {id}");
    }

    let served = load_quantized(&path).unwrap();
    let mut e1 = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let mut e2 = ServeEngine::compile(&model, &served, &[3, 16, 16]).unwrap();
    assert_eq!(
        e1.forward_quantized(&val).data,
        e2.forward_quantized(&val).data,
        "serving from the bundle must equal serving from the live pipeline"
    );
    std::fs::remove_file(path).ok();
}

#[test]
fn adaround_weights_serve_too() {
    // the engine is method-agnostic: AdaRound-optimized grids lower the
    // same way nearest ones do (short run, small layer budget)
    let mut rng = Rng::new(51);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(48, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(48, 3, 16, &mut rng);
    let cfg = PipelineConfig {
        method: Method::AdaRound,
        bits: 8,
        per_channel: true,
        act_bits: Some(8),
        calib_n: 48,
        col_budget: 256,
        adaround: adaround::adaround::AdaRoundConfig { iters: 60, ..Default::default() },
        ..Default::default()
    };
    let qm = Pipeline::new(&model, cfg, None).quantize(&calib, &mut Rng::new(3)).unwrap();
    let mut engine = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let logits_fq = model.forward(&val, &qm.opts());
    let logits_i8 = engine.forward(&val);
    let pred_i8 = engine.classify(&val);
    assert_parity(&logits_fq, &logits_i8, &pred_i8, engine.out_q().scale);
}

#[test]
fn portable_kernel_override_serves_bit_identical() {
    use adaround::tensor::int8::kernel::{self, Kernel};
    // the PALLAS_NO_SIMD=1 env override must resolve dispatch to the
    // portable kernel (the full-suite CI job runs every test under it;
    // here we pin the uncached decision so the assertion is
    // order-independent within this test binary). The prior value is
    // RESTORED, not removed — under the PALLAS_NO_SIMD=1 CI job the
    // override must stay in force for the rest of this test binary.
    let prior = std::env::var("PALLAS_NO_SIMD").ok();
    std::env::set_var("PALLAS_NO_SIMD", "1");
    assert_eq!(
        kernel::select_uncached(),
        Kernel::Portable,
        "PALLAS_NO_SIMD=1 must force the portable kernel"
    );
    match prior {
        Some(v) => std::env::set_var("PALLAS_NO_SIMD", v),
        None => std::env::remove_var("PALLAS_NO_SIMD"),
    }

    // ...and serving on the forced portable path must be bit-identical
    // to whatever kernel dispatch picked for this machine
    let mut rng = Rng::new(71);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(48, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib, Method::Nearest);
    let mut dispatched = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let mut portable = ServeEngine::compile(&model, &qm, &[3, 16, 16])
        .unwrap()
        .with_kernel(Kernel::Portable);
    assert_eq!(portable.kernel(), Kernel::Portable);
    assert_eq!(
        dispatched.forward_quantized(&val).data,
        portable.forward_quantized(&val).data,
        "served outputs differ between the {} kernel and the portable override",
        dispatched.kernel().name()
    );
    // forks inherit the pinned kernel (the sharded-batcher path)
    assert_eq!(portable.fork().kernel(), Kernel::Portable);
}

/// 4-bit weights + 8-bit activations: every layer records `wbits = 4`,
/// so the serve compiler must lower nibble-packed (w4) GEMMs.
fn quantize_4_8(model: &Model, calib: &Tensor) -> QuantizedModel {
    let cfg = PipelineConfig {
        method: Method::Nearest,
        bits: 4,
        per_channel: true,
        act_bits: Some(8),
        calib_n: calib.shape[0],
        ..Default::default()
    };
    Pipeline::new(model, cfg, None).quantize(calib, &mut Rng::new(7)).unwrap()
}

/// The same quantized weights with the bit-width record stripped: the
/// serve compiler sees no `wbits` and packs plain i8 (w8) — the
/// reference the w4 path must match bit-for-bit, since the unpacked
/// nibble IS the i8 code.
fn strip_wbits(qm: &QuantizedModel) -> QuantizedModel {
    QuantizedModel {
        weight_overrides: qm.weight_overrides.clone(),
        bias_overrides: qm.bias_overrides.clone(),
        act_quant: qm.act_quant.clone(),
        scales: qm.scales.clone(),
        wbits: BTreeMap::new(),
        stats: Vec::new(),
        layer_execs: 0,
    }
}

#[test]
fn w4_plan_bit_identical_to_w8_and_fake_quant_parity() {
    use adaround::tensor::int8::kernel::Kernel;
    use adaround::util::parallel::with_threads;
    let mut rng = Rng::new(81);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(64, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(96, 3, 16, &mut rng);
    let qm = quantize_4_8(&model, &calib);
    let qm_w8 = strip_wbits(&qm);

    let mut e4 = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let mut e8 = ServeEngine::compile(&model, &qm_w8, &[3, 16, 16]).unwrap();
    // the 4-bit model really lowered to nibble-packed ops, at about half
    // the weight footprint of the w8 lowering of the same codes
    assert!(
        e4.plan.op_dtypes().iter().all(|(_, d)| *d == "w4"),
        "4-bit model must lower every gemm as w4: {:?}",
        e4.plan.op_dtypes()
    );
    assert!(e8.plan.op_dtypes().iter().all(|(_, d)| *d == "w8"));
    let (b4, b8) = (e4.plan.weight_bytes(), e8.plan.weight_bytes());
    assert!(
        b4 * 2 <= b8 + e4.plan.op_dtypes().len(), // +1 byte/op odd-K slack
        "w4 plan ({b4} B) not ~half of w8 ({b8} B)"
    );

    // w4 == w8 bit-for-bit: same codes, same exact-intermediate GEMMs
    let q8 = e8.forward_quantized(&val).data;
    assert_eq!(e4.forward_quantized(&val).data, q8, "w4 plan diverged from w8");
    // ...on every kernel and thread count
    for kern in [Kernel::Portable, Kernel::Avx2] {
        if kern == Kernel::Avx2 && !adaround::tensor::int8::kernel::avx2_available() {
            continue;
        }
        for threads in [1usize, 4] {
            let got = with_threads(threads, || {
                let mut e = ServeEngine::compile(&model, &qm, &[3, 16, 16])
                    .unwrap()
                    .with_kernel(kern);
                e.forward_quantized(&val).data
            });
            assert_eq!(got, q8, "w4 differs on {} kernel, {threads} threads", kern.name());
        }
    }

    // and the integer path still tracks the f32 fake-quant simulation
    let logits_fq = model.forward(&val, &qm.opts());
    let logits_i4 = e4.forward(&val);
    let pred_i4 = e4.classify(&val);
    assert_parity(&logits_fq, &logits_i4, &pred_i4, e4.out_q().scale);
}

#[test]
fn export_v3_nibble_bundle_roundtrip() {
    // quantize 4-bit -> save .qtz v3 (i4 entries) -> serve from the
    // bundle with no float weights: identical integer outputs
    let mut rng = Rng::new(91);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(64, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(48, 3, 16, &mut rng);
    let qm = quantize_4_8(&model, &calib);
    let path = std::env::temp_dir().join("serve_roundtrip_v3.qtz");
    save_quantized(&path, &qm).unwrap();

    let raw = adaround::io::read_qtz(&path).unwrap();
    assert_eq!(raw["__meta.version"].as_i32().unwrap().data, vec![3]);
    for id in ["c1", "c2", "d1"] {
        assert!(raw.contains_key(&format!("i4:{id}")), "no i4 weights for {id}");
        assert!(!raw.contains_key(&format!("i8:{id}")), "i8 leaked for {id}");
        assert!(!raw.contains_key(&format!("w:{id}")), "f32 leaked for {id}");
    }

    let served = load_quantized(&path).unwrap();
    assert!(served.wbits.values().all(|&b| b == 4), "wbits not restored: {:?}", served.wbits);
    let mut e1 = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let mut e2 = ServeEngine::compile(&model, &served, &[3, 16, 16]).unwrap();
    assert!(e2.plan.op_dtypes().iter().all(|(_, d)| *d == "w4"));
    assert_eq!(
        e1.forward_quantized(&val).data,
        e2.forward_quantized(&val).data,
        "serving from the v3 bundle must equal serving from the live pipeline"
    );
    std::fs::remove_file(path).ok();
}

#[test]
fn forced_w4_on_8bit_model_is_output_invariant() {
    // PALLAS_FORCE_W4 semantics (CI's forced-w4 job): layers whose i8
    // codes happen to fit [-8, 7] repack as nibbles, the rest stay w8 —
    // and outputs are bit-identical either way, so the whole 8-bit test
    // suite stays green under the override
    use adaround::serve::PlanOptions;
    let mut rng = Rng::new(93);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(48, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib, Method::Nearest);
    let mut plain = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let mut forced =
        ServeEngine::compile_with(&model, &qm, &[3, 16, 16], PlanOptions { force_w4: true, ..Default::default() })
            .unwrap();
    assert_eq!(
        plain.forward_quantized(&val).data,
        forced.forward_quantized(&val).data,
        "force_w4 changed integer outputs"
    );
}

#[test]
fn v3_bundles_are_at_least_1p9x_smaller_than_v2() {
    // the headline size claim: on a model whose weight payload dominates
    // the per-layer metadata, nibble packing nearly halves the bundle
    let mut rng = Rng::new(97);
    let model = Model::synthetic_chain(8, 32, true, &mut rng);
    let (calib, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let cfg = PipelineConfig {
        method: Method::Nearest,
        bits: 4,
        per_channel: true,
        calib_n: 32,
        ..Default::default()
    };
    let qm = Pipeline::new(&model, cfg, None).quantize(&calib, &mut Rng::new(7)).unwrap();
    let p3 = std::env::temp_dir().join("size_v3.qtz");
    let p2 = std::env::temp_dir().join("size_v2.qtz");
    save_quantized(&p3, &qm).unwrap();
    save_quantized(&p2, &strip_wbits(&qm)).unwrap();
    let s3 = std::fs::metadata(&p3).unwrap().len() as f64;
    let s2 = std::fs::metadata(&p2).unwrap().len() as f64;
    assert!(
        s2 / s3 >= 1.9,
        "v3 bundle only {:.2}x smaller than v2 ({s2} vs {s3} bytes)",
        s2 / s3
    );
    std::fs::remove_file(p3).ok();
    std::fs::remove_file(p2).ok();
}

/// Corrupt-bundle matrix: every malformed `.qtz` a server might be
/// pointed at (truncation anywhere, bad magic, payloads smaller than the
/// declared shape, dtype codes from the future) must surface as a clean
/// `Err` from the loader — never a panic, never a garbage model. This is
/// what makes a failed hot reload safe: the registry counts the error
/// and keeps serving the old generation (`rust/tests/registry_serving.rs`
/// asserts that half).
#[test]
fn corrupt_bundles_fail_cleanly() {
    let mut rng = Rng::new(111);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(32, 3, 16, &mut rng);
    let dir = std::env::temp_dir();
    let check = |name: &str, bytes: &[u8], needle: &str| {
        let p = dir.join(name);
        std::fs::write(&p, bytes).unwrap();
        let err = load_quantized(&p)
            .err()
            .unwrap_or_else(|| panic!("{name}: corrupt bundle loaded successfully"));
        let msg = format!("{err:#}"); // full anyhow chain
        assert!(msg.contains(needle), "{name}: error {msg:?} lacks {needle:?}");
        std::fs::remove_file(&p).ok();
    };

    // a real v2 and a real v3 bundle as corruption substrates
    for (version, qm) in [
        (2, strip_wbits(&quantize_4_8(&model, &calib))),
        (3, quantize_4_8(&model, &calib)),
    ] {
        let p = dir.join(format!("corrupt_src_v{version}.qtz"));
        save_quantized(&p, &qm).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        // truncation at a spread of offsets: inside the header, inside an
        // entry name, inside a shape, inside payloads, one byte short
        for cut in [3, 6, 11, full.len() / 4, full.len() / 2, full.len() - 1] {
            let needle = if cut < 4 { "" } else { "truncated" };
            check(&format!("trunc_v{version}_{cut}.qtz"), &full[..cut], needle);
        }
        // flipped magic on otherwise-valid bytes
        let mut bad = full.clone();
        bad[0] = b'X';
        check(&format!("badmagic_v{version}.qtz"), &bad, "bad magic");
    }

    // hand-crafted single-entry bundles whose payload is smaller than the
    // declared shape demands (i8 wants 10 bytes, i4 wants ceil(9/2)=5)
    let entry = |dtype: u8, dim: u32, payload: &[u8]| -> Vec<u8> {
        let mut raw = Vec::new();
        raw.extend_from_slice(b"QTZ1");
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&1u16.to_le_bytes());
        raw.push(b'x');
        raw.push(dtype);
        raw.push(1); // ndim
        raw.extend_from_slice(&dim.to_le_bytes());
        raw.extend_from_slice(payload);
        raw
    };
    check("undersized_i8.qtz", &entry(3, 10, &[1, 2, 3, 4]), "truncated");
    check("undersized_i4.qtz", &entry(4, 9, &[0xAB, 0xCD]), "truncated");
    check("undersized_f32.qtz", &entry(0, 4, &[0; 7]), "truncated");
    // a dtype code this build has never heard of
    check("future_dtype.qtz", &entry(9, 2, &[0; 8]), "unknown dtype code 9");
    // a shape engineered to overflow the payload-size arithmetic
    let mut huge = Vec::new();
    huge.extend_from_slice(b"QTZ1");
    huge.extend_from_slice(&1u32.to_le_bytes());
    huge.extend_from_slice(&1u16.to_le_bytes());
    huge.push(b'x');
    huge.push(0);
    huge.push(3);
    for _ in 0..3 {
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
    }
    check("overflow_shape.qtz", &huge, "overflow");
}

#[test]
fn batcher_coalesces_and_answers_correctly() {
    let mut rng = Rng::new(61);
    let model = tiny_model(&mut rng);
    let (calib, _) = synthetic_stripes(48, 3, 16, &mut rng);
    let (val, _) = synthetic_stripes(24, 3, 16, &mut rng);
    let qm = quantize_8_8(&model, &calib, Method::Nearest);
    let mut oracle = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let want = oracle.forward(&val);

    let engine = ServeEngine::compile(&model, &qm, &[3, 16, 16]).unwrap();
    let batcher = Batcher::new(
        engine,
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20), ..Default::default() },
    );
    let per: usize = val.shape[1..].iter().product();
    let rxs: Vec<_> = (0..val.shape[0])
        .map(|i| {
            let img = Tensor::from_vec(&[3, 16, 16], val.data[i * per..(i + 1) * per].to_vec());
            batcher.submit(img).expect("batcher alive")
        })
        .collect();
    let classes = want.cols();
    for (i, rx) in rxs.into_iter().enumerate() {
        let row = rx.recv().expect("response");
        assert_eq!(row.len(), classes);
        for (a, b) in row.iter().zip(want.row(i)) {
            assert_eq!(a, b, "request {i} differs from direct batched forward");
        }
    }
    // a malformed request is rejected at submit and doesn't kill the worker
    assert!(batcher.submit(Tensor::zeros(&[3, 8, 8])).is_err());
    let per2: usize = val.shape[1..].iter().product();
    let ok = batcher
        .submit(Tensor::from_vec(&[3, 16, 16], val.data[..per2].to_vec()))
        .expect("batcher still alive");
    assert_eq!(ok.recv().expect("response after bad request").len(), classes);
    batcher.shutdown();
}
