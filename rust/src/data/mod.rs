//! Dataset utilities: slicing/batching of the calibration & validation
//! bundles, plus a self-contained synthetic generator for tests that must
//! run without `make artifacts`.

use crate::tensor::{IntTensor, Tensor};
use crate::util::Rng;

/// Select a subset of images (dim 0) from [N, C, H, W] (+ labels).
pub fn subset(x: &Tensor, y: &IntTensor, idx: &[usize]) -> (Tensor, IntTensor) {
    let per: usize = x.shape[1..].iter().product();
    let mut xs = Vec::with_capacity(idx.len() * per);
    for &i in idx {
        xs.extend_from_slice(&x.data[i * per..(i + 1) * per]);
    }
    let mut shape = x.shape.clone();
    shape[0] = idx.len();
    // labels may be [N] or [N, H, W]
    let yper: usize = y.shape[1..].iter().product::<usize>().max(1);
    let mut ys = Vec::with_capacity(idx.len() * yper);
    for &i in idx {
        ys.extend_from_slice(&y.data[i * yper..(i + 1) * yper]);
    }
    let mut yshape = y.shape.clone();
    yshape[0] = idx.len();
    (Tensor::from_vec(&shape, xs), IntTensor::from_vec(&yshape, ys))
}

/// First-n convenience subset.
pub fn take(x: &Tensor, y: &IntTensor, n: usize) -> (Tensor, IntTensor) {
    let n = n.min(x.shape[0]);
    let idx: Vec<usize> = (0..n).collect();
    subset(x, y, &idx)
}

/// Iterate images in chunks: yields (start, end) ranges.
pub fn chunks(n: usize, chunk: usize) -> impl Iterator<Item = (usize, usize)> {
    (0..n).step_by(chunk.max(1)).map(move |s| (s, (s + chunk).min(n)))
}

/// Tiny self-contained classification dataset for artifact-free tests:
/// two "orientation" classes of vertical vs horizontal stripes + noise.
pub fn synthetic_stripes(n: usize, ch: usize, hw: usize, rng: &mut Rng) -> (Tensor, IntTensor) {
    let mut x = Tensor::zeros(&[n, ch, hw, hw]);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let label = rng.below(2) as i32;
        y.push(label);
        for c in 0..ch {
            for a in 0..hw {
                for b in 0..hw {
                    let stripe = if label == 0 { b } else { a };
                    let v = if stripe % 4 < 2 { 0.8 } else { -0.8 };
                    x.data[((i * ch + c) * hw + a) * hw + b] =
                        v + rng.normal_f32(0.0, 0.35);
                }
            }
        }
    }
    (x, IntTensor::from_vec(&[n], y))
}

/// Synthetic token-id calibration set for the transformer workload:
/// `[n, 1, 1, seq]` f32 ids drawn uniformly from `[0, vocab)` (the 4-D
/// layout keeps the image-chunk slicing in the calibration pipeline
/// working unchanged; the embedding lookup rounds them back to indices).
pub fn synthetic_tokens(n: usize, seq: usize, vocab: usize, rng: &mut Rng) -> Tensor {
    let ids = (0..n * seq).map(|_| rng.below(vocab) as f32).collect();
    Tensor::from_vec(&[n, 1, 1, seq], ids)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_slices_correctly() {
        let x = Tensor::from_vec(&[3, 1, 1, 2], vec![1., 2., 3., 4., 5., 6.]);
        let y = IntTensor::from_vec(&[3], vec![7, 8, 9]);
        let (xs, ys) = subset(&x, &y, &[2, 0]);
        assert_eq!(xs.shape, vec![2, 1, 1, 2]);
        assert_eq!(xs.data, vec![5., 6., 1., 2.]);
        assert_eq!(ys.data, vec![9, 7]);
    }

    #[test]
    fn subset_seg_labels() {
        let x = Tensor::from_vec(&[2, 1, 2, 2], (0..8).map(|v| v as f32).collect());
        let y = IntTensor::from_vec(&[2, 2, 2], vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let (_, ys) = subset(&x, &y, &[1]);
        assert_eq!(ys.shape, vec![1, 2, 2]);
        assert_eq!(ys.data, vec![4, 5, 6, 7]);
    }

    #[test]
    fn chunk_ranges_cover() {
        let ranges: Vec<_> = chunks(10, 4).collect();
        assert_eq!(ranges, vec![(0, 4), (4, 8), (8, 10)]);
    }

    #[test]
    fn tokens_shaped_and_in_vocab() {
        let mut rng = Rng::new(9);
        let x = synthetic_tokens(5, 7, 32, &mut rng);
        assert_eq!(x.shape, vec![5, 1, 1, 7]);
        assert!(x.data.iter().all(|&v| v >= 0.0 && v < 32.0 && v.fract() == 0.0));
        assert!(x.data.iter().any(|&v| v != x.data[0]), "not degenerate");
    }

    #[test]
    fn stripes_balanced_and_shaped() {
        let mut rng = Rng::new(5);
        let (x, y) = synthetic_stripes(40, 3, 8, &mut rng);
        assert_eq!(x.shape, vec![40, 3, 8, 8]);
        let ones = y.data.iter().filter(|&&l| l == 1).count();
        assert!(ones > 5 && ones < 35);
    }
}
