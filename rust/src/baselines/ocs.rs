//! Outlier channel splitting (Zhao et al., 2019), Table 7's "OCS".
//!
//! OCS duplicates the most extreme input channels and halves their
//! weights, shrinking the weight range before the grid is fit. Because the
//! duplicated input channel carries identical activations, the network
//! after splitting is *exactly* equivalent to keeping the original
//! architecture with merged quantized weights 2*Q(w/2) on the split
//! channels — which is how we realize it (no graph surgery needed).

use crate::quant::{fake_quant_nearest, GridMethod, QuantGrid};
use crate::tensor::Tensor;

/// Quantize a GEMM weight [rows, cols] with OCS at the given expand ratio
/// (fraction of input channels split, e.g. 0.05). Returns the effective
/// quantized weights on the ORIGINAL geometry.
pub fn ocs_quantize(w: &Tensor, bits: u32, expand: f64) -> Tensor {
    let (rows, cols) = (w.rows(), w.cols());
    let n_split = ((cols as f64 * expand).ceil() as usize).min(cols);
    // rank input columns by max |w|
    let mut col_max: Vec<(f32, usize)> = (0..cols)
        .map(|c| {
            let m = (0..rows).fold(0.0f32, |m, r| m.max(w.at2(r, c).abs()));
            (m, c)
        })
        .collect();
    col_max.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let split: Vec<usize> = col_max[..n_split].iter().map(|&(_, c)| c).collect();

    // build the split weight matrix (halved outlier columns, duplicated)
    let mut wsplit = Tensor::zeros(&[rows, cols + n_split]);
    for r in 0..rows {
        for c in 0..cols {
            let v = w.at2(r, c);
            let halved = split.contains(&c);
            wsplit.set2(r, c, if halved { v / 2.0 } else { v });
        }
        for (j, &c) in split.iter().enumerate() {
            wsplit.set2(r, cols + j, w.at2(r, c) / 2.0);
        }
    }
    // fit the grid on the split tensor (this is where OCS wins: range shrinks)
    let grid = QuantGrid::fit(&wsplit, bits, GridMethod::MseW, false, None);
    let wq_split = fake_quant_nearest(&wsplit, &grid);
    // merge back: effective weight on original channel = sum of its halves
    let mut out = Tensor::zeros(&[rows, cols]);
    for r in 0..rows {
        for c in 0..cols {
            out.set2(r, c, wq_split.at2(r, c));
        }
        for (j, &c) in split.iter().enumerate() {
            let v = out.at2(r, c) + wq_split.at2(r, cols + j);
            out.set2(r, c, v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn outlier_weights(seed: u64, rows: usize, cols: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut w = Tensor::from_vec(
            &[rows, cols],
            (0..rows * cols).map(|_| rng.normal_f32(0.0, 0.1)).collect(),
        );
        // one giant outlier column dominating the range
        for r in 0..rows {
            w.set2(r, 0, rng.normal_f32(0.0, 2.0));
        }
        w
    }

    #[test]
    fn beats_plain_nearest_with_outliers() {
        let w = outlier_weights(1, 8, 40);
        let grid = QuantGrid::fit(&w, 4, GridMethod::MseW, false, None);
        let plain = fake_quant_nearest(&w, &grid);
        let ocs = ocs_quantize(&w, 4, 0.05);
        assert!(
            w.mse(&ocs) < w.mse(&plain),
            "ocs {} vs plain {}",
            w.mse(&ocs),
            w.mse(&plain)
        );
    }

    #[test]
    fn zero_expand_equals_plain() {
        let w = outlier_weights(2, 4, 16);
        let ocs = ocs_quantize(&w, 4, 0.0);
        // expand 0 still ceil()s to 0 splits? ceil(0)=0 -> identical to plain
        let grid = QuantGrid::fit(&w, 4, GridMethod::MseW, false, None);
        let plain = fake_quant_nearest(&w, &grid);
        assert_eq!(ocs.data, plain.data);
    }
}
