//! Empirical bias correction (paper eq. 26, Table 8).
//!
//! Quantizing weights shifts the expected preactivation:
//! E[Wx] != E[W^ x^]. The correction adds E[Wx] - E[W^ x^] to the layer
//! bias — the optimal *bias-only* fix of the same MSE objective AdaRound
//! optimizes over roundings.

use crate::tensor::{matmul, Tensor};

/// Per-output-row bias delta from calibration samples.
///
/// `w_fp` [rows, cols] with FP32 input sample `x_fp` [cols, N];
/// `w_q` with quantized-prefix input `x_q` (same shapes).
pub fn correct_bias(w_fp: &Tensor, x_fp: &Tensor, w_q: &Tensor, x_q: &Tensor) -> Vec<f32> {
    let y_fp = matmul(w_fp, x_fp);
    let y_q = matmul(w_q, x_q);
    let n = y_fp.cols() as f64;
    (0..y_fp.rows())
        .map(|r| {
            let m_fp: f64 = y_fp.row(r).iter().map(|&v| v as f64).sum::<f64>() / n;
            let m_q: f64 = y_q.row(r).iter().map(|&v| v as f64).sum::<f64>() / n;
            (m_fp - m_q) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn restores_expected_output() {
        let mut rng = Rng::new(1);
        let w = Tensor::from_vec(&[4, 8], (0..32).map(|_| rng.normal_f32(0.0, 0.3)).collect());
        // crude "quantization": add a constant bias-inducing error
        let wq = w.map(|v| v + 0.03);
        let x = Tensor::from_vec(&[8, 100], (0..800).map(|_| rng.normal_f32(0.5, 1.0)).collect());
        let delta = correct_bias(&w, &x, &wq, &x);
        let y_fp = matmul(&w, &x);
        let y_q = matmul(&wq, &x);
        for r in 0..4 {
            let m_fp: f32 = y_fp.row(r).iter().sum::<f32>() / 100.0;
            let m_q: f32 = y_q.row(r).iter().sum::<f32>() / 100.0 + delta[r];
            assert!((m_fp - m_q).abs() < 1e-4, "row {r}: {m_fp} vs {m_q}");
        }
    }

    #[test]
    fn zero_when_no_quantization() {
        let mut rng = Rng::new(2);
        let w = Tensor::from_vec(&[3, 6], (0..18).map(|_| rng.normal_f32(0.0, 0.3)).collect());
        let x = Tensor::from_vec(&[6, 50], (0..300).map(|_| rng.normal_f32(0.0, 1.0)).collect());
        let delta = correct_bias(&w, &x, &w, &x);
        assert!(delta.iter().all(|d| d.abs() < 1e-6));
    }
}
