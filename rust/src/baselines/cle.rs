//! Cross-layer equalization (Nagel et al. 2019, "DFQ").
//!
//! For a conv/dense pair (L1 + ReLU) -> L2, ReLU's positive homogeneity
//! allows rescaling output channel i of L1 by 1/s_i and the matching input
//! channel of L2 by s_i without changing the function. Choosing
//! s_i = sqrt(r1_i / r2_i) equalizes per-channel ranges, which helps
//! per-tensor quantization grids. The paper uses CLE as preprocessing for
//! MobilenetV2 (Table 7 footnote); DFQ (our impl.) = CLE + bias correction.

use std::collections::BTreeMap;

use crate::nn::{Model, Op};
use crate::tensor::Tensor;

/// Find directly-connected (producer, consumer) quantizable pairs where
/// the producer has ReLU and the consumer consumes only it.
fn equalizable_pairs(model: &Model) -> Vec<(String, String)> {
    let mut pairs = Vec::new();
    for nd in &model.nodes {
        let (is_conv_relu, _cout) = match &nd.op {
            Op::Conv { relu, .. } => (*relu, nd.cout),
            _ => (false, 0),
        };
        if !is_conv_relu {
            continue;
        }
        // the producer's output must feed EXACTLY one node (rescaling it
        // would otherwise break residual adds / concats that also read it)
        let consumers: Vec<_> = model
            .nodes
            .iter()
            .filter(|c| c.inputs.iter().any(|i| i == &nd.id))
            .collect();
        if consumers.len() != 1 {
            continue;
        }
        let consumer = consumers[0];
        let ok = match &consumer.op {
            Op::Conv { groups, .. } => {
                consumer.inputs.len() == 1 && (*groups == 1 || *groups == consumer.cin)
            }
            _ => false,
        };
        if ok {
            pairs.push((nd.id.clone(), consumer.id.clone()));
        }
    }
    pairs
}

/// Per-output-channel |max| range of a conv weight [O, C/g, k, k].
fn out_ranges(w: &Tensor) -> Vec<f32> {
    let o = w.shape[0];
    let per = w.numel() / o;
    (0..o)
        .map(|i| w.data[i * per..(i + 1) * per].iter().fold(0.0f32, |m, &v| m.max(v.abs())))
        .collect()
}

/// Per-input-channel |max| range of a conv weight.
fn in_ranges(w: &Tensor, groups: usize) -> Vec<f32> {
    let (o, cg, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    if groups > 1 {
        // depthwise: input channel i feeds filter i
        return out_ranges(w);
    }
    let mut r = vec![0.0f32; cg];
    for oi in 0..o {
        for ci in 0..cg {
            for t in 0..kh * kw {
                let v = w.data[(oi * cg + ci) * kh * kw + t].abs();
                if v > r[ci] {
                    r[ci] = v;
                }
            }
        }
    }
    r
}

/// Apply CLE in place on a copy of the model's weights; returns the
/// equalized weight map (same keys as `model.weights`) and the number of
/// equalized pairs.
pub fn equalize_model(model: &Model) -> (BTreeMap<String, Tensor>, usize) {
    let mut weights = model.weights.clone();
    let pairs = equalizable_pairs(model);
    for (a, b) in &pairs {
        let wa_key = format!("{a}.w");
        let ba_key = format!("{a}.b");
        let wb_key = format!("{b}.w");
        let wa = weights[&wa_key].clone();
        let wb = weights[&wb_key].clone();
        let groups_b = match &model.node(b).unwrap().op {
            Op::Conv { groups, .. } => *groups,
            _ => 1,
        };
        let r1 = out_ranges(&wa);
        let r2 = in_ranges(&wb, groups_b);
        if r1.len() != r2.len() {
            continue; // channel mismatch (shouldn't happen for valid pairs)
        }
        let s: Vec<f32> = r1
            .iter()
            .zip(&r2)
            .map(|(&a, &b)| {
                if a <= 1e-12 || b <= 1e-12 {
                    1.0
                } else {
                    (a / b).sqrt().clamp(1e-2, 1e2)
                }
            })
            .collect();
        // scale producer rows down by s_i
        let mut wa2 = wa.clone();
        let per = wa.numel() / wa.shape[0];
        for i in 0..wa.shape[0] {
            for v in &mut wa2.data[i * per..(i + 1) * per] {
                *v /= s[i];
            }
        }
        let mut ba2 = weights[&ba_key].clone();
        for (i, v) in ba2.data.iter_mut().enumerate() {
            *v /= s[i];
        }
        // scale consumer input channels up by s_i
        let mut wb2 = wb.clone();
        let (o, cg, kh, kw) = (wb.shape[0], wb.shape[1], wb.shape[2], wb.shape[3]);
        if groups_b > 1 {
            for i in 0..o {
                for v in &mut wb2.data[i * cg * kh * kw..(i + 1) * cg * kh * kw] {
                    *v *= s[i];
                }
            }
        } else {
            for oi in 0..o {
                for ci in 0..cg {
                    for t in 0..kh * kw {
                        wb2.data[(oi * cg + ci) * kh * kw + t] *= s[ci];
                    }
                }
            }
        }
        weights.insert(wa_key, wa2);
        weights.insert(ba_key, ba2);
        weights.insert(wb_key, wb2);
    }
    let n = pairs.len();
    (weights, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ForwardOptions;
    use crate::util::Json;
    use crate::util::Rng;

    fn chain_model() -> Model {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":4,
               "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
              {"id":"c2","op":"conv","inputs":["c1"],"cin":4,"cout":2,
               "k":1,"stride":1,"pad":0,"groups":1,"relu":false},
              {"id":"g1","op":"gpool","inputs":["c2"]},
              {"id":"d1","op":"dense","inputs":["g1"],"cin":2,"cout":2,"relu":false}
            ]}"#,
        )
        .unwrap();
        let mut rng = Rng::new(3);
        let mut w = BTreeMap::new();
        // deliberately mis-scaled channels
        let mut w1 = Tensor::from_vec(&[4, 3, 3, 3],
            (0..108).map(|_| rng.normal_f32(0.0, 0.3)).collect());
        for v in &mut w1.data[0..27] {
            *v *= 20.0; // channel 0 is a huge outlier
        }
        w.insert("c1.w".into(), w1);
        w.insert("c1.b".into(), Tensor::zeros(&[4]));
        w.insert("c2.w".into(), Tensor::from_vec(&[2, 4, 1, 1],
            (0..8).map(|_| rng.normal_f32(0.0, 0.3)).collect()));
        w.insert("c2.b".into(), Tensor::zeros(&[2]));
        w.insert("d1.w".into(), Tensor::from_vec(&[2, 2],
            (0..4).map(|_| rng.normal_f32(0.0, 0.3)).collect()));
        w.insert("d1.b".into(), Tensor::zeros(&[2]));
        Model::from_manifest("chain", &j, w).unwrap()
    }

    #[test]
    fn function_preserved() {
        let model = chain_model();
        let (eq, n) = equalize_model(&model);
        assert!(n >= 1, "no pairs equalized");
        let mut rng = Rng::new(4);
        let x = Tensor::from_vec(&[2, 3, 32, 32],
            (0..2 * 3 * 1024).map(|_| rng.normal_f32(0.0, 1.0)).collect());
        let y0 = model.forward(&x, &ForwardOptions::default());
        let eq_model = Model { weights: eq, ..model.clone() };
        let y1 = eq_model.forward(&x, &ForwardOptions::default());
        for (a, b) in y0.data.iter().zip(&y1.data) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn ranges_equalized() {
        let model = chain_model();
        let (eq, _) = equalize_model(&model);
        let before = out_ranges(&model.weights["c1.w"]);
        let after = out_ranges(&eq["c1.w"]);
        let spread = |r: &[f32]| {
            let mx = r.iter().cloned().fold(0.0f32, f32::max);
            let mn = r.iter().cloned().fold(f32::INFINITY, f32::min);
            mx / mn.max(1e-9)
        };
        assert!(spread(&after) < spread(&before), "spread not reduced");
    }
}
