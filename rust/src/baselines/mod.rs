//! Literature-comparison baselines (paper §5.2, Tables 7-9):
//!
//! * [`bias_correction`] — empirical bias correction (Banner et al. 2019 /
//!   Nagel et al. 2019), eq. (26).
//! * [`cle`] — cross-layer equalization (the "CLE" preprocessing from DFQ,
//!   Nagel et al. 2019). DFQ (our impl.) = CLE + bias correction.
//! * [`ocs`] — outlier channel splitting (Zhao et al. 2019), realized as
//!   the exactly-equivalent merged-weight transform.
//! * OMSE (Choukroun et al. 2019) needs no code of its own: it is the
//!   per-channel `GridMethod::MseW` grid with nearest rounding.
//! * [`attention_round`] — Attention Round (Diao et al. 2022), adapted:
//!   softmax-attention rounding probabilities over grid neighbors + a
//!   recon-MSE-scored Bernoulli mask lottery.

pub mod attention_round;
pub mod bias_correction;
pub mod cle;
pub mod ocs;

pub use attention_round::{attention_round, up_probabilities, AttentionRoundConfig};
pub use bias_correction::correct_bias;
pub use cle::equalize_model;
pub use ocs::ocs_quantize;
