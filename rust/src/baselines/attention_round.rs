//! Attention Round (Diao et al. 2022, adapted to this pipeline).
//!
//! The paper frames per-weight rounding as *attention* over the quantized
//! grid: each weight attends to candidate grid points with weights given
//! by a Gaussian function of the distance, and the rounding direction is
//! sampled from the resulting distribution ("lottery"), keeping the best
//! candidate under the task loss.
//!
//! Our adaptation to the layer-wise reconstruction setting (only the
//! abstract is available offline, so this is a faithful-in-spirit
//! reimplementation, not a port): the two reachable grid neighbors of
//! `w/s` get attention logits `-d²/τ` where `d` is the distance to each
//! neighbor (`frac` down, `1 - frac` up) and `τ` is a temperature —
//! i.e. a softmax over negative squared distances, so a weight sitting
//! near a grid point rounds toward it with high probability while
//! half-way weights stay genuinely stochastic. We then draw
//! [`AttentionRoundConfig::samples`] Bernoulli mask candidates from the
//! per-weight up-probabilities, score each (plus the deterministic
//! round-to-nearest mask) on the layer reconstruction MSE of
//! [`crate::adaround::LayerProblem::recon_mse`], and keep the argmin.
//! Including the nearest mask in the lottery guarantees the result is
//! never worse than round-to-nearest on the calibration objective — the
//! invariant the CI transformer smoke asserts.
//!
//! Determinism: all draws come from the per-group [`Rng`] forked by the
//! pipeline, so results are bit-identical across `PALLAS_THREADS` and
//! between the streaming and replay samplers.

use crate::adaround::LayerProblem;
use crate::tensor::Tensor;
use crate::util::Rng;

#[derive(Clone, Copy, Debug)]
pub struct AttentionRoundConfig {
    /// Softmax temperature over the squared grid distances. Small values
    /// approach round-to-nearest; large values approach a uniform coin.
    pub temp: f32,
    /// Number of Bernoulli lottery masks drawn (the nearest mask is
    /// always evaluated in addition).
    pub samples: usize,
}

impl Default for AttentionRoundConfig {
    fn default() -> Self {
        AttentionRoundConfig { temp: 0.2, samples: 32 }
    }
}

/// Outcome of the lottery: the winning mask and its reconstruction MSE.
pub struct AttentionRoundResult {
    pub mask: Tensor,
    pub mse: f64,
    /// true when a sampled mask beat round-to-nearest
    pub beat_nearest: bool,
}

/// Per-weight probability of rounding UP: softmax over the attention
/// logits `-d²/τ` of the two grid neighbors. Clipped weights (past the
/// grid ends) keep their nearest direction deterministically.
pub fn up_probabilities(prob: &LayerProblem, cfg: &AttentionRoundConfig) -> Tensor {
    let cols = prob.cols();
    let mut p = Tensor::zeros(&prob.w.shape);
    let inv_t = 1.0 / cfg.temp.max(1e-6);
    for r in 0..prob.rows() {
        let s = prob.s(r);
        for c in 0..cols {
            let i = r * cols + c;
            let z = prob.w.data[i] / s;
            let frac = z - z.floor();
            // saturated weights: both candidates clamp to the same grid
            // end, so the direction is forced
            if z.floor() < prob.n {
                p.data[i] = 1.0;
                continue;
            }
            if z.floor() + 1.0 > prob.p {
                p.data[i] = 0.0;
                continue;
            }
            let a_up = (-(1.0 - frac) * (1.0 - frac) * inv_t).exp();
            let a_down = (-frac * frac * inv_t).exp();
            p.data[i] = a_up / (a_up + a_down);
        }
    }
    p
}

/// Run the rounding lottery for one layer group: draw `cfg.samples`
/// Bernoulli masks from [`up_probabilities`], score each and the nearest
/// mask on `recon_mse` over (x, t), return the best. `x` should be the
/// quantized-prefix input in asymmetric mode (same convention as the
/// AdaRound optimizer).
pub fn attention_round(
    prob: &LayerProblem,
    x: &Tensor,
    t: &Tensor,
    cfg: &AttentionRoundConfig,
    rng: &mut Rng,
) -> AttentionRoundResult {
    let probs = up_probabilities(prob, cfg);
    let near = prob.nearest_mask();
    let near_mse = prob.recon_mse(&prob.hard_weights(&near), x, t);
    let mut best = AttentionRoundResult { mask: near, mse: near_mse, beat_nearest: false };
    let mut cand = Tensor::zeros(&prob.w.shape);
    for _ in 0..cfg.samples {
        for (m, &pu) in cand.data.iter_mut().zip(&probs.data) {
            *m = rng.bernoulli(pu as f64) as u8 as f32;
        }
        let mse = prob.recon_mse(&prob.hard_weights(&cand), x, t);
        if mse < best.mse {
            best = AttentionRoundResult { mask: cand.clone(), mse, beat_nearest: true };
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantGrid;

    fn problem(seed: u64, rows: usize, cols: usize) -> LayerProblem {
        let mut rng = Rng::new(seed);
        let w = Tensor::from_vec(
            &[rows, cols],
            (0..rows * cols).map(|_| rng.normal_f32(0.0, 0.3)).collect(),
        );
        let grid = QuantGrid::per_tensor(0.05, 4);
        let bias = (0..rows).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        LayerProblem::new(w, &grid, 0, bias, false)
    }

    fn batch(seed: u64, prob: &LayerProblem, n: usize) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let x = Tensor::from_vec(
            &[prob.cols(), n],
            (0..prob.cols() * n).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let mut t = crate::tensor::matmul(&prob.w, &x);
        prob.add_bias(&mut t);
        (x, t)
    }

    #[test]
    fn probabilities_track_grid_distance() {
        let grid = QuantGrid::per_tensor(1.0, 4);
        // 0.1: close to floor -> low p(up); 0.9: close to ceil -> high;
        // 0.5: indifferent -> exactly 1/2
        let w = Tensor::from_vec(&[1, 3], vec![0.1, 0.9, 0.5]);
        let prob = LayerProblem::new(w, &grid, 0, vec![0.0], false);
        let p = up_probabilities(&prob, &AttentionRoundConfig::default());
        assert!(p.data[0] < 0.05, "near-floor weight must round down, p={}", p.data[0]);
        assert!(p.data[1] > 0.95, "near-ceil weight must round up, p={}", p.data[1]);
        assert!((p.data[2] - 0.5).abs() < 1e-6, "half-way weight is a fair coin");
    }

    #[test]
    fn saturated_weights_get_deterministic_direction() {
        let grid = QuantGrid::per_tensor(0.01, 4); // grid spans [-0.08, 0.07]
        let w = Tensor::from_vec(&[1, 2], vec![5.0, -5.0]);
        let prob = LayerProblem::new(w, &grid, 0, vec![0.0], false);
        let p = up_probabilities(&prob, &AttentionRoundConfig::default());
        assert_eq!(p.data[0], 0.0, "above the grid: floor already clamps to p");
        assert_eq!(p.data[1], 1.0, "below the grid: must round up toward n");
    }

    #[test]
    fn never_worse_than_nearest() {
        for seed in 0..5 {
            let prob = problem(seed, 6, 12);
            let (x, t) = batch(seed + 100, &prob, 24);
            let near_mse =
                prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), &x, &t);
            let res = attention_round(
                &prob,
                &x,
                &t,
                &AttentionRoundConfig::default(),
                &mut Rng::new(seed),
            );
            assert!(res.mse <= near_mse, "lottery must include the nearest mask");
        }
    }

    #[test]
    fn lottery_beats_nearest_on_correlated_inputs() {
        // with enough samples on a small layer, some drawn mask should
        // beat nearest on the reconstruction objective (the whole point
        // of adaptive rounding — nearest is optimal per weight, not per
        // layer output)
        let mut won = 0;
        for seed in 0..8 {
            let prob = problem(seed + 50, 4, 16);
            let (x, t) = batch(seed + 200, &prob, 32);
            let cfg = AttentionRoundConfig { temp: 0.4, samples: 128 };
            let res = attention_round(&prob, &x, &t, &cfg, &mut Rng::new(seed));
            won += res.beat_nearest as u32;
        }
        assert!(won >= 4, "lottery beat nearest on only {won}/8 problems");
    }

    #[test]
    fn deterministic_per_seed() {
        let prob = problem(7, 5, 10);
        let (x, t) = batch(77, &prob, 16);
        let cfg = AttentionRoundConfig::default();
        let a = attention_round(&prob, &x, &t, &cfg, &mut Rng::new(3));
        let b = attention_round(&prob, &x, &t, &cfg, &mut Rng::new(3));
        assert_eq!(a.mask.data, b.mask.data);
        assert_eq!(a.mse.to_bits(), b.mse.to_bits());
        let c = attention_round(&prob, &x, &t, &cfg, &mut Rng::new(4));
        let _ = c; // different seed may or may not differ; just must run
    }

    #[test]
    fn masks_are_binary() {
        let prob = problem(11, 3, 9);
        let (x, t) = batch(111, &prob, 12);
        let res = attention_round(
            &prob,
            &x,
            &t,
            &AttentionRoundConfig::default(),
            &mut Rng::new(1),
        );
        assert!(res.mask.data.iter().all(|&m| m == 0.0 || m == 1.0));
    }
}
