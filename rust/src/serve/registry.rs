//! Multi-model registry with zero-downtime hot-swap: the layer that turns
//! one [`Batcher`] into an operable fleet component.
//!
//! A [`ModelRegistry`] maps model-id → [`ModelEntry`], each owning its own
//! batcher (per-model [`BatchPolicy`], per-model queue — a small model is
//! never head-of-line blocked behind a large one) under one shared
//! machine thread budget, divided near-equally across models at build
//! time. The id map is immutable after [`RegistryBuilder::build`], so
//! request routing is a lock-free `BTreeMap` lookup; all mutability lives
//! inside each batcher's generation cell.
//!
//! **Hot reload.** A model registered from a `.qtz` bundle
//! ([`RegistryBuilder::register_qtz`]) remembers its float architecture,
//! input geometry and bundle path. [`ModelRegistry::reload`] — or the
//! watcher thread, when built with [`RegistryBuilder::build_watched`] —
//! re-reads the bundle, compiles the new [`super::QuantizedPlan`] *off
//! the hot path*, and publishes it through [`Batcher::swap_plan`]: in-flight
//! batches finish on the old generation, shards adopt between batches,
//! and the old weights are freed when the last shard moves off them.
//! A reload that fails (truncated bundle, corrupt payload, compile
//! error) leaves the old generation serving untouched and counts in
//! `pallas_model_reloads_total{outcome="failed"}`.
//!
//! **Watcher.** One thread polls each registered bundle's mtime every
//! `interval`. A changed mtime is *debounced*: it must hold still for two
//! consecutive polls before the reload fires, so a writer mid-`save` is
//! never half-read (the `last_file_mtime` + reload-in-progress pattern).
//! A vanished file is ignored (keep serving); the next complete write
//! triggers a fresh reload.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime};

use anyhow::{bail, Context, Result};

use crate::coordinator::load_quantized;
use crate::nn::Model;
use crate::util::parallel;

use super::batch::{BatchPolicy, Batcher, BatcherHandle, PlanStamp};
use super::engine::ServeEngine;
use super::plan::compile_plan;
use super::telemetry::ServeMetrics;

/// Everything a reloadable model needs to rebuild itself from disk: the
/// float architecture (weights in the bundle override it), the per-image
/// input geometry, and where the bundle lives.
struct ReloadSpec {
    model: Model,
    in_shape: Vec<usize>,
    qtz_path: PathBuf,
}

/// Watcher bookkeeping per model: the mtime we last (attempted to)
/// load, and a changed mtime awaiting its stability confirmation poll.
#[derive(Default)]
struct WatchState {
    last_mtime: Option<SystemTime>,
    pending: Option<SystemTime>,
}

/// One registered model: its batcher plus (for `.qtz`-backed models) the
/// reload recipe and watcher state.
pub struct ModelEntry {
    batcher: Batcher,
    reload: Option<ReloadSpec>,
    watch: Mutex<WatchState>,
}

impl ModelEntry {
    pub fn batcher(&self) -> &Batcher {
        &self.batcher
    }

    pub fn handle(&self) -> BatcherHandle {
        self.batcher.handle()
    }

    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        self.batcher.metrics()
    }

    /// Identity of the generation currently published for this model.
    pub fn stamp(&self) -> PlanStamp {
        self.batcher.plan_stamp()
    }

    /// Whether this model can hot-reload (registered from a `.qtz`).
    pub fn reloadable(&self) -> bool {
        self.reload.is_some()
    }

    /// The watched bundle path, if reloadable.
    pub fn qtz_path(&self) -> Option<&Path> {
        self.reload.as_ref().map(|s| s.qtz_path.as_path())
    }

    /// Load + compile + swap, with telemetry. The compile runs on the
    /// caller's thread (the watcher, or a test) — never a shard worker —
    /// so serving latency is untouched while the new generation builds.
    fn reload_now(&self, id: &str) -> Result<u64> {
        let spec = self
            .reload
            .as_ref()
            .with_context(|| format!("model '{id}' was not registered from a .qtz bundle"))?;
        let m = self.metrics();
        let t0 = Instant::now();
        let swapped = (|| -> Result<u64> {
            let qm = load_quantized(&spec.qtz_path)
                .with_context(|| format!("reload '{id}': {}", spec.qtz_path.display()))?;
            let plan = compile_plan(&spec.model, &qm, &spec.in_shape)
                .with_context(|| format!("reload '{id}': compile"))?;
            Ok(self.batcher.swap_plan(plan)?)
        })();
        match &swapped {
            Ok(generation) => {
                m.reloads_ok.inc();
                m.swap_latency.observe(t0.elapsed().as_secs_f64());
                crate::info!(
                    "model '{id}': hot-swapped to generation {generation} in {:.1} ms",
                    t0.elapsed().as_secs_f64() * 1e3
                );
            }
            Err(e) => {
                m.reloads_failed.inc();
                crate::warnlog!("model '{id}': reload failed, serving old generation: {e:#}");
            }
        }
        swapped
    }
}

/// A model waiting for [`RegistryBuilder::build`] to learn the final
/// model count (and therefore its slice of the thread budget).
struct PendingModel {
    engine: ServeEngine,
    policy: BatchPolicy,
    reload: Option<ReloadSpec>,
    boot_mtime: Option<SystemTime>,
}

/// Builder: register models, then [`build`](RegistryBuilder::build) (or
/// [`build_watched`](RegistryBuilder::build_watched)) to spawn the
/// batchers under a shared thread budget. The first registered model is
/// the default (`/v1/infer` routes to it).
#[derive(Default)]
pub struct RegistryBuilder {
    models: Vec<(String, PendingModel)>,
}

/// Model ids appear in URL paths and metric labels: short, non-empty,
/// `[A-Za-z0-9._-]` only.
fn valid_id(id: &str) -> bool {
    !id.is_empty()
        && id.len() <= 64
        && id.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
}

impl RegistryBuilder {
    fn push(&mut self, id: &str, pending: PendingModel) -> Result<()> {
        if !valid_id(id) {
            bail!("invalid model id '{id}': use 1-64 chars from [A-Za-z0-9._-]");
        }
        if self.models.iter().any(|(m, _)| m == id) {
            bail!("duplicate model id '{id}'");
        }
        self.models.push((id.to_string(), pending));
        Ok(())
    }

    /// Register a model from an already-built engine. Not reloadable —
    /// there is no bundle on disk to watch.
    pub fn register(mut self, id: &str, engine: ServeEngine, policy: BatchPolicy) -> Result<Self> {
        self.push(id, PendingModel { engine, policy, reload: None, boot_mtime: None })?;
        Ok(self)
    }

    /// Register a reloadable model: compile the boot generation from the
    /// bundle at `qtz_path` over the float architecture `model`, and
    /// remember the recipe so [`ModelRegistry::reload`] (or the watcher)
    /// can rebuild from the same path later.
    pub fn register_qtz(
        mut self,
        id: &str,
        model: Model,
        qtz_path: impl Into<PathBuf>,
        in_shape: &[usize],
        policy: BatchPolicy,
    ) -> Result<Self> {
        let qtz_path = qtz_path.into();
        let qm = load_quantized(&qtz_path)
            .with_context(|| format!("model '{id}': {}", qtz_path.display()))?;
        let engine = ServeEngine::compile(&model, &qm, in_shape)
            .with_context(|| format!("model '{id}': compile"))?;
        let boot_mtime = std::fs::metadata(&qtz_path).and_then(|m| m.modified()).ok();
        let reload = Some(ReloadSpec { model, in_shape: in_shape.to_vec(), qtz_path });
        self.push(id, PendingModel { engine, policy, reload, boot_mtime })?;
        Ok(self)
    }

    /// Spawn every model's batcher, dividing the machine thread budget
    /// near-equally (remainder to the first-registered models, floor 1
    /// thread each). No watcher — hot reload only via
    /// [`ModelRegistry::reload`].
    pub fn build(self) -> Result<ModelRegistry> {
        self.build_inner(None)
    }

    /// [`build`](RegistryBuilder::build), plus a watcher thread polling
    /// every reloadable model's bundle mtime at `interval`.
    pub fn build_watched(self, interval: Duration) -> Result<ModelRegistry> {
        self.build_inner(Some(interval))
    }

    fn build_inner(self, watch: Option<Duration>) -> Result<ModelRegistry> {
        if self.models.is_empty() {
            bail!("registry needs at least one model");
        }
        let n = self.models.len();
        let total = parallel::num_threads().max(1);
        let default_id = self.models[0].0.clone();
        let mut map = BTreeMap::new();
        // cumulative core-slot offset: each model's pinned shards start
        // where the previous model's stopped, so co-resident batchers
        // land on disjoint cores (mod machine capacity)
        let mut core_offset = 0usize;
        for (i, (id, p)) in self.models.into_iter().enumerate() {
            let budget = parallel::split_budget(total, n, i);
            let batcher = Batcher::with_placement(p.engine, p.policy, budget, core_offset);
            core_offset += budget.max(p.policy.shards);
            let entry = ModelEntry {
                batcher,
                reload: p.reload,
                watch: Mutex::new(WatchState { last_mtime: p.boot_mtime, pending: None }),
            };
            map.insert(id, entry);
        }
        let models = Arc::new(map);
        let stop = Arc::new(AtomicBool::new(false));
        let watcher = match watch {
            Some(interval) if models.values().any(|e| e.reloadable()) => {
                let models = Arc::clone(&models);
                let stop = Arc::clone(&stop);
                Some(
                    std::thread::Builder::new()
                        .name("qtz-watcher".into())
                        .spawn(move || watch_loop(models, interval, stop))
                        .expect("spawn qtz watcher"),
                )
            }
            _ => None,
        };
        Ok(ModelRegistry { models, default_id, stop, watcher })
    }
}

/// The registry: an immutable id → entry map (lock-free routing), an
/// optional bundle watcher, and lifecycle plumbing. See the module docs
/// for the swap protocol.
pub struct ModelRegistry {
    models: Arc<BTreeMap<String, ModelEntry>>,
    default_id: String,
    stop: Arc<AtomicBool>,
    watcher: Option<std::thread::JoinHandle<()>>,
}

impl ModelRegistry {
    pub fn builder() -> RegistryBuilder {
        RegistryBuilder::default()
    }

    /// Wrap one existing batcher as a single-model registry (id
    /// `default`, not reloadable) — the back-compat path behind
    /// [`super::HttpServer::bind`].
    pub fn single(batcher: Batcher) -> ModelRegistry {
        let mut map = BTreeMap::new();
        map.insert(
            DEFAULT_MODEL_ID.to_string(),
            ModelEntry { batcher, reload: None, watch: Mutex::new(WatchState::default()) },
        );
        ModelRegistry {
            models: Arc::new(map),
            default_id: DEFAULT_MODEL_ID.to_string(),
            stop: Arc::new(AtomicBool::new(false)),
            watcher: None,
        }
    }

    /// The model `/v1/infer` aliases (first registered).
    pub fn default_id(&self) -> &str {
        &self.default_id
    }

    pub fn get(&self, id: &str) -> Option<&ModelEntry> {
        self.models.get(id)
    }

    pub fn default_entry(&self) -> &ModelEntry {
        &self.models[&self.default_id]
    }

    /// Registered ids in sorted order.
    pub fn ids(&self) -> impl Iterator<Item = &str> {
        self.models.keys().map(String::as_str)
    }

    pub fn entries(&self) -> impl Iterator<Item = (&str, &ModelEntry)> {
        self.models.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Whether a watcher thread is polling bundle mtimes.
    pub fn watching(&self) -> bool {
        self.watcher.is_some()
    }

    /// Manually trigger a reload of `id` from its bundle path. Returns
    /// the new generation; on error the old generation keeps serving
    /// (and the failure is already counted in the model's metrics).
    pub fn reload(&self, id: &str) -> Result<u64> {
        let entry = self.models.get(id).with_context(|| format!("unknown model '{id}'"))?;
        let generation = entry.reload_now(id)?;
        // remember what we just loaded so the watcher doesn't re-fire
        if let Some(spec) = &entry.reload {
            let mtime = std::fs::metadata(&spec.qtz_path).and_then(|m| m.modified()).ok();
            let mut w = entry.watch.lock().unwrap_or_else(|e| e.into_inner());
            w.last_mtime = mtime;
            w.pending = None;
        }
        Ok(generation)
    }

    /// Flip every model's drain flag: new submits fail with
    /// `ShuttingDown` while in-flight requests complete.
    pub fn begin_drain(&self) {
        for e in self.models.values() {
            e.batcher.begin_drain();
        }
    }

    /// Stop the watcher, then drain and join every model's batcher.
    /// Outstanding [`BatcherHandle`]s must be dropped first — they keep
    /// their model's queue open.
    pub fn shutdown(mut self) {
        self.stop_watcher();
        // dropping the map drains each batcher (Batcher::drop → stop)
    }

    fn stop_watcher(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(w) = self.watcher.take() {
            let _ = w.join();
        }
    }
}

impl Drop for ModelRegistry {
    fn drop(&mut self) {
        self.stop_watcher();
    }
}

/// The id [`ModelRegistry::single`] registers under, and the id the CLI
/// uses when no explicit `--model id=path` is given.
pub const DEFAULT_MODEL_ID: &str = "default";

/// Poll cadence guidance lives in `docs/SERVING.md`; 500 ms is prompt
/// without burning a core on stat calls.
pub const DEFAULT_WATCH_INTERVAL: Duration = Duration::from_millis(500);

/// The watcher: sleep `interval` (in small chunks so shutdown is
/// prompt), then scan every reloadable model's bundle mtime. A change is
/// held `pending` until it repeats on the next poll — the stability
/// debounce that avoids reading a bundle mid-write.
fn watch_loop(
    models: Arc<BTreeMap<String, ModelEntry>>,
    interval: Duration,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::SeqCst) {
        let mut slept = Duration::ZERO;
        while slept < interval && !stop.load(Ordering::SeqCst) {
            let chunk = (interval - slept).min(Duration::from_millis(50));
            std::thread::sleep(chunk);
            slept += chunk;
        }
        if stop.load(Ordering::SeqCst) {
            return;
        }
        for (id, entry) in models.iter() {
            let Some(spec) = &entry.reload else { continue };
            let mtime = std::fs::metadata(&spec.qtz_path).and_then(|m| m.modified()).ok();
            let fire = {
                let mut w = entry.watch.lock().unwrap_or_else(|e| e.into_inner());
                match mtime {
                    // missing / unreadable: keep serving, forget pending
                    None => {
                        w.pending = None;
                        false
                    }
                    Some(m) if Some(m) == w.last_mtime => {
                        w.pending = None;
                        false
                    }
                    Some(m) if w.pending == Some(m) => {
                        // stable across two polls — commit before the
                        // attempt so a failing bundle doesn't hot-loop
                        // (the next *write* re-arms the reload)
                        w.last_mtime = Some(m);
                        w.pending = None;
                        true
                    }
                    Some(m) => {
                        w.pending = Some(m);
                        false
                    }
                }
            };
            if fire {
                let _ = entry.reload_now(id); // outcome already logged + counted
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_validation() {
        for ok in ["a", "resnet-18", "m.v2_final", "X9"] {
            assert!(valid_id(ok), "{ok} should be valid");
        }
        for bad in ["", "a/b", "a b", "ü", "a?b", &"x".repeat(65)] {
            assert!(!valid_id(bad), "{bad:?} should be invalid");
        }
    }

    #[test]
    fn builder_rejects_duplicates_and_empties() {
        assert!(ModelRegistry::builder().build().is_err());
    }
}
