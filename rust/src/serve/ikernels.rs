//! Integer-domain layer kernels: i8 im2col convolution, i8 dense, and the
//! elementwise/pooling data movers, all with fused requant + ReLU +
//! saturate back to u8.
//!
//! No float arithmetic anywhere in this module's run-time paths — real
//! values exist only as (mantissa, shift) fixed-point multipliers encoded
//! at compile time ([`super::plan::Requant`]). Quantize/dequantize at the
//! engine boundary live in [`super::engine`].
//!
//! Parallel structure mirrors the f32 kernels: convs fan out over the
//! FLAT (group x patch-row / group x output-channel) index space so any
//! `groups` value uses every core, the GEMM is row-parallel
//! ([`crate::tensor::int8`]), the requant scatter fans out per image, and
//! the elementwise movers (add / relu / pool / upsample / concat) split
//! their planes across the pool once a batch carries enough elements —
//! deterministic index-based splits throughout
//! ([`crate::util::parallel`]).
//!
//! The conv/dense GEMMs run on the runtime-dispatched packed micro-kernels
//! ([`crate::tensor::int8::kernel`]): weights arrive pre-packed from plan
//! compilation, the [`GemmChoice`] (per-op autotuned by the plan compiler,
//! or a pinned [`crate::tensor::int8::kernel::Kernel`] override) is passed
//! down by the engine, and packed-layout invariants are re-checked by
//! `debug_assert!` here so a layout bug fails loudly instead of
//! corrupting accumulators.

use crate::tensor::conv::out_size;
use crate::tensor::int8::kernel::{
    gemm_conv4_packed_into, gemm_conv_packed_into, gemm_dense4_packed_into,
    gemm_dense_packed_into, GemmChoice,
};
use crate::tensor::{Conv2dParams, U8Tensor};
use crate::util::parallel;

use super::plan::{ConvW, DenseW, Requant};

/// Reusable scratch for the integer conv/dense path (the engine keeps one
/// across layers and requests, making the hot loop allocation-free once
/// shapes have been seen).
#[derive(Default)]
pub struct Int8Workspace {
    /// im2col columns, [groups * cg*k*k, N*Ho*Wo] stacked group-major
    cols: Vec<u8>,
    /// i32 accumulators, [O, N*Ho*Wo] (conv) or [N, O] (dense)
    acc: Vec<i32>,
}

impl Int8Workspace {
    pub fn new() -> Int8Workspace {
        Int8Workspace::default()
    }

    fn ensure_cols(&mut self, len: usize) -> &mut Vec<u8> {
        if self.cols.len() != len {
            self.cols.resize(len, 0);
        }
        &mut self.cols
    }

    fn ensure_acc(&mut self, len: usize) -> &mut Vec<i32> {
        if self.acc.len() != len {
            self.acc.resize(len, 0);
        }
        &mut self.acc
    }
}

/// Saturating requant of one accumulator to u8: `zp_out + round(M·acc)`,
/// clamped to `[lo, 255]` (`lo = zp_out` fuses ReLU: real 0 sits exactly
/// at the zero point).
#[inline]
fn requant_u8(acc: i32, r: Requant, zp_out: i32, lo: i32) -> u8 {
    (zp_out + r.apply(acc)).clamp(lo, 255) as u8
}

/// im2col for u8 activations; padding positions get the input zero point
/// (the integer encoding of real 0). Layout identical to the f32
/// [`crate::tensor::im2col_into`]: [cg*k*k, N*Ho*Wo], columns ordered
/// (n, ho, wo). Parallel over patch rows.
pub fn im2col_u8_into(input: &U8Tensor, group: usize, p: Conv2dParams, zp: u8, out: &mut [u8]) {
    let (n, c) = (input.shape[0], input.shape[1]);
    let (h, w) = (input.shape[2], input.shape[3]);
    let cg = c / p.groups;
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(w, p.k, p.stride, p.pad));
    let npos = n * ho * wo;
    let rows = cg * p.k * p.k;
    assert_eq!(out.len(), rows * npos);
    let grain = ((1 << 16) / npos.max(1)).max(1);
    parallel::par_chunks_mut(out, npos, grain, |r, orow| {
        im2col_u8_row(input, group, p, zp, r, orow);
    });
}

/// Serial extraction of ONE u8 im2col patch row — the per-item unit
/// behind [`im2col_u8_into`] and the group-flat fan-out in [`conv2d_i8`].
/// Same geometry implementation as the f32 path
/// ([`crate::tensor::conv`]'s `im2col_row_any`), with the zero point as
/// the padding value.
fn im2col_u8_row(
    input: &U8Tensor,
    group: usize,
    p: Conv2dParams,
    zp: u8,
    r: usize,
    orow: &mut [u8],
) {
    crate::tensor::conv::im2col_row_any(&input.shape, &input.data, group, p, zp, r, orow);
}

/// Integer conv2d: input [N,C,H,W] u8, packed weights ([`ConvW`]: w8 or
/// nibble-packed w4, `O` rows of the grouped patch `C/g·k·k`) ->
/// [N,O,Ho,Wo] u8. The three passes (im2col, per-group GEMM, requant
/// scatter) follow [`crate::tensor::conv2d_with`]; the GEMM runs the
/// `kern` micro-kernel for the weight precision of the pack.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_i8(
    ws: &mut Int8Workspace,
    kern: impl Into<GemmChoice>,
    input: &U8Tensor,
    w: &ConvW,
    p: Conv2dParams,
    bias_q: &[i32],
    wsum: &[i32],
    requant: &[Requant],
    zp_in: i32,
    zp_out: i32,
    relu: bool,
) -> U8Tensor {
    let kern: GemmChoice = kern.into();
    let (n, c, h, wd) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    let o = w.rows();
    let og = o / p.groups;
    let patch = w.k();
    // packed-layout invariants: a stale or corrupted pack must fail here,
    // in tests, not silently poison the accumulators below
    debug_assert_eq!(patch, (c / p.groups) * p.k * p.k, "packed patch vs input geometry");
    debug_assert!(w.layout_ok(), "PackedConv layout invariants violated");
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(wd, p.k, p.stride, p.pad));
    let npos = n * ho * wo;
    let hw = ho * wo;

    // pass 1: im2col of every group, fanned out over the FLAT patch-row
    // index (group-major: row r belongs to group r/patch), so any groups
    // value saturates the cores
    let cols: &mut Vec<u8> = ws.ensure_cols(p.groups * patch * npos);
    let grain = ((1 << 16) / npos.max(1)).max(1);
    parallel::par_chunks_mut(cols, npos, grain, |r, orow| {
        im2col_u8_row(input, r / patch, p, zp_in as u8, r % patch, orow);
    });

    // pass 2: grouped i8 GEMM over the FLAT output-channel index; a
    // unit's row range is cut at group boundaries so each segment
    // multiplies against its own group's im2col block. Packed rows stay
    // contiguous, so the group/row split slices the pack directly; the
    // micro-kernel overwrites its rows, so no accumulator clear is needed
    let cols_len = p.groups * patch * npos;
    ws.ensure_acc(o * npos);
    // split the borrow: cols is read-only below
    let (cols_ref, acc_ref) = (&ws.cols[..cols_len], &mut ws.acc);
    parallel::par_grouped_rows_mut(
        acc_ref,
        npos,
        og,
        crate::tensor::int8::row_grain(patch, npos),
        |g, rows, seg| {
            let cslice = &cols_ref[g * patch * npos..(g + 1) * patch * npos];
            let m = rows.end - rows.start;
            match w {
                ConvW::W8(pw) => gemm_conv_packed_into(
                    kern,
                    pw.row_slice(rows.clone()),
                    m,
                    patch,
                    pw.kp,
                    cslice,
                    seg,
                    npos,
                ),
                ConvW::W4(pw) => gemm_conv4_packed_into(
                    kern,
                    pw.row_slice(rows.clone()),
                    m,
                    patch,
                    pw.kp,
                    cslice,
                    seg,
                    npos,
                ),
            }
        },
    );

    // pass 3: zero-point correction + bias + requant + relu + saturate,
    // scattered [O, n*ho*wo] -> [n, O, ho, wo]; parallel over images
    let mut out = U8Tensor::zeros(&[n, o, ho, wo]);
    let acc_ref = &ws.acc;
    let lo = if relu { zp_out } else { 0 };
    let grain = ((1 << 16) / (o * hw).max(1)).max(1);
    parallel::par_chunks_mut(&mut out.data, o * hw, grain, |ni, dst| {
        for oc in 0..o {
            let corr = bias_q[oc] - zp_in * wsum[oc];
            let r = requant[oc];
            let src = &acc_ref[oc * npos + ni * hw..oc * npos + (ni + 1) * hw];
            let drow = &mut dst[oc * hw..(oc + 1) * hw];
            for (d, &a) in drow.iter_mut().zip(src) {
                *d = requant_u8(a + corr, r, zp_out, lo);
            }
        }
    });
    out
}

/// Integer dense layer: input [N, C] u8, packed weights ([`DenseW`]: w8
/// or nibble-packed w4, `O` rows of `C`) -> [N, O] u8.
#[allow(clippy::too_many_arguments)]
pub fn dense_i8(
    ws: &mut Int8Workspace,
    kern: impl Into<GemmChoice>,
    input: &U8Tensor,
    w: &DenseW,
    bias_q: &[i32],
    wsum: &[i32],
    requant: &[Requant],
    zp_in: i32,
    zp_out: i32,
    relu: bool,
) -> U8Tensor {
    let kern: GemmChoice = kern.into();
    let (n, c) = (input.shape[0], input.shape[1]);
    let o = w.n();
    assert_eq!(w.k(), c, "dense weight shape mismatch");
    debug_assert!(w.layout_ok(), "PackedDense layout invariants violated");
    let acc: &mut Vec<i32> = ws.ensure_acc(n * o);
    match w {
        DenseW::W8(pw) => gemm_dense_packed_into(kern, &input.data, pw, acc, n),
        DenseW::W4(pw) => gemm_dense4_packed_into(kern, &input.data, pw, acc, n),
    }
    let mut out = U8Tensor::zeros(&[n, o]);
    let lo = if relu { zp_out } else { 0 };
    let acc_ref = &ws.acc;
    let grain = ((1 << 14) / o.max(1)).max(1);
    parallel::par_chunks_mut(&mut out.data, o, grain, |ni, orow| {
        let arow = &acc_ref[ni * o..(ni + 1) * o];
        for (oc, (d, &a)) in orow.iter_mut().zip(arow).enumerate() {
            let corr = bias_q[oc] - zp_in * wsum[oc];
            *d = requant_u8(a + corr, requant[oc], zp_out, lo);
        }
    });
    out
}

/// Minimum elements per unit for the elementwise movers: below this the
/// loop runs serially on the caller (a mover touches each element once,
/// so fine-grained fan-out would be pure dispatch overhead).
const MOVER_GRAIN: usize = 1 << 15;

/// Integer residual add: out = zp_o + Ra·(qa - za) + Rb·(qb - zb).
/// Element-parallel for large batches (chunk = 1 element, grain
/// `MOVER_GRAIN`); each element's math is a fixed serial expression, so
/// outputs are identical for any split.
#[allow(clippy::too_many_arguments)]
pub fn add_i8(
    a: &U8Tensor,
    b: &U8Tensor,
    ra: Requant,
    rb: Requant,
    za: i32,
    zb: i32,
    zp_out: i32,
    relu: bool,
) -> U8Tensor {
    assert_eq!(a.shape, b.shape);
    let mut out = U8Tensor::zeros(&a.shape);
    let lo = if relu { zp_out } else { 0 };
    let (adata, bdata) = (&a.data, &b.data);
    parallel::par_ranges_mut(&mut out.data, 1, MOVER_GRAIN, |range, span| {
        let av = &adata[range.start..range.end];
        let bv = &bdata[range.start..range.end];
        for ((o, &qa), &qb) in span.iter_mut().zip(av).zip(bv) {
            let v = ra.apply(qa as i32 - za) + rb.apply(qb as i32 - zb);
            *o = (zp_out + v).clamp(lo, 255) as u8;
        }
    });
    out
}

/// Standalone ReLU node: rescale to the output grid, clamped at zero.
/// Element-parallel as in [`add_i8`].
pub fn relu_i8(a: &U8Tensor, r: Requant, zp_in: i32, zp_out: i32) -> U8Tensor {
    let mut out = U8Tensor::zeros(&a.shape);
    let adata = &a.data;
    parallel::par_ranges_mut(&mut out.data, 1, MOVER_GRAIN, |range, span| {
        let av = &adata[range.start..range.end];
        for (o, &q) in span.iter_mut().zip(av) {
            *o = requant_u8(q as i32 - zp_in, r, zp_out, zp_out);
        }
    });
    out
}

/// Integer average pool (VALID): the k²-window sum requants by
/// `s_in/(s_out·k²)` in one go — no intermediate division. Parallel over
/// (image, channel) planes for large batches.
pub fn avgpool_i8(
    a: &U8Tensor,
    k: usize,
    stride: usize,
    r: Requant,
    zp_in: i32,
    zp_out: i32,
) -> U8Tensor {
    let (n, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
    let ho = (h - k) / stride + 1;
    let wo = (w - k) / stride + 1;
    let mut out = U8Tensor::zeros(&[n, c, ho, wo]);
    let kk2 = (k * k) as i32;
    let adata = &a.data;
    let grain = (MOVER_GRAIN / (ho * wo * k * k).max(1)).max(1);
    parallel::par_chunks_mut(&mut out.data, ho * wo, grain, |nc, dst| {
        let src = &adata[nc * h * w..(nc + 1) * h * w];
        for oy in 0..ho {
            for ox in 0..wo {
                let mut sum = 0i32;
                for ky in 0..k {
                    for kx in 0..k {
                        sum += src[(oy * stride + ky) * w + ox * stride + kx] as i32;
                    }
                }
                dst[oy * wo + ox] = requant_u8(sum - kk2 * zp_in, r, zp_out, 0);
            }
        }
    });
    out
}

/// Integer global average pool: [N,C,H,W] -> [N,C]; `hw` is baked into
/// the requant multiplier at compile time and re-checked here. Parallel
/// over (image, channel) planes for large batches.
pub fn gpool_i8(a: &U8Tensor, r: Requant, hw: usize, zp_in: i32, zp_out: i32) -> U8Tensor {
    let (n, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
    assert_eq!(h * w, hw, "gpool compiled for {hw} positions, got {h}x{w}");
    let mut out = U8Tensor::zeros(&[n, c]);
    let adata = &a.data;
    let grain = (MOVER_GRAIN / hw.max(1)).max(1);
    parallel::par_chunks_mut(&mut out.data, 1, grain, |nc, dst| {
        let src = &adata[nc * hw..(nc + 1) * hw];
        let sum: i32 = src.iter().map(|&q| q as i32).sum();
        dst[0] = requant_u8(sum - (hw as i32) * zp_in, r, zp_out, 0);
    });
    out
}

/// Nearest-neighbor x2 upsample with rescale to the output grid.
/// Parallel over (image, channel) planes for large batches.
pub fn upsample_i8(a: &U8Tensor, r: Requant, zp_in: i32, zp_out: i32) -> U8Tensor {
    let (n, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
    let mut out = U8Tensor::zeros(&[n, c, 2 * h, 2 * w]);
    let adata = &a.data;
    let grain = (MOVER_GRAIN / (4 * h * w).max(1)).max(1);
    parallel::par_chunks_mut(&mut out.data, 4 * h * w, grain, |nc, dst| {
        let src = &adata[nc * h * w..(nc + 1) * h * w];
        for y in 0..2 * h {
            for x in 0..2 * w {
                let q = src[(y / 2) * w + x / 2] as i32;
                dst[y * 2 * w + x] = requant_u8(q - zp_in, r, zp_out, 0);
            }
        }
    });
    out
}

/// Channel concat with per-input rescale to the shared output grid.
/// Parallel over images for large batches.
pub fn concat_i8(
    inputs: &[&U8Tensor],
    rs: &[Requant],
    zps: &[i32],
    zp_out: i32,
) -> U8Tensor {
    let (n, h, w) = (inputs[0].shape[0], inputs[0].shape[2], inputs[0].shape[3]);
    let ctot: usize = inputs.iter().map(|t| t.shape[1]).sum();
    let mut out = U8Tensor::zeros(&[n, ctot, h, w]);
    let hw = h * w;
    let grain = (MOVER_GRAIN / (ctot * hw).max(1)).max(1);
    parallel::par_chunks_mut(&mut out.data, ctot * hw, grain, |ni, dimg| {
        let mut coff = 0;
        for (ti, t) in inputs.iter().enumerate() {
            let ci = t.shape[1];
            let src = &t.data[ni * ci * hw..(ni + 1) * ci * hw];
            let dst = &mut dimg[coff * hw..(coff + ci) * hw];
            for (d, &q) in dst.iter_mut().zip(src) {
                *d = requant_u8(q as i32 - zps[ti], rs[ti], zp_out, 0);
            }
            coff += ci;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::int8::kernel::{PackedConv, PackedDense};
    use crate::tensor::{conv2d, I8Tensor, Tensor};

    fn identity_requant() -> Requant {
        Requant::from_real(1.0)
    }

    fn pack_conv(w: &I8Tensor) -> ConvW {
        let o = w.shape[0];
        ConvW::W8(PackedConv::pack(&w.data, o, w.numel() / o))
    }

    #[test]
    fn requant_identity_is_exact() {
        let r = identity_requant();
        for acc in [-300i32, -1, 0, 1, 17, 255, 100_000] {
            assert_eq!(r.apply(acc), acc);
        }
    }

    #[test]
    fn im2col_u8_matches_f32_on_symmetric_input() {
        // zp = 0 and values 0..=N: the u8 and f32 im2col layouts must agree
        let p = Conv2dParams { k: 3, stride: 1, pad: 1, groups: 1 };
        let shape = [2usize, 3, 5, 5];
        let n: usize = shape.iter().product();
        let qdata: Vec<u8> = (0..n).map(|i| (i % 200) as u8).collect();
        let qin = U8Tensor::from_vec(&shape, qdata.clone());
        let fin = Tensor::from_vec(&shape, qdata.iter().map(|&v| v as f32).collect());
        let cg_kk = 3 * 9;
        let npos = 2 * 5 * 5;
        let mut got = vec![0u8; cg_kk * npos];
        im2col_u8_into(&qin, 0, p, 0, &mut got);
        let want = crate::tensor::im2col(&fin, 0, p);
        for (g, w) in got.iter().zip(&want.data) {
            assert_eq!(*g as f32, *w);
        }
    }

    #[test]
    fn conv_i8_matches_f32_conv_in_integer_domain() {
        // unit scales everywhere: the integer conv must equal the f32 conv
        // computed on the raw codes (zp_in = 3 exercises the correction)
        let p = Conv2dParams { k: 3, stride: 1, pad: 1, groups: 1 };
        let (n, c, o, hw) = (2usize, 2usize, 3usize, 6usize);
        let mut rng = crate::util::Rng::new(5);
        let zp_in = 3i32;
        let qin = U8Tensor::from_vec(
            &[n, c, hw, hw],
            (0..n * c * hw * hw).map(|_| rng.below(20) as u8).collect(),
        );
        let wi = I8Tensor::from_vec(
            &[o, c, 3, 3],
            (0..o * c * 9).map(|_| (rng.below(7) as i32 - 3) as i8).collect(),
        );
        let bias_q = vec![5i32, -2, 0];
        let patch = c * 9;
        let wsum: Vec<i32> = (0..o)
            .map(|oc| wi.data[oc * patch..(oc + 1) * patch].iter().map(|&z| z as i32).sum())
            .collect();
        let requant = vec![identity_requant(); o];
        let mut ws = Int8Workspace::new();
        let wp = pack_conv(&wi);
        let got = conv2d_i8(
            &mut ws,
            crate::tensor::int8::kernel::select(),
            &qin,
            &wp,
            p,
            &bias_q,
            &wsum,
            &requant,
            zp_in,
            0,
            false,
        );
        // f32 oracle on real values (q - zp) with unit scale
        let fin = Tensor::from_vec(
            &[n, c, hw, hw],
            qin.data.iter().map(|&q| (q as i32 - zp_in) as f32).collect(),
        );
        let fw = Tensor::from_vec(&[o, c, 3, 3], wi.data.iter().map(|&z| z as f32).collect());
        let fb: Vec<f32> = bias_q.iter().map(|&b| b as f32).collect();
        let want = conv2d(&fin, &fw, Some(&fb), p);
        assert_eq!(got.shape, want.shape);
        for (g, w) in got.data.iter().zip(&want.data) {
            let clamped = w.round().clamp(0.0, 255.0);
            assert_eq!(*g as f32, clamped, "int {g} vs f32 {w}");
        }
    }

    #[test]
    fn grouped_conv_i8_flat_fanout_matches_oracle_across_threads() {
        use crate::util::parallel::with_threads;
        // groups=2 with enough positions that the flat row fan-out engages
        // (row ranges cut at the group boundary)
        let p = Conv2dParams { k: 3, stride: 1, pad: 1, groups: 2 };
        let (n, c, o, hw) = (8usize, 8usize, 8usize, 16usize);
        let cg = c / p.groups;
        let mut rng = crate::util::Rng::new(15);
        let zp_in = 2i32;
        let qin = U8Tensor::from_vec(
            &[n, c, hw, hw],
            (0..n * c * hw * hw).map(|_| rng.below(20) as u8).collect(),
        );
        let wi = I8Tensor::from_vec(
            &[o, cg, 3, 3],
            (0..o * cg * 9).map(|_| (rng.below(7) as i32 - 3) as i8).collect(),
        );
        let patch = cg * 9;
        let bias_q = vec![0i32; o];
        let wsum: Vec<i32> = (0..o)
            .map(|oc| wi.data[oc * patch..(oc + 1) * patch].iter().map(|&z| z as i32).sum())
            .collect();
        let requant = vec![identity_requant(); o];
        let wp = pack_conv(&wi);
        let run = |threads: usize| {
            with_threads(threads, || {
                let mut ws = Int8Workspace::new();
                conv2d_i8(
                    &mut ws,
                    crate::tensor::int8::kernel::select(),
                    &qin,
                    &wp,
                    p,
                    &bias_q,
                    &wsum,
                    &requant,
                    zp_in,
                    0,
                    false,
                )
                .data
            })
        };
        let got = run(1);
        assert_eq!(got, run(4), "grouped conv2d_i8 differs across thread counts");
        // f32 oracle on the real codes (q - zp) with unit scales
        let fin = Tensor::from_vec(
            &[n, c, hw, hw],
            qin.data.iter().map(|&q| (q as i32 - zp_in) as f32).collect(),
        );
        let fw = Tensor::from_vec(&[o, cg, 3, 3], wi.data.iter().map(|&z| z as f32).collect());
        let want = conv2d(&fin, &fw, None, p);
        for (g, w) in got.iter().zip(&want.data) {
            assert_eq!(*g as f32, w.round().clamp(0.0, 255.0), "int {g} vs f32 {w}");
        }
    }

    #[test]
    fn dense_i8_matches_oracle() {
        let (n, c, o) = (3usize, 5usize, 4usize);
        let mut rng = crate::util::Rng::new(9);
        let zp_in = 7i32;
        let qin = U8Tensor::from_vec(
            &[n, c],
            (0..n * c).map(|_| rng.below(40) as u8).collect(),
        );
        let wi = I8Tensor::from_vec(
            &[o, c],
            (0..o * c).map(|_| (rng.below(11) as i32 - 5) as i8).collect(),
        );
        let bias_q = vec![1i32, 0, -4, 9];
        let wsum: Vec<i32> = (0..o)
            .map(|oc| wi.data[oc * c..(oc + 1) * c].iter().map(|&z| z as i32).sum())
            .collect();
        let requant = vec![identity_requant(); o];
        let mut ws = Int8Workspace::new();
        let wp = DenseW::W8(PackedDense::pack(&wi.data, o, c));
        let got = dense_i8(
            &mut ws,
            crate::tensor::int8::kernel::select(),
            &qin,
            &wp,
            &bias_q,
            &wsum,
            &requant,
            zp_in,
            0,
            true,
        );
        for ni in 0..n {
            for oc in 0..o {
                let mut acc = bias_q[oc];
                for cc in 0..c {
                    acc += (qin.data[ni * c + cc] as i32 - zp_in) * wi.data[oc * c + cc] as i32;
                }
                // relu with zp_out = 0 clamps at 0
                let want = acc.clamp(0, 255) as u8;
                assert_eq!(got.data[ni * o + oc], want);
            }
        }
    }

    #[test]
    fn pooling_and_movers() {
        let r = identity_requant();
        // gpool: mean of codes (requant multiplier folds the 1/hw — here
        // emulate hw=4 with multiplier 1/4)
        let quarter = Requant::from_real(0.25);
        let a = U8Tensor::from_vec(&[1, 1, 2, 2], vec![4, 8, 12, 16]);
        let g = gpool_i8(&a, quarter, 4, 0, 0);
        assert_eq!(g.shape, vec![1, 1]);
        assert_eq!(g.data, vec![10]);
        // avgpool 2x2 stride 2 on the same data
        let ap = avgpool_i8(&a, 2, 2, quarter, 0, 0);
        assert_eq!(ap.data, vec![10]);
        // upsample doubles spatially, identity scale
        let up = upsample_i8(&a, r, 0, 0);
        assert_eq!(up.shape, vec![1, 1, 4, 4]);
        assert_eq!(up.data[0], 4);
        assert_eq!(up.data[5], 4);
        assert_eq!(up.data[15], 16);
        // add with both zero points 2: (qa-2)+(qb-2)+zo
        let b = U8Tensor::from_vec(&[1, 1, 2, 2], vec![2, 3, 4, 5]);
        let s = add_i8(&a, &b, r, r, 2, 2, 2, false);
        assert_eq!(s.data, vec![4, 9, 14, 19]); // (qa-2) + (qb-2) + 2
        // concat rescales each input to the shared grid
        let cc = concat_i8(&[&a, &b], &[r, r], &[0, 0], 0);
        assert_eq!(cc.shape, vec![1, 2, 2, 2]);
        assert_eq!(&cc.data[..4], &a.data[..]);
        assert_eq!(&cc.data[4..], &b.data[..]);
        // standalone relu clamps below the output zero point
        let rl = relu_i8(&b, r, 4, 0);
        assert_eq!(rl.data, vec![0, 0, 0, 1]);
    }

    #[test]
    fn movers_bit_identical_across_threads() {
        use crate::util::parallel::with_threads;
        let mut rng = crate::util::Rng::new(77);
        // big enough to cross MOVER_GRAIN so the fan-out actually engages
        let shape = [8usize, 16, 24, 24];
        let numel: usize = shape.iter().product();
        let a = U8Tensor::from_vec(&shape, (0..numel).map(|_| rng.below(256) as u8).collect());
        let b = U8Tensor::from_vec(&shape, (0..numel).map(|_| rng.below(256) as u8).collect());
        let r = Requant::from_real(0.37);
        let run = |threads: usize| {
            with_threads(threads, || {
                (
                    add_i8(&a, &b, r, r, 3, 5, 2, true).data,
                    relu_i8(&a, r, 3, 1).data,
                    avgpool_i8(&a, 2, 2, r, 3, 0).data,
                    gpool_i8(&a, r, 24 * 24, 3, 0).data,
                    upsample_i8(&a, r, 3, 0).data,
                    concat_i8(&[&a, &b], &[r, r], &[3, 5], 0).data,
                )
            })
        };
        assert_eq!(run(1), run(4));
    }
}
