//! Compile step: lower a [`Model`] + [`QuantizedModel`] into a
//! [`QuantizedPlan`] of integer-domain layers.
//!
//! All float arithmetic lives here, at compile time: scale recovery,
//! zero-point nudging, bias folding and fixed-point multiplier encoding.
//! The runtime loop ([`super::engine`]) sees only i8/u8/i32 tensors and
//! the [`Requant`] (mantissa, shift) pairs produced here.
//!
//! Quantization convention (asymmetric activations, symmetric per-channel
//! weights — the deployment scheme of Nagel et al., 2020 §2 and the
//! standard gemmlowp pipeline):
//!
//! ```text
//! activation:  real = s_a * (q - zp),  q in [0, 255]
//! weight:      real = s_w[oc] * z,     z in [-128, 127]
//! conv/dense:  acc = Σ z·q  (i32);  real_y = s_w·s_a·(acc - zp·Σz) + bias
//! requantize:  q_out = zp_out + round(M · corrected),  M = s_w·s_a/s_out
//! ```
//!
//! with `M` encoded as an i32 mantissa and a right shift, applied in i64.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::QuantizedModel;
use crate::nn::{Model, Op};
use crate::quant::ActQuant;
use crate::tensor::int8::fits_i4;
use crate::tensor::int8::kernel::{
    autotune, GemmChoice, PackedConv, PackedConv4, PackedDense, PackedDense4,
};
use crate::tensor::{Conv2dParams, I8Tensor, Tensor};

/// Fixed-point multiplier: `real ≈ m / 2^shift`, `m` in `[0, 2^31)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Requant {
    pub m: i32,
    pub shift: u32,
}

impl Requant {
    /// Encode a positive real multiplier. Mantissa is normalized into
    /// `[2^30, 2^31)` for ~9 significant decimal digits; shift is clamped
    /// so the i64 rounding term below never overflows.
    pub fn from_real(x: f64) -> Requant {
        assert!(x > 0.0 && x.is_finite(), "requant multiplier must be positive: {x}");
        // normalize: x = y * 2^e with y in [0.5, 1)
        let mut y = x;
        let mut e = 0i32;
        while y >= 1.0 {
            y /= 2.0;
            e += 1;
        }
        while y < 0.5 {
            y *= 2.0;
            e -= 1;
        }
        let mut m = (y * (1u64 << 31) as f64).round() as i64;
        let mut shift = 31 - e; // x = m / 2^shift
        if m == 1i64 << 31 {
            m >>= 1;
            shift -= 1;
        }
        // shift must land in [1, 62] for the i64 rounding term; tiny
        // multipliers trade mantissa bits, huge ones can't arise from
        // sane scale ratios
        while shift > 62 {
            m >>= 1;
            shift -= 1;
        }
        assert!(shift >= 1, "multiplier {x} too large to encode");
        Requant { m: m as i32, shift: shift as u32 }
    }

    /// `round(acc * m / 2^shift)` in i64, round-half-up.
    #[inline]
    pub fn apply(&self, acc: i32) -> i32 {
        let prod = acc as i64 * self.m as i64 + (1i64 << (self.shift - 1));
        (prod >> self.shift) as i32
    }
}

/// (scale, zero_point) of a u8 activation tensor, nudged so the
/// zero-point is an exact integer (real 0 is exactly representable).
#[derive(Clone, Copy, Debug)]
pub struct ActQ {
    pub scale: f32,
    pub zp: i32,
}

impl ActQ {
    pub fn from_act_quant(q: &ActQuant) -> Result<ActQ> {
        if q.bits != 8 {
            bail!("integer serving needs 8-bit activation quantizers (got {} bits)", q.bits);
        }
        let scale = q.scale();
        if !(scale > 0.0 && scale.is_finite()) {
            bail!("degenerate activation scale {scale}");
        }
        let zp = (-q.min / scale).round();
        // zp > 255 means the calibrated range lies entirely below zero
        // (max < 0) — a u8 grid anchored at that zero point cannot
        // represent the layer; refuse at compile time rather than serve
        // silently-wrong values
        if !(0.0..=255.0).contains(&zp) {
            bail!(
                "activation range [{}, {}] puts the zero point at {zp}, outside u8",
                q.min,
                q.max
            );
        }
        Ok(ActQ { scale, zp: zp as i32 })
    }

    /// f32 -> u8 (boundary op, not part of the integer loop).
    #[inline]
    pub fn quantize(&self, x: f32) -> u8 {
        ((x / self.scale).round() as i32 + self.zp).clamp(0, 255) as u8
    }

    /// u8 -> f32 (boundary op).
    #[inline]
    pub fn dequantize(&self, q: u8) -> f32 {
        self.scale * (q as i32 - self.zp) as f32
    }
}

/// Conv weights packed at either serving precision. The w4 variant holds
/// the same codes at half the bytes (two's-complement nibbles) and its
/// GEMM is bit-identical to w8 over the same codes, so the choice is a
/// pure bandwidth/footprint knob.
pub enum ConvW {
    W8(PackedConv),
    W4(PackedConv4),
}

impl ConvW {
    pub fn rows(&self) -> usize {
        match self {
            ConvW::W8(p) => p.rows,
            ConvW::W4(p) => p.rows,
        }
    }

    pub fn k(&self) -> usize {
        match self {
            ConvW::W8(p) => p.k,
            ConvW::W4(p) => p.k,
        }
    }

    /// Packed payload size in bytes — the weight-bandwidth metric
    /// `serve-bench` reports per plan.
    pub fn weight_bytes(&self) -> usize {
        match self {
            ConvW::W8(p) => p.data.len(),
            ConvW::W4(p) => p.data.len(),
        }
    }

    /// Stable label for benches and `serve-bench` output.
    pub fn dtype(&self) -> &'static str {
        match self {
            ConvW::W8(_) => "w8",
            ConvW::W4(_) => "w4",
        }
    }

    pub fn layout_ok(&self) -> bool {
        match self {
            ConvW::W8(p) => p.layout_ok(),
            ConvW::W4(p) => p.layout_ok(),
        }
    }
}

/// Dense weights packed at either serving precision (see [`ConvW`]).
pub enum DenseW {
    W8(PackedDense),
    W4(PackedDense4),
}

impl DenseW {
    pub fn n(&self) -> usize {
        match self {
            DenseW::W8(p) => p.n,
            DenseW::W4(p) => p.n,
        }
    }

    pub fn k(&self) -> usize {
        match self {
            DenseW::W8(p) => p.k,
            DenseW::W4(p) => p.k,
        }
    }

    /// Packed payload size in bytes.
    pub fn weight_bytes(&self) -> usize {
        match self {
            DenseW::W8(p) => p.data.len(),
            DenseW::W4(p) => p.data.len(),
        }
    }

    /// Stable label for benches and `serve-bench` output.
    pub fn dtype(&self) -> &'static str {
        match self {
            DenseW::W8(_) => "w8",
            DenseW::W4(_) => "w4",
        }
    }

    pub fn layout_ok(&self) -> bool {
        match self {
            DenseW::W8(p) => p.layout_ok(),
            DenseW::W4(p) => p.layout_ok(),
        }
    }
}

/// One integer layer. Weight-bearing variants carry everything the kernel
/// needs precomputed — including the weights already packed into the
/// micro-kernel layout ([`crate::tensor::int8::kernel`]), so the serving
/// hot loop does zero repacking; data-movement variants carry per-input
/// requant pairs.
pub enum PlanOp {
    /// f32 input -> u8 (the only op touching floats at run time).
    Quantize,
    Conv {
        /// weights in the packed conv-GEMM layout (w8 or nibble-packed
        /// w4): `cout` rows of the grouped patch (`cin/g·k·k`), K-padded
        w: ConvW,
        p: Conv2dParams,
        /// bias folded to the accumulator domain, per output channel
        bias_q: Vec<i32>,
        /// Σ_k w[oc,k] — the zero-point correction term, per channel
        wsum: Vec<i32>,
        /// s_w[oc]·s_in/s_out, per output channel
        requant: Vec<Requant>,
        relu: bool,
        /// GEMM variant autotuned for this layer's packed shape at compile
        /// time (or the pinned heuristic under `PALLAS_AUTOTUNE=0`) — the
        /// hot loop reads it with zero dispatch logic
        choice: GemmChoice,
    },
    Dense {
        /// weights `[cout, cin]` in the packed quad-interleaved layout
        /// (w8 or nibble-packed w4)
        w: DenseW,
        bias_q: Vec<i32>,
        wsum: Vec<i32>,
        requant: Vec<Requant>,
        relu: bool,
        /// autotuned GEMM variant for this layer's packed shape (see
        /// `PlanOp::Conv::choice`)
        choice: GemmChoice,
    },
    /// out = zp_o + Ra·(qa - za) + Rb·(qb - zb)
    Add { ra: Requant, rb: Requant, relu: bool },
    /// out = max(zp_o + R·(q - z_in), zp_o-if-relu); standalone relu nodes
    Relu { r: Requant },
    /// out = zp_o + R·(sum_{k·k} q - k²·z_in), R = s_in/(s_out·k²)
    AvgPool { k: usize, stride: usize, r: Requant },
    /// global pool: R = s_in/(s_out·H·W), computed per input shape at run
    /// time is impossible without floats — so the spatial size is fixed at
    /// compile time from the model geometry
    GPool { r: Requant, hw: usize },
    Upsample { r: Requant },
    Concat { rs: Vec<Requant> },
}

pub struct PlanNode {
    pub id: String,
    pub op: PlanOp,
    /// indices into `QuantizedPlan::nodes`
    pub inputs: Vec<usize>,
    /// quantization of each input tensor
    pub in_q: Vec<ActQ>,
    /// quantization of this node's output
    pub out_q: ActQ,
}

/// A compiled integer inference program: nodes in topological order, u8
/// tensors flowing between them.
pub struct QuantizedPlan {
    pub nodes: Vec<PlanNode>,
    /// input image geometry [C, H, W] the plan was compiled for
    pub in_shape: Vec<usize>,
    /// wall time the per-op kernel autotuner spent during compilation
    /// (0.0 when `PALLAS_AUTOTUNE=0` pinned the heuristic choice) —
    /// reported by `serve-bench` as the `plan autotune` entry
    pub autotune_ms: f64,
}

impl QuantizedPlan {
    /// Total packed weight bytes across conv/dense ops — the bandwidth
    /// and model-footprint metric the w4 path halves.
    pub fn weight_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match &n.op {
                PlanOp::Conv { w, .. } => w.weight_bytes(),
                PlanOp::Dense { w, .. } => w.weight_bytes(),
                _ => 0,
            })
            .sum()
    }

    /// Stable identity of the compiled program: FNV-1a over the input
    /// geometry, node ids, weight dtypes and every packed weight byte.
    /// Two plans agree iff they run the same integer program, so this is
    /// the "which model generation is live" answer `/healthz` reports.
    /// O(weight bytes) — compute once and cache, not per request.
    pub fn plan_id(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        fn eat(mut h: u64, bytes: &[u8]) -> u64 {
            for &b in bytes {
                h = (h ^ b as u64).wrapping_mul(PRIME);
            }
            h
        }
        fn eat_i8(mut h: u64, data: &[i8]) -> u64 {
            for &b in data {
                h = (h ^ b as u8 as u64).wrapping_mul(PRIME);
            }
            h
        }
        let mut h = OFFSET;
        for &d in &self.in_shape {
            h = eat(h, &(d as u64).to_le_bytes());
        }
        for n in &self.nodes {
            h = eat(h, n.id.as_bytes());
            match &n.op {
                PlanOp::Conv { w, .. } => {
                    h = eat(h, w.dtype().as_bytes());
                    h = match w {
                        ConvW::W8(p) => eat_i8(h, &p.data),
                        ConvW::W4(p) => eat_i8(h, &p.data),
                    };
                }
                PlanOp::Dense { w, .. } => {
                    h = eat(h, w.dtype().as_bytes());
                    h = match w {
                        DenseW::W8(p) => eat_i8(h, &p.data),
                        DenseW::W4(p) => eat_i8(h, &p.data),
                    };
                }
                _ => {}
            }
        }
        h
    }

    /// `(node id, "w8" | "w4")` for every weight-bearing op, in plan
    /// order — recorded by `serve-bench` alongside the latency entries.
    pub fn op_dtypes(&self) -> Vec<(String, &'static str)> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                PlanOp::Conv { w, .. } => Some((n.id.clone(), w.dtype())),
                PlanOp::Dense { w, .. } => Some((n.id.clone(), w.dtype())),
                _ => None,
            })
            .collect()
    }

    /// `(node id, autotuned GEMM choice)` for every weight-bearing op, in
    /// plan order — surfaced by `serve-bench` and the `/metrics`
    /// `pallas_plan_kernel` gauge. Deliberately excluded from
    /// [`QuantizedPlan::plan_id`]: all choices are bit-identical, so two
    /// plans that differ only in tuning outcomes run the same integer
    /// program.
    pub fn op_choices(&self) -> Vec<(String, GemmChoice)> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                PlanOp::Conv { choice, .. } => Some((n.id.clone(), *choice)),
                PlanOp::Dense { choice, .. } => Some((n.id.clone(), *choice)),
                _ => None,
            })
            .collect()
    }
}

/// Compile-time knobs for [`compile_plan_with`].
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    /// Pack w4 wherever the codes happen to fit `[-8, 7]`, even without
    /// a recorded ≤4-bit width (the `PALLAS_FORCE_W4` CI knob). Layers
    /// whose codes don't fit keep w8, so numerics never change — this
    /// exercises the w4 kernels under the full 8-bit test suite.
    pub force_w4: bool,
    /// Autotune the GEMM variant per op on its actual packed shape
    /// (default). When off (`PALLAS_AUTOTUNE=0`), every op pins the
    /// process-wide heuristic [`GemmChoice::heuristic`] — the pre-tuning
    /// behavior. Results are bit-identical either way; this is a
    /// compile-latency / reproducible-benchmark knob.
    pub autotune: bool,
}

// Manual impl: `derive(Default)` would default `autotune` to false, but
// tuning is opt-out.
impl Default for PlanOptions {
    fn default() -> PlanOptions {
        PlanOptions { force_w4: false, autotune: true }
    }
}

impl PlanOptions {
    /// Options implied by the environment (`PALLAS_FORCE_W4`,
    /// `PALLAS_AUTOTUNE`).
    pub fn from_env() -> PlanOptions {
        PlanOptions {
            force_w4: force_w4_requested(std::env::var("PALLAS_FORCE_W4").ok().as_deref()),
            autotune: autotune_enabled(std::env::var("PALLAS_AUTOTUNE").ok().as_deref()),
        }
    }
}

/// `PALLAS_FORCE_W4` contract: same parsing as `PALLAS_NO_SIMD` — any
/// non-empty value other than `0` requests opportunistic w4 packing.
pub fn force_w4_requested(v: Option<&str>) -> bool {
    matches!(v.map(str::trim), Some(s) if !s.is_empty() && s != "0")
}

/// `PALLAS_AUTOTUNE` contract: tuning is **on by default** and only the
/// exact value `0` turns it off (inverted polarity from the other knobs
/// because those default to off; `PALLAS_AUTOTUNE=1`, unset, or anything
/// else keeps tuning on).
pub fn autotune_enabled(v: Option<&str>) -> bool {
    !matches!(v.map(str::trim), Some("0"))
}

/// Compile-time autotune state threaded through [`lower_node`]: memoizes
/// winners by packed shape so repeated layers (residual towers) tune
/// once, and accumulates the tuner's wall time for the bench report.
struct Tuner {
    enabled: bool,
    /// key: (is_dense, w4, rows, k, positions)
    cache: BTreeMap<(bool, bool, usize, usize, usize), GemmChoice>,
    ms: f64,
}

impl Tuner {
    fn new(enabled: bool) -> Tuner {
        Tuner { enabled, cache: BTreeMap::new(), ms: 0.0 }
    }

    fn tune(&mut self, dense: bool, w4: bool, rows: usize, k: usize, npos: usize) -> GemmChoice {
        if !self.enabled {
            return GemmChoice::heuristic();
        }
        if let Some(&ch) = self.cache.get(&(dense, w4, rows, k, npos)) {
            return ch;
        }
        let t0 = std::time::Instant::now();
        let ch = if dense {
            autotune::tune_dense(rows, k, w4)
        } else {
            autotune::tune_conv(rows, k, npos, w4)
        };
        self.ms += t0.elapsed().as_secs_f64() * 1e3;
        self.cache.insert((dense, w4, rows, k, npos), ch);
        ch
    }
}

/// Recover the grid scale of one weight row whose entries lie on
/// `{s·z : z integer}`: the smallest nonzero magnitude is `s·z_min`, so
/// try `s = min/t` for t = 1, 2, ... until every entry lands on an
/// integer multiple within tolerance. Returns 1.0 for an all-zero row.
pub fn recover_row_scale(row: &[f32]) -> f32 {
    let mut min_abs = f32::INFINITY;
    for &v in row {
        if v != 0.0 && v.abs() < min_abs {
            min_abs = v.abs();
        }
    }
    if !min_abs.is_finite() {
        return 1.0;
    }
    'cand: for t in 1..=128u32 {
        let s = min_abs / t as f32;
        for &v in row {
            let z = v / s;
            // same acceptance range as weight_to_i8, so a recovered scale
            // is always encodable and out-of-range rows reach the
            // min-max fallback below instead of failing later
            if (z - z.round()).abs() > 1e-3 || !(-128.0..=127.0).contains(&z.round()) {
                continue 'cand;
            }
        }
        return s;
    }
    // no consistent grid found (shouldn't happen for quantized weights);
    // fall back to an 8-bit min-max scale
    row.iter().fold(0.0f32, |m, &v| m.max(v.abs())) / 127.0
}

/// Quantize one weight matrix [cout, cols] to i8 with per-channel scales.
/// `scales` (if given, from the pipeline) wins; otherwise scales are
/// recovered from the grid, and as a last resort fit per-row min-max
/// (covers float layers that were never quantized).
fn weight_to_i8(w: &Tensor, cout: usize, scales: Option<&[f32]>) -> Result<(I8Tensor, Vec<f32>)> {
    let cols = w.numel() / cout;
    let mut data = vec![0i8; w.numel()];
    let mut out_scales = Vec::with_capacity(cout);
    for oc in 0..cout {
        let row = &w.data[oc * cols..(oc + 1) * cols];
        let s = match scales {
            Some(sc) => {
                if sc.len() == 1 {
                    sc[0]
                } else {
                    *sc.get(oc).ok_or_else(|| anyhow!("scale vector too short"))?
                }
            }
            None => recover_row_scale(row),
        };
        if !(s > 0.0 && s.is_finite()) {
            bail!("bad weight scale {s} for channel {oc}");
        }
        for (d, &v) in data[oc * cols..(oc + 1) * cols].iter_mut().zip(row) {
            let z = (v / s).round();
            if !(-128.0..=127.0).contains(&z) {
                bail!("weight {v} at channel {oc} exceeds i8 grid (z = {z}, scale {s})");
            }
            *d = z as i8;
        }
        out_scales.push(s);
    }
    Ok((I8Tensor::from_vec(&w.shape, data), out_scales))
}

/// Compile a quantized model into an integer plan. Needs activation
/// quantizers for every node (run the pipeline with `--act-bits 8`) and
/// the input image geometry (e.g. `[3, 32, 32]`). Honors the
/// `PALLAS_FORCE_W4` env knob; use [`compile_plan_with`] to pass
/// explicit [`PlanOptions`].
pub fn compile_plan(
    model: &Model,
    qm: &QuantizedModel,
    in_shape: &[usize],
) -> Result<QuantizedPlan> {
    compile_plan_with(model, qm, in_shape, PlanOptions::from_env())
}

/// [`compile_plan`] with explicit compile-time options.
pub fn compile_plan_with(
    model: &Model,
    qm: &QuantizedModel,
    in_shape: &[usize],
    opts: PlanOptions,
) -> Result<QuantizedPlan> {
    let aq = qm
        .act_quant
        .as_ref()
        .ok_or_else(|| anyhow!("integer serving needs activation quantizers (--act-bits 8)"))?;
    assert_eq!(in_shape.len(), 3, "in_shape must be [C, H, W]");
    let mut idx: BTreeMap<&str, usize> = BTreeMap::new();
    let mut nodes: Vec<PlanNode> = Vec::with_capacity(model.nodes.len());
    // spatial size of every node's output (for GPool's fixed reduction)
    let mut spatial: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
    let mut tuner = Tuner::new(opts.autotune);
    for nd in &model.nodes {
        let out_q = ActQ::from_act_quant(
            aq.get(&nd.id)
                .ok_or_else(|| anyhow!("no activation quantizer for node {}", nd.id))?,
        )?;
        let inputs: Vec<usize> = nd
            .inputs
            .iter()
            .map(|i| {
                idx.get(i.as_str())
                    .copied()
                    .ok_or_else(|| anyhow!("node {} input {} not compiled", nd.id, i))
            })
            .collect::<Result<_>>()?;
        let in_q: Vec<ActQ> = inputs.iter().map(|&i| nodes[i].out_q).collect();
        let in_hw = nd
            .inputs
            .first()
            .and_then(|i| spatial.get(i.as_str()).copied())
            .unwrap_or((in_shape[1], in_shape[2]));
        let (op, out_hw) = lower_node(model, qm, nd, &in_q, out_q, in_hw, opts, &mut tuner)?;
        spatial.insert(nd.id.as_str(), out_hw);
        idx.insert(nd.id.as_str(), nodes.len());
        nodes.push(PlanNode { id: nd.id.clone(), op, inputs, in_q, out_q });
    }
    Ok(QuantizedPlan { nodes, in_shape: in_shape.to_vec(), autotune_ms: tuner.ms })
}

/// Decide the packed precision for one layer. The pipeline's recorded
/// bit width wins: a layer quantized at ≤4 bits packs w4 (its codes fit
/// `[-8, 7]` by construction, so a miss means a corrupt bundle and is an
/// error, not a silent fallback). Without a recorded width, `force_w4`
/// packs w4 opportunistically wherever the codes happen to fit and keeps
/// w8 otherwise — numerics are unchanged either way.
fn choose_w4(qm: &QuantizedModel, id: &str, codes: &[i8], force_w4: bool) -> Result<bool> {
    let fits = fits_i4(codes);
    if let Some(&b) = qm.wbits.get(id) {
        if b <= 4 {
            if !fits {
                bail!("layer {id}: recorded {b}-bit weights, but codes exceed [-8, 7]");
            }
            return Ok(true);
        }
    }
    Ok(force_w4 && fits)
}

#[allow(clippy::too_many_arguments)]
fn lower_node(
    model: &Model,
    qm: &QuantizedModel,
    nd: &crate::nn::Node,
    in_q: &[ActQ],
    out_q: ActQ,
    in_hw: (usize, usize),
    opts: PlanOptions,
    tuner: &mut Tuner,
) -> Result<(PlanOp, (usize, usize))> {
    use crate::tensor::conv::out_size;
    let op = match &nd.op {
        Op::Input => return Ok((PlanOp::Quantize, in_hw)),
        Op::Conv { k, stride, pad, groups, relu } => {
            let (wi, bias_q, wsum, requant) = lower_weights(model, qm, nd, in_q[0], out_q)?;
            let p = Conv2dParams { k: *k, stride: *stride, pad: *pad, groups: *groups };
            let ho = out_size(in_hw.0, *k, *stride, *pad);
            let wo = out_size(in_hw.1, *k, *stride, *pad);
            // pack once, at compile time: the batcher's hot loop feeds the
            // micro-kernel straight from this buffer
            let cout = wi.shape[0];
            let cols = wi.numel() / cout;
            let w = if choose_w4(qm, &nd.id, &wi.data, opts.force_w4)? {
                ConvW::W4(PackedConv4::pack(&wi.data, cout, cols))
            } else {
                ConvW::W8(PackedConv::pack(&wi.data, cout, cols))
            };
            // tune on the layer's GEMM shape: cout rows x (ho·wo)
            // positions over the im2col patch (grouped convs hand the
            // kernel per-group row spans of the same k, so the shape is
            // representative either way)
            let w4 = matches!(w, ConvW::W4(_));
            let choice = tuner.tune(false, w4, cout, cols, ho * wo);
            return Ok((
                PlanOp::Conv { w, p, bias_q, wsum, requant, relu: *relu, choice },
                (ho, wo),
            ));
        }
        Op::Dense { relu } => {
            let (wi, bias_q, wsum, requant) = lower_weights(model, qm, nd, in_q[0], out_q)?;
            let cout = wi.shape[0];
            let cols = wi.numel() / cout;
            let w = if choose_w4(qm, &nd.id, &wi.data, opts.force_w4)? {
                DenseW::W4(PackedDense4::pack(&wi.data, cout, cols))
            } else {
                DenseW::W8(PackedDense::pack(&wi.data, cout, cols))
            };
            // dense shapes are batch-dependent; tune at the tuner's
            // nominal serving batch (autotune::TUNE_BATCH)
            let w4 = matches!(w, DenseW::W4(_));
            let choice = tuner.tune(true, w4, cout, cols, autotune::TUNE_BATCH);
            PlanOp::Dense { w, bias_q, wsum, requant, relu: *relu, choice }
        }
        Op::Add { relu } => PlanOp::Add {
            ra: Requant::from_real(in_q[0].scale as f64 / out_q.scale as f64),
            rb: Requant::from_real(in_q[1].scale as f64 / out_q.scale as f64),
            relu: *relu,
        },
        Op::Relu => PlanOp::Relu {
            r: Requant::from_real(in_q[0].scale as f64 / out_q.scale as f64),
        },
        Op::AvgPool { k, stride } => {
            let ho = (in_hw.0 - k) / stride + 1;
            let wo = (in_hw.1 - k) / stride + 1;
            let r = Requant::from_real(
                in_q[0].scale as f64 / (out_q.scale as f64 * (k * k) as f64),
            );
            return Ok((PlanOp::AvgPool { k: *k, stride: *stride, r }, (ho, wo)));
        }
        Op::GPool => {
            let hw = in_hw.0 * in_hw.1;
            let r = Requant::from_real(in_q[0].scale as f64 / (out_q.scale as f64 * hw as f64));
            return Ok((PlanOp::GPool { r, hw }, (1, 1)));
        }
        Op::Upsample => {
            let r = Requant::from_real(in_q[0].scale as f64 / out_q.scale as f64);
            return Ok((PlanOp::Upsample { r }, (2 * in_hw.0, 2 * in_hw.1)));
        }
        Op::Concat => PlanOp::Concat {
            rs: in_q
                .iter()
                .map(|q| Requant::from_real(q.scale as f64 / out_q.scale as f64))
                .collect(),
        },
        // transformer ops quantize + calibrate fine, but the integer
        // serving engine has no lowering for them yet (see ROADMAP)
        Op::LayerNorm | Op::Softmax { .. } | Op::MatMul { .. } | Op::Gelu | Op::Embedding => {
            bail!(
                "serve: op '{:?}' of node '{}' has no integer lowering yet \
                 (transformer graphs are quantize/eval-only)",
                nd.op,
                nd.id
            )
        }
    };
    Ok((op, in_hw))
}

/// Shared lowering of a conv/dense weight layer: i8 weights, i32 bias in
/// the accumulator domain, zero-point row sums and per-channel requant.
fn lower_weights(
    model: &Model,
    qm: &QuantizedModel,
    nd: &crate::nn::Node,
    in_q: ActQ,
    out_q: ActQ,
) -> Result<(I8Tensor, Vec<i32>, Vec<i32>, Vec<Requant>)> {
    let w = qm
        .weight_overrides
        .get(&nd.id)
        .unwrap_or_else(|| model.weight(&nd.id));
    let bias = qm
        .bias_overrides
        .get(&nd.id)
        .unwrap_or_else(|| model.bias(&nd.id));
    let cout = w.shape[0];
    let cols = w.numel() / cout;
    let (wi, scales) = weight_to_i8(w, cout, qm.scales.get(&nd.id).map(|v| v.as_slice()))?;
    let mut bias_q = Vec::with_capacity(cout);
    let mut wsum = Vec::with_capacity(cout);
    let mut requant = Vec::with_capacity(cout);
    for oc in 0..cout {
        let s_acc = scales[oc] as f64 * in_q.scale as f64;
        bias_q.push((bias.data[oc] as f64 / s_acc).round() as i32);
        wsum.push(
            wi.data[oc * cols..(oc + 1) * cols]
                .iter()
                .map(|&z| z as i32)
                .sum(),
        );
        requant.push(Requant::from_real(s_acc / out_q.scale as f64));
    }
    Ok((wi, bias_q, wsum, requant))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requant_encodes_multipliers() {
        for x in [1e-6, 0.003, 0.25, 0.9999, 1.0, 1.5, 17.0, 900.0] {
            let r = Requant::from_real(x);
            assert!(r.m > 0, "mantissa for {x}");
            for acc in [-100_000i32, -37, 0, 1, 999, 2_000_000] {
                let got = r.apply(acc) as f64;
                let want = acc as f64 * x;
                let tol = 1.0 + want.abs() * 1e-6;
                assert!(
                    (got - want).abs() <= tol,
                    "requant({acc}) * {x}: got {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn requant_rounds_half_up() {
        let r = Requant::from_real(0.5);
        assert_eq!(r.apply(3), 2); // 1.5 -> 2
        assert_eq!(r.apply(1), 1); // 0.5 -> 1
        assert_eq!(r.apply(-1), 0); // -0.5 -> 0 (half-up)
    }

    #[test]
    fn scale_recovery_on_grid_rows() {
        let s = 0.037f32;
        let row: Vec<f32> = [-3i32, 0, 1, 7, -8, 2].iter().map(|&z| s * z as f32).collect();
        let got = recover_row_scale(&row);
        // min |z| is 1, so recovery lands exactly on s
        assert!((got - s).abs() < 1e-6, "{got} vs {s}");
        // a row whose smallest |z| is 2: recovered scale may be 2s, but
        // every entry must still be an integer multiple
        let row2: Vec<f32> = [-4i32, 2, 6].iter().map(|&z| s * z as f32).collect();
        let g2 = recover_row_scale(&row2);
        for v in &row2 {
            let z = v / g2;
            assert!((z - z.round()).abs() < 1e-3, "{v} not on recovered grid {g2}");
        }
        assert_eq!(recover_row_scale(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn autotune_env_contract() {
        // inverted polarity: on unless the value is exactly "0"
        assert!(autotune_enabled(None));
        assert!(autotune_enabled(Some("")));
        assert!(autotune_enabled(Some("1")));
        assert!(autotune_enabled(Some("yes")));
        assert!(!autotune_enabled(Some("0")));
        assert!(!autotune_enabled(Some(" 0 ")));
        // and the derive-proof default keeps tuning on
        assert!(PlanOptions::default().autotune);
        assert!(!PlanOptions::default().force_w4);
    }

    #[test]
    fn disabled_tuner_pins_the_heuristic_choice() {
        let mut t = Tuner::new(false);
        assert_eq!(t.tune(false, false, 8, 27, 196), GemmChoice::heuristic());
        assert_eq!(t.ms, 0.0, "disabled tuner must not time anything");
        // enabled tuner memoizes: same shape twice, one timing
        let mut t = Tuner::new(true);
        let a = t.tune(true, false, 10, 64, 8);
        let ms = t.ms;
        let b = t.tune(true, false, 10, 64, 8);
        assert_eq!(a, b);
        assert_eq!(t.ms, ms, "second identical shape must hit the memo");
    }

    #[test]
    fn force_w4_env_contract() {
        assert!(!force_w4_requested(None));
        assert!(!force_w4_requested(Some("")));
        assert!(!force_w4_requested(Some("0")));
        assert!(!force_w4_requested(Some(" 0 ")));
        assert!(force_w4_requested(Some("1")));
        assert!(force_w4_requested(Some("true")));
        assert!(force_w4_requested(Some("yes")));
    }

    #[test]
    fn packed_weight_enums_report_shape_and_bytes() {
        let codes: Vec<i8> = (0..6).map(|v| v - 3).collect();
        let w8 = ConvW::W8(PackedConv::pack(&codes, 2, 3));
        let w4 = ConvW::W4(PackedConv4::pack(&codes, 2, 3));
        assert_eq!((w8.rows(), w8.k(), w8.dtype()), (2, 3, "w8"));
        assert_eq!((w4.rows(), w4.k(), w4.dtype()), (2, 3, "w4"));
        // kp = 4 -> w8 stores 8 bytes, w4 stores 4
        assert_eq!(w8.weight_bytes(), 8);
        assert_eq!(w4.weight_bytes(), 4);
        assert!(w8.layout_ok() && w4.layout_ok());
        let d8 = DenseW::W8(PackedDense::pack(&codes, 2, 3));
        let d4 = DenseW::W4(PackedDense4::pack(&codes, 2, 3));
        assert_eq!((d8.n(), d8.k(), d8.dtype()), (2, 3, "w8"));
        assert_eq!((d4.n(), d4.k(), d4.dtype()), (2, 3, "w4"));
        assert_eq!(d8.weight_bytes(), 2 * d4.weight_bytes());
        assert!(d8.layout_ok() && d4.layout_ok());
    }

    #[test]
    fn actq_roundtrip_and_zero() {
        let q = ActQuant::new(-1.0, 3.0, 8);
        let a = ActQ::from_act_quant(&q).unwrap();
        // real zero maps exactly to the zero point
        assert_eq!(a.quantize(0.0) as i32, a.zp);
        assert_eq!(a.dequantize(a.zp as u8), 0.0);
        // quantize/dequantize error bounded by half a step
        for x in [-0.9f32, -0.1, 0.0, 0.4, 1.7, 2.9] {
            let back = a.dequantize(a.quantize(x));
            assert!((back - x).abs() <= a.scale * 0.5 + 1e-6, "{x} -> {back}");
        }
        // post-relu quantizers (min 0) get zp 0
        let relu_q = ActQuant::new(0.0, 5.0, 8);
        assert_eq!(ActQ::from_act_quant(&relu_q).unwrap().zp, 0);
        // an all-negative range cannot anchor a u8 grid: refuse, don't clamp
        let neg = ActQuant { min: -5.0, max: -4.0, bits: 8 };
        assert!(ActQ::from_act_quant(&neg).is_err());
        // non-8-bit quantizers are rejected too
        assert!(ActQ::from_act_quant(&ActQuant::new(-1.0, 1.0, 4)).is_err());
    }

    #[test]
    fn weight_to_i8_exact_on_grid() {
        let s = [0.02f32, 0.05];
        let z = [[3i32, -7, 0, 127], [-128, 1, 64, -2]];
        let data: Vec<f32> = (0..2)
            .flat_map(|r| z[r].iter().map(move |&v| s[r] * v as f32))
            .collect();
        let w = Tensor::from_vec(&[2, 4], data);
        let (wi, sc) = weight_to_i8(&w, 2, Some(&s[..])).unwrap();
        assert_eq!(sc, s.to_vec());
        assert_eq!(wi.data, vec![3, -7, 0, 127, -128, 1, 64, -2]);
        // and with recovery instead of recorded scales
        let (wi2, _) = weight_to_i8(&w, 2, None).unwrap();
        for (a, b) in wi2.data.iter().zip(&wi.data) {
            // recovered scale may differ by an integer factor; dequantized
            // values must agree — here min |z| is 1 per row, so exact
            assert_eq!(a, b);
        }
    }
}
