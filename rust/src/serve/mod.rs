//! `pallas-serve` — integer-domain inference engine + batched serving
//! front-end.
//!
//! The PTQ pipeline produces weights that live exactly on a fixed-point
//! grid; this subsystem serves them *as integers* instead of re-simulating
//! quantization in f32:
//!
//! 1. **compile** ([`plan`]): lower a [`crate::nn::Model`] +
//!    [`crate::coordinator::QuantizedModel`] into a [`QuantizedPlan`] —
//!    i8 weights with per-channel scales recovered from the grid, i32
//!    bias, and fixed-point requantization multipliers. All float math
//!    happens here, once.
//! 2. **execute** ([`engine`], [`ikernels`]): a [`ServeEngine`] walks the
//!    plan with u8 activations, i8×u8→i32 GEMMs and fused
//!    requant+ReLU+saturate — no float ops in the layer loop. Each GEMM
//!    runs the [`GemmChoice`] the plan compiler autotuned for that op's
//!    packed shape ([`crate::tensor::int8::kernel`]): AVX-512 VNNI
//!    `vpdpwssd`, AVX2 `vpmaddwd`, AArch64 NEON `smlal`, or the portable
//!    scalar core — all bit-identical, so `PALLAS_NO_SIMD=1` /
//!    `PALLAS_KERNEL=<variant>` / `PALLAS_AUTOTUNE=0` only move time,
//!    never results.
//! 3. **serve** ([`batch`]): a [`Batcher`] coalesces single-image requests
//!    into batched forwards under a max-batch / max-wait policy, sharded
//!    across `shards` engines that share one read-only plan
//!    ([`ServeEngine::fork`]) with per-shard scratch. Admission is
//!    bounded ([`SubmitError`]): past `depth_budget × shards` in-flight
//!    requests a submit fails instead of growing the queue.
//! 4. **operate** ([`registry`]): a [`ModelRegistry`] maps model-id →
//!    per-model batcher under one shared thread budget, with
//!    zero-downtime hot reload — a watcher polls each `.qtz` bundle's
//!    mtime, recompiles off the hot path, and [`Batcher::swap_plan`]
//!    publishes a new generation that shards adopt between batches.
//! 5. **expose** ([`http`], [`telemetry`]): `adaround serve --listen`
//!    puts a zero-dependency HTTP/1.1 front-end over the registry —
//!    `POST /v1/infer`, `POST /v1/models/<id>/infer`, Prometheus
//!    `GET /metrics`, `GET /healthz` — with lock-free
//!    counters/histograms ([`ServeMetrics`]) recorded off the hot path
//!    and a graceful drain on SIGTERM/ctrl-c that answers every
//!    in-flight request before exiting.
//!
//! Accuracy contract: the integer engine mirrors the f32 fake-quant
//! simulation up to requantization rounding (argmax parity on the test
//! models; see `rust/tests/serve_parity.rs`). Determinism contract:
//! per-image results are bit-identical for any (`PALLAS_THREADS`,
//! `shards`) pair (`rust/tests/pool_serving.rs`).
//!
//! See `docs/SERVING.md` for the CLI quickstart, the `.qtz` format
//! specification and policy tuning, and `docs/ARCHITECTURE.md` for where
//! this subsystem sits in the pipeline.
//!
//! Compiling and serving in-process:
//!
//! ```
//! use std::collections::BTreeMap;
//! use adaround::coordinator::{Method, Pipeline, PipelineConfig};
//! use adaround::data::synthetic_stripes;
//! use adaround::nn::Model;
//! use adaround::serve::ServeEngine;
//! use adaround::tensor::Tensor;
//! use adaround::util::{Json, Rng};
//!
//! // a tiny conv classifier built from an inline manifest
//! let ir = r#"{"task":"cls","ir":[
//!   {"id":"in","op":"input","inputs":[]},
//!   {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":4,
//!    "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
//!   {"id":"g1","op":"gpool","inputs":["c1"]},
//!   {"id":"d1","op":"dense","inputs":["g1"],"cin":4,"cout":2,"relu":false}
//! ]}"#;
//! let mut rng = Rng::new(5);
//! let mut weights = BTreeMap::new();
//! for (name, shape) in [
//!     ("c1.w", vec![4usize, 3, 3, 3]),
//!     ("c1.b", vec![4]),
//!     ("d1.w", vec![2, 4]),
//!     ("d1.b", vec![2]),
//! ] {
//!     let n: usize = shape.iter().product();
//!     let data = (0..n).map(|_| rng.normal_f32(0.0, 0.2)).collect();
//!     weights.insert(name.to_string(), Tensor::from_vec(&shape, data));
//! }
//! let model = Model::from_manifest("doc", &Json::parse(ir).unwrap(), weights).unwrap();
//!
//! // quantize 8/8 (weights AND activations — the integer engine needs
//! // activation quantizers), then lower to the integer plan
//! let (calib, _) = synthetic_stripes(16, 3, 8, &mut rng);
//! let cfg = PipelineConfig {
//!     method: Method::Nearest,
//!     bits: 8,
//!     per_channel: true,
//!     act_bits: Some(8),
//!     calib_n: 16,
//!     ..Default::default()
//! };
//! let qm = Pipeline::new(&model, cfg, None).quantize(&calib, &mut Rng::new(1)).unwrap();
//! let mut engine = ServeEngine::compile(&model, &qm, &[3, 8, 8]).unwrap();
//!
//! // batched forward: [N, C, H, W] f32 in, [N, classes] f32 logits out
//! let (val, _) = synthetic_stripes(4, 3, 8, &mut rng);
//! let logits = engine.forward(&val);
//! assert_eq!(logits.shape, vec![4, 2]);
//! ```
//!
//! The CLI wraps the same loop:
//!
//! ```text
//! adaround quantize --model micro18 --bits 4 --act-bits 8 --save m.qtz
//! adaround serve-bench --model micro18 --quantized m.qtz --shards 4
//! ```

pub mod batch;
pub mod engine;
pub mod http;
pub mod ikernels;
pub mod plan;
pub mod registry;
pub mod telemetry;

pub use batch::{
    offered_load_latencies, saturation_throughput, Batcher, BatcherHandle, BatchPolicy, PlanStamp,
    PlanView, SubmitError, SwapError,
};
pub use engine::ServeEngine;
pub use http::{http_offered_load_latencies, infer_body, HttpClient, HttpConfig, HttpServer};
pub use registry::{ModelRegistry, RegistryBuilder, DEFAULT_MODEL_ID, DEFAULT_WATCH_INTERVAL};
pub use telemetry::ServeMetrics;
pub use plan::{
    compile_plan, compile_plan_with, ActQ, ConvW, DenseW, PlanOptions, QuantizedPlan, Requant,
};
pub use crate::tensor::int8::kernel::{GemmChoice, Kernel};

use std::collections::BTreeMap;

use crate::tensor::Tensor;
use crate::util::Json;

/// `BENCH_serving.json` result entry: throughput at one batch size. The
/// field names here are the contract `bench-diff` string-matches on —
/// both emitters (`benches/serving.rs` and `adaround serve-bench`) build
/// entries through these constructors so the schema lives in one place.
pub fn throughput_entry(name: &str, imgs_per_sec: f64) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(name.to_string()));
    o.insert("imgs_per_sec".to_string(), Json::Num(imgs_per_sec));
    Json::Obj(o)
}

/// `BENCH_serving.json` result entry: latency percentiles at one offered
/// load.
pub fn latency_entry(name: &str, p50_ms: f64, p99_ms: f64) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(name.to_string()));
    o.insert("p50_ms".to_string(), Json::Num(p50_ms));
    o.insert("p99_ms".to_string(), Json::Num(p99_ms));
    Json::Obj(o)
}

/// The saturated closed-loop shard sweep shared by `benches/serving.rs`
/// and `adaround serve-bench`: measure shards=1 and (when `max_shards`
/// exceeds 1) shards=`max_shards`, printing one row per point. Returns
/// the `BENCH_serving.json` entries plus the max-shard speedup over the
/// single-engine baseline. Entry names are machine-independent
/// (`shards=1` / `shards=max`) so `bench-diff` can track them across
/// hosts with different core counts — keeping the naming in one place is
/// what keeps the regression gate's name matching stable.
pub fn shard_sweep(
    mut compile: impl FnMut() -> ServeEngine,
    base_policy: BatchPolicy,
    pool: &[Tensor],
    max_shards: usize,
    label_width: usize,
) -> (Vec<Json>, f64) {
    let mut counts = vec![1usize];
    if max_shards > 1 {
        counts.push(max_shards);
    }
    println!("{:<w$} {:>12} {:>8}", "saturated closed loop", "img/s", "speedup", w = label_width);
    let mut entries = Vec::new();
    let mut base_tp = 0.0f64;
    let mut speedup = 1.0f64;
    for &sc in &counts {
        let b = Batcher::new(compile(), BatchPolicy { shards: sc, ..base_policy });
        let tp = saturation_throughput(&b, pool, 256 * sc.max(4), 2 * sc);
        b.shutdown();
        if sc == 1 {
            base_tp = tp;
        } else {
            speedup = tp / base_tp;
        }
        println!("{:<w$} {:>12.1} {:>7.2}x", format!("shards {sc}"), tp, tp / base_tp, w = label_width);
        let label = if sc == 1 { "serve saturated shards=1" } else { "serve saturated shards=max" };
        entries.push(throughput_entry(label, tp));
    }
    (entries, speedup)
}
