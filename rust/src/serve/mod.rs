//! `pallas-serve` — integer-domain inference engine + batched serving
//! front-end.
//!
//! The PTQ pipeline produces weights that live exactly on a fixed-point
//! grid; this subsystem serves them *as integers* instead of re-simulating
//! quantization in f32:
//!
//! 1. **compile** ([`plan`]): lower a [`crate::nn::Model`] +
//!    [`crate::coordinator::QuantizedModel`] into a [`QuantizedPlan`] —
//!    i8 weights with per-channel scales recovered from the grid, i32
//!    bias, and fixed-point requantization multipliers. All float math
//!    happens here, once.
//! 2. **execute** ([`engine`], [`ikernels`]): a [`ServeEngine`] walks the
//!    plan with u8 activations, i8×u8→i32 GEMMs and fused
//!    requant+ReLU+saturate — no float ops in the layer loop.
//! 3. **serve** ([`batch`]): a [`Batcher`] coalesces single-image requests
//!    into batched forwards under a max-batch / max-wait policy.
//!
//! Accuracy contract: the integer engine mirrors the f32 fake-quant
//! simulation up to requantization rounding (argmax parity on the test
//! models; see `rust/tests/serve_parity.rs`).
//!
//! ```text
//! adaround quantize --model micro18 --bits 4 --act-bits 8 --save m.qtz
//! adaround serve-bench --model micro18 --quantized m.qtz
//! ```

pub mod batch;
pub mod engine;
pub mod ikernels;
pub mod plan;

pub use batch::{offered_load_latencies, Batcher, BatcherHandle, BatchPolicy};
pub use engine::ServeEngine;
pub use plan::{compile_plan, ActQ, QuantizedPlan, Requant};

use std::collections::BTreeMap;

use crate::util::Json;

/// `BENCH_serving.json` result entry: throughput at one batch size. The
/// field names here are the contract `bench-diff` string-matches on —
/// both emitters (`benches/serving.rs` and `adaround serve-bench`) build
/// entries through these constructors so the schema lives in one place.
pub fn throughput_entry(name: &str, imgs_per_sec: f64) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(name.to_string()));
    o.insert("imgs_per_sec".to_string(), Json::Num(imgs_per_sec));
    Json::Obj(o)
}

/// `BENCH_serving.json` result entry: latency percentiles at one offered
/// load.
pub fn latency_entry(name: &str, p50_ms: f64, p99_ms: f64) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(name.to_string()));
    o.insert("p50_ms".to_string(), Json::Num(p50_ms));
    o.insert("p99_ms".to_string(), Json::Num(p99_ms));
    Json::Obj(o)
}
