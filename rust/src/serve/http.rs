//! Zero-dependency HTTP/1.1 front-end for the batched serving stack:
//! `std::net::TcpListener`, blocking I/O, one thread per connection with
//! keep-alive — no tokio/hyper (the offline vendor registry has neither),
//! mirroring the endpoint *shape* of surver's `server.rs`
//! (status/data/metrics routes, optional bearer token), not its async
//! stack.
//!
//! Routes:
//!
//! | route                    | method | body                                   |
//! |--------------------------|--------|----------------------------------------|
//! | `/v1/infer`              | POST   | one image, LE f32 bytes or JSON array  |
//! | `/v1/models/<id>/infer`  | POST   | same, routed to model `<id>`           |
//! | `/v1/models`             | GET    | JSON: registered model ids             |
//! | `/metrics`               | GET    | Prometheus text ([`super::telemetry`]) |
//! | `/healthz`               | GET    | JSON: per-model generation/drain state |
//! | `/`                      | GET    | plain-text route index                 |
//!
//! `/v1/infer` aliases the registry's default model, so a single-model
//! server ([`HttpServer::bind`]) behaves exactly as before the registry
//! existed; [`HttpServer::bind_registry`] serves many models, each with
//! its own batcher, queue and generation counter
//! ([`super::registry::ModelRegistry`]).
//!
//! Admission maps [`SubmitError`] onto status codes: `QueueFull` → 429 +
//! `Retry-After`, `ShuttingDown` → 503, `BadShape` → 400. Graceful drain
//! ([`HttpServer::shutdown`]): flip the shared drain flag (new infers
//! 503, `/healthz` reports `draining`), stop accepting, let every
//! connection finish its in-flight response, then drain and join the
//! shard pool — no admitted request is ever dropped (enforced by
//! `rust/tests/http_serving.rs`).
//!
//! The exact metric names and the full status-code table live in
//! `docs/SERVING.md`; the socket→admission→batcher→shard data flow in
//! `docs/ARCHITECTURE.md`.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::tensor::Tensor;
use crate::util::Json;

use super::batch::{Batcher, BatcherHandle, PlanView, SubmitError};
use super::registry::ModelRegistry;
use super::telemetry::{Counter, ServeMetrics};

// ---------------------------------------------------------------------
// request/response parsing (shared by the server and the test client)
// ---------------------------------------------------------------------

/// Why reading or parsing an HTTP message failed. The server maps these
/// onto status codes (see [`HttpError::status`]).
#[derive(Debug, PartialEq, Eq)]
pub enum HttpError {
    /// header block exceeds the limit → 431
    HeadTooLarge,
    /// declared content-length exceeds the limit → 413
    BodyTooLarge { len: usize },
    /// syntactically invalid message → 400
    Malformed(&'static str),
    /// the read timed out; `started` = mid-message (some bytes consumed)
    Timeout { started: bool },
    /// peer closed the stream mid-message
    Eof,
    /// transport error
    Io(ErrorKind),
}

impl HttpError {
    /// Status code the server answers with before closing.
    pub fn status(&self) -> u16 {
        match self {
            HttpError::HeadTooLarge => 431,
            HttpError::BodyTooLarge { .. } => 413,
            HttpError::Malformed(_) => 400,
            HttpError::Timeout { .. } => 408,
            HttpError::Eof | HttpError::Io(_) => 400,
        }
    }
}

/// First line + headers of one HTTP message (request or response).
/// Header names are lowercased; values are trimmed.
#[derive(Debug)]
pub struct MsgHead {
    pub line: String,
    pub headers: Vec<(String, String)>,
}

impl MsgHead {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn parse_head(bytes: &[u8]) -> Result<MsgHead, HttpError> {
    let text = std::str::from_utf8(bytes).map_err(|_| HttpError::Malformed("non-utf8 head"))?;
    let mut lines = text.split("\r\n");
    let line = lines.next().unwrap_or("").to_string();
    if line.is_empty() {
        return Err(HttpError::Malformed("empty start line"));
    }
    let mut headers = Vec::new();
    for l in lines {
        if l.is_empty() {
            continue;
        }
        let (k, v) = l.split_once(':').ok_or(HttpError::Malformed("header missing ':'"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    Ok(MsgHead { line, headers })
}

/// Read one HTTP/1.1 message (head + content-length body) from `r`.
///
/// `carry` holds bytes already read but not yet consumed — pass the same
/// buffer across calls on a keep-alive connection and partial reads,
/// pipelining and timeouts all resume cleanly: the buffer is only
/// drained once a complete message has been parsed, so a
/// [`HttpError::Timeout`] mid-message loses nothing.
///
/// Returns `Ok(None)` on a clean close at a message boundary.
pub fn read_message<R: Read>(
    r: &mut R,
    carry: &mut Vec<u8>,
    max_head: usize,
    max_body: usize,
) -> Result<Option<(MsgHead, Vec<u8>)>, HttpError> {
    let mut tmp = [0u8; 8192];
    loop {
        if let Some(head_end) = find_head_end(carry) {
            let head = parse_head(&carry[..head_end])?;
            if head.header("transfer-encoding").is_some() {
                return Err(HttpError::Malformed("transfer-encoding unsupported"));
            }
            let content_len = match head.header("content-length") {
                None => 0usize,
                Some(v) => v
                    .parse()
                    .map_err(|_| HttpError::Malformed("bad content-length"))?,
            };
            if content_len > max_body {
                return Err(HttpError::BodyTooLarge { len: content_len });
            }
            let total = head_end + 4 + content_len;
            if carry.len() >= total {
                let body = carry[head_end + 4..total].to_vec();
                carry.drain(..total);
                return Ok(Some((head, body)));
            }
        } else if carry.len() > max_head {
            return Err(HttpError::HeadTooLarge);
        }
        match r.read(&mut tmp) {
            Ok(0) => {
                return if carry.is_empty() { Ok(None) } else { Err(HttpError::Eof) };
            }
            Ok(n) => carry.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                return Err(HttpError::Timeout { started: !carry.is_empty() });
            }
            Err(e) => return Err(HttpError::Io(e.kind())),
        }
    }
}

/// Split a request start line into (METHOD, path, version).
pub fn parse_request_line(line: &str) -> Result<(&str, &str, &str), HttpError> {
    let mut it = line.split_whitespace();
    let (m, p, v) = (it.next(), it.next(), it.next());
    match (m, p, v, it.next()) {
        (Some(m), Some(p), Some(v), None) if v.starts_with("HTTP/1.") => Ok((m, p, v)),
        _ => Err(HttpError::Malformed("bad request line")),
    }
}

fn reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// One response, ready to serialize.
struct Response {
    code: u16,
    ctype: &'static str,
    extra: Vec<(&'static str, String)>,
    body: Vec<u8>,
}

impl Response {
    fn new(code: u16, ctype: &'static str, body: Vec<u8>) -> Response {
        Response { code, ctype, extra: Vec::new(), body }
    }

    fn text(code: u16, msg: &str) -> Response {
        Response::new(code, "text/plain", format!("{msg}\n").into_bytes())
    }

    fn with(mut self, k: &'static str, v: String) -> Response {
        self.extra.push((k, v));
        self
    }
}

fn write_response(w: &mut impl Write, r: &Response, keep_alive: bool) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        r.code,
        reason(r.code),
        r.ctype,
        r.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (k, v) in &r.extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(&r.body)?;
    w.flush()
}

// ---------------------------------------------------------------------
// server
// ---------------------------------------------------------------------

/// Front-end configuration. Defaults serve curl out of the box.
#[derive(Clone)]
pub struct HttpConfig {
    /// when set, `POST /v1/infer` requires `Authorization: Bearer <tok>`
    /// (`/healthz` and `/metrics` stay open for probes and scrapers)
    pub auth_token: Option<String>,
    /// 413 past this declared content-length
    pub max_body_bytes: usize,
    /// 431 past this header-block size
    pub max_head_bytes: usize,
    /// read-timeout granularity: how often an idle connection rechecks
    /// the drain flag
    pub read_poll: Duration,
    /// a connection stalled mid-request longer than this gets 408
    pub request_deadline: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            auth_token: None,
            max_body_bytes: 16 << 20,
            max_head_bytes: 16 << 10,
            read_poll: Duration::from_millis(100),
            request_deadline: Duration::from_secs(10),
        }
    }
}

/// HTTP-layer counters, rendered after the batcher block in `/metrics`.
struct HttpStats {
    routes: [(&'static str, Counter); 6],
    codes: [(u16, Counter); 11],
}

impl HttpStats {
    fn new() -> HttpStats {
        let routes = ["infer", "metrics", "healthz", "index", "models", "other"]
            .map(|r| (r, Counter::default()));
        let codes = [200u16, 400, 401, 404, 405, 408, 413, 429, 431, 500, 503]
            .map(|c| (c, Counter::default()));
        HttpStats { routes, codes }
    }

    fn count_route(&self, path: &str) {
        let key = match path {
            "/v1/infer" => "infer",
            "/metrics" => "metrics",
            "/healthz" => "healthz",
            "/" => "index",
            "/v1/models" => "models",
            p if model_route(p).is_some() => "infer",
            _ => "other",
        };
        if let Some((_, c)) = self.routes.iter().find(|(r, _)| *r == key) {
            c.inc();
        }
    }

    fn count_code(&self, code: u16) {
        if let Some((_, c)) = self.codes.iter().find(|(k, _)| *k == code) {
            c.inc();
        }
    }

    fn render(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "# HELP pallas_http_requests_total HTTP requests by route");
        let _ = writeln!(out, "# TYPE pallas_http_requests_total counter");
        for (r, c) in &self.routes {
            let _ = writeln!(out, "pallas_http_requests_total{{route=\"{r}\"}} {}", c.get());
        }
        let _ = writeln!(out, "# HELP pallas_http_responses_total HTTP responses by status code");
        let _ = writeln!(out, "# TYPE pallas_http_responses_total counter");
        for (k, c) in &self.codes {
            let _ = writeln!(out, "pallas_http_responses_total{{code=\"{k}\"}} {}", c.get());
        }
    }
}

/// Extract the model id from a `/v1/models/<id>/infer` path.
fn model_route(path: &str) -> Option<&str> {
    let id = path.strip_prefix("/v1/models/")?.strip_suffix("/infer")?;
    (!id.is_empty() && !id.contains('/')).then_some(id)
}

/// Per-model serving context, captured at bind. The submit handle,
/// metrics, shard count, kernel and input geometry are fixed for the
/// server's lifetime; plan identity (generation, plan id, footprint) is
/// read live through `view` so `/healthz` and `/metrics` stay truthful
/// across hot-swaps.
struct ModelCtx {
    handle: BatcherHandle,
    metrics: Arc<ServeMetrics>,
    view: PlanView,
    reloadable: bool,
    shards: usize,
    kernel: &'static str,
    in_shape: Vec<usize>,
    per: usize,
}

impl ModelCtx {
    fn render_plan(&self, out: &mut String) {
        use std::fmt::Write as _;
        let stamp = self.view.stamp();
        let _ = writeln!(out, "# HELP pallas_plan_info identity of the plan being served");
        let _ = writeln!(out, "# TYPE pallas_plan_info gauge");
        let _ = writeln!(
            out,
            "pallas_plan_info{{id=\"{}\",kernel=\"{}\",shards=\"{}\",generation=\"{}\"}} 1",
            stamp.id_hex, self.kernel, self.shards, stamp.generation
        );
        let _ = writeln!(out, "# HELP pallas_plan_weight_bytes packed weight footprint");
        let _ = writeln!(out, "# TYPE pallas_plan_weight_bytes gauge");
        let _ = writeln!(out, "pallas_plan_weight_bytes {}", stamp.weight_bytes);
        let _ = writeln!(out, "# HELP pallas_plan_ops weight-bearing ops by packed dtype");
        let _ = writeln!(out, "# TYPE pallas_plan_ops gauge");
        let _ = writeln!(out, "pallas_plan_ops{{dtype=\"w8\"}} {}", stamp.w8_ops);
        let _ = writeln!(out, "pallas_plan_ops{{dtype=\"w4\"}} {}", stamp.w4_ops);
        let _ = writeln!(
            out,
            "# HELP pallas_plan_kernel autotuned GEMM variant per weight-bearing op"
        );
        let _ = writeln!(out, "# TYPE pallas_plan_kernel gauge");
        for (op, ch) in &stamp.op_kernels {
            let _ = writeln!(
                out,
                "pallas_plan_kernel{{op=\"{}\",kernel=\"{}\",cfg=\"{}\"}} 1",
                op,
                ch.kernel.name(),
                ch.cfg
            );
        }
        let _ = writeln!(out, "# HELP pallas_plan_autotune_ms compile-time autotuning cost");
        let _ = writeln!(out, "# TYPE pallas_plan_autotune_ms gauge");
        let _ = writeln!(out, "pallas_plan_autotune_ms {}", stamp.autotune_ms);
    }
}

struct ServerState {
    models: std::collections::BTreeMap<String, ModelCtx>,
    default_id: String,
    http: HttpStats,
    cfg: HttpConfig,
}

impl ServerState {
    fn default_model(&self) -> &ModelCtx {
        &self.models[&self.default_id]
    }

    fn draining(&self) -> bool {
        // the drain flag is flipped on every model at once (shutdown),
        // so the default model's is the connection-level truth
        self.default_model().metrics.draining()
    }
}

/// The serving front-end: a listener, an accept thread, one thread per
/// connection, all sharing the registry's telemetry. Owns the
/// [`ModelRegistry`] (and through it every [`Batcher`]) so
/// [`HttpServer::shutdown`] can drain the whole stack in order.
pub struct HttpServer {
    addr: SocketAddr,
    state: Option<Arc<ServerState>>,
    stop_accept: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    registry: Option<ModelRegistry>,
    metrics: Arc<ServeMetrics>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`, port 0 for ephemeral) and
    /// serve one batcher as the registry's sole (default) model — the
    /// single-model layout every pre-registry caller keeps using.
    pub fn bind(batcher: Batcher, addr: &str, cfg: HttpConfig) -> Result<HttpServer> {
        HttpServer::bind_registry(ModelRegistry::single(batcher), addr, cfg)
    }

    /// Bind `addr` and serve every model in `registry`: `/v1/infer`
    /// aliases the default model, `/v1/models/<id>/infer` routes by id.
    pub fn bind_registry(
        registry: ModelRegistry,
        addr: &str,
        cfg: HttpConfig,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let addr = listener.local_addr()?;
        let mut models = std::collections::BTreeMap::new();
        for (id, entry) in registry.entries() {
            let b = entry.batcher();
            let stamp = b.plan_stamp();
            models.insert(
                id.to_string(),
                ModelCtx {
                    handle: b.handle(),
                    metrics: Arc::clone(b.metrics()),
                    view: b.plan_view(),
                    reloadable: entry.reloadable(),
                    shards: b.shards(),
                    kernel: b.kernel().name(),
                    per: stamp.in_shape.iter().product(),
                    in_shape: stamp.in_shape,
                },
            );
        }
        let metrics = Arc::clone(registry.default_entry().batcher().metrics());
        let state = Arc::new(ServerState {
            models,
            default_id: registry.default_id().to_string(),
            http: HttpStats::new(),
            cfg,
        });
        let stop_accept = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop_accept);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let state = Arc::clone(&state);
                        let h = std::thread::Builder::new()
                            .name("serve-http".into())
                            .spawn(move || conn_loop(stream, state));
                        if let Ok(h) = h {
                            let mut guard = conns.lock().expect("conns lock");
                            // reap finished connection threads in passing
                            let mut i = 0;
                            while i < guard.len() {
                                if guard[i].is_finished() {
                                    let _ = guard.swap_remove(i).join();
                                } else {
                                    i += 1;
                                }
                            }
                            guard.push(h);
                        }
                    }
                })
                .expect("spawn accept thread")
        };
        Ok(HttpServer {
            addr,
            state: Some(state),
            stop_accept,
            accept: Some(accept),
            conns,
            registry: Some(registry),
            metrics,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The default model's live telemetry — valid after shutdown too
    /// (tests assert zero-loss against it).
    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }

    /// The registry being served (for manual [`ModelRegistry::reload`]
    /// calls from tests and tooling). `None` after shutdown.
    pub fn registry(&self) -> Option<&ModelRegistry> {
        self.registry.as_ref()
    }

    /// Graceful drain: reject new infers with 503 (drain flag), stop
    /// accepting connections, let every connection write its in-flight
    /// response, then drain the batcher queue and join the shard pool.
    /// Blocks until everything has stopped; admitted requests always get
    /// their response.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        let Some(registry) = self.registry.as_ref() else {
            return; // already shut down
        };
        // 1. no new work: every model's submits fail ShuttingDown,
        // /healthz says draining
        registry.begin_drain();
        // 2. stop accepting (poke the blocking accept loop awake)
        self.stop_accept.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // 3. connections: each finishes its in-flight response, then
        // notices the drain flag at its next read poll and exits
        loop {
            let handles: Vec<_> = {
                let mut guard = self.conns.lock().expect("conns lock");
                guard.drain(..).collect()
            };
            if handles.is_empty() {
                break;
            }
            for h in handles {
                let _ = h.join();
            }
        }
        // 4. drop our submit handles (the last senders), then stop the
        // watcher and join every model's shards: the workers drain
        // what's queued and exit
        self.state.take();
        if let Some(r) = self.registry.take() {
            r.shutdown();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// One connection: keep-alive request loop with drain-aware idling.
fn conn_loop(mut stream: TcpStream, state: Arc<ServerState>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(state.cfg.read_poll));
    let mut carry = Vec::new();
    let mut stalled_since: Option<Instant> = None;
    loop {
        let msg = read_message(
            &mut stream,
            &mut carry,
            state.cfg.max_head_bytes,
            state.cfg.max_body_bytes,
        );
        match msg {
            Ok(Some((head, body))) => {
                stalled_since = None;
                let resp = handle_request(&state, &head, body);
                // drain closes the connection after the in-flight
                // response; so does an explicit Connection: close
                let keep = !state.draining()
                    && head.header("connection").map(|v| v.eq_ignore_ascii_case("close"))
                        != Some(true);
                state.http.count_code(resp.code);
                if write_response(&mut stream, &resp, keep).is_err() || !keep {
                    return;
                }
            }
            Ok(None) => return, // clean close at a message boundary
            Err(HttpError::Timeout { started: false }) => {
                stalled_since = None;
                if state.draining() {
                    return; // idle and draining: close
                }
            }
            Err(HttpError::Timeout { started: true }) => {
                let t = *stalled_since.get_or_insert_with(Instant::now);
                if t.elapsed() > state.cfg.request_deadline
                    || (state.draining() && t.elapsed() > Duration::from_secs(1))
                {
                    let resp = Response::text(408, "request timed out");
                    state.http.count_code(resp.code);
                    let _ = write_response(&mut stream, &resp, false);
                    return;
                }
            }
            Err(e) => {
                // answer what we can, then close; a vanished peer (Eof /
                // transport error) gets nothing
                if !matches!(e, HttpError::Eof | HttpError::Io(_)) {
                    let resp = Response::text(e.status(), &format!("{e:?}"));
                    state.http.count_code(resp.code);
                    let _ = write_response(&mut stream, &resp, false);
                }
                return;
            }
        }
    }
}

fn handle_request(state: &ServerState, head: &MsgHead, body: Vec<u8>) -> Response {
    let Ok((method, path, _)) = parse_request_line(&head.line) else {
        return Response::text(400, "malformed request line");
    };
    state.http.count_route(path);
    if let Some(id) = model_route(path) {
        return match (method, state.models.get(id)) {
            ("POST", Some(model)) => infer(state, model, head, body),
            (_, Some(_)) => {
                Response::text(405, "method not allowed").with("Allow", "POST".into())
            }
            (_, None) => Response::text(404, &format!("unknown model '{id}'")),
        };
    }
    match (method, path) {
        ("GET", "/healthz") => healthz(state),
        ("GET", "/metrics") => metrics_page(state),
        ("GET", "/v1/models") => models_page(state),
        ("POST", "/v1/infer") => infer(state, state.default_model(), head, body),
        ("GET", "/") => Response::text(
            200,
            "pallas-serve\n  POST /v1/infer  (LE f32 bytes or JSON array; default model)\n  POST /v1/models/<id>/infer\n  GET /v1/models\n  GET /metrics\n  GET /healthz",
        ),
        (_, "/healthz" | "/metrics" | "/" | "/v1/models") => {
            Response::text(405, "method not allowed").with("Allow", "GET".into())
        }
        (_, "/v1/infer") => Response::text(405, "method not allowed").with("Allow", "POST".into()),
        _ => Response::text(404, "unknown route"),
    }
}

fn healthz(state: &ServerState) -> Response {
    let def = state.default_model();
    let m = &def.metrics;
    let mut o = std::collections::BTreeMap::new();
    let status = if state.draining() { "draining" } else { "ok" };
    o.insert("status".to_string(), Json::Str(status.to_string()));
    o.insert("draining".to_string(), Json::Bool(state.draining()));
    // top-level plan facts describe the default model (back-compat with
    // single-model probes); the "models" object covers every model
    let stamp = def.view.stamp();
    o.insert("plan_id".to_string(), Json::Str(stamp.id_hex));
    o.insert("generation".to_string(), Json::Num(stamp.generation as f64));
    o.insert("default_model".to_string(), Json::Str(state.default_id.clone()));
    o.insert("shards".to_string(), Json::Num(def.shards as f64));
    o.insert("kernel".to_string(), Json::Str(def.kernel.to_string()));
    o.insert(
        "in_shape".to_string(),
        Json::Arr(def.in_shape.iter().map(|&d| Json::Num(d as f64)).collect()),
    );
    o.insert("queue_depth".to_string(), Json::Num(m.queue_depth.get() as f64));
    o.insert("inflight".to_string(), Json::Num(m.inflight() as f64));
    o.insert("admission_budget".to_string(), Json::Num(m.budget() as f64));
    o.insert("requests_total".to_string(), Json::Num(m.submitted.get() as f64));
    o.insert("responses_total".to_string(), Json::Num(m.responses.get() as f64));
    let mut models = std::collections::BTreeMap::new();
    for (id, ctx) in &state.models {
        let stamp = ctx.view.stamp();
        let mut mo = std::collections::BTreeMap::new();
        mo.insert("generation".to_string(), Json::Num(stamp.generation as f64));
        mo.insert("plan_id".to_string(), Json::Str(stamp.id_hex));
        mo.insert("reloadable".to_string(), Json::Bool(ctx.reloadable));
        mo.insert("reloads_ok".to_string(), Json::Num(ctx.metrics.reloads_ok.get() as f64));
        mo.insert(
            "reloads_failed".to_string(),
            Json::Num(ctx.metrics.reloads_failed.get() as f64),
        );
        mo.insert("inflight".to_string(), Json::Num(ctx.metrics.inflight() as f64));
        models.insert(id.clone(), Json::Obj(mo));
    }
    o.insert("models".to_string(), Json::Obj(models));
    Response::new(200, "application/json", Json::Obj(o).to_string_pretty().into_bytes())
}

fn models_page(state: &ServerState) -> Response {
    let ids = state.models.keys().map(|k| Json::Str(k.clone())).collect();
    let mut o = std::collections::BTreeMap::new();
    o.insert("default".to_string(), Json::Str(state.default_id.clone()));
    o.insert("models".to_string(), Json::Arr(ids));
    Response::new(200, "application/json", Json::Obj(o).to_string_pretty().into_bytes())
}

fn metrics_page(state: &ServerState) -> Response {
    let mut out = String::with_capacity(8 << 10);
    // the classic unlabeled block (batcher + plan) describes the default
    // model — its series names are a public contract predating the
    // registry; every model (default included) additionally gets the
    // labeled pallas_model_* block
    let def = state.default_model();
    def.metrics.render_prometheus(&mut out);
    state.http.render(&mut out);
    def.render_plan(&mut out);
    for (id, ctx) in &state.models {
        ctx.metrics.render_model_prometheus(id, &mut out);
    }
    Response::new(200, "text/plain; version=0.0.4", out.into_bytes())
}

/// Flatten a JSON number tree (`[...]`, nested arrays, or `{"data": ...}`)
/// into f32s.
fn flatten_numbers(j: &Json, out: &mut Vec<f32>) -> bool {
    match j {
        Json::Num(n) => {
            out.push(*n as f32);
            true
        }
        Json::Arr(items) => items.iter().all(|it| flatten_numbers(it, out)),
        Json::Obj(_) => match j.get("data") {
            Some(inner) => flatten_numbers(inner, out),
            None => false,
        },
        _ => false,
    }
}

fn infer(state: &ServerState, model: &ModelCtx, head: &MsgHead, body: Vec<u8>) -> Response {
    if let Some(tok) = &state.cfg.auth_token {
        let want = format!("Bearer {tok}");
        if head.header("authorization") != Some(want.as_str()) {
            return Response::text(401, "missing or invalid bearer token")
                .with("WWW-Authenticate", "Bearer".into());
        }
    }
    let per = model.per;
    let ctype = head.header("content-type").unwrap_or("");
    let floats: Vec<f32> = if ctype.contains("json") {
        let Ok(text) = std::str::from_utf8(&body) else {
            return Response::text(400, "JSON body is not UTF-8");
        };
        let Ok(j) = Json::parse(text) else {
            return Response::text(400, "invalid JSON body");
        };
        let mut f = Vec::with_capacity(per);
        if !flatten_numbers(&j, &mut f) {
            return Response::text(400, "JSON body must be an array of numbers");
        }
        f
    } else {
        if body.len() != per * 4 {
            return Response::text(
                400,
                &format!(
                    "body must be {} little-endian f32 bytes ({} values), got {}",
                    per * 4,
                    per,
                    body.len()
                ),
            );
        }
        body.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    };
    if floats.len() != per {
        return Response::text(400, &format!("expected {per} values, got {}", floats.len()));
    }
    let img = Tensor::from_vec(&model.in_shape, floats);
    match model.handle.submit(img) {
        Ok(rx) => match rx.recv() {
            Ok(row) => {
                if head.header("accept").map(|a| a.contains("json")) == Some(true) {
                    let arr = Json::Arr(row.iter().map(|&v| Json::Num(v as f64)).collect());
                    Response::new(200, "application/json", arr.to_string_pretty().into_bytes())
                } else {
                    let bytes = row.iter().flat_map(|v| v.to_le_bytes()).collect();
                    Response::new(200, "application/octet-stream", bytes)
                }
            }
            // the batch worker died between admit and respond — only
            // possible in a shutdown race
            Err(_) => Response::text(503, "shutting down").with("Retry-After", "2".into()),
        },
        Err(SubmitError::QueueFull { budget }) => {
            Response::text(429, &format!("queue full ({budget} in flight)"))
                .with("Retry-After", "1".into())
        }
        Err(SubmitError::ShuttingDown) => {
            Response::text(503, "draining").with("Retry-After", "2".into())
        }
        Err(e @ SubmitError::BadShape { .. }) => Response::text(400, &e.to_string()),
    }
}

// ---------------------------------------------------------------------
// minimal blocking client (benches, tests, smoke tooling)
// ---------------------------------------------------------------------

/// A keep-alive HTTP/1.1 client over one `TcpStream` — just enough for
/// the socket load generator and the integration tests.
pub struct HttpClient {
    stream: TcpStream,
    carry: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> std::io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(HttpClient { stream, carry: Vec::new() })
    }

    /// One round trip; returns (status code, response body).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        self.request_full(method, path, headers, body).map(|(c, _, b)| (c, b))
    }

    /// One round trip, keeping the response head (status-code tests
    /// assert on `Retry-After` / `Allow`).
    pub fn request_full(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> std::io::Result<(u16, MsgHead, Vec<u8>)> {
        let mut req = format!(
            "{method} {path} HTTP/1.1\r\nHost: pallas\r\nContent-Length: {}\r\n",
            body.len()
        );
        for (k, v) in headers {
            req.push_str(k);
            req.push_str(": ");
            req.push_str(v);
            req.push_str("\r\n");
        }
        req.push_str("\r\n");
        self.stream.write_all(req.as_bytes())?;
        self.stream.write_all(body)?;
        self.stream.flush()?;
        let msg = read_message(&mut self.stream, &mut self.carry, 64 << 10, 64 << 20)
            .map_err(|e| std::io::Error::new(ErrorKind::InvalidData, format!("{e:?}")))?;
        let (head, rbody) = msg.ok_or_else(|| {
            std::io::Error::new(ErrorKind::UnexpectedEof, "connection closed")
        })?;
        let code = head
            .line
            .split_whitespace()
            .nth(1)
            .and_then(|c| c.parse().ok())
            .ok_or_else(|| std::io::Error::new(ErrorKind::InvalidData, "bad status line"))?;
        Ok((code, head, rbody))
    }
}

/// Serialize one [C,H,W] image to the `/v1/infer` binary body format.
pub fn infer_body(img: &Tensor) -> Vec<u8> {
    img.data.iter().flat_map(|v| v.to_le_bytes()).collect()
}

/// Open-loop load generator over the socket: `connections` keep-alive
/// clients drain a shared job queue fed at `rate_per_sec` — end-to-end
/// latency (serialize, socket, parse, admission, batcher, shard,
/// response) measured from each request's *scheduled* dispatch time, so
/// client-side queueing counts, as open loop demands. Returns
/// (latencies of 200s in ms, rejected count: 429/503/non-200).
pub fn http_offered_load_latencies(
    addr: SocketAddr,
    bodies: &[Vec<u8>],
    n_requests: usize,
    rate_per_sec: f64,
    connections: usize,
) -> (Vec<f64>, usize) {
    assert!(!bodies.is_empty() && rate_per_sec > 0.0 && connections >= 1);
    let (jtx, jrx) = mpsc::channel::<(Instant, usize)>();
    let jrx = Arc::new(Mutex::new(jrx));
    std::thread::scope(|s| {
        let workers: Vec<_> = (0..connections)
            .map(|_| {
                let jrx = Arc::clone(&jrx);
                s.spawn(move || {
                    let Ok(mut cli) = HttpClient::connect(addr) else {
                        return (Vec::new(), 0usize);
                    };
                    let hdr = [("Content-Type", "application/octet-stream")];
                    let mut lat = Vec::new();
                    let mut rejected = 0usize;
                    loop {
                        let job = jrx.lock().expect("job queue lock").recv();
                        let Ok((t0, idx)) = job else { break };
                        match cli.request("POST", "/v1/infer", &hdr, &bodies[idx]) {
                            Ok((200, _)) => lat.push(t0.elapsed().as_secs_f64() * 1e3),
                            Ok(_) => rejected += 1,
                            Err(_) => break,
                        }
                    }
                    (lat, rejected)
                })
            })
            .collect();
        let interval = Duration::from_secs_f64(1.0 / rate_per_sec);
        let start = Instant::now();
        for i in 0..n_requests {
            let target = start + interval.mul_f64(i as f64);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
            let _ = jtx.send((Instant::now(), i % bodies.len()));
        }
        drop(jtx);
        let mut all = Vec::new();
        let mut rejected = 0usize;
        for w in workers {
            let (l, r) = w.join().unwrap_or_default();
            all.extend(l);
            rejected += r;
        }
        (all, rejected)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A reader that hands out at most `chunk` bytes per read — the
    /// partial-read torture harness for the parser.
    struct Dribble<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
    }

    impl Read for Dribble<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.chunk.min(self.data.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn read_all(data: &[u8], chunk: usize) -> Result<Option<(MsgHead, Vec<u8>)>, HttpError> {
        let mut r = Dribble { data, pos: 0, chunk };
        let mut carry = Vec::new();
        read_message(&mut r, &mut carry, 8 << 10, 1 << 20)
    }

    #[test]
    fn parses_simple_get() {
        let raw = b"GET /healthz HTTP/1.1\r\nHost: x\r\nAccept: */*\r\n\r\n";
        let (head, body) = read_all(raw, 8192).unwrap().unwrap();
        let (m, p, v) = parse_request_line(&head.line).unwrap();
        assert_eq!((m, p, v), ("GET", "/healthz", "HTTP/1.1"));
        assert_eq!(head.header("host"), Some("x"));
        assert!(body.is_empty());
    }

    #[test]
    fn parses_across_partial_reads() {
        let raw = b"POST /v1/infer HTTP/1.1\r\nContent-Length: 8\r\nContent-Type: application/octet-stream\r\n\r\nabcdefgh";
        for chunk in [1usize, 2, 3, 7, 64] {
            let (head, body) = read_all(raw, chunk).unwrap().unwrap();
            let (m, p, _) = parse_request_line(&head.line).unwrap();
            assert_eq!((m, p), ("POST", "/v1/infer"), "chunk={chunk}");
            assert_eq!(body, b"abcdefgh", "chunk={chunk}");
        }
    }

    #[test]
    fn keep_alive_carry_resumes_pipelined_messages() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi";
        let mut r = Dribble { data: raw, pos: 0, chunk: 5 };
        let mut carry = Vec::new();
        let (h1, b1) = read_message(&mut r, &mut carry, 8192, 1024).unwrap().unwrap();
        assert_eq!(parse_request_line(&h1.line).unwrap().1, "/a");
        assert!(b1.is_empty());
        let (h2, b2) = read_message(&mut r, &mut carry, 8192, 1024).unwrap().unwrap();
        assert_eq!(parse_request_line(&h2.line).unwrap().1, "/b");
        assert_eq!(b2, b"hi");
        assert!(read_message(&mut r, &mut carry, 8192, 1024).unwrap().is_none());
    }

    #[test]
    fn malformed_request_lines_rejected() {
        for line in ["GARBAGE", "GET /x", "GET /x SPDY/3", "GET /x HTTP/1.1 extra"] {
            assert!(
                parse_request_line(line).is_err(),
                "'{line}' should not parse"
            );
        }
        // header without a colon
        let raw = b"GET / HTTP/1.1\r\nbroken header line\r\n\r\n";
        assert!(matches!(read_all(raw, 8192), Err(HttpError::Malformed(_))));
    }

    #[test]
    fn oversized_body_is_413() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 9999999\r\n\r\n";
        let mut r = Dribble { data: raw, pos: 0, chunk: 64 };
        let mut carry = Vec::new();
        let err = read_message(&mut r, &mut carry, 8192, 1024).unwrap_err();
        assert_eq!(err, HttpError::BodyTooLarge { len: 9999999 });
        assert_eq!(err.status(), 413);
    }

    #[test]
    fn truncated_body_is_eof() {
        // declares 10 bytes, peer sends 4 then closes
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabcd";
        assert_eq!(read_all(raw, 3).unwrap_err(), HttpError::Eof);
    }

    #[test]
    fn huge_head_is_431() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend(vec![b'x'; 9000]);
        let mut r = Dribble { data: &raw, pos: 0, chunk: 512 };
        let mut carry = Vec::new();
        let err = read_message(&mut r, &mut carry, 8192, 1024).unwrap_err();
        assert_eq!(err, HttpError::HeadTooLarge);
        assert_eq!(err.status(), 431);
    }

    #[test]
    fn model_route_extraction() {
        assert_eq!(model_route("/v1/models/resnet/infer"), Some("resnet"));
        assert_eq!(model_route("/v1/models/a.b-c_9/infer"), Some("a.b-c_9"));
        assert_eq!(model_route("/v1/models//infer"), None);
        assert_eq!(model_route("/v1/models/a/b/infer"), None);
        assert_eq!(model_route("/v1/models"), None);
        assert_eq!(model_route("/v1/models/x"), None);
        assert_eq!(model_route("/v1/infer"), None);
    }

    /// Satellite fuzz harness: a seeded-random request mutator (split
    /// points via random dribble chunks, byte flips, truncation,
    /// oversized headers/bodies, pipelined garbage) hammering the
    /// carry-buffer parser. The parser must never panic and every
    /// failure must map onto a clean answerable status — 400/408/413/431
    /// — or a close (`Ok(None)`/`Eof`). 10k cases per run; override with
    /// `PALLAS_FUZZ_ITERS`.
    #[test]
    fn fuzz_parser_never_panics_and_fails_clean() {
        use crate::util::Rng;
        let iters: usize = std::env::var("PALLAS_FUZZ_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(10_000);
        let seeds: &[&[u8]] = &[
            b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n",
            b"POST /v1/infer HTTP/1.1\r\nContent-Length: 12\r\nContent-Type: application/octet-stream\r\n\r\nharmlessbody",
            b"POST /v1/models/m-1/infer HTTP/1.1\r\nContent-Length: 2\r\nAccept: application/json\r\n\r\nhi",
            b"GET / HTTP/1.1\r\nAccept: */*\r\nConnection: close\r\n\r\n",
        ];
        let mut rng = Rng::new(0x5eed);
        for case in 0..iters {
            let mut data = seeds[rng.below(seeds.len())].to_vec();
            match rng.below(6) {
                // byte flips
                0 => {
                    for _ in 0..=rng.below(8) {
                        let p = rng.below(data.len());
                        data[p] ^= (1 + rng.below(255)) as u8;
                    }
                }
                // truncation at a random split point
                1 => {
                    let keep = rng.below(data.len() + 1);
                    data.truncate(keep);
                }
                // oversized header block
                2 => {
                    let pad = "a".repeat(2000 + rng.below(12_000));
                    let extra = format!("X-Fuzz: {pad}\r\n");
                    if let Some(p) = data.windows(2).position(|w| w == b"\r\n") {
                        let mut v = data[..p + 2].to_vec();
                        v.extend_from_slice(extra.as_bytes());
                        v.extend_from_slice(&data[p + 2..]);
                        data = v;
                    }
                }
                // oversized declared body
                3 => {
                    let len = (1usize << 20) + rng.below(1 << 30);
                    data = format!("POST /v1/infer HTTP/1.1\r\nContent-Length: {len}\r\n\r\n")
                        .into_bytes();
                }
                // pipelined garbage appended after a valid message
                4 => {
                    for _ in 0..rng.below(64) {
                        data.push(rng.below(256) as u8);
                    }
                }
                // random single-byte insertion
                _ => {
                    let p = rng.below(data.len() + 1);
                    data.insert(p, rng.below(256) as u8);
                }
            }
            let chunk = 1 + rng.below(96);
            let mut r = Dribble { data: &data, pos: 0, chunk };
            let mut carry = Vec::new();
            // drain messages the way conn_loop would, bounded
            for _ in 0..6 {
                match read_message(&mut r, &mut carry, 8 << 10, 1 << 20) {
                    Ok(Some((head, _body))) => {
                        // routing the head must not panic either
                        let _ = parse_request_line(&head.line);
                    }
                    Ok(None) => break, // clean close at a boundary
                    Err(e) => {
                        assert!(
                            matches!(e.status(), 400 | 408 | 413 | 431),
                            "case {case}: {e:?} maps to unanswerable status {}",
                            e.status()
                        );
                        break;
                    }
                }
            }
        }
    }

    #[test]
    fn json_body_flattening() {
        let mut out = Vec::new();
        let j = Json::parse("[1, [2, 3], 4]").unwrap();
        assert!(flatten_numbers(&j, &mut out));
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
        let j = Json::parse("{\"data\": [5, 6]}").unwrap();
        let mut out = Vec::new();
        assert!(flatten_numbers(&j, &mut out));
        assert_eq!(out, vec![5.0, 6.0]);
        let j = Json::parse("[1, \"x\"]").unwrap();
        assert!(!flatten_numbers(&j, &mut Vec::new()));
    }
}
