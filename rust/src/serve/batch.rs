//! Batched serving front-end: coalesce single-image requests into batched
//! engine forwards under a max-batch / max-wait policy, sharded across a
//! pool of engines for multi-core serving.
//!
//! `shards` worker threads each own one [`ServeEngine`] (and therefore its
//! scratch arenas); all shards share ONE read-only plan
//! ([`ServeEngine::fork`]), so weights are resident once no matter the
//! shard count. Clients submit single images over an mpsc channel and
//! block on a per-request response channel. A free shard takes the queue
//! lock, drains up to `max_batch` images (waiting at most `max_wait` past
//! the first request before launching a partial batch), releases the lock
//! and computes — so one shard collects while its siblings run forwards.
//! The lock is only ever held while *collecting*, which keeps shard
//! hand-off at queue speed under load.
//!
//! Each shard runs its forwards under a near-equal slice of the
//! machine's thread budget ([`parallel::split_budget`] — the remainder
//! of `PALLAS_THREADS / shards` is spread over the first shards, floor
//! 1/shard): at shards=1 the engine keeps full intra-op parallelism (the
//! PR-2 behavior); at shards=cores, inter-request parallelism takes over
//! completely. Multi-shard layouts additionally pin each shard (and the
//! pool threads serving its forwards) to a distinct NUMA-aware core set
//! ([`crate::util::topo`]), unless `BatchPolicy::pin` is off or
//! `PALLAS_NO_PIN=1` — placement only, never results.
//!
//! **Admission.** The queue is bounded by in-flight depth: a submit past
//! `depth_budget × shards` admitted-but-unanswered requests fails with
//! [`SubmitError::QueueFull`] instead of growing the queue without
//! limit, and a drain ([`Batcher::begin_drain`] / shutdown) fails new
//! submits with [`SubmitError::ShuttingDown`] while in-flight requests
//! complete. Every admission outcome, queue depth, batch fill and
//! service time lands in a shared [`ServeMetrics`]
//! ([`super::telemetry`]) — a few relaxed atomics per event, exported
//! live by the HTTP front-end ([`super::http`]).
//!
//! **Hot-swap.** The plan lives in a generation cell ([`PlanCell`]): one
//! atomic sequence number plus a mutex-guarded `Arc<QuantizedPlan>` and
//! its precomputed identity stamp. [`Batcher::swap_plan`] publishes a new
//! generation (validated to keep the input geometry); each shard checks
//! the sequence between batches — one relaxed-cost load on the hot path —
//! and rebuilds its engine from the new `Arc` when it moved, so in-flight
//! batches always finish on the generation they started on and the old
//! weights are freed once the last shard adopts. Idle shards wake every
//! [`IDLE_RECHECK`] to adopt without traffic. The multi-model wrapper
//! (registry, watcher thread, `.qtz` reload) is [`super::registry`].
//!
//! **Determinism.** Per-image outputs do not depend on which shard served
//! the image, how requests were batched together, or the thread count:
//! every integer kernel computes each image's rows independently with
//! thread-count-invariant math ([`crate::util::parallel`]), so serving
//! results are bit-identical for any (`PALLAS_THREADS`, `shards`) pair —
//! enforced by `rust/tests/pool_serving.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::tensor::int8::kernel::{GemmChoice, Kernel};
use crate::tensor::Tensor;
use crate::util::{parallel, topo};

use super::engine::ServeEngine;
use super::plan::QuantizedPlan;
use super::telemetry::ServeMetrics;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// launch as soon as this many requests are queued
    pub max_batch: usize,
    /// launch a partial batch this long after its first request arrived
    pub max_wait: Duration,
    /// engine shards serving the queue (1 = the single-engine layout);
    /// see `docs/SERVING.md` for sizing guidance
    pub shards: usize,
    /// bounded admission: max in-flight requests (admitted, response not
    /// yet sent) *per shard* — the effective budget is
    /// `depth_budget × shards`, and a submit past it fails with
    /// [`SubmitError::QueueFull`] (the HTTP layer's 429)
    pub depth_budget: usize,
    /// pin each shard's threads to a distinct NUMA-aware core set
    /// ([`crate::util::topo`]). On by default for multi-shard layouts;
    /// `PALLAS_NO_PIN=1` (or the serve CLI's `--no-pin`) overrides this
    /// process-wide. Placement only — results are bit-identical either way.
    pub pin: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(5),
            shards: 1,
            depth_budget: 128,
            pin: true,
        }
    }
}

/// Why a [`BatcherHandle::submit`] was refused — the admission outcomes
/// the HTTP front-end maps onto status codes (429 / 503 / 400).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// in-flight depth is at the admission budget; retry after a drain
    QueueFull { budget: u64 },
    /// the batcher is draining or has shut down
    ShuttingDown,
    /// image numel doesn't match the plan's input geometry
    BadShape { got: usize, want: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { budget } => {
                write!(f, "queue full: {budget} requests already in flight")
            }
            SubmitError::ShuttingDown => write!(f, "batcher is shutting down"),
            SubmitError::BadShape { got, want } => {
                write!(f, "bad image shape: {got} values, plan expects {want}")
            }
        }
    }
}

/// Why a [`Batcher::swap_plan`] was refused: the replacement must keep
/// the input geometry outstanding handles were validated against.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SwapError {
    ShapeMismatch { got: Vec<usize>, want: Vec<usize> },
}

impl std::fmt::Display for SwapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SwapError::ShapeMismatch { got, want } => {
                write!(f, "swap rejected: plan input {got:?} differs from serving input {want:?}")
            }
        }
    }
}

impl std::error::Error for SwapError {}

/// Identity snapshot of one plan generation — captured once per swap so
/// `/healthz` and `/metrics` never pay the O(weight-bytes) plan hash on
/// the scrape path. Cheap to clone.
#[derive(Clone, Debug)]
pub struct PlanStamp {
    /// 1 at boot, +1 per successful [`Batcher::swap_plan`]
    pub generation: u64,
    /// [`QuantizedPlan::plan_id`] in hex
    pub id_hex: String,
    pub weight_bytes: usize,
    pub w8_ops: usize,
    pub w4_ops: usize,
    pub in_shape: Vec<usize>,
    /// autotuned GEMM choice per conv/dense op (plan order) — what
    /// `serve-bench` prints and `/metrics` exports as `pallas_plan_kernel`
    pub op_kernels: Vec<(String, GemmChoice)>,
    /// wall-clock the autotuner spent timing candidates at compile time
    /// (0.0 when `PALLAS_AUTOTUNE=0` pinned the heuristic)
    pub autotune_ms: f64,
}

fn stamp_of(plan: &QuantizedPlan, generation: u64) -> PlanStamp {
    let dtypes = plan.op_dtypes();
    let w4_ops = dtypes.iter().filter(|(_, d)| *d == "w4").count();
    PlanStamp {
        generation,
        id_hex: format!("{:016x}", plan.plan_id()),
        weight_bytes: plan.weight_bytes(),
        w8_ops: dtypes.len() - w4_ops,
        w4_ops,
        in_shape: plan.in_shape.clone(),
        op_kernels: plan.op_choices(),
        autotune_ms: plan.autotune_ms,
    }
}

/// The generation cell: the ONE place the live plan `Arc` is published.
/// Shard workers watch `seq` (a single relaxed-cost atomic load between
/// batches) and take the lock only when it moved, so the steady state
/// adds one uncontended load per batch to the hot path. Once every shard
/// has adopted a newer generation, nothing holds the old `Arc` and the
/// old weights are freed — asserted by the strong-count probe in
/// `rust/tests/registry_serving.rs`.
struct PlanCell {
    seq: AtomicU64,
    cur: Mutex<(Arc<QuantizedPlan>, PlanStamp)>,
}

impl PlanCell {
    fn new(plan: Arc<QuantizedPlan>) -> PlanCell {
        let stamp = stamp_of(&plan, 1);
        PlanCell { seq: AtomicU64::new(1), cur: Mutex::new((plan, stamp)) }
    }

    fn generation(&self) -> u64 {
        self.seq.load(Ordering::Acquire)
    }

    fn current(&self) -> (Arc<QuantizedPlan>, PlanStamp) {
        let g = self.cur.lock().unwrap_or_else(|e| e.into_inner());
        (Arc::clone(&g.0), g.1.clone())
    }

    fn publish(&self, plan: Arc<QuantizedPlan>) -> u64 {
        let mut g = self.cur.lock().unwrap_or_else(|e| e.into_inner());
        let generation = g.1.generation + 1;
        g.1 = stamp_of(&plan, generation);
        g.0 = plan;
        self.seq.store(generation, Ordering::Release);
        generation
    }
}

/// A read-only window onto a batcher's generation cell — what the HTTP
/// front-end holds so `/healthz` and `/metrics` report the *live*
/// generation after a hot-swap, without keeping a plan `Arc` pinned.
#[derive(Clone)]
pub struct PlanView {
    cell: Arc<PlanCell>,
}

impl PlanView {
    pub fn generation(&self) -> u64 {
        self.cell.generation()
    }

    pub fn stamp(&self) -> PlanStamp {
        self.cell.current().1
    }
}

struct Request {
    /// one image [C, H, W]
    img: Tensor,
    /// where the dequantized output row goes
    resp: SyncSender<Vec<f32>>,
    /// submit time — start of the service-time measurement
    t0: Instant,
}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<Request>,
    /// expected image numel (the plan's C*H*W) — validated at submit so a
    /// malformed request is rejected at its source, never in a shard
    per: usize,
    metrics: Arc<ServeMetrics>,
}

impl BatcherHandle {
    /// Enqueue one image; returns the channel the result row arrives on,
    /// or the admission failure: geometry mismatch, in-flight depth at
    /// budget, or drain/shutdown. Admission is lock-free (one CAS on the
    /// in-flight counter) and every outcome is counted in
    /// [`ServeMetrics`].
    pub fn submit(&self, img: Tensor) -> Result<Receiver<Vec<f32>>, SubmitError> {
        let m = &*self.metrics;
        if img.numel() != self.per {
            m.rejected_shape.inc();
            return Err(SubmitError::BadShape { got: img.numel(), want: self.per });
        }
        if m.draining() {
            m.rejected_draining.inc();
            return Err(SubmitError::ShuttingDown);
        }
        if !m.try_admit() {
            m.rejected_full.inc();
            return Err(SubmitError::QueueFull { budget: m.budget() });
        }
        let (rtx, rrx) = mpsc::sync_channel(1);
        let req = Request { img, resp: rtx, t0: Instant::now() };
        if self.tx.send(req).is_err() {
            // workers gone (shutdown raced the drain flag)
            m.release_admission();
            m.rejected_draining.inc();
            return Err(SubmitError::ShuttingDown);
        }
        m.submitted.inc();
        m.queue_depth.inc();
        Ok(rrx)
    }

    /// The live metrics shared with the batcher.
    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }
}

pub struct Batcher {
    tx: Option<Sender<Request>>,
    per: usize,
    shards: usize,
    /// the live generation: plan `Arc` + identity stamp, swapped by
    /// [`Batcher::swap_plan`] and adopted by shard workers between
    /// batches. The batcher itself keeps no direct plan reference, so an
    /// old generation is freed as soon as the last shard moves off it.
    cell: Arc<PlanCell>,
    kernel: Kernel,
    metrics: Arc<ServeMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn `policy.shards` worker threads, one engine each: the last
    /// owns `engine` itself, the rest own [`ServeEngine::fork`]s of it
    /// (shared plan, private scratch — the distinction is unobservable,
    /// forks are exact siblings). Uses the whole machine thread budget
    /// ([`parallel::num_threads`]); a multi-model registry divides the
    /// budget instead via [`Batcher::with_threads`].
    pub fn new(engine: ServeEngine, policy: BatchPolicy) -> Batcher {
        Batcher::with_threads(engine, policy, parallel::num_threads())
    }

    /// [`Batcher::new`] under an explicit intra-op thread budget —
    /// `thread_budget` threads are divided across the shards. The
    /// registry gives each model an equal slice of the machine so
    /// per-model batchers coexist without oversubscribing cores.
    pub fn with_threads(engine: ServeEngine, policy: BatchPolicy, thread_budget: usize) -> Batcher {
        Batcher::with_placement(engine, policy, thread_budget, 0)
    }

    /// [`Batcher::with_threads`] with an explicit core-slot offset for the
    /// pinned placement: shard `i` gets [`parallel::split_budget`]`(total,
    /// shards, i)` threads and (when `policy.pin` and pinning is enabled)
    /// a matching set of consecutive node-major cores starting at
    /// `core_offset` ([`topo::shard_core_sets`]). The registry stacks
    /// several models onto disjoint slots by passing cumulative offsets.
    pub fn with_placement(
        engine: ServeEngine,
        policy: BatchPolicy,
        thread_budget: usize,
        core_offset: usize,
    ) -> Batcher {
        assert!(policy.max_batch >= 1);
        assert!(policy.shards >= 1);
        assert!(policy.depth_budget >= 1);
        let per: usize = engine.plan.in_shape.iter().product();
        let cell = Arc::new(PlanCell::new(Arc::clone(&engine.plan)));
        let kernel = engine.kernel();
        let metrics = Arc::new(ServeMetrics::new(
            policy.shards,
            policy.depth_budget.saturating_mul(policy.shards),
        ));
        metrics.generation.set(1);
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        // divide the budget: intra-op threads recede as shards take
        // over. Near-equal split with the remainder spread over the first
        // shards, so e.g. 16 threads / 3 shards = 6+5+5 rather than
        // stranding a core on floor(16/3).
        let total = thread_budget.max(1);
        let budgets: Vec<usize> =
            (0..policy.shards).map(|i| parallel::split_budget(total, policy.shards, i)).collect();
        // NUMA-aware placement: carve one consecutive node-major core set
        // per shard, sized to its thread budget. Single-shard layouts skip
        // pinning — the whole machine is already the right place.
        let core_sets: Option<Vec<Arc<[usize]>>> =
            (policy.pin && policy.shards > 1 && topo::pinning_enabled())
                .then(|| topo::shard_core_sets(&budgets, core_offset));
        let mut engines = Vec::with_capacity(policy.shards);
        for _ in 1..policy.shards {
            engines.push(engine.fork());
        }
        engines.push(engine);
        let workers = engines
            .into_iter()
            .enumerate()
            .map(|(i, eng)| {
                let threads = budgets[i];
                let cores = core_sets.as_ref().map(|s| Arc::clone(&s[i]));
                let rx = Arc::clone(&rx);
                let metrics = Arc::clone(&metrics);
                let cell = Arc::clone(&cell);
                std::thread::Builder::new()
                    .name(format!("serve-shard-{i}"))
                    .spawn(move || {
                        // bind this shard (and, transitively, the pool
                        // units its forwards submit) to its core set
                        parallel::pin_thread_and_units(cores);
                        worker_loop(eng, policy, rx, cell, threads, metrics, i)
                    })
                    .expect("spawn shard worker")
            })
            .collect();
        Batcher { tx: Some(tx), per, shards: policy.shards, cell, kernel, metrics, workers }
    }

    /// Publish a new plan generation without stopping the world: the
    /// `Arc` is swapped atomically under the cell lock, each shard worker
    /// adopts it between batches (in-flight batches finish on the old
    /// generation), and the old weights are freed once the last shard
    /// moves off them. The replacement must keep the serving input
    /// geometry — outstanding [`BatcherHandle`]s validated against it.
    /// Returns the new generation number.
    pub fn swap_plan(&self, plan: QuantizedPlan) -> Result<u64, SwapError> {
        let want = self.cell.current().1.in_shape;
        if plan.in_shape != want {
            return Err(SwapError::ShapeMismatch { got: plan.in_shape.clone(), want });
        }
        let generation = self.cell.publish(Arc::new(plan));
        self.metrics.generation.set(generation as i64);
        Ok(generation)
    }

    /// The generation currently published (shards may still be finishing
    /// a batch on the previous one).
    pub fn generation(&self) -> u64 {
        self.cell.generation()
    }

    /// Identity snapshot of the published generation (precomputed at
    /// swap, O(1) to read) — what `/healthz` and `/metrics` report.
    pub fn plan_stamp(&self) -> PlanStamp {
        self.cell.current().1
    }

    /// A cloneable live view of the generation cell (see [`PlanView`]).
    pub fn plan_view(&self) -> PlanView {
        PlanView { cell: Arc::clone(&self.cell) }
    }

    pub fn handle(&self) -> BatcherHandle {
        BatcherHandle {
            tx: self.tx.as_ref().expect("batcher running").clone(),
            per: self.per,
            metrics: Arc::clone(&self.metrics),
        }
    }

    /// Number of engine shards serving the queue.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The published plan generation (read-only). A clone of the live
    /// `Arc` at call time — the caller's reference does NOT pin future
    /// generations, and holding it across a swap keeps only the old one.
    pub fn plan(&self) -> Arc<QuantizedPlan> {
        self.cell.current().0
    }

    /// The GEMM micro-kernel every shard dispatches to.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Live serving telemetry (shared with every handle and worker).
    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }

    /// Start a graceful drain without blocking: new submits fail with
    /// [`SubmitError::ShuttingDown`] from this point on, while admitted
    /// requests keep flowing to completion. [`Batcher::shutdown`] (or
    /// drop) still joins the workers.
    pub fn begin_drain(&self) {
        self.metrics.begin_drain();
    }

    /// Convenience: submit directly on the batcher.
    pub fn submit(&self, img: Tensor) -> Result<Receiver<Vec<f32>>, SubmitError> {
        self.handle().submit(img)
    }

    /// Stop accepting new requests, let every shard drain the queue
    /// (in-flight requests still get responses), then join the workers.
    /// Drop any cloned [`BatcherHandle`]s first: an outstanding handle
    /// keeps the queue open, so shards would keep serving (and this call
    /// would block) until it dies.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.metrics.begin_drain(); // reject new submits from live handles
        self.tx.take(); // close the channel; shards exit after draining
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Open-loop load generator for the serving benchmarks: submit
/// `n_requests` images (cycling through `pool`) at a fixed arrival rate
/// and return per-request latencies in milliseconds. A drainer thread
/// receives results in submit order; a single shard completes batches
/// FIFO so drain time tracks completion time exactly, while multiple
/// shards may reorder completions slightly — the drain-order measurement
/// is then a tight upper bound on each request's latency.
pub fn offered_load_latencies(
    batcher: &Batcher,
    pool: &[Tensor],
    n_requests: usize,
    rate_per_sec: f64,
) -> Vec<f64> {
    assert!(!pool.is_empty() && rate_per_sec > 0.0);
    let interval = Duration::from_secs_f64(1.0 / rate_per_sec);
    let (ltx, lrx) = mpsc::channel::<(Instant, Receiver<Vec<f32>>)>();
    let drainer = std::thread::spawn(move || {
        let mut lat = Vec::new();
        while let Ok((t0, rx)) = lrx.recv() {
            if rx.recv().is_ok() {
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
            }
        }
        lat
    });
    let start = Instant::now();
    for i in 0..n_requests {
        let target = start + interval.mul_f64(i as f64);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let img = pool[i % pool.len()].clone();
        let t0 = Instant::now();
        if let Ok(rx) = batcher.submit(img) {
            let _ = ltx.send((t0, rx));
        }
    }
    drop(ltx);
    drainer.join().unwrap_or_default()
}

/// Closed-loop batch-heavy load generator for the shard-scaling
/// benchmarks: `clients` submitter threads each keep a window of requests
/// in flight (submit ahead, drain behind) until `n_requests` total have
/// completed; returns aggregate throughput in images/sec. The queue never
/// runs dry, so the number is compute-bound — the regime a shard sweep is
/// meant to move, as opposed to the latency-bound open-loop measurement
/// above.
pub fn saturation_throughput(
    batcher: &Batcher,
    pool: &[Tensor],
    n_requests: usize,
    clients: usize,
) -> f64 {
    assert!(!pool.is_empty() && clients >= 1);
    let per_client = n_requests.div_ceil(clients);
    let start = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let h = batcher.handle();
            s.spawn(move || {
                const WINDOW: usize = 32;
                let mut inflight = std::collections::VecDeque::with_capacity(WINDOW);
                for i in 0..per_client {
                    let img = pool[(c + i * clients) % pool.len()].clone();
                    if let Ok(rx) = h.submit(img) {
                        inflight.push_back(rx);
                    }
                    if inflight.len() >= WINDOW {
                        let _ = inflight.pop_front().expect("window nonempty").recv();
                    }
                }
                for rx in inflight {
                    let _ = rx.recv();
                }
            });
        }
    });
    (per_client * clients) as f64 / start.elapsed().as_secs_f64()
}

/// How long an idle shard waits for a first request before releasing the
/// queue lock to re-check the generation cell. Bounds hot-swap adoption
/// latency on an idle server at roughly `shards × IDLE_RECHECK`.
const IDLE_RECHECK: Duration = Duration::from_millis(25);

/// One shard: adopt the published plan generation if it moved, collect a
/// batch under the shared queue lock, release it, compute, respond;
/// repeat until the queue is closed AND drained. Adoption happens only
/// between batches, so a batch is always computed by exactly one
/// generation — never a torn mix.
fn worker_loop(
    mut engine: ServeEngine,
    policy: BatchPolicy,
    rx: Arc<Mutex<Receiver<Request>>>,
    cell: Arc<PlanCell>,
    threads: usize,
    metrics: Arc<ServeMetrics>,
    shard: usize,
) {
    let per: usize = engine.plan.in_shape.iter().product();
    // the engine was built from generation 1's plan; if a swap already
    // landed, the check below adopts it before the first batch
    let mut my_generation = 1u64;
    loop {
        if cell.generation() != my_generation {
            let (plan, stamp) = cell.current();
            engine.adopt_plan(plan);
            my_generation = stamp.generation;
        }
        let batch = {
            let q = match rx.lock() {
                Ok(g) => g,
                Err(_) => return, // a sibling shard panicked mid-collect
            };
            // wait for the first request of the batch, but wake up every
            // IDLE_RECHECK to let an idle shard notice a hot-swap;
            // Disconnected means every sender is gone and the queue is
            // empty — fully drained
            let first = match q.recv_timeout(IDLE_RECHECK) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return,
            };
            metrics.queue_depth.dec();
            let deadline = Instant::now() + policy.max_wait;
            let mut batch = vec![first];
            while batch.len() < policy.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match q.recv_timeout(deadline - now) {
                    Ok(r) => {
                        metrics.queue_depth.dec();
                        batch.push(r);
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            batch
        };
        run_batch(&mut engine, per, threads, batch, &metrics, shard);
    }
}

/// Stack [C,H,W] images into one [B,C,H,W] forward and scatter the
/// dequantized rows back to their requesters. `submit` validated the
/// geometry, so every request in the batch is well-formed; a client that
/// dropped its receiver just misses its row. Telemetry (batch fill,
/// per-shard counters, service time, admission release) is a handful of
/// relaxed atomics around the forward — off the hot path.
fn run_batch(
    engine: &mut ServeEngine,
    per: usize,
    threads: usize,
    batch: Vec<Request>,
    metrics: &ServeMetrics,
    shard: usize,
) {
    debug_assert!(batch.iter().all(|r| r.img.numel() == per));
    if batch.is_empty() {
        return;
    }
    let b = batch.len();
    let stats = &metrics.shards[shard];
    metrics.batch_fill.observe(b as f64);
    stats.batches.inc();
    stats.images.add(b as u64);
    stats.busy.set(1);
    let mut data = Vec::with_capacity(b * per);
    for r in &batch {
        data.extend_from_slice(&r.img.data);
    }
    let mut shape = vec![b];
    shape.extend_from_slice(&engine.plan.in_shape);
    let x = Tensor::from_vec(&shape, data);
    let out = parallel::with_threads(threads, || engine.forward(&x));
    stats.busy.set(0);
    let row = out.numel() / b;
    for (i, r) in batch.into_iter().enumerate() {
        let _ = r.resp.send(out.data[i * row..(i + 1) * row].to_vec());
        metrics.service_time.observe(r.t0.elapsed().as_secs_f64());
        metrics.responses.inc();
        metrics.release_admission();
    }
}
