//! Batched serving front-end: coalesce single-image requests into batched
//! engine forwards under a max-batch / max-wait policy, sharded across a
//! pool of engines for multi-core serving.
//!
//! `shards` worker threads each own one [`ServeEngine`] (and therefore its
//! scratch arenas); all shards share ONE read-only plan
//! ([`ServeEngine::fork`]), so weights are resident once no matter the
//! shard count. Clients submit single images over an mpsc channel and
//! block on a per-request response channel. A free shard takes the queue
//! lock, drains up to `max_batch` images (waiting at most `max_wait` past
//! the first request before launching a partial batch), releases the lock
//! and computes — so one shard collects while its siblings run forwards.
//! The lock is only ever held while *collecting*, which keeps shard
//! hand-off at queue speed under load.
//!
//! Each shard runs its forwards under an equal slice of the machine's
//! thread budget (`PALLAS_THREADS / shards`, floor 1): at shards=1 the
//! engine keeps full intra-op parallelism (the PR-2 behavior); at
//! shards=cores, inter-request parallelism takes over completely.
//!
//! **Determinism.** Per-image outputs do not depend on which shard served
//! the image, how requests were batched together, or the thread count:
//! every integer kernel computes each image's rows independently with
//! thread-count-invariant math ([`crate::util::parallel`]), so serving
//! results are bit-identical for any (`PALLAS_THREADS`, `shards`) pair —
//! enforced by `rust/tests/pool_serving.rs`.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::tensor::Tensor;
use crate::util::parallel;

use super::engine::ServeEngine;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// launch as soon as this many requests are queued
    pub max_batch: usize,
    /// launch a partial batch this long after its first request arrived
    pub max_wait: Duration,
    /// engine shards serving the queue (1 = the single-engine layout);
    /// see `docs/SERVING.md` for sizing guidance
    pub shards: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(5), shards: 1 }
    }
}

struct Request {
    /// one image [C, H, W]
    img: Tensor,
    /// where the dequantized output row goes
    resp: SyncSender<Vec<f32>>,
}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<Request>,
    /// expected image numel (the plan's C*H*W) — validated at submit so a
    /// malformed request is rejected at its source, never in a shard
    per: usize,
}

impl BatcherHandle {
    /// Enqueue one image; returns the channel the result row arrives on.
    /// Returns `None` if the image geometry is wrong or the batcher has
    /// shut down.
    pub fn submit(&self, img: Tensor) -> Option<Receiver<Vec<f32>>> {
        if img.numel() != self.per {
            return None;
        }
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx.send(Request { img, resp: rtx }).ok()?;
        Some(rrx)
    }
}

pub struct Batcher {
    tx: Option<Sender<Request>>,
    per: usize,
    shards: usize,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn `policy.shards` worker threads, one engine each: the last
    /// owns `engine` itself, the rest own [`ServeEngine::fork`]s of it
    /// (shared plan, private scratch — the distinction is unobservable,
    /// forks are exact siblings).
    pub fn new(engine: ServeEngine, policy: BatchPolicy) -> Batcher {
        assert!(policy.max_batch >= 1);
        assert!(policy.shards >= 1);
        let per: usize = engine.plan.in_shape.iter().product();
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        // divide the machine: intra-op threads recede as shards take
        // over. Near-equal split with the remainder spread over the first
        // shards (as in `parallel::split_ranges`), so e.g. 16 threads /
        // 3 shards = 6+5+5 rather than stranding a core on floor(16/3).
        // Captured here so the submitter's thread policy propagates.
        let total = parallel::num_threads();
        let mut engines = Vec::with_capacity(policy.shards);
        for _ in 1..policy.shards {
            engines.push(engine.fork());
        }
        engines.push(engine);
        let workers = engines
            .into_iter()
            .enumerate()
            .map(|(i, eng)| {
                let threads =
                    (total / policy.shards + usize::from(i < total % policy.shards)).max(1);
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("serve-shard-{i}"))
                    .spawn(move || worker_loop(eng, policy, rx, threads))
                    .expect("spawn shard worker")
            })
            .collect();
        Batcher { tx: Some(tx), per, shards: policy.shards, workers }
    }

    pub fn handle(&self) -> BatcherHandle {
        BatcherHandle {
            tx: self.tx.as_ref().expect("batcher running").clone(),
            per: self.per,
        }
    }

    /// Number of engine shards serving the queue.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Convenience: submit directly on the batcher.
    pub fn submit(&self, img: Tensor) -> Option<Receiver<Vec<f32>>> {
        self.handle().submit(img)
    }

    /// Stop accepting new requests, let every shard drain the queue
    /// (in-flight requests still get responses), then join the workers.
    /// Drop any cloned [`BatcherHandle`]s first: an outstanding handle
    /// keeps the queue open, so shards would keep serving (and this call
    /// would block) until it dies.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.tx.take(); // close the channel; shards exit after draining
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Open-loop load generator for the serving benchmarks: submit
/// `n_requests` images (cycling through `pool`) at a fixed arrival rate
/// and return per-request latencies in milliseconds. A drainer thread
/// receives results in submit order; a single shard completes batches
/// FIFO so drain time tracks completion time exactly, while multiple
/// shards may reorder completions slightly — the drain-order measurement
/// is then a tight upper bound on each request's latency.
pub fn offered_load_latencies(
    batcher: &Batcher,
    pool: &[Tensor],
    n_requests: usize,
    rate_per_sec: f64,
) -> Vec<f64> {
    assert!(!pool.is_empty() && rate_per_sec > 0.0);
    let interval = Duration::from_secs_f64(1.0 / rate_per_sec);
    let (ltx, lrx) = mpsc::channel::<(Instant, Receiver<Vec<f32>>)>();
    let drainer = std::thread::spawn(move || {
        let mut lat = Vec::new();
        while let Ok((t0, rx)) = lrx.recv() {
            if rx.recv().is_ok() {
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
            }
        }
        lat
    });
    let start = Instant::now();
    for i in 0..n_requests {
        let target = start + interval.mul_f64(i as f64);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let img = pool[i % pool.len()].clone();
        let t0 = Instant::now();
        if let Some(rx) = batcher.submit(img) {
            let _ = ltx.send((t0, rx));
        }
    }
    drop(ltx);
    drainer.join().unwrap_or_default()
}

/// Closed-loop batch-heavy load generator for the shard-scaling
/// benchmarks: `clients` submitter threads each keep a window of requests
/// in flight (submit ahead, drain behind) until `n_requests` total have
/// completed; returns aggregate throughput in images/sec. The queue never
/// runs dry, so the number is compute-bound — the regime a shard sweep is
/// meant to move, as opposed to the latency-bound open-loop measurement
/// above.
pub fn saturation_throughput(
    batcher: &Batcher,
    pool: &[Tensor],
    n_requests: usize,
    clients: usize,
) -> f64 {
    assert!(!pool.is_empty() && clients >= 1);
    let per_client = n_requests.div_ceil(clients);
    let start = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let h = batcher.handle();
            s.spawn(move || {
                const WINDOW: usize = 32;
                let mut inflight = std::collections::VecDeque::with_capacity(WINDOW);
                for i in 0..per_client {
                    let img = pool[(c + i * clients) % pool.len()].clone();
                    if let Some(rx) = h.submit(img) {
                        inflight.push_back(rx);
                    }
                    if inflight.len() >= WINDOW {
                        let _ = inflight.pop_front().expect("window nonempty").recv();
                    }
                }
                for rx in inflight {
                    let _ = rx.recv();
                }
            });
        }
    });
    (per_client * clients) as f64 / start.elapsed().as_secs_f64()
}

/// One shard: collect a batch under the shared queue lock, release it,
/// compute, respond; repeat until the queue is closed AND drained.
fn worker_loop(
    mut engine: ServeEngine,
    policy: BatchPolicy,
    rx: Arc<Mutex<Receiver<Request>>>,
    threads: usize,
) {
    let per: usize = engine.plan.in_shape.iter().product();
    loop {
        let batch = {
            let q = match rx.lock() {
                Ok(g) => g,
                Err(_) => return, // a sibling shard panicked mid-collect
            };
            // block for the first request of the batch; Err means every
            // sender is gone and the queue is empty — fully drained
            let first = match q.recv() {
                Ok(r) => r,
                Err(_) => return,
            };
            let deadline = Instant::now() + policy.max_wait;
            let mut batch = vec![first];
            while batch.len() < policy.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match q.recv_timeout(deadline - now) {
                    Ok(r) => batch.push(r),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            batch
        };
        run_batch(&mut engine, per, threads, batch);
    }
}

/// Stack [C,H,W] images into one [B,C,H,W] forward and scatter the
/// dequantized rows back to their requesters. A malformed request
/// (`submit` already rejects these — belt and braces) is dropped here,
/// failing only its own response channel; a client that dropped its
/// receiver just misses its row.
fn run_batch(engine: &mut ServeEngine, per: usize, threads: usize, mut batch: Vec<Request>) {
    batch.retain(|r| r.img.numel() == per);
    if batch.is_empty() {
        return;
    }
    let b = batch.len();
    let mut data = Vec::with_capacity(b * per);
    for r in &batch {
        data.extend_from_slice(&r.img.data);
    }
    let mut shape = vec![b];
    shape.extend_from_slice(&engine.plan.in_shape);
    let x = Tensor::from_vec(&shape, data);
    let out = parallel::with_threads(threads, || engine.forward(&x));
    let row = out.numel() / b;
    for (i, r) in batch.into_iter().enumerate() {
        let _ = r.resp.send(out.data[i * row..(i + 1) * row].to_vec());
    }
}
