//! Batched serving front-end: coalesce single-image requests into batched
//! engine forwards under a max-batch / max-wait policy.
//!
//! One worker thread owns the [`ServeEngine`] (and therefore its scratch
//! arenas); clients submit single images over an mpsc channel and block on
//! a per-request response channel. The worker drains the queue up to
//! `max_batch` images, waiting at most `max_wait` past the first request
//! before launching a partial batch — the classic latency/throughput
//! trade-off surface that `benches/serving.rs` maps out.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::time::{Duration, Instant};

use crate::tensor::Tensor;

use super::engine::ServeEngine;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// launch as soon as this many requests are queued
    pub max_batch: usize,
    /// launch a partial batch this long after its first request arrived
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(5) }
    }
}

struct Request {
    /// one image [C, H, W]
    img: Tensor,
    /// where the dequantized output row goes
    resp: SyncSender<Vec<f32>>,
}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<Request>,
    /// expected image numel (the plan's C*H*W) — validated at submit so a
    /// malformed request is rejected at its source, never in the worker
    per: usize,
}

impl BatcherHandle {
    /// Enqueue one image; returns the channel the result row arrives on.
    /// Returns `None` if the image geometry is wrong or the batcher has
    /// shut down.
    pub fn submit(&self, img: Tensor) -> Option<Receiver<Vec<f32>>> {
        if img.numel() != self.per {
            return None;
        }
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx.send(Request { img, resp: rtx }).ok()?;
        Some(rrx)
    }
}

pub struct Batcher {
    tx: Option<Sender<Request>>,
    per: usize,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the worker thread that owns `engine`.
    pub fn new(engine: ServeEngine, policy: BatchPolicy) -> Batcher {
        assert!(policy.max_batch >= 1);
        let per: usize = engine.plan.in_shape.iter().product();
        let (tx, rx) = mpsc::channel::<Request>();
        let worker = std::thread::spawn(move || worker_loop(engine, policy, rx));
        Batcher { tx: Some(tx), per, worker: Some(worker) }
    }

    pub fn handle(&self) -> BatcherHandle {
        BatcherHandle {
            tx: self.tx.as_ref().expect("batcher running").clone(),
            per: self.per,
        }
    }

    /// Convenience: submit directly on the batcher.
    pub fn submit(&self, img: Tensor) -> Option<Receiver<Vec<f32>>> {
        self.handle().submit(img)
    }

    /// Drain outstanding requests and stop the worker.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.tx.take(); // close the channel; worker exits after draining
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Open-loop load generator for the serving benchmarks: submit
/// `n_requests` images (cycling through `pool`) at a fixed arrival rate
/// and return per-request latencies in milliseconds. A drainer thread
/// receives results in submit order — the worker completes batches FIFO,
/// so drain time tracks completion time.
pub fn offered_load_latencies(
    batcher: &Batcher,
    pool: &[Tensor],
    n_requests: usize,
    rate_per_sec: f64,
) -> Vec<f64> {
    assert!(!pool.is_empty() && rate_per_sec > 0.0);
    let interval = Duration::from_secs_f64(1.0 / rate_per_sec);
    let (ltx, lrx) = mpsc::channel::<(Instant, Receiver<Vec<f32>>)>();
    let drainer = std::thread::spawn(move || {
        let mut lat = Vec::new();
        while let Ok((t0, rx)) = lrx.recv() {
            if rx.recv().is_ok() {
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
            }
        }
        lat
    });
    let start = Instant::now();
    for i in 0..n_requests {
        let target = start + interval.mul_f64(i as f64);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let img = pool[i % pool.len()].clone();
        let t0 = Instant::now();
        if let Some(rx) = batcher.submit(img) {
            let _ = ltx.send((t0, rx));
        }
    }
    drop(ltx);
    drainer.join().unwrap_or_default()
}

fn worker_loop(mut engine: ServeEngine, policy: BatchPolicy, rx: Receiver<Request>) {
    let per: usize = engine.plan.in_shape.iter().product();
    loop {
        // block for the first request of the batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone
        };
        let deadline = Instant::now() + policy.max_wait;
        let mut batch = vec![first];
        while batch.len() < policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // stack [C,H,W] images into one [B,C,H,W] forward; a malformed
        // request (submit() already rejects these — belt and braces) is
        // dropped here, failing only its own response channel
        batch.retain(|r| r.img.numel() == per);
        if batch.is_empty() {
            continue;
        }
        let b = batch.len();
        let mut data = Vec::with_capacity(b * per);
        for r in &batch {
            data.extend_from_slice(&r.img.data);
        }
        let mut shape = vec![b];
        shape.extend_from_slice(&engine.plan.in_shape);
        let out = engine.forward(&Tensor::from_vec(&shape, data));
        let row = out.numel() / b;
        for (i, r) in batch.into_iter().enumerate() {
            // a client that dropped its receiver just misses its row
            let _ = r.resp.send(out.data[i * row..(i + 1) * row].to_vec());
        }
    }
}
