//! The integer inference engine: owns a [`QuantizedPlan`] plus reusable
//! scratch, executes batched forwards entirely in the integer domain.
//!
//! `forward` quantizes the f32 input batch once, walks the plan with u8
//! tensors flowing between nodes, and dequantizes the final logits — the
//! only two float touches per request. Weight traffic is 4x smaller than
//! the f32 path and the GEMMs run on i8/u8 with i32 accumulation
//! ([`crate::tensor::int8`]).

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::QuantizedModel;
use crate::nn::Model;
use crate::tensor::int8::kernel::{self, GemmChoice, Kernel};
use crate::tensor::{Tensor, U8Tensor};

use super::ikernels::{
    add_i8, avgpool_i8, concat_i8, conv2d_i8, dense_i8, gpool_i8, relu_i8, upsample_i8,
    Int8Workspace,
};
use super::plan::{compile_plan, ActQ, PlanOp, QuantizedPlan};

pub struct ServeEngine {
    /// The compiled program. Read-only after compilation and shared
    /// (`Arc`) so a sharded [`super::Batcher`] can run one engine per
    /// core without duplicating weights — only the scratch below is
    /// per-engine.
    pub plan: Arc<QuantizedPlan>,
    /// index of each node's last consumer — lets the forward drop
    /// activation tensors as soon as they're dead, keeping the resident
    /// set at the live frontier instead of the whole network
    last_use: Vec<usize>,
    /// GEMM micro-kernel override. `None` (production) runs each op's
    /// plan-cached autotuned [`GemmChoice`]; `Some` (tests, benches, the
    /// differential harness) pins one ISA variant for every op — results
    /// are bit-identical either way, so the override is never needed for
    /// correctness.
    forced: Option<Kernel>,
    ws: Int8Workspace,
}

impl ServeEngine {
    pub fn new(plan: QuantizedPlan) -> ServeEngine {
        ServeEngine::from_shared(Arc::new(plan))
    }

    /// Build an engine over an already-shared plan (fresh scratch).
    pub fn from_shared(plan: Arc<QuantizedPlan>) -> ServeEngine {
        let n = plan.nodes.len();
        let mut last_use = vec![0usize; n];
        for (i, nd) in plan.nodes.iter().enumerate() {
            last_use[i] = i; // unconsumed outputs die right away
            for &j in &nd.inputs {
                last_use[j] = i;
            }
        }
        if n > 0 {
            last_use[n - 1] = usize::MAX; // the output survives the walk
        }
        ServeEngine { plan, last_use, forced: None, ws: Int8Workspace::new() }
    }

    /// Fork a sibling engine: same read-only plan (shared, no weight
    /// copy), same kernel override, fresh private scratch. The unit of
    /// sharding in [`super::Batcher`] — forwards on forks are
    /// bit-identical to forwards on `self` because the plan is immutable
    /// and every kernel is deterministic.
    pub fn fork(&self) -> ServeEngine {
        let mut e = ServeEngine::from_shared(Arc::clone(&self.plan));
        e.forced = self.forced;
        e
    }

    /// Replace the plan in place: rebuild the liveness table and scratch
    /// for `plan`, keeping the kernel override. The hot-swap adoption step
    /// — a shard worker calls this between batches when the generation
    /// cell has moved, dropping its reference to the old generation's Arc.
    pub fn adopt_plan(&mut self, plan: Arc<QuantizedPlan>) {
        let forced = self.forced;
        *self = ServeEngine::from_shared(plan);
        self.forced = forced;
    }

    /// Pin a specific GEMM micro-kernel for every op, overriding the
    /// plan's per-op autotuned choices (tests, benches, the differential
    /// harness). Results are bit-identical across kernels, so this is
    /// never needed for correctness.
    pub fn with_kernel(mut self, kernel: Kernel) -> ServeEngine {
        self.forced = Some(kernel);
        self
    }

    /// The GEMM micro-kernel family this engine dispatches to: the pinned
    /// override if [`ServeEngine::with_kernel`] set one, else the
    /// process-wide heuristic (per-op autotuned choices may still differ
    /// in blocking config; see [`QuantizedPlan::op_choices`]). Reported by
    /// `adaround serve-bench` and `/metrics`.
    pub fn kernel(&self) -> Kernel {
        self.forced.unwrap_or_else(kernel::select)
    }

    /// Compile a float model + its quantized overrides into an engine.
    /// `in_shape` is the per-image geometry, e.g. `[3, 32, 32]`.
    pub fn compile(model: &Model, qm: &QuantizedModel, in_shape: &[usize]) -> Result<ServeEngine> {
        Ok(ServeEngine::new(compile_plan(model, qm, in_shape)?))
    }

    /// [`ServeEngine::compile`] with explicit plan options — e.g.
    /// `PlanOptions { force_w4: true, ..Default::default() }` to
    /// nibble-pack every layer whose codes fit i4 regardless of the
    /// recorded bit width (the w4-vs-w8 comparison in `serve-bench`, and
    /// CI's forced-w4 job), or `autotune: false` to pin the heuristic
    /// kernel choice instead of timing candidates per shape.
    pub fn compile_with(
        model: &Model,
        qm: &QuantizedModel,
        in_shape: &[usize],
        opts: super::plan::PlanOptions,
    ) -> Result<ServeEngine> {
        Ok(ServeEngine::new(super::plan::compile_plan_with(model, qm, in_shape, opts)?))
    }

    /// Stable identity of the compiled plan (forks share it) — see
    /// [`QuantizedPlan::plan_id`]. O(weight bytes); callers that report
    /// it repeatedly (the HTTP front-end) compute it once and cache.
    pub fn plan_id(&self) -> u64 {
        self.plan.plan_id()
    }

    /// Quantization of the final output tensor (for external dequant).
    pub fn out_q(&self) -> ActQ {
        self.plan.nodes.last().expect("empty plan").out_q
    }

    /// Batched forward: f32 images [N, C, H, W] -> dequantized f32 output
    /// (logits [N, classes] for classifiers).
    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        let q = self.forward_quantized(x);
        let aq = self.out_q();
        Tensor {
            shape: q.shape.clone(),
            data: q.data.iter().map(|&v| aq.dequantize(v)).collect(),
        }
    }

    /// Batched forward returning the raw u8 output codes.
    pub fn forward_quantized(&mut self, x: &Tensor) -> U8Tensor {
        assert_eq!(x.ndim(), 4, "expected [N, C, H, W] input");
        assert_eq!(
            &x.shape[1..],
            &self.plan.in_shape[..],
            "engine compiled for input {:?}",
            self.plan.in_shape
        );
        let nodes = &self.plan.nodes;
        let mut vals: Vec<Option<U8Tensor>> = (0..nodes.len()).map(|_| None).collect();
        for (i, nd) in nodes.iter().enumerate() {
            let out = match &nd.op {
                PlanOp::Quantize => {
                    let aq = nd.out_q;
                    U8Tensor {
                        shape: x.shape.clone(),
                        data: x.data.iter().map(|&v| aq.quantize(v)).collect(),
                    }
                }
                PlanOp::Conv { w, p, bias_q, wsum, requant, relu, choice } => {
                    let inp = vals[nd.inputs[0]].as_ref().expect("topological order");
                    let ch: GemmChoice =
                        self.forced.map(GemmChoice::from).unwrap_or(*choice);
                    conv2d_i8(
                        &mut self.ws,
                        ch,
                        inp,
                        w,
                        *p,
                        bias_q,
                        wsum,
                        requant,
                        nd.in_q[0].zp,
                        nd.out_q.zp,
                        *relu,
                    )
                }
                PlanOp::Dense { w, bias_q, wsum, requant, relu, choice } => {
                    let inp = vals[nd.inputs[0]].as_ref().expect("topological order");
                    let ch: GemmChoice =
                        self.forced.map(GemmChoice::from).unwrap_or(*choice);
                    dense_i8(
                        &mut self.ws,
                        ch,
                        inp,
                        w,
                        bias_q,
                        wsum,
                        requant,
                        nd.in_q[0].zp,
                        nd.out_q.zp,
                        *relu,
                    )
                }
                PlanOp::Add { ra, rb, relu } => {
                    let a = vals[nd.inputs[0]].as_ref().expect("topological order");
                    let b = vals[nd.inputs[1]].as_ref().expect("topological order");
                    add_i8(a, b, *ra, *rb, nd.in_q[0].zp, nd.in_q[1].zp, nd.out_q.zp, *relu)
                }
                PlanOp::Relu { r } => {
                    let a = vals[nd.inputs[0]].as_ref().expect("topological order");
                    relu_i8(a, *r, nd.in_q[0].zp, nd.out_q.zp)
                }
                PlanOp::AvgPool { k, stride, r } => {
                    let a = vals[nd.inputs[0]].as_ref().expect("topological order");
                    avgpool_i8(a, *k, *stride, *r, nd.in_q[0].zp, nd.out_q.zp)
                }
                PlanOp::GPool { r, hw } => {
                    let a = vals[nd.inputs[0]].as_ref().expect("topological order");
                    gpool_i8(a, *r, *hw, nd.in_q[0].zp, nd.out_q.zp)
                }
                PlanOp::Upsample { r } => {
                    let a = vals[nd.inputs[0]].as_ref().expect("topological order");
                    upsample_i8(a, *r, nd.in_q[0].zp, nd.out_q.zp)
                }
                PlanOp::Concat { rs } => {
                    let ins: Vec<&U8Tensor> = nd
                        .inputs
                        .iter()
                        .map(|&j| vals[j].as_ref().expect("topological order"))
                        .collect();
                    let zps: Vec<i32> = nd.in_q.iter().map(|q| q.zp).collect();
                    concat_i8(&ins, rs, &zps, nd.out_q.zp)
                }
            };
            vals[i] = Some(out);
            for (j, &lu) in self.last_use.iter().enumerate() {
                if lu == i {
                    vals[j] = None;
                }
            }
        }
        vals.pop().flatten().expect("empty plan")
    }

    /// argmax over the last axis of the quantized output — for classifiers
    /// this equals argmax of the dequantized logits (scale is positive).
    pub fn classify(&mut self, x: &Tensor) -> Vec<usize> {
        let q = self.forward_quantized(x);
        let rows = q.shape[0];
        let cols = q.numel() / rows.max(1);
        (0..rows)
            .map(|r| {
                let row = &q.data[r * cols..(r + 1) * cols];
                let mut best = 0;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }
}
