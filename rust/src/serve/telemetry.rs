//! Live serving telemetry: lock-free counters, gauges and fixed-bucket
//! histograms threaded through the [`super::Batcher`] off the hot path,
//! rendered as Prometheus text by the HTTP front-end ([`super::http`]).
//!
//! Every instrument is a plain atomic — one `fetch_add` per event, no
//! locks, no allocation after construction — so recording a batch or a
//! response costs a few nanoseconds next to a forward that costs
//! micro-to-milliseconds. Histograms use fixed bucket bounds chosen at
//! construction (powers of two for batch fill, log-spaced seconds for
//! service time); observations land in the first bucket whose upper
//! bound covers the value, and the running sum is kept in scaled integer
//! units so integer-valued histograms (batch fill) stay *exact* — the
//! integration tests assert `pallas_batch_fill_sum` equals the ground
//! truth request count, bit for bit.
//!
//! [`ServeMetrics`] also owns the admission state: the in-flight gauge
//! doubles as the bounded-admission counter ([`ServeMetrics::try_admit`]
//! is a CAS loop against the depth budget) and the draining flag is the
//! single source of truth the batcher, the HTTP layer and `/healthz` all
//! read. Metric names are part of the public contract — the full
//! reference table lives in `docs/SERVING.md`.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};

use crate::util::stats::histogram_quantile;

/// Monotonic event counter (Prometheus `counter`).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (Prometheus `gauge`).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram: `bounds.len() + 1` atomic bucket counters
/// (the last is the overflow bucket) plus a running sum in integer units
/// of `1/scale` — `scale = 1.0` makes integer-valued observations exact,
/// `scale = 1e6` keeps seconds at microsecond resolution.
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    sum_scaled: AtomicU64,
    scale: f64,
}

impl Histogram {
    pub fn new(bounds: &[f64], scale: f64) -> Histogram {
        assert!(!bounds.is_empty() && scale > 0.0);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must increase");
        Histogram {
            bounds: bounds.to_vec(),
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum_scaled: AtomicU64::new(0),
            scale,
        }
    }

    pub fn observe(&self, v: f64) {
        let i = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        self.sum_scaled
            .fetch_add((v * self.scale).round().max(0.0) as u64, Ordering::Relaxed);
    }

    /// Total observation count.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Sum of observations (exact when `scale` matches their granularity).
    pub fn sum(&self) -> f64 {
        self.sum_scaled.load(Ordering::Relaxed) as f64 / self.scale
    }

    /// Per-bucket counts, overflow bucket last.
    pub fn snapshot(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Estimated q-quantile (q in 0..=1) by linear interpolation within
    /// the covering bucket; NaN while empty.
    pub fn quantile(&self, q: f64) -> f64 {
        histogram_quantile(&self.bounds, &self.snapshot(), q)
    }

    /// Render in Prometheus histogram exposition format (cumulative
    /// `_bucket{le=...}` series plus `_sum` and `_count`).
    pub fn render(&self, name: &str, help: &str, out: &mut String) {
        let snap = self.snapshot();
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, &b) in self.bounds.iter().enumerate() {
            cum += snap[i];
            let _ = writeln!(out, "{name}_bucket{{le=\"{b}\"}} {cum}");
        }
        cum += snap[self.bounds.len()];
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum {}", self.sum());
        let _ = writeln!(out, "{name}_count {cum}");
    }
}

/// Per-shard serving counters (the queue itself is shared — see
/// `docs/SERVING.md` for what "per shard" means under the shared-queue
/// batcher design).
#[derive(Default)]
pub struct ShardStats {
    /// batches this shard has computed
    pub batches: Counter,
    /// images this shard has computed
    pub images: Counter,
    /// 1 while the shard is inside an engine forward
    pub busy: Gauge,
}

/// All live serving instruments, shared (`Arc`) between the batcher, its
/// shard workers, every [`super::BatcherHandle`] and the HTTP front-end.
pub struct ServeMetrics {
    /// infer requests admitted into the queue
    pub submitted: Counter,
    /// responses delivered back to requesters
    pub responses: Counter,
    /// rejections at admission: in-flight depth at budget
    pub rejected_full: Counter,
    /// rejections at admission: batcher draining / shut down
    pub rejected_draining: Counter,
    /// rejections at admission: image geometry mismatch
    pub rejected_shape: Counter,
    /// requests sitting in the shared queue (admitted, not yet collected
    /// into a batch)
    pub queue_depth: Gauge,
    /// batch sizes at launch; `sum` == images served, `count` == batches
    pub batch_fill: Histogram,
    /// submit-to-response seconds (queue wait + batching wait + forward)
    pub service_time: Histogram,
    /// plan generation currently being served (1 at boot, +1 per
    /// successful hot-swap)
    pub generation: Gauge,
    /// hot reloads that compiled and swapped in a new generation
    pub reloads_ok: Counter,
    /// hot reloads that failed (load or compile error) — the old
    /// generation keeps serving
    pub reloads_failed: Counter,
    /// seconds from reload start to the new generation being published
    /// (load + compile + swap, all off the hot path)
    pub swap_latency: Histogram,
    pub shards: Vec<ShardStats>,
    /// admitted requests whose response has not been sent yet — the
    /// bounded-admission counter
    inflight: AtomicU64,
    /// admission budget: max in-flight requests (depth_budget × shards)
    budget: u64,
    /// set once at drain start; never cleared
    draining: AtomicBool,
}

/// Batch-fill bucket upper bounds (powers of two up to the largest
/// `max_batch` anyone configures in practice).
pub const BATCH_FILL_BOUNDS: [f64; 8] = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];

/// Service-time bucket upper bounds in seconds, log-spaced 0.5ms..5s.
pub const SERVICE_TIME_BOUNDS: [f64; 13] = [
    0.0005, 0.001, 0.002, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
];

impl ServeMetrics {
    pub fn new(shards: usize, budget: usize) -> ServeMetrics {
        ServeMetrics {
            submitted: Counter::default(),
            responses: Counter::default(),
            rejected_full: Counter::default(),
            rejected_draining: Counter::default(),
            rejected_shape: Counter::default(),
            queue_depth: Gauge::default(),
            batch_fill: Histogram::new(&BATCH_FILL_BOUNDS, 1.0),
            service_time: Histogram::new(&SERVICE_TIME_BOUNDS, 1e6),
            generation: Gauge::default(),
            reloads_ok: Counter::default(),
            reloads_failed: Counter::default(),
            swap_latency: Histogram::new(&SERVICE_TIME_BOUNDS, 1e6),
            shards: (0..shards).map(|_| ShardStats::default()).collect(),
            inflight: AtomicU64::new(0),
            budget: budget as u64,
            draining: AtomicBool::new(false),
        }
    }

    /// Try to take one admission slot; `false` means the in-flight depth
    /// is at budget (the caller maps this to 429). Lock-free CAS loop.
    pub fn try_admit(&self) -> bool {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.budget {
                return false;
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
    }

    /// Release one admission slot (response sent, or submit failed after
    /// admission).
    pub fn release_admission(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Flip the drain flag: every subsequent submit is rejected with
    /// `ShuttingDown`; in-flight requests are unaffected.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Render every batcher-level instrument in Prometheus text format.
    /// The HTTP front-end appends its own route/status counters and plan
    /// info lines after this block.
    pub fn render_prometheus(&self, out: &mut String) {
        let counter = |out: &mut String, name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        let gauge = |out: &mut String, name: &str, help: &str, v: i64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        };
        let submitted = self.submitted.get();
        let responses = self.responses.get();
        counter(out, "pallas_infer_requests_total", "infer requests admitted", submitted);
        counter(out, "pallas_infer_responses_total", "infer responses delivered", responses);
        let _ = writeln!(
            out,
            "# HELP pallas_infer_rejected_total infer requests rejected at admission"
        );
        let _ = writeln!(out, "# TYPE pallas_infer_rejected_total counter");
        for (reason, c) in [
            ("queue_full", &self.rejected_full),
            ("draining", &self.rejected_draining),
            ("bad_shape", &self.rejected_shape),
        ] {
            let _ = writeln!(out, "pallas_infer_rejected_total{{reason=\"{reason}\"}} {}", c.get());
        }
        let depth = self.queue_depth.get();
        let inflight = self.inflight() as i64;
        gauge(out, "pallas_queue_depth", "requests waiting in the shared queue", depth);
        gauge(out, "pallas_inflight_requests", "admitted requests not yet answered", inflight);
        let budget = self.budget as i64;
        let draining = i64::from(self.draining());
        gauge(out, "pallas_admission_budget", "max in-flight requests before 429", budget);
        gauge(out, "pallas_draining", "1 once graceful drain has begun", draining);
        for (name, help, pick) in [
            ("pallas_shard_batches_total", "batches computed", 0usize),
            ("pallas_shard_images_total", "images computed", 1),
        ] {
            let _ = writeln!(out, "# HELP {name} {help} by this shard");
            let _ = writeln!(out, "# TYPE {name} counter");
            for (i, s) in self.shards.iter().enumerate() {
                let v = if pick == 0 { s.batches.get() } else { s.images.get() };
                let _ = writeln!(out, "{name}{{shard=\"{i}\"}} {v}");
            }
        }
        let _ = writeln!(out, "# HELP pallas_shard_busy 1 while the shard is inside a forward");
        let _ = writeln!(out, "# TYPE pallas_shard_busy gauge");
        for (i, s) in self.shards.iter().enumerate() {
            let _ = writeln!(out, "pallas_shard_busy{{shard=\"{i}\"}} {}", s.busy.get());
        }
        self.batch_fill
            .render("pallas_batch_fill", "images per launched batch", out);
        self.service_time.render(
            "pallas_service_time_seconds",
            "submit-to-response latency in seconds",
            out,
        );
        for (q, name) in [
            (0.5, "pallas_service_time_seconds_p50"),
            (0.99, "pallas_service_time_seconds_p99"),
        ] {
            let v = self.service_time.quantile(q);
            let v = if v.is_nan() { 0.0 } else { v };
            gauge_f(out, name, "estimated from the service-time histogram", v);
        }
    }

    /// Render the per-model registry series, labeled with `model="<id>"`.
    /// Every registered model gets one of these blocks on `/metrics`
    /// (including single-model servers, where the id is `default`), next
    /// to the classic unlabeled block the default model keeps for
    /// backwards compatibility.
    pub fn render_model_prometheus(&self, model: &str, out: &mut String) {
        let lbl = format!("{{model=\"{model}\"}}");
        let series = |out: &mut String, name: &str, kind: &str, help: &str, v: String| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
            let _ = writeln!(out, "{name}{lbl} {v}");
        };
        series(
            out,
            "pallas_model_generation",
            "gauge",
            "plan generation being served (bumped by hot-swap)",
            self.generation.get().to_string(),
        );
        let _ = writeln!(out, "# HELP pallas_model_reloads_total hot reload attempts by outcome");
        let _ = writeln!(out, "# TYPE pallas_model_reloads_total counter");
        for (outcome, c) in [("ok", &self.reloads_ok), ("failed", &self.reloads_failed)] {
            let _ = writeln!(
                out,
                "pallas_model_reloads_total{{model=\"{model}\",outcome=\"{outcome}\"}} {}",
                c.get()
            );
        }
        // labeled histogram: the model label joins `le` inside the braces
        let name = "pallas_model_swap_latency_seconds";
        let snap = self.swap_latency.snapshot();
        let _ = writeln!(out, "# HELP {name} reload-to-publish latency in seconds");
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, &b) in SERVICE_TIME_BOUNDS.iter().enumerate() {
            cum += snap[i];
            let _ = writeln!(out, "{name}_bucket{{model=\"{model}\",le=\"{b}\"}} {cum}");
        }
        cum += snap[SERVICE_TIME_BOUNDS.len()];
        let _ = writeln!(out, "{name}_bucket{{model=\"{model}\",le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum{lbl} {}", self.swap_latency.sum());
        let _ = writeln!(out, "{name}_count{lbl} {cum}");
        series(
            out,
            "pallas_model_requests_total",
            "counter",
            "infer requests admitted for this model",
            self.submitted.get().to_string(),
        );
        series(
            out,
            "pallas_model_responses_total",
            "counter",
            "infer responses delivered for this model",
            self.responses.get().to_string(),
        );
        series(
            out,
            "pallas_model_inflight_requests",
            "gauge",
            "admitted requests not yet answered for this model",
            (self.inflight() as i64).to_string(),
        );
    }
}

fn gauge_f(out: &mut String, name: &str, help: &str, v: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }

    #[test]
    fn histogram_exact_integer_sum() {
        let h = Histogram::new(&[1.0, 2.0, 4.0], 1.0);
        for v in [1.0, 1.0, 2.0, 3.0, 9.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 16.0); // exact at scale 1
        assert_eq!(h.snapshot(), vec![2, 1, 1, 1]); // overflow bucket last
    }

    #[test]
    fn histogram_renders_cumulative_buckets() {
        let h = Histogram::new(&[1.0, 2.0], 1.0);
        h.observe(1.0);
        h.observe(2.0);
        h.observe(5.0);
        let mut s = String::new();
        h.render("x", "help", &mut s);
        assert!(s.contains("x_bucket{le=\"1\"} 1"));
        assert!(s.contains("x_bucket{le=\"2\"} 2"));
        assert!(s.contains("x_bucket{le=\"+Inf\"} 3"));
        assert!(s.contains("x_sum 4"));
        assert!(s.contains("x_count 3"));
    }

    #[test]
    fn admission_budget_is_a_hard_cap() {
        let m = ServeMetrics::new(2, 3);
        assert!(m.try_admit() && m.try_admit() && m.try_admit());
        assert!(!m.try_admit(), "budget 3 must reject the 4th admission");
        m.release_admission();
        assert!(m.try_admit());
        assert_eq!(m.inflight(), 3);
    }

    #[test]
    fn drain_flag_latches() {
        let m = ServeMetrics::new(1, 1);
        assert!(!m.draining());
        m.begin_drain();
        assert!(m.draining());
    }

    #[test]
    fn model_render_labels_every_series() {
        let m = ServeMetrics::new(1, 4);
        m.generation.set(3);
        m.reloads_ok.inc();
        m.reloads_failed.inc();
        m.swap_latency.observe(0.004);
        m.submitted.add(7);
        let mut s = String::new();
        m.render_model_prometheus("resnet", &mut s);
        for needle in [
            "pallas_model_generation{model=\"resnet\"} 3",
            "pallas_model_reloads_total{model=\"resnet\",outcome=\"ok\"} 1",
            "pallas_model_reloads_total{model=\"resnet\",outcome=\"failed\"} 1",
            "pallas_model_swap_latency_seconds_bucket{model=\"resnet\",le=\"+Inf\"} 1",
            "pallas_model_swap_latency_seconds_count{model=\"resnet\"} 1",
            "pallas_model_requests_total{model=\"resnet\"} 7",
        ] {
            assert!(s.contains(needle), "missing {needle} in:\n{s}");
        }
    }

    #[test]
    fn prometheus_render_contains_core_series() {
        let m = ServeMetrics::new(2, 8);
        m.submitted.inc();
        m.batch_fill.observe(1.0);
        let mut s = String::new();
        m.render_prometheus(&mut s);
        for needle in [
            "pallas_infer_requests_total 1",
            "pallas_infer_rejected_total{reason=\"queue_full\"} 0",
            "pallas_admission_budget 8",
            "pallas_shard_batches_total{shard=\"1\"} 0",
            "pallas_batch_fill_sum 1",
        ] {
            assert!(s.contains(needle), "missing {needle} in:\n{s}");
        }
    }
}
