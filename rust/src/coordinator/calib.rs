//! Calibration-activation sampling: build the per-layer (X, X^, T)
//! matrices the reconstruction objective needs, with bounded memory.
//!
//! For layer L we need paired column samples of
//!   * X   — im2col of the FP32 input activation (targets T = W X + b),
//!   * X^  — im2col of the *quantized-prefix* input activation (eq. 25),
//! taken at identical (image, spatial) positions. The calibration set is
//! streamed in chunks; a deterministic per-chunk subsample keeps the
//! column budget fixed regardless of layer spatial size.
//!
//! Two samplers share the collection/assembly code in this module:
//!
//! * the **streaming** sampler ([`super::stream::TapStore`], the default)
//!   reads both activations from incrementally advanced per-chunk
//!   frontiers — O(L) layer-forwards over the whole pipeline;
//! * the **full-replay** sampler ([`sample_layer_cached`], retained as
//!   the paper-literal reference and A/B path) re-runs the quantized
//!   prefix from the network input for every layer — O(L²). Both produce
//!   bit-identical samples (`rust/tests/stream_pipeline.rs`).
//!
//! The per-chunk forwards fan out across threads, so peak activation
//! memory scales with `min(PALLAS_THREADS, n_chunks)` concurrent chunks
//! (one chunk at a time in the serial case). On memory-constrained hosts
//! with large calibration sets, bound it by lowering `PALLAS_THREADS`.

use std::collections::BTreeSet;
use std::sync::atomic::AtomicU64;

use crate::data::chunks;
use crate::nn::{ForwardOptions, Model, Node, Op};
use crate::tensor::{im2col, Conv2dParams, Tensor};
use crate::util::{parallel, Rng};

/// Paired activation sample for one layer (all groups).
pub struct LayerSample {
    /// FP32-input im2col per group: [cols, n_cols]
    pub x_fp: Vec<Tensor>,
    /// quantized-prefix im2col per group: [cols, n_cols]
    pub x_q: Vec<Tensor>,
}

fn conv_params(node: &Node) -> Option<Conv2dParams> {
    match node.op {
        Op::Conv { k, stride, pad, groups, .. } => {
            Some(Conv2dParams { k, stride, pad, groups })
        }
        _ => None,
    }
}

/// im2col of an activation for a quantizable node (dense layers use the
/// activation matrix transposed to [cin, n]; inputs with more than 2
/// dims — token activations [N, S, C] — flatten their leading dims so
/// every row is a sampleable column).
pub fn im2col_sample(node: &Node, act: &Tensor) -> Vec<Tensor> {
    match conv_params(node) {
        Some(p) => (0..p.groups).map(|g| im2col(act, g, p)).collect(),
        None => {
            let d = *act.shape.last().expect("activation has dims");
            let t = if act.ndim() == 2 {
                act.transpose2() // dense: [n, cin] -> [cin, n]
            } else {
                Tensor::from_vec(&[act.numel() / d, d], act.data.clone()).transpose2()
            };
            // heads > 1 (per-head Q/K/V groups): every head reads the
            // full input, so each per-head reconstruction group gets the
            // same sample matrix — unlike grouped conv, where im2col
            // slices out per-group input channels
            let groups = node.geom().map(|g| g.groups).unwrap_or(1);
            vec![t; groups]
        }
    }
}

/// Select `want` column indices deterministically out of `total`.
fn pick_cols(total: usize, want: usize, rng: &mut Rng) -> Vec<usize> {
    if want >= total {
        (0..total).collect()
    } else {
        let mut idx = rng.sample_indices(total, want);
        idx.sort_unstable();
        idx
    }
}

/// Column sample of one calibration chunk: per-group row-major blocks
/// `[dim, n]` (samples as columns), ready to splice into the final
/// sample matrices without a transpose pass.
pub(crate) struct ChunkCols {
    pub fp: Vec<Vec<f32>>,
    pub q: Vec<Vec<f32>>,
    pub dim: usize,
    /// columns picked from this chunk
    pub n: usize,
}

/// im2col both activation variants of one chunk and gather the
/// deterministic column subsample, writing row-major directly.
/// `q_act = None` means the quantized prefix equals the FP32 activation
/// (no overrides installed yet, or symmetric mode): X^ copies X without
/// a second im2col. Borrows the activations — sampling never mutates or
/// clones a stored tap.
pub(crate) fn collect_chunk_cols(
    node: &Node,
    fp_act: &Tensor,
    q_act: Option<&Tensor>,
    budget: usize,
    rng: &mut Rng,
) -> ChunkCols {
    let cols_fp = im2col_sample(node, fp_act);
    let cols_q: Option<Vec<Tensor>> = q_act.map(|a| im2col_sample(node, a));
    let groups = cols_fp.len();
    let total = cols_fp[0].cols();
    let picked = pick_cols(total, budget, rng);
    let dim = cols_fp[0].rows();
    let mut fp: Vec<Vec<f32>> = vec![Vec::with_capacity(picked.len() * dim); groups];
    let mut q: Vec<Vec<f32>> = vec![Vec::with_capacity(picked.len() * dim); groups];
    for g in 0..groups {
        let src_fp = &cols_fp[g];
        let src_q = cols_q.as_ref().map(|c| &c[g]).unwrap_or(src_fp);
        for r in 0..dim {
            for &c in &picked {
                fp[g].push(src_fp.at2(r, c));
                q[g].push(src_q.at2(r, c));
            }
        }
    }
    ChunkCols { fp, q, dim, n: picked.len() }
}

/// Concatenate per-chunk blocks in chunk order into the final
/// `[dim, n_cols]` sample matrices. Chunk results always splice in chunk
/// order regardless of which thread produced them, and rows copy as
/// contiguous segments (this replaced a per-element column-major→
/// row-major transpose).
pub(crate) fn assemble_sample(chunk_cols: Vec<ChunkCols>) -> LayerSample {
    let groups = chunk_cols.first().map(|c| c.fp.len()).unwrap_or(0);
    let dim = chunk_cols.first().map(|c| c.dim).unwrap_or(0);
    let ncols: usize = chunk_cols.iter().map(|c| c.n).sum();
    let mut x_fp: Vec<Tensor> = (0..groups).map(|_| Tensor::zeros(&[dim, ncols])).collect();
    let mut x_q: Vec<Tensor> = (0..groups).map(|_| Tensor::zeros(&[dim, ncols])).collect();
    for g in 0..groups {
        let mut off = 0;
        for ch in &chunk_cols {
            for r in 0..dim {
                x_fp[g].data[r * ncols + off..r * ncols + off + ch.n]
                    .copy_from_slice(&ch.fp[g][r * ch.n..(r + 1) * ch.n]);
                x_q[g].data[r * ncols + off..r * ncols + off + ch.n]
                    .copy_from_slice(&ch.q[g][r * ch.n..(r + 1) * ch.n]);
            }
            off += ch.n;
        }
    }
    LayerSample { x_fp, x_q }
}

/// Cache of FP32 input activations per layer-input node, per calibration
/// chunk — the **full-replay** sampler's FP32 half. The streaming
/// pipeline replaces this with [`super::stream::TapStore`] (live frontier
/// instead of every tap resident at once); the cache remains as the
/// reference path (`PipelineConfig::replay_sampler`) and for callers
/// outside the pipeline.
pub struct FpTapCache {
    pub chunk_imgs: usize,
    /// input-node id -> per-chunk activation tensors
    pub taps: std::collections::BTreeMap<String, Vec<Tensor>>,
}

/// Build the FP32 tap cache for the given input-node ids. The per-chunk
/// forwards are independent and fan out across threads; taps are
/// assembled in chunk order so the cache never depends on scheduling.
/// `counter`, if set, counts the executed Conv/Dense nodes.
pub fn build_fp_cache(
    model: &Model,
    calib: &Tensor,
    input_ids: &BTreeSet<String>,
    chunk_imgs: usize,
    counter: Option<&AtomicU64>,
) -> FpTapCache {
    let n = calib.shape[0];
    let per: usize = calib.shape[1..].iter().product();
    let opts = ForwardOptions { layer_counter: counter, ..Default::default() };
    let chunk_list: Vec<(usize, usize)> = chunks(n, chunk_imgs).collect();
    let per_chunk: Vec<std::collections::BTreeMap<String, Tensor>> =
        parallel::par_map(chunk_list.len(), 1, |ci| {
            let (s, e) = chunk_list[ci];
            let xb = Tensor::from_vec(
                &[e - s, calib.shape[1], calib.shape[2], calib.shape[3]],
                calib.data[s * per..e * per].to_vec(),
            );
            let (_, got) = model.forward_collect(&xb, &opts, input_ids);
            got
        });
    let mut taps: std::collections::BTreeMap<String, Vec<Tensor>> =
        input_ids.iter().map(|i| (i.clone(), Vec::new())).collect();
    for got in per_chunk {
        for (id, t) in got {
            taps.get_mut(&id).unwrap().push(t);
        }
    }
    FpTapCache { chunk_imgs, taps }
}

/// Full-replay sampler: stream the calibration images through the FP32
/// model and the quantized-prefix model — the latter re-executed from
/// the network input — collecting paired im2col column samples for
/// `node`. `quant_opts` carries the overrides accumulated so far (its
/// `layer_counter`, if any, counts every forward this call runs);
/// `fp_cache` (if present, and covering this node) supplies the FP32
/// taps without re-running the FP32 forward; `prefix_quantized` = false
/// skips the quantized-prefix forward entirely (x^ == x before any
/// override).
#[allow(clippy::too_many_arguments)]
pub fn sample_layer_cached(
    model: &Model,
    node: &Node,
    calib: &Tensor,
    quant_opts: &ForwardOptions,
    prefix_quantized: bool,
    fp_cache: Option<&FpTapCache>,
    col_budget: usize,
    chunk_imgs: usize,
    rng: &mut Rng,
) -> LayerSample {
    sample_layer_cached_input(
        model, node, 0, calib, quant_opts, prefix_quantized, fp_cache, col_budget, chunk_imgs,
        rng,
    )
}

/// [`sample_layer_cached`] generalized to any input index of `node`:
/// multi-activation-input ops (attention MatMul) tap the activation
/// feeding `node.inputs[input_idx]` instead of assuming `inputs[0]`.
#[allow(clippy::too_many_arguments)]
pub fn sample_layer_cached_input(
    model: &Model,
    node: &Node,
    input_idx: usize,
    calib: &Tensor,
    quant_opts: &ForwardOptions,
    prefix_quantized: bool,
    fp_cache: Option<&FpTapCache>,
    col_budget: usize,
    chunk_imgs: usize,
    rng: &mut Rng,
) -> LayerSample {
    assert!(
        input_idx < node.inputs.len(),
        "node '{}' has {} inputs, no index {input_idx}",
        node.id,
        node.inputs.len()
    );
    let input_id = node.inputs[input_idx].clone();
    let want: BTreeSet<String> = [input_id.clone()].into();
    let n = calib.shape[0];
    let per: usize = calib.shape[1..].iter().product();
    let cache_ok = fp_cache
        .map(|c| c.chunk_imgs == chunk_imgs && c.taps.contains_key(&input_id))
        .unwrap_or(false);

    let chunk_list: Vec<(usize, usize)> = chunks(n, chunk_imgs).collect();
    let n_chunks = chunk_list.len();
    let per_chunk_budget = col_budget.div_ceil(n_chunks.max(1));
    // one RNG per chunk, forked serially up front: the column picks are
    // the same whatever thread executes the chunk
    let mut crngs: Vec<Rng> = (0..n_chunks).map(|ci| rng.fork(ci as u64)).collect();

    let chunk_cols: Vec<ChunkCols> = parallel::par_map_rng(&mut crngs, 1, |ci, crng| {
        let (s, e) = chunk_list[ci];
        let xb = || {
            Tensor::from_vec(
                &[e - s, calib.shape[1], calib.shape[2], calib.shape[3]],
                calib.data[s * per..e * per].to_vec(),
            )
        };
        // borrow cached taps; only a cache miss materializes a tensor
        let computed_fp;
        let fp_act: &Tensor = if cache_ok {
            &fp_cache.unwrap().taps[&input_id][ci]
        } else {
            let fp_opts =
                ForwardOptions { layer_counter: quant_opts.layer_counter, ..Default::default() };
            let (_, taps_fp) = model.forward_collect(&xb(), &fp_opts, &want);
            computed_fp = taps_fp.into_iter().next().unwrap().1;
            &computed_fp
        };
        let computed_q;
        let q_act: Option<&Tensor> = if prefix_quantized {
            let (_, mut taps_q) = model.forward_collect(&xb(), quant_opts, &want);
            computed_q = taps_q.remove(&input_id).unwrap();
            Some(&computed_q)
        } else {
            None
        };
        collect_chunk_cols(node, fp_act, q_act, per_chunk_budget, crng)
    });

    assemble_sample(chunk_cols)
}

/// Uncached variant (kept for callers outside the pipeline: figs, tests).
#[allow(clippy::too_many_arguments)]
pub fn sample_layer(
    model: &Model,
    node: &Node,
    calib: &Tensor,
    quant_opts: &ForwardOptions,
    col_budget: usize,
    chunk_imgs: usize,
    rng: &mut Rng,
) -> LayerSample {
    let prefix_quantized = quant_opts.weight_overrides.map(|m| !m.is_empty()).unwrap_or(false)
        || quant_opts.bias_overrides.map(|m| !m.is_empty()).unwrap_or(false);
    sample_layer_cached(model, node, calib, quant_opts, prefix_quantized, None,
                        col_budget, chunk_imgs, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Model;
    use crate::util::Json;
    use std::collections::BTreeMap;

    fn conv_model() -> Model {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"c1","op":"conv","inputs":["in"],"cin":2,"cout":3,
               "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
              {"id":"c2","op":"conv","inputs":["c1"],"cin":3,"cout":2,
               "k":1,"stride":1,"pad":0,"groups":1,"relu":false}
            ]}"#,
        )
        .unwrap();
        let mut w = BTreeMap::new();
        w.insert("c1.w".into(), Tensor::full(&[3, 2, 3, 3], 0.1));
        w.insert("c1.b".into(), Tensor::zeros(&[3]));
        w.insert("c2.w".into(), Tensor::full(&[2, 3, 1, 1], 0.2));
        w.insert("c2.b".into(), Tensor::zeros(&[2]));
        Model::from_manifest("cm", &j, w).unwrap()
    }

    #[test]
    fn sample_shapes_and_pairing() {
        let m = conv_model();
        let mut rng = Rng::new(1);
        let calib = Tensor::from_vec(
            &[4, 2, 8, 8],
            (0..4 * 2 * 64).map(|i| (i % 13) as f32 * 0.1).collect(),
        );
        let node = m.node("c2").unwrap().clone();
        let s = sample_layer(&m, &node, &calib, &ForwardOptions::default(), 32, 2, &mut rng);
        assert_eq!(s.x_fp.len(), 1);
        assert_eq!(s.x_fp[0].rows(), 3); // 1x1 conv over 3 channels
        assert!(s.x_fp[0].cols() >= 16);
        // without overrides, fp and quant paths must be identical
        assert_eq!(s.x_fp[0].data, s.x_q[0].data);
    }

    #[test]
    fn overrides_affect_only_quant_path() {
        let m = conv_model();
        let mut rng = Rng::new(2);
        let calib = Tensor::full(&[2, 2, 8, 8], 1.0);
        let node = m.node("c2").unwrap().clone();
        let mut ov = BTreeMap::new();
        ov.insert("c1".to_string(), Tensor::full(&[3, 2, 3, 3], 0.05));
        let opts = ForwardOptions { weight_overrides: Some(&ov), ..Default::default() };
        let s = sample_layer(&m, &node, &calib, &opts, 16, 2, &mut rng);
        assert_ne!(s.x_fp[0].data, s.x_q[0].data);
        // halved weights => halved activations
        for (a, b) in s.x_fp[0].data.iter().zip(&s.x_q[0].data) {
            assert!((a * 0.5 - b).abs() < 1e-5);
        }
    }

    #[test]
    fn dense_sample_is_transposed_activation() {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"g1","op":"gpool","inputs":["in"]},
              {"id":"d1","op":"dense","inputs":["g1"],"cin":2,"cout":2,"relu":false}
            ]}"#,
        )
        .unwrap();
        let mut w = BTreeMap::new();
        let mut eye = Tensor::zeros(&[2, 2]);
        eye.set2(0, 0, 1.0);
        eye.set2(1, 1, 1.0);
        w.insert("d1.w".into(), eye);
        w.insert("d1.b".into(), Tensor::zeros(&[2]));
        let m = Model::from_manifest("dm", &j, w).unwrap();
        let mut rng = Rng::new(3);
        let calib = Tensor::full(&[3, 2, 4, 4], 2.0);
        let node = m.node("d1").unwrap().clone();
        let s = sample_layer(&m, &node, &calib, &ForwardOptions::default(), 100, 2, &mut rng);
        assert_eq!(s.x_fp[0].shape, vec![2, 3]); // [cin, n_images]
        assert!(s.x_fp[0].data.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn cached_taps_are_borrowed_not_recomputed() {
        // the cache-backed path and the cache-less path must agree bit
        // for bit (the sampler reads the same tensors either way)
        let m = conv_model();
        let calib = Tensor::from_vec(
            &[4, 2, 8, 8],
            (0..4 * 2 * 64).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect(),
        );
        let node = m.node("c2").unwrap().clone();
        let ids: BTreeSet<String> = ["c1".to_string()].into();
        let cache = build_fp_cache(&m, &calib, &ids, 2, None);
        let opts = ForwardOptions::default();
        let a = sample_layer_cached(&m, &node, &calib, &opts, false, Some(&cache),
                                    24, 2, &mut Rng::new(9));
        let b = sample_layer_cached(&m, &node, &calib, &opts, false, None,
                                    24, 2, &mut Rng::new(9));
        assert_eq!(a.x_fp[0].data, b.x_fp[0].data);
        assert_eq!(a.x_q[0].data, b.x_q[0].data);
    }

    #[test]
    fn assembly_is_row_major_in_chunk_order() {
        // two chunks with distinct values: chunk 0's columns must precede
        // chunk 1's, rows laid out [dim, ncols] row-major
        let mk = |dim: usize, n: usize, base: f32| ChunkCols {
            fp: vec![(0..dim * n).map(|i| base + i as f32).collect()],
            q: vec![(0..dim * n).map(|i| -(base + i as f32)).collect()],
            dim,
            n,
        };
        let s = assemble_sample(vec![mk(2, 3, 0.0), mk(2, 2, 100.0)]);
        assert_eq!(s.x_fp[0].shape, vec![2, 5]);
        assert_eq!(s.x_fp[0].data, vec![0.0, 1.0, 2.0, 100.0, 101.0,
                                        3.0, 4.0, 5.0, 102.0, 103.0]);
        assert_eq!(s.x_q[0].data[3], -100.0);
    }
}
