//! Calibration-activation sampling: build the per-layer (X, X^, T)
//! matrices the reconstruction objective needs, with bounded memory.
//!
//! For layer L we need paired column samples of
//!   * X   — im2col of the FP32 input activation (targets T = W X + b),
//!   * X^  — im2col of the *quantized-prefix* input activation (eq. 25),
//! taken at identical (image, spatial) positions. The calibration set is
//! streamed in chunks; a deterministic per-chunk subsample keeps the
//! column budget fixed regardless of layer spatial size.
//!
//! The per-chunk forwards fan out across threads, so peak activation
//! memory scales with `min(PALLAS_THREADS, n_chunks)` concurrent chunks
//! (one chunk at a time in the serial case). On memory-constrained hosts
//! with large calibration sets, bound it by lowering `PALLAS_THREADS`.

use std::collections::BTreeSet;

use crate::data::chunks;
use crate::nn::{ForwardOptions, Model, Node, Op};
use crate::tensor::{im2col, Conv2dParams, Tensor};
use crate::util::{parallel, Rng};

/// Paired activation sample for one layer (all groups).
pub struct LayerSample {
    /// FP32-input im2col per group: [cols, n_cols]
    pub x_fp: Vec<Tensor>,
    /// quantized-prefix im2col per group: [cols, n_cols]
    pub x_q: Vec<Tensor>,
}

fn conv_params(node: &Node) -> Option<Conv2dParams> {
    match node.op {
        Op::Conv { k, stride, pad, groups, .. } => {
            Some(Conv2dParams { k, stride, pad, groups })
        }
        _ => None,
    }
}

/// im2col of an activation for a quantizable node (dense layers use the
/// activation matrix transposed to [cin, n]).
pub fn im2col_sample(node: &Node, act: &Tensor) -> Vec<Tensor> {
    match conv_params(node) {
        Some(p) => (0..p.groups).map(|g| im2col(act, g, p)).collect(),
        None => vec![act.transpose2()], // dense: [n, cin] -> [cin, n]
    }
}

/// Select `want` column indices deterministically out of `total`.
fn pick_cols(total: usize, want: usize, rng: &mut Rng) -> Vec<usize> {
    if want >= total {
        (0..total).collect()
    } else {
        let mut idx = rng.sample_indices(total, want);
        idx.sort_unstable();
        idx
    }
}

/// Cache of FP32 input activations per layer-input node, per calibration
/// chunk. The FP32 pass does not depend on quantization overrides, so it
/// is computed ONCE per pipeline run instead of once per layer — the
/// biggest single wall-clock win of the perf pass (EXPERIMENTS.md §Perf).
pub struct FpTapCache {
    pub chunk_imgs: usize,
    /// input-node id -> per-chunk activation tensors
    pub taps: std::collections::BTreeMap<String, Vec<Tensor>>,
}

/// Build the FP32 tap cache for the given input-node ids. The per-chunk
/// forwards are independent and fan out across threads; taps are
/// assembled in chunk order so the cache never depends on scheduling.
pub fn build_fp_cache(
    model: &Model,
    calib: &Tensor,
    input_ids: &BTreeSet<String>,
    chunk_imgs: usize,
) -> FpTapCache {
    let n = calib.shape[0];
    let per: usize = calib.shape[1..].iter().product();
    let chunk_list: Vec<(usize, usize)> = chunks(n, chunk_imgs).collect();
    let per_chunk: Vec<std::collections::BTreeMap<String, Tensor>> =
        parallel::par_map(chunk_list.len(), 1, |ci| {
            let (s, e) = chunk_list[ci];
            let xb = Tensor::from_vec(
                &[e - s, calib.shape[1], calib.shape[2], calib.shape[3]],
                calib.data[s * per..e * per].to_vec(),
            );
            let (_, got) = model.forward_collect(&xb, &ForwardOptions::default(), input_ids);
            got
        });
    let mut taps: std::collections::BTreeMap<String, Vec<Tensor>> =
        input_ids.iter().map(|i| (i.clone(), Vec::new())).collect();
    for got in per_chunk {
        for (id, t) in got {
            taps.get_mut(&id).unwrap().push(t);
        }
    }
    FpTapCache { chunk_imgs, taps }
}

/// Stream the calibration images through the FP32 model and the
/// quantized-prefix model, collecting paired im2col column samples for
/// `node`. `quant_opts` carries the overrides accumulated so far;
/// `fp_cache` (if present, and covering this node) supplies the FP32 taps
/// without re-running the FP32 forward; `prefix_quantized` = false skips
/// the quantized-prefix forward entirely (x^ == x before any override).
#[allow(clippy::too_many_arguments)]
pub fn sample_layer_cached(
    model: &Model,
    node: &Node,
    calib: &Tensor,
    quant_opts: &ForwardOptions,
    prefix_quantized: bool,
    fp_cache: Option<&FpTapCache>,
    col_budget: usize,
    chunk_imgs: usize,
    rng: &mut Rng,
) -> LayerSample {
    let input_id = node.inputs[0].clone();
    let want: BTreeSet<String> = [input_id.clone()].into();
    let n = calib.shape[0];
    let per: usize = calib.shape[1..].iter().product();
    let groups = match conv_params(node) {
        Some(p) => p.groups,
        None => 1,
    };
    let cache_ok = fp_cache
        .map(|c| c.chunk_imgs == chunk_imgs && c.taps.contains_key(&input_id))
        .unwrap_or(false);

    let chunk_list: Vec<(usize, usize)> = chunks(n, chunk_imgs).collect();
    let n_chunks = chunk_list.len();
    let per_chunk_budget = col_budget.div_ceil(n_chunks.max(1));
    // one RNG per chunk, forked serially up front: the column picks are
    // the same whatever thread executes the chunk
    let mut crngs: Vec<Rng> = (0..n_chunks).map(|ci| rng.fork(ci as u64)).collect();

    // column sample of one calibration chunk, per group
    struct ChunkCols {
        fp: Vec<Vec<f32>>,
        q: Vec<Vec<f32>>,
        dim: usize,
    }
    let chunk_cols: Vec<ChunkCols> = parallel::par_map_rng(&mut crngs, 1, |ci, crng| {
        let (s, e) = chunk_list[ci];
        let xb = || {
            Tensor::from_vec(
                &[e - s, calib.shape[1], calib.shape[2], calib.shape[3]],
                calib.data[s * per..e * per].to_vec(),
            )
        };
        let fp_act: Tensor = if cache_ok {
            fp_cache.unwrap().taps[&input_id][ci].clone()
        } else {
            let (_, taps_fp) = model.forward_collect(&xb(), &ForwardOptions::default(), &want);
            taps_fp.into_iter().next().unwrap().1
        };
        let cols_fp = im2col_sample(node, &fp_act);
        let cols_q = if prefix_quantized {
            let (_, taps_q) = model.forward_collect(&xb(), quant_opts, &want);
            im2col_sample(node, &taps_q[&input_id])
        } else {
            cols_fp.clone()
        };
        let total = cols_fp[0].cols();
        let picked = pick_cols(total, per_chunk_budget, crng);
        let dim = cols_fp[0].rows();
        let mut fp: Vec<Vec<f32>> = vec![Vec::with_capacity(picked.len() * dim); groups];
        let mut q: Vec<Vec<f32>> = vec![Vec::with_capacity(picked.len() * dim); groups];
        for g in 0..groups {
            for &c in &picked {
                for r in 0..dim {
                    fp[g].push(cols_fp[g].at2(r, c));
                    q[g].push(cols_q[g].at2(r, c));
                }
            }
        }
        ChunkCols { fp, q, dim }
    });

    // ordered assembly: chunk results concatenate in chunk order
    let mut x_fp: Vec<Vec<f32>> = vec![Vec::new(); groups];
    let mut x_q: Vec<Vec<f32>> = vec![Vec::new(); groups];
    let mut cols_dim = 0usize;
    for s in chunk_cols {
        cols_dim = s.dim;
        for g in 0..groups {
            x_fp[g].extend_from_slice(&s.fp[g]);
            x_q[g].extend_from_slice(&s.q[g]);
        }
    }
    // data was pushed column-major [c0r0 c0r1 ...]; transpose into [cols, n]
    let ncols = x_fp[0].len() / cols_dim;
    let finish = |raw: Vec<f32>| {
        let mut t = Tensor::zeros(&[cols_dim, ncols]);
        for c in 0..ncols {
            for r in 0..cols_dim {
                t.data[r * ncols + c] = raw[c * cols_dim + r];
            }
        }
        t
    };
    LayerSample {
        x_fp: x_fp.into_iter().map(finish).collect(),
        x_q: x_q.into_iter().map(finish).collect(),
    }
}

/// Uncached variant (kept for callers outside the pipeline: figs, tests).
#[allow(clippy::too_many_arguments)]
pub fn sample_layer(
    model: &Model,
    node: &Node,
    calib: &Tensor,
    quant_opts: &ForwardOptions,
    col_budget: usize,
    chunk_imgs: usize,
    rng: &mut Rng,
) -> LayerSample {
    let prefix_quantized = quant_opts.weight_overrides.map(|m| !m.is_empty()).unwrap_or(false)
        || quant_opts.bias_overrides.map(|m| !m.is_empty()).unwrap_or(false);
    sample_layer_cached(model, node, calib, quant_opts, prefix_quantized, None,
                        col_budget, chunk_imgs, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Model;
    use crate::util::Json;
    use std::collections::BTreeMap;

    fn conv_model() -> Model {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"c1","op":"conv","inputs":["in"],"cin":2,"cout":3,
               "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
              {"id":"c2","op":"conv","inputs":["c1"],"cin":3,"cout":2,
               "k":1,"stride":1,"pad":0,"groups":1,"relu":false}
            ]}"#,
        )
        .unwrap();
        let mut w = BTreeMap::new();
        w.insert("c1.w".into(), Tensor::full(&[3, 2, 3, 3], 0.1));
        w.insert("c1.b".into(), Tensor::zeros(&[3]));
        w.insert("c2.w".into(), Tensor::full(&[2, 3, 1, 1], 0.2));
        w.insert("c2.b".into(), Tensor::zeros(&[2]));
        Model::from_manifest("cm", &j, w).unwrap()
    }

    #[test]
    fn sample_shapes_and_pairing() {
        let m = conv_model();
        let mut rng = Rng::new(1);
        let calib = Tensor::from_vec(
            &[4, 2, 8, 8],
            (0..4 * 2 * 64).map(|i| (i % 13) as f32 * 0.1).collect(),
        );
        let node = m.node("c2").unwrap().clone();
        let s = sample_layer(&m, &node, &calib, &ForwardOptions::default(), 32, 2, &mut rng);
        assert_eq!(s.x_fp.len(), 1);
        assert_eq!(s.x_fp[0].rows(), 3); // 1x1 conv over 3 channels
        assert!(s.x_fp[0].cols() >= 16);
        // without overrides, fp and quant paths must be identical
        assert_eq!(s.x_fp[0].data, s.x_q[0].data);
    }

    #[test]
    fn overrides_affect_only_quant_path() {
        let m = conv_model();
        let mut rng = Rng::new(2);
        let calib = Tensor::full(&[2, 2, 8, 8], 1.0);
        let node = m.node("c2").unwrap().clone();
        let mut ov = BTreeMap::new();
        ov.insert("c1".to_string(), Tensor::full(&[3, 2, 3, 3], 0.05));
        let opts = ForwardOptions {
            weight_overrides: Some(&ov),
            bias_overrides: None,
            act_quant: None,
        };
        let s = sample_layer(&m, &node, &calib, &opts, 16, 2, &mut rng);
        assert_ne!(s.x_fp[0].data, s.x_q[0].data);
        // halved weights => halved activations
        for (a, b) in s.x_fp[0].data.iter().zip(&s.x_q[0].data) {
            assert!((a * 0.5 - b).abs() < 1e-5);
        }
    }

    #[test]
    fn dense_sample_is_transposed_activation() {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"g1","op":"gpool","inputs":["in"]},
              {"id":"d1","op":"dense","inputs":["g1"],"cin":2,"cout":2,"relu":false}
            ]}"#,
        )
        .unwrap();
        let mut w = BTreeMap::new();
        let mut eye = Tensor::zeros(&[2, 2]);
        eye.set2(0, 0, 1.0);
        eye.set2(1, 1, 1.0);
        w.insert("d1.w".into(), eye);
        w.insert("d1.b".into(), Tensor::zeros(&[2]));
        let m = Model::from_manifest("dm", &j, w).unwrap();
        let mut rng = Rng::new(3);
        let calib = Tensor::full(&[3, 2, 4, 4], 2.0);
        let node = m.node("d1").unwrap().clone();
        let s = sample_layer(&m, &node, &calib, &ForwardOptions::default(), 100, 2, &mut rng);
        assert_eq!(s.x_fp[0].shape, vec![2, 3]); // [cin, n_images]
        assert!(s.x_fp[0].data.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }
}
