//! The PTQ coordinator: sequential layer-reconstruction pipeline
//! (the paper's §3.3 procedure, "optimize (21)/(25) layer-by-layer
//! sequentially"), configuration, and quantized-model assembly.

pub mod calib;
pub mod config;
pub mod export;
pub mod pipeline;
pub mod stream;

pub use calib::{im2col_sample, LayerSample};
pub use export::{load_quantized, save_quantized};
pub use config::{Method, PipelineConfig};
pub use pipeline::{LayerStat, Pipeline, QuantizedModel};
pub use stream::TapStore;
