//! Pipeline configuration: which rounding method, grid, bit-width and
//! reconstruction variant to run.

use crate::adaround::AdaRoundConfig;
use crate::quant::GridMethod;

/// Rounding / PTQ method — one per paper table row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// round-to-nearest (eq. 1 baseline)
    Nearest,
    Floor,
    Ceil,
    /// stochastic rounding (Gupta et al. 2015); seeded per run
    Stochastic,
    /// the paper's method, continuous relaxation (eq. 25)
    AdaRound,
    /// AdaRound driven through the PJRT HLO step artifacts
    AdaRoundPjrt,
    /// straight-through-estimator baseline (Table 5)
    Ste,
    /// sigmoid + temperature annealing (Table 3 row 1)
    Hopfield,
    /// plain sigmoid + explicit f_reg (Table 3 row 2)
    SigmoidFreg,
    /// local-MSE QUBO (eq. 20) solved with the cross-entropy method
    LocalQuboCem,
    /// local-MSE QUBO solved with tabu search (qbsolv stand-in, Table 10)
    LocalQuboTabu,
    /// nearest + empirical bias correction (Table 8)
    BiasCorr,
    /// CLE + bias correction ("DFQ (our impl.)", Tables 7/9)
    Dfq,
    /// outlier channel splitting (Zhao et al. 2019)
    Ocs,
    /// per-channel MSE grids + nearest ("OMSE", Choukroun et al. 2019)
    Omse,
    /// Attention Round (Diao et al. 2022, adapted): softmax-attention
    /// over the two grid neighbors picks per-weight up-probabilities, a
    /// lottery of Bernoulli masks is scored on layer recon-MSE and the
    /// best (including the nearest mask) wins
    AttentionRound,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "nearest" => Method::Nearest,
            "floor" => Method::Floor,
            "ceil" => Method::Ceil,
            "stochastic" => Method::Stochastic,
            "adaround" => Method::AdaRound,
            "adaround-pjrt" => Method::AdaRoundPjrt,
            "ste" => Method::Ste,
            "hopfield" => Method::Hopfield,
            "sigmoid-freg" => Method::SigmoidFreg,
            "qubo-cem" => Method::LocalQuboCem,
            "qubo-tabu" => Method::LocalQuboTabu,
            "biascorr" => Method::BiasCorr,
            "dfq" => Method::Dfq,
            "ocs" => Method::Ocs,
            "omse" => Method::Omse,
            "attention-round" => Method::AttentionRound,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Nearest => "nearest",
            Method::Floor => "floor",
            Method::Ceil => "ceil",
            Method::Stochastic => "stochastic",
            Method::AdaRound => "adaround",
            Method::AdaRoundPjrt => "adaround-pjrt",
            Method::Ste => "ste",
            Method::Hopfield => "hopfield",
            Method::SigmoidFreg => "sigmoid-freg",
            Method::LocalQuboCem => "qubo-cem",
            Method::LocalQuboTabu => "qubo-tabu",
            Method::BiasCorr => "biascorr",
            Method::Dfq => "dfq",
            Method::Ocs => "ocs",
            Method::Omse => "omse",
            Method::AttentionRound => "attention-round",
        }
    }
}

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub method: Method,
    pub bits: u32,
    pub grid: GridMethod,
    pub per_channel: bool,
    /// feed the quantized-prefix activation x^ into the reconstruction
    /// (paper's "asymmetric" objective, Table 4); plain layer-wise uses x
    pub asymmetric: bool,
    /// account for the activation function in the objective (Table 4)
    pub use_relu: bool,
    /// quantize only these node ids (None = all layers)
    pub only_layers: Option<Vec<String>>,
    /// number of calibration images used
    pub calib_n: usize,
    /// im2col column budget per layer for reconstruction/QUBO
    pub col_budget: usize,
    /// activation quantization bit-width (None = FP32 activations)
    pub act_bits: Option<u32>,
    /// mixed-precision weight budget, in *mean bits per weight* (e.g.
    /// 4.5). When set, a sensitivity pre-pass
    /// ([`crate::adaround::alloc`]) assigns each layer 4 or 8 bits so the
    /// parameter-weighted mean stays within budget, overriding the
    /// uniform `bits` for weights. None = uniform `bits` everywhere.
    pub bit_budget: Option<f32>,
    pub adaround: AdaRoundConfig,
    /// OCS channel expand ratio
    pub ocs_expand: f64,
    /// apply cross-layer equalization before quantizing (paper Table 7:
    /// "using CLE as preprocessing" for the MobilenetV2 analog)
    pub pre_cle: bool,
    /// use the full-replay sampler (re-runs the quantized prefix from the
    /// network input for every layer, O(L²) layer-forwards) instead of
    /// the streaming `TapStore` (O(L)). Retained as the paper-literal
    /// reference path: both produce bit-identical `QuantizedModel`s
    /// (`rust/tests/stream_pipeline.rs`), so this is only for A/B
    /// verification and the `quantize-bench` comparison.
    pub replay_sampler: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            method: Method::AdaRound,
            bits: 4,
            grid: GridMethod::MseW,
            per_channel: false,
            asymmetric: true,
            use_relu: true,
            only_layers: None,
            calib_n: 512,
            col_budget: 2048,
            act_bits: None,
            bit_budget: None,
            adaround: AdaRoundConfig::default(),
            ocs_expand: 0.05,
            pre_cle: false,
            replay_sampler: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in [
            Method::Nearest,
            Method::AdaRound,
            Method::LocalQuboCem,
            Method::Dfq,
            Method::Omse,
            Method::AttentionRound,
        ] {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("bogus"), None);
    }
}
