//! Sequential layer-reconstruction pipeline — the system's core loop.
//!
//! For each quantizable layer in topological order:
//!   1. fit the quantization grid (§5 "determined prior to AdaRound"),
//!   2. sample paired (X, X^) im2col columns from the streaming
//!      activation store ([`super::stream::TapStore`]), where X^ sees all
//!      *previously quantized* layers (the paper's asymmetric
//!      reconstruction, eq. 25) through incrementally advanced
//!      per-chunk frontiers — O(L) layer-forwards over the whole run,
//!   3. choose the rounding per the configured [`Method`],
//!   4. install the quantized weights and move to the next layer (which
//!      advances both streams through exactly the newly-quantized
//!      segment).
//!
//! Finally, optional activation quantizers are calibrated on the fully
//! quantized network.
//!
//! `PipelineConfig::replay_sampler` swaps step 2 for the retained
//! full-replay sampler (O(L²), [`super::calib::sample_layer_cached`]);
//! both paths produce bit-identical `QuantizedModel`s — the equivalence
//! is enforced by `rust/tests/stream_pipeline.rs`.
//!
//! Layers are inherently sequential (each one reconstructs against the
//! quantized prefix), but the per-group rounding problems of a grouped
//! conv are independent and fan out across threads, each with an RNG
//! forked deterministically from the pipeline stream — results do not
//! depend on `PALLAS_THREADS`. The PJRT driver is the exception: its
//! runtime owns single-threaded state, so it stays on the caller thread.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::adaround::alloc::{allocate_bits, BitAllocation, LayerSensitivity};
use crate::adaround::hopfield::{optimize_hopfield, optimize_sigmoid_freg, TempSchedule};
use crate::adaround::ste::optimize_ste;
use crate::adaround::{AdaRoundConfig, LayerProblem, NativeOptimizer, PjrtOptimizer, RoundingOptimizer};
use crate::baselines::{correct_bias, equalize_model, ocs_quantize};
use crate::data::chunks;
use crate::nn::{ForwardOptions, Model, Node};
use crate::quant::{ActQuant, GridMethod, QuantGrid, RoundingMode};
use crate::qubo::{gram, solve_cem, solve_tabu, CemParams, QuboProblem, TabuParams};
use crate::runtime::Runtime;
use crate::tensor::{matmul, Tensor};
use crate::util::{parallel, Rng, Stopwatch};

use super::calib::{build_fp_cache, sample_layer_cached, LayerSample};
use super::config::{Method, PipelineConfig};
use super::stream::TapStore;

/// Calibration images per chunk: the granularity of streaming forwards
/// and of the per-chunk column subsample/RNG forks. Part of the
/// determinism contract — changing it changes the sampled columns.
pub const CHUNK_IMGS: usize = 64;

/// Candidate per-layer weight widths for the mixed-precision allocator
/// (`PipelineConfig::bit_budget`): serve packs ≤4-bit layers as nibbles
/// (w4) and everything else as plain i8 (w8), so these are the only two
/// widths with distinct serving cost.
pub const BIT_CHOICES: &[u32] = &[4, 8];

/// RNG fork tag for the allocator's sensitivity pre-pass — chosen out of
/// the per-group tag range (small integers) so budgeted runs never
/// collide with a group stream.
const ALLOC_FORK_TAG: u64 = 0xA110C;

#[derive(Clone, Debug)]
pub struct LayerStat {
    pub id: String,
    pub rows: usize,
    pub cols: usize,
    pub groups: usize,
    pub mse_before: f64,
    pub mse_after: f64,
    pub flipped_frac: f64,
    pub secs: f64,
}

/// The quantized network: overrides to apply on top of the FP32 model.
pub struct QuantizedModel {
    pub weight_overrides: BTreeMap<String, Tensor>,
    pub bias_overrides: BTreeMap<String, Tensor>,
    pub act_quant: Option<BTreeMap<String, ActQuant>>,
    /// Per-output-channel weight grid scales per layer (len = cout; the
    /// exact scales the overridden weights live on). Lets the export path
    /// and the integer serving engine skip scale recovery.
    pub scales: BTreeMap<String, Vec<f32>>,
    /// Per-layer weight bit-width actually used (uniform `cfg.bits`, or
    /// the mixed-precision allocator's choice under `cfg.bit_budget`).
    /// The serve compiler honors this: layers recorded at ≤ 4 bits pack
    /// nibble (w4) weights; the `.qtz` v3 exporter stores them as i4.
    /// Only present for methods whose codes land exactly on the grid
    /// (same condition as `scales`).
    pub wbits: BTreeMap<String, u32>,
    pub stats: Vec<LayerStat>,
    /// Conv/Dense executions the calibration sampling performed (the
    /// streaming pipeline's O(L) instrumentation; `quantize` reports it).
    pub layer_execs: u64,
}

impl QuantizedModel {
    pub fn opts(&self) -> ForwardOptions<'_> {
        ForwardOptions {
            weight_overrides: Some(&self.weight_overrides),
            bias_overrides: if self.bias_overrides.is_empty() {
                None
            } else {
                Some(&self.bias_overrides)
            },
            act_quant: self.act_quant.as_ref(),
            layer_counter: None,
        }
    }

    pub fn total_mse_before(&self) -> f64 {
        self.stats.iter().map(|s| s.mse_before).sum()
    }

    pub fn total_mse_after(&self) -> f64 {
        self.stats.iter().map(|s| s.mse_after).sum()
    }
}

/// Outcome of rounding one group, produced (possibly on a worker thread)
/// before any shared state is touched.
struct GroupOut {
    wq: Tensor,
    near_mse: f64,
    after: f64,
    flipped: f64,
    /// bias-correction delta to fold into the layer bias (BiasCorr / DFQ)
    bias_delta: Option<Vec<f32>>,
}

pub struct Pipeline<'a> {
    /// working model (CLE-equalized copy for DFQ)
    pub work: Model,
    pub cfg: PipelineConfig,
    pub runtime: Option<&'a Runtime>,
}

impl<'a> Pipeline<'a> {
    pub fn new(model: &Model, cfg: PipelineConfig, runtime: Option<&'a Runtime>) -> Pipeline<'a> {
        let mut work = model.clone();
        if cfg.method == Method::Dfq || cfg.pre_cle {
            let (eq, _) = equalize_model(model);
            work.weights = eq;
        }
        Pipeline { work, cfg, runtime }
    }

    fn layer_selected(&self, id: &str) -> bool {
        match &self.cfg.only_layers {
            None => true,
            Some(ids) => ids.iter().any(|l| l == id),
        }
    }

    /// Run the full PTQ pipeline over the calibration images.
    pub fn quantize(&self, calib: &Tensor, rng: &mut Rng) -> Result<QuantizedModel> {
        let calib = self.slice_calib(calib);
        let mut out = QuantizedModel {
            weight_overrides: BTreeMap::new(),
            bias_overrides: BTreeMap::new(),
            act_quant: None,
            scales: BTreeMap::new(),
            wbits: BTreeMap::new(),
            stats: Vec::new(),
            layer_execs: 0,
        };
        let nodes: Vec<Node> = self.work.quant_layers().into_iter().cloned().collect();
        // mixed-precision pre-pass: only when a budget is set, so
        // budget-free runs fork no extra RNG streams and stay
        // byte-identical with earlier versions
        let layer_bits: Option<BTreeMap<String, u32>> = match self.cfg.bit_budget {
            Some(budget) => {
                let alloc =
                    self.allocate_layer_bits(&calib, budget as f64, &mut rng.fork(ALLOC_FORK_TAG))?;
                Some(alloc.bits)
            }
            None => None,
        };
        // reference path: FP32 taps for every selected layer resident at
        // once + per-layer prefix replays (the streaming store makes both
        // obsolete on the default path)
        let replay_execs = AtomicU64::new(0);
        let fp_cache = if self.cfg.replay_sampler {
            let input_ids: std::collections::BTreeSet<String> = nodes
                .iter()
                .filter(|n| self.layer_selected(&n.id))
                .flat_map(|n| n.inputs.iter().cloned())
                .collect();
            Some(build_fp_cache(&self.work, &calib, &input_ids, CHUNK_IMGS, Some(&replay_execs)))
        } else {
            None
        };
        let mut store = if self.cfg.replay_sampler {
            None
        } else {
            Some(TapStore::new(&self.work, &calib, CHUNK_IMGS))
        };
        for node in &nodes {
            if !self.layer_selected(&node.id) {
                continue;
            }
            let sw = Stopwatch::start();
            // the quantized-prefix forward is only needed in asymmetric
            // mode once at least one earlier layer has been overridden
            let prefix_quantized = self.cfg.asymmetric
                && (!out.weight_overrides.is_empty() || !out.bias_overrides.is_empty());
            let sample = {
                let quant_opts = ForwardOptions {
                    weight_overrides: Some(&out.weight_overrides),
                    bias_overrides: if out.bias_overrides.is_empty() {
                        None
                    } else {
                        Some(&out.bias_overrides)
                    },
                    act_quant: None,
                    layer_counter: Some(&replay_execs),
                };
                match &mut store {
                    Some(st) => st.sample_layer(
                        node,
                        &quant_opts,
                        prefix_quantized,
                        self.cfg.col_budget,
                        rng,
                    ),
                    None => sample_layer_cached(
                        &self.work,
                        node,
                        &calib,
                        &quant_opts,
                        prefix_quantized,
                        fp_cache.as_ref(),
                        self.cfg.col_budget,
                        CHUNK_IMGS,
                        rng,
                    ),
                }
            };
            let bits = layer_bits
                .as_ref()
                .and_then(|m| m.get(&node.id).copied())
                .unwrap_or(self.cfg.bits);
            let stat = self.quantize_layer(node, &sample, &mut out, rng, bits)?;
            out.stats.push(LayerStat { secs: sw.secs(), ..stat });
        }
        out.layer_execs = match &store {
            Some(st) => st.layer_execs(),
            None => replay_execs.load(Ordering::Relaxed),
        };
        if let Some(bits) = self.cfg.act_bits {
            out.act_quant = Some(self.calibrate_activations(&calib, &out, bits));
        }
        Ok(out)
    }

    fn slice_calib(&self, calib: &Tensor) -> Tensor {
        let n = self.cfg.calib_n.min(calib.shape[0]);
        let per: usize = calib.shape[1..].iter().product();
        Tensor::from_vec(
            &[n, calib.shape[1], calib.shape[2], calib.shape[3]],
            calib.data[..n * per].to_vec(),
        )
    }

    /// Sensitivity pre-pass for the mixed-precision budget: sample FP32
    /// calibration columns for every selected layer (no quantized prefix
    /// — sensitivities must not depend on rounding decisions that the
    /// allocation itself will influence), score nearest rounding on each
    /// candidate grid with the Gauss-Newton reconstruction proxy, and
    /// let the greedy allocator spend the budget.
    pub fn allocate_layer_bits(
        &self,
        calib: &Tensor,
        budget_mean_bits: f64,
        rng: &mut Rng,
    ) -> Result<BitAllocation> {
        let nodes: Vec<Node> = self.work.quant_layers().into_iter().cloned().collect();
        let mut store = TapStore::new(&self.work, calib, CHUNK_IMGS);
        let quant_opts = ForwardOptions {
            weight_overrides: None,
            bias_overrides: None,
            act_quant: None,
            layer_counter: None,
        };
        let mut layers = Vec::new();
        for node in &nodes {
            if !self.layer_selected(&node.id) {
                continue;
            }
            let sample = store.sample_layer(node, &quant_opts, false, self.cfg.col_budget, rng);
            layers.push(self.layer_sensitivity(node, &sample)?);
        }
        Ok(allocate_bits(&layers, budget_mean_bits))
    }

    /// Proxy cost of serving one layer at each candidate width: the
    /// reconstruction MSE of nearest rounding on that width's grid over
    /// the layer's FP32 calibration columns — the Δwᵀ(x xᵀ)Δw quadratic
    /// of eq. (14), evaluated with the same [`LayerProblem`] machinery
    /// the rounding optimizer uses.
    fn layer_sensitivity(&self, node: &Node, sample: &LayerSample) -> Result<LayerSensitivity> {
        let geom = node.geom().expect("quantizable node");
        let w_full = self.work.weight(&node.id).clone();
        let bias_full = self.work.bias(&node.id).clone();
        let cout = w_full.shape[0];
        let w_gemm = Tensor::from_vec(&[cout, geom.cols], w_full.data.clone());
        let (grid_method, per_channel) = match self.cfg.method {
            Method::Omse => (GridMethod::MseW, true),
            _ => (self.cfg.grid, self.cfg.per_channel),
        };
        let og = geom.rows;
        let relu = self.cfg.use_relu && geom.relu;
        let mut cost = Vec::new();
        for &b in BIT_CHOICES {
            let grid = fit_layer_grid(node, &w_gemm, b, grid_method, per_channel, &sample.x_fp[0]);
            let mut c = 0.0;
            for g in 0..geom.groups {
                let row0 = g * og;
                let w_g = Tensor::from_vec(
                    &[og, geom.cols],
                    w_gemm.data[row0 * geom.cols..(row0 + og) * geom.cols].to_vec(),
                );
                let bias_g: Vec<f32> = bias_full.data[row0..row0 + og].to_vec();
                let prob = LayerProblem::new(w_g, &grid, row0, bias_g, relu);
                let x_fp = &sample.x_fp[g];
                let t = group_target(&prob, x_fp);
                c += prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), x_fp, &t);
            }
            cost.push((b, c));
        }
        Ok(LayerSensitivity { id: node.id.clone(), params: w_full.numel(), cost })
    }

    /// Grid fit + per-group rounding + assembly for one layer, from an
    /// already-collected calibration sample. `bits` is this layer's
    /// weight width — `cfg.bits` on uniform runs, the allocator's choice
    /// under a `bit_budget`.
    fn quantize_layer(
        &self,
        node: &Node,
        sample: &LayerSample,
        out: &mut QuantizedModel,
        rng: &mut Rng,
        bits: u32,
    ) -> Result<LayerStat> {
        let lcfg = PipelineConfig { bits, ..self.cfg.clone() };
        let cfg = &lcfg;
        let geom = node.geom().expect("quantizable node");
        let w4 = self.work.weight(&node.id).clone();
        let bias_full = self.work.bias(&node.id).clone();
        // full GEMM view [cout, cols] (groups stacked along rows)
        let cout = w4.shape[0];
        let w_gemm = Tensor::from_vec(&[cout, geom.cols], w4.data.clone());

        // --- grid fit (per layer, before rounding optimization) ---
        let (grid_method, per_channel) = match cfg.method {
            Method::Omse => (GridMethod::MseW, true),
            _ => (cfg.grid, cfg.per_channel),
        };
        let grid = fit_layer_grid(node, &w_gemm, cfg.bits, grid_method, per_channel, &sample.x_fp[0]);
        // record the exact per-channel scales for export / integer serving
        // (STE's continuous weights and OCS's expanded grid don't land on
        // this grid, so recovery at serve-compile time handles them)
        if !matches!(cfg.method, Method::Ste | Method::Ocs) {
            out.scales.insert(
                node.id.clone(),
                (0..cout).map(|r| grid.scale_for_row(r)).collect(),
            );
            // wbits shares the condition: it is a promise that the
            // overridden weights are exact multiples of `scales` with
            // codes inside the `bits`-wide signed range
            out.wbits.insert(node.id.clone(), bits);
        }

        // --- per-group rounding ---
        let og = geom.rows;
        let relu = cfg.use_relu && geom.relu;
        let acfg = self.adaround_cfg();
        let probs: Vec<LayerProblem> = (0..geom.groups)
            .map(|g| {
                let row0 = g * og;
                let w_g = Tensor::from_vec(
                    &[og, geom.cols],
                    w_gemm.data[row0 * geom.cols..(row0 + og) * geom.cols].to_vec(),
                );
                let bias_g: Vec<f32> = bias_full.data[row0..row0 + og].to_vec();
                LayerProblem::new(w_g, &grid, row0, bias_g, relu)
            })
            .collect();
        // fork one RNG per group up front (serial, so the streams are the
        // same whatever the thread count / processing order)
        let mut rngs: Vec<Rng> = (0..geom.groups).map(|g| rng.fork(g as u64)).collect();

        let results: Vec<Result<GroupOut>> = if cfg.method == Method::AdaRoundPjrt {
            // PJRT runtime state is single-threaded; keep the caller thread
            probs
                .iter()
                .enumerate()
                .map(|(g, prob)| {
                    let x_fp = &sample.x_fp[g];
                    let x_opt = if cfg.asymmetric { &sample.x_q[g] } else { x_fp };
                    self.round_group_pjrt(prob, x_fp, x_opt, &acfg, &mut rngs[g])
                })
                .collect()
        } else {
            parallel::par_map_rng(&mut rngs, 1, |g, grng| {
                let x_fp = &sample.x_fp[g];
                let x_opt = if cfg.asymmetric { &sample.x_q[g] } else { x_fp };
                round_group_native(cfg, &acfg, &probs[g], x_fp, x_opt, grng)
            })
        };

        // --- assemble (serial, in group order) ---
        let mut wq_full = vec![0.0f32; w_gemm.numel()];
        let mut mse_before = 0.0;
        let mut mse_after = 0.0;
        let mut flipped = 0.0;
        // bias-correction deltas accumulate into ONE clone of the layer
        // bias (groups touch disjoint row ranges), inserted once at the end
        let mut bias_new: Option<Tensor> = None;
        for (g, res) in results.into_iter().enumerate() {
            let go = res?;
            let row0 = g * og;
            wq_full[row0 * geom.cols..(row0 + og) * geom.cols].copy_from_slice(&go.wq.data);
            mse_before += go.near_mse;
            mse_after += go.after;
            flipped += go.flipped;
            if let Some(delta) = go.bias_delta {
                let nb = bias_new.get_or_insert_with(|| {
                    out.bias_overrides
                        .get(&node.id)
                        .cloned()
                        .unwrap_or_else(|| bias_full.clone())
                });
                for (i, d) in delta.iter().enumerate() {
                    nb.data[row0 + i] += d;
                }
            }
        }
        if let Some(nb) = bias_new {
            out.bias_overrides.insert(node.id.clone(), nb);
        }
        out.weight_overrides.insert(
            node.id.clone(),
            Tensor::from_vec(&w4.shape, wq_full),
        );
        Ok(LayerStat {
            id: node.id.clone(),
            rows: geom.rows,
            cols: geom.cols,
            groups: geom.groups,
            mse_before: mse_before / geom.groups as f64,
            mse_after: mse_after / geom.groups as f64,
            flipped_frac: flipped / geom.groups as f64,
            secs: 0.0,
        })
    }

    /// PJRT rounding for one group (must stay on the pipeline thread).
    fn round_group_pjrt(
        &self,
        prob: &LayerProblem,
        x_fp: &Tensor,
        x_opt: &Tensor,
        acfg: &AdaRoundConfig,
        rng: &mut Rng,
    ) -> Result<GroupOut> {
        let Some(rt) = self.runtime else {
            bail!("adaround-pjrt requires a PJRT runtime (artifacts)")
        };
        let t = group_target(prob, x_fp);
        let near_mse = prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), x_opt, &t);
        let res = PjrtOptimizer::new(rt).optimize(prob, x_opt, &t, acfg, rng)?;
        Ok(GroupOut {
            wq: prob.hard_weights(&res.mask),
            near_mse,
            after: res.mse_after,
            flipped: res.flipped_frac,
            bias_delta: None,
        })
    }

    fn adaround_cfg(&self) -> crate::adaround::AdaRoundConfig {
        let mut c = self.cfg.adaround;
        c.use_relu = self.cfg.use_relu;
        c
    }

    /// Min/max activation calibration on the fully quantized network;
    /// chunks fan out across threads, ranges merge in chunk order (min/max
    /// merging is exact, so the result is thread-count independent).
    fn calibrate_activations(
        &self,
        calib: &Tensor,
        qm: &QuantizedModel,
        bits: u32,
    ) -> BTreeMap<String, ActQuant> {
        let want: std::collections::BTreeSet<String> =
            self.work.nodes.iter().map(|n| n.id.clone()).collect();
        let n = calib.shape[0];
        let per: usize = calib.shape[1..].iter().product();
        let opts = ForwardOptions {
            weight_overrides: Some(&qm.weight_overrides),
            bias_overrides: if qm.bias_overrides.is_empty() {
                None
            } else {
                Some(&qm.bias_overrides)
            },
            act_quant: None,
            layer_counter: None,
        };
        let chunk_list: Vec<(usize, usize)> = chunks(n, CHUNK_IMGS).collect();
        // bind the model by field so the worker closure never captures
        // `self` (the PJRT runtime reference is not Sync)
        let work = &self.work;
        let per_chunk: Vec<BTreeMap<String, ActQuant>> =
            parallel::par_map(chunk_list.len(), 1, |ci| {
                let (s, e) = chunk_list[ci];
                let xb = Tensor::from_vec(
                    &[e - s, calib.shape[1], calib.shape[2], calib.shape[3]],
                    calib.data[s * per..e * per].to_vec(),
                );
                let (_, taps) = work.forward_collect(&xb, &opts, &want);
                taps.into_iter()
                    .map(|(id, t)| (id, ActQuant::calibrate(&t, bits)))
                    .collect()
            });
        let mut ranges: BTreeMap<String, ActQuant> = BTreeMap::new();
        for chunk in per_chunk {
            for (id, q) in chunk {
                ranges
                    .entry(id)
                    .and_modify(|r| *r = r.merge(&q))
                    .or_insert(q);
            }
        }
        ranges
    }
}

/// Grid fit for one layer: per-channel when requested; otherwise
/// per-head grids for multi-head projections (`node.heads > 1`, one
/// scale per contiguous head row-block — each head's value range is
/// independent, so a shared per-tensor scale wastes codes on the
/// quietest head) and the plain per-tensor fit for everything else.
/// heads == 1 is byte-identical to the pre-transformer behavior.
fn fit_layer_grid(
    node: &Node,
    w_gemm: &Tensor,
    bits: u32,
    grid_method: GridMethod,
    per_channel: bool,
    x_sample: &Tensor,
) -> QuantGrid {
    if !per_channel && node.heads > 1 {
        let geom = node.geom().expect("quantizable node");
        QuantGrid::fit_grouped(w_gemm, bits, grid_method, geom.rows, Some(x_sample))
    } else {
        QuantGrid::fit(w_gemm, bits, grid_method, per_channel, Some(x_sample))
    }
}

/// T = W x_fp + b for one group's problem.
fn group_target(prob: &LayerProblem, x_fp: &Tensor) -> Tensor {
    let mut t = matmul(&prob.w, x_fp);
    prob.add_bias(&mut t);
    t
}

fn flip_frac(mask: &Tensor, near: &Tensor) -> f64 {
    mask.data
        .iter()
        .zip(&near.data)
        .filter(|(a, b)| (*a - *b).abs() > 0.5)
        .count() as f64
        / mask.numel() as f64
}

/// Rounding decision for one group, every method except PJRT. Free of
/// pipeline state so it can run on worker threads ([`GroupOut`] carries
/// everything back to the sequential assembly).
fn round_group_native(
    cfg: &PipelineConfig,
    acfg: &AdaRoundConfig,
    prob: &LayerProblem,
    x_fp: &Tensor,
    x_opt: &Tensor,
    rng: &mut Rng,
) -> Result<GroupOut> {
    let t = group_target(prob, x_fp);
    let x = x_opt;
    let near_mse = prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), x, &t);
    let grid_for_rowmodes =
        QuantGrid { scale: prob.scale.clone(), bits: cfg.bits, n: prob.n, p: prob.p };
    let (wq, fl, after): (Tensor, f64, f64) = match cfg.method {
        Method::Nearest | Method::Floor | Method::Ceil | Method::Stochastic
        | Method::Omse | Method::BiasCorr | Method::Dfq => {
            let mode = match cfg.method {
                Method::Floor => RoundingMode::Floor,
                Method::Ceil => RoundingMode::Ceil,
                Method::Stochastic => RoundingMode::Stochastic,
                _ => RoundingMode::Nearest,
            };
            let mask = crate::quant::rounding_mask(&prob.w, &grid_for_rowmodes, mode, rng);
            // note: per-group scales live at rows [0, og) of this grid view
            let wq = prob.hard_weights(&mask);
            let fl = flip_frac(&mask, &prob.nearest_mask());
            let after = prob.recon_mse(&wq, x, &t);
            (wq, fl, after)
        }
        Method::AdaRound => {
            let res = NativeOptimizer.optimize(prob, x, &t, acfg, rng)?;
            (prob.hard_weights(&res.mask), res.flipped_frac, res.mse_after)
        }
        Method::AdaRoundPjrt => bail!("pjrt path handled by round_group_pjrt"),
        Method::Ste => {
            let mut c = *acfg;
            c.lr = 2e-3; // continuous weights need a gentler step
            let res = optimize_ste(prob, x, &t, &c, rng)?;
            (res.v.clone(), res.flipped_frac, res.mse_after)
        }
        Method::Hopfield => {
            let res = optimize_hopfield(prob, x, &t, acfg, TempSchedule::default(), rng)?;
            (prob.hard_weights(&res.mask), res.flipped_frac, res.mse_after)
        }
        Method::SigmoidFreg => {
            let res = optimize_sigmoid_freg(prob, x, &t, acfg, rng)?;
            (prob.hard_weights(&res.mask), res.flipped_frac, res.mse_after)
        }
        Method::LocalQuboCem | Method::LocalQuboTabu => {
            let h = gram(x);
            let near = prob.nearest_mask();
            let mut mask = Tensor::zeros(&prob.w.shape);
            let cols = prob.cols();
            // rows are independent QUBOs: fork one RNG per row up front
            // (serial, in row order) and fan the solves out across
            // threads — results are bit-identical for any thread count
            let mut row_rngs: Vec<Rng> = (0..prob.rows()).map(|r| rng.fork(r as u64)).collect();
            let use_cem = cfg.method == Method::LocalQuboCem;
            let wdata = &prob.w.data;
            let href = &h;
            let gridref = &grid_for_rowmodes;
            parallel::par_chunks2_mut(
                &mut mask.data,
                cols,
                &mut row_rngs,
                1,
                1,
                |r, mrow, rrow| {
                    let qp =
                        QuboProblem::from_row(&wdata[r * cols..(r + 1) * cols], gridref, r, href);
                    let (sol, _) = if use_cem {
                        solve_cem(&qp, CemParams::default(), &mut rrow[0])
                    } else {
                        solve_tabu(&qp, TabuParams::default(), &mut rrow[0])
                    };
                    for (m, &b) in mrow.iter_mut().zip(&sol) {
                        *m = b as f32;
                    }
                },
            );
            let wq = prob.hard_weights(&mask);
            let fl = flip_frac(&mask, &near);
            let after = prob.recon_mse(&wq, x, &t);
            (wq, fl, after)
        }
        Method::Ocs => {
            let wq = ocs_quantize(&prob.w, cfg.bits, cfg.ocs_expand);
            let after = prob.recon_mse(&wq, x, &t);
            (wq, 0.0, after)
        }
        Method::AttentionRound => {
            let res = crate::baselines::attention_round(
                prob,
                x,
                &t,
                &crate::baselines::AttentionRoundConfig::default(),
                rng,
            );
            let fl = flip_frac(&res.mask, &prob.nearest_mask());
            (prob.hard_weights(&res.mask), fl, res.mse)
        }
    };
    let bias_delta = if matches!(cfg.method, Method::BiasCorr | Method::Dfq) {
        Some(correct_bias(&prob.w, x_fp, &wq, x_opt))
    } else {
        None
    };
    Ok(GroupOut { wq, near_mse, after, flipped: fl, bias_delta })
}
