//! Quantized-model export/import: persist a [`QuantizedModel`] as a `.qtz`
//! bundle so quantization (expensive) and serving (cheap) can run in
//! different processes — the deployment hand-off of the framework.
//!
//! v2 layout (written by [`save_quantized`]):
//!   __meta.version        [2] (i32)
//!   __meta.counts         [n_weights, n_biases, n_actquant] (i32)
//!   i8:<node>             raw integer weight codes (i8, grid multiples)
//!   scale:<node>          per-output-channel grid scales (f32, len cout)
//!   w:<node>              f32 fallback for layers without a clean grid
//!   b:<node>              corrected bias tensor (f32)
//!   aq:<node>             [min, max, bits] (f32 triple)
//!
//! The i8 + scale pair is what the integer serving engine boots from —
//! weight payloads are 4x smaller than v1, and dequantization
//! (`scale[oc] * z`) reproduces the fake-quant f32 values bit-exactly
//! because it is the same multiplication [`crate::quant::fake_quant`]
//! performed. v1 bundles (f32 `w:` entries, no version tag) still load.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::io::{read_qtz, write_qtz, QtzValue};
use crate::quant::ActQuant;
use crate::tensor::{I8Tensor, IntTensor, Tensor};

use super::pipeline::QuantizedModel;

/// Encode one weight tensor as grid codes if its recorded per-channel
/// scales reproduce it exactly within i8 range; `None` -> keep f32.
fn encode_i8(w: &Tensor, scales: &[f32]) -> Option<I8Tensor> {
    let cout = w.shape[0];
    if scales.len() != cout {
        return None;
    }
    let cols = w.numel() / cout;
    let mut data = vec![0i8; w.numel()];
    for oc in 0..cout {
        let s = scales[oc];
        if !(s > 0.0 && s.is_finite()) {
            return None;
        }
        for (d, &v) in data[oc * cols..(oc + 1) * cols].iter_mut().zip(&w.data[oc * cols..]) {
            let z = (v / s).round();
            // exact reproduction required: s * z must equal v bit-for-bit
            if !(-128.0..=127.0).contains(&z) || s * z != v {
                return None;
            }
            *d = z as i8;
        }
    }
    Some(I8Tensor::from_vec(&w.shape, data))
}

pub fn save_quantized(path: impl AsRef<Path>, qm: &QuantizedModel) -> Result<()> {
    let mut bundle: BTreeMap<String, QtzValue> = BTreeMap::new();
    for (id, w) in &qm.weight_overrides {
        let enc = qm.scales.get(id).and_then(|sc| encode_i8(w, sc));
        match enc {
            Some(wi) => {
                bundle.insert(format!("i8:{id}"), QtzValue::I8(wi));
                bundle.insert(
                    format!("scale:{id}"),
                    QtzValue::F32(Tensor::from_vec(
                        &[qm.scales[id].len()],
                        qm.scales[id].clone(),
                    )),
                );
            }
            None => {
                bundle.insert(format!("w:{id}"), QtzValue::F32(w.clone()));
            }
        }
    }
    for (id, b) in &qm.bias_overrides {
        bundle.insert(format!("b:{id}"), QtzValue::F32(b.clone()));
    }
    let n_aq = qm.act_quant.as_ref().map(|m| m.len()).unwrap_or(0);
    if let Some(aq) = &qm.act_quant {
        for (id, q) in aq {
            bundle.insert(
                format!("aq:{id}"),
                QtzValue::F32(Tensor::from_vec(&[3], vec![q.min, q.max, q.bits as f32])),
            );
        }
    }
    bundle.insert(
        "__meta.version".into(),
        QtzValue::I32(IntTensor::from_vec(&[1], vec![2])),
    );
    bundle.insert(
        "__meta.counts".into(),
        QtzValue::I32(IntTensor::from_vec(
            &[3],
            vec![
                qm.weight_overrides.len() as i32,
                qm.bias_overrides.len() as i32,
                n_aq as i32,
            ],
        )),
    );
    write_qtz(path, &bundle)
}

pub fn load_quantized(path: impl AsRef<Path>) -> Result<QuantizedModel> {
    let bundle = read_qtz(path)?;
    let counts = bundle
        .get("__meta.counts")
        .ok_or_else(|| anyhow::anyhow!("not a quantized-model bundle (no __meta.counts)"))?
        .as_i32()?
        .data
        .clone();
    let version = bundle
        .get("__meta.version")
        .and_then(|v| v.as_i32().ok())
        .and_then(|t| t.data.first().copied())
        .unwrap_or(1);
    if version > 2 {
        bail!("bundle version {version} is newer than this build understands");
    }
    let mut qm = QuantizedModel {
        weight_overrides: BTreeMap::new(),
        bias_overrides: BTreeMap::new(),
        act_quant: None,
        scales: BTreeMap::new(),
        stats: Vec::new(),
        layer_execs: 0,
    };
    let mut aq: BTreeMap<String, ActQuant> = BTreeMap::new();
    for (k, v) in &bundle {
        if let Some(id) = k.strip_prefix("w:") {
            qm.weight_overrides.insert(id.to_string(), v.as_f32()?.clone());
        } else if let Some(id) = k.strip_prefix("scale:") {
            qm.scales.insert(id.to_string(), v.as_f32()?.data.clone());
        } else if let Some(id) = k.strip_prefix("b:") {
            qm.bias_overrides.insert(id.to_string(), v.as_f32()?.clone());
        } else if let Some(id) = k.strip_prefix("aq:") {
            let t = v.as_f32()?;
            aq.insert(id.to_string(), ActQuant::new(t.data[0], t.data[1], t.data[2] as u32));
        }
    }
    // dequantize i8 weight codes (after the scale pass above, so the map
    // iteration order doesn't matter)
    for (k, v) in &bundle {
        if let Some(id) = k.strip_prefix("i8:") {
            let wi = v.as_i8()?;
            let sc = qm
                .scales
                .get(id)
                .ok_or_else(|| anyhow::anyhow!("i8 weights for {id} without scale:{id}"))?;
            let cout = *wi.shape.first().unwrap_or(&0);
            if cout == 0 {
                bail!("i8 weights for {id} have empty shape {:?}", wi.shape);
            }
            if sc.len() != cout && sc.len() != 1 {
                bail!("scale:{id} has {} entries for {cout} output channels", sc.len());
            }
            let cols = wi.numel() / cout;
            let mut data = vec![0.0f32; wi.numel()];
            for oc in 0..cout {
                let s = if sc.len() == 1 { sc[0] } else { sc[oc] };
                for (d, &z) in data[oc * cols..(oc + 1) * cols]
                    .iter_mut()
                    .zip(&wi.data[oc * cols..])
                {
                    *d = s * z as f32;
                }
            }
            qm.weight_overrides
                .insert(id.to_string(), Tensor::from_vec(&wi.shape, data));
        }
    }
    if !aq.is_empty() {
        qm.act_quant = Some(aq);
    }
    if qm.weight_overrides.len() != counts[0] as usize {
        bail!(
            "corrupt bundle: {} weight tensors, meta says {}",
            qm.weight_overrides.len(),
            counts[0]
        );
    }
    Ok(qm)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_qm() -> QuantizedModel {
        let mut qm = QuantizedModel {
            weight_overrides: BTreeMap::new(),
            bias_overrides: BTreeMap::new(),
            act_quant: None,
            scales: BTreeMap::new(),
            stats: Vec::new(),
            layer_execs: 0,
        };
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 1, 1, 1], vec![0.5, -0.5]));
        qm.bias_overrides
            .insert("c1".into(), Tensor::from_vec(&[2], vec![0.1, 0.2]));
        let mut aq = BTreeMap::new();
        aq.insert("c1".to_string(), ActQuant::new(-1.5, 2.5, 8));
        qm.act_quant = Some(aq);
        qm
    }

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("qm_roundtrip.qtz");
        let qm = sample_qm();
        save_quantized(&path, &qm).unwrap();
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, vec![0.5, -0.5]);
        assert_eq!(back.bias_overrides["c1"].data, vec![0.1, 0.2]);
        let aq = &back.act_quant.unwrap()["c1"];
        assert_eq!((aq.min, aq.max, aq.bits), (-1.5, 2.5, 8));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn i8_roundtrip_is_bit_exact() {
        // weights on a per-channel grid round-trip through i8 codes with
        // bit-identical f32 values and 4x smaller weight payload
        let path = std::env::temp_dir().join("qm_i8_roundtrip.qtz");
        let mut qm = sample_qm();
        let scales = vec![0.013f32, 0.07];
        let zs: [i32; 8] = [-128, -7, 0, 127, 1, -1, 33, 100];
        let w: Vec<f32> = zs
            .iter()
            .enumerate()
            .map(|(i, &z)| scales[i / 4] * z as f32)
            .collect();
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 4], w.clone()));
        qm.scales.insert("c1".into(), scales.clone());
        save_quantized(&path, &qm).unwrap();
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, w, "dequant must be bit-exact");
        assert_eq!(back.scales["c1"], scales);
        // the bundle actually stores i8 codes, not f32
        let raw = crate::io::read_qtz(&path).unwrap();
        assert!(raw.contains_key("i8:c1"));
        assert!(!raw.contains_key("w:c1"));
        assert_eq!(raw["i8:c1"].as_i8().unwrap().data.len(), 8);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v1_bundles_still_load() {
        // hand-write an old-style bundle: f32 w:/b:/aq: and counts, no
        // version tag — the pre-i8 format
        let path = std::env::temp_dir().join("qm_v1_compat.qtz");
        let mut old: BTreeMap<String, QtzValue> = BTreeMap::new();
        old.insert(
            "w:c1".into(),
            QtzValue::F32(Tensor::from_vec(&[2, 1, 1, 1], vec![0.25, -0.75])),
        );
        old.insert("b:c1".into(), QtzValue::F32(Tensor::from_vec(&[2], vec![0.0, 1.0])));
        old.insert(
            "aq:c1".into(),
            QtzValue::F32(Tensor::from_vec(&[3], vec![-1.0, 1.0, 8.0])),
        );
        old.insert(
            "__meta.counts".into(),
            QtzValue::I32(IntTensor::from_vec(&[3], vec![1, 1, 1])),
        );
        write_qtz(&path, &old).unwrap();
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, vec![0.25, -0.75]);
        assert_eq!(back.bias_overrides["c1"].data, vec![0.0, 1.0]);
        assert!(back.scales.is_empty());
        assert_eq!(back.act_quant.unwrap()["c1"].bits, 8);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn off_grid_weights_fall_back_to_f32() {
        let path = std::env::temp_dir().join("qm_offgrid.qtz");
        let mut qm = sample_qm();
        // scales recorded but the weights are NOT multiples -> f32 path
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 1, 1, 1], vec![0.51, -0.52]));
        qm.scales.insert("c1".into(), vec![0.5, 0.5]);
        save_quantized(&path, &qm).unwrap();
        let raw = crate::io::read_qtz(&path).unwrap();
        assert!(raw.contains_key("w:c1"));
        assert!(!raw.contains_key("i8:c1"));
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, vec![0.51, -0.52]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_non_bundle() {
        let path = std::env::temp_dir().join("qm_bad.qtz");
        let mut plain = BTreeMap::new();
        plain.insert("x".to_string(), QtzValue::F32(Tensor::zeros(&[1])));
        write_qtz(&path, &plain).unwrap();
        assert!(load_quantized(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
