//! Quantized-model export/import: persist a [`QuantizedModel`] as a `.qtz`
//! bundle so quantization (expensive) and serving (cheap) can run in
//! different processes — the deployment hand-off of the framework.
//!
//! Bundle contents:
//!   __meta.counts        [n_weights, n_biases, n_actquant] (i32)
//!   w:<node>             quantized weight tensor
//!   b:<node>             corrected bias tensor
//!   aq:<node>            [min, max, bits] (f32 triple)

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::io::{read_qtz, write_qtz, QtzValue};
use crate::quant::ActQuant;
use crate::tensor::{IntTensor, Tensor};

use super::pipeline::QuantizedModel;

pub fn save_quantized(path: impl AsRef<Path>, qm: &QuantizedModel) -> Result<()> {
    let mut bundle: BTreeMap<String, QtzValue> = BTreeMap::new();
    for (id, w) in &qm.weight_overrides {
        bundle.insert(format!("w:{id}"), QtzValue::F32(w.clone()));
    }
    for (id, b) in &qm.bias_overrides {
        bundle.insert(format!("b:{id}"), QtzValue::F32(b.clone()));
    }
    let n_aq = qm.act_quant.as_ref().map(|m| m.len()).unwrap_or(0);
    if let Some(aq) = &qm.act_quant {
        for (id, q) in aq {
            bundle.insert(
                format!("aq:{id}"),
                QtzValue::F32(Tensor::from_vec(&[3], vec![q.min, q.max, q.bits as f32])),
            );
        }
    }
    bundle.insert(
        "__meta.counts".into(),
        QtzValue::I32(IntTensor::from_vec(
            &[3],
            vec![
                qm.weight_overrides.len() as i32,
                qm.bias_overrides.len() as i32,
                n_aq as i32,
            ],
        )),
    );
    write_qtz(path, &bundle)
}

pub fn load_quantized(path: impl AsRef<Path>) -> Result<QuantizedModel> {
    let bundle = read_qtz(path)?;
    let counts = bundle
        .get("__meta.counts")
        .ok_or_else(|| anyhow::anyhow!("not a quantized-model bundle (no __meta.counts)"))?
        .as_i32()?
        .data
        .clone();
    let mut qm = QuantizedModel {
        weight_overrides: BTreeMap::new(),
        bias_overrides: BTreeMap::new(),
        act_quant: None,
        stats: Vec::new(),
    };
    let mut aq: BTreeMap<String, ActQuant> = BTreeMap::new();
    for (k, v) in &bundle {
        if let Some(id) = k.strip_prefix("w:") {
            qm.weight_overrides.insert(id.to_string(), v.as_f32()?.clone());
        } else if let Some(id) = k.strip_prefix("b:") {
            qm.bias_overrides.insert(id.to_string(), v.as_f32()?.clone());
        } else if let Some(id) = k.strip_prefix("aq:") {
            let t = v.as_f32()?;
            aq.insert(id.to_string(), ActQuant::new(t.data[0], t.data[1], t.data[2] as u32));
        }
    }
    if !aq.is_empty() {
        qm.act_quant = Some(aq);
    }
    if qm.weight_overrides.len() != counts[0] as usize {
        bail!(
            "corrupt bundle: {} weight tensors, meta says {}",
            qm.weight_overrides.len(),
            counts[0]
        );
    }
    Ok(qm)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_qm() -> QuantizedModel {
        let mut qm = QuantizedModel {
            weight_overrides: BTreeMap::new(),
            bias_overrides: BTreeMap::new(),
            act_quant: None,
            stats: Vec::new(),
        };
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 1, 1, 1], vec![0.5, -0.5]));
        qm.bias_overrides
            .insert("c1".into(), Tensor::from_vec(&[2], vec![0.1, 0.2]));
        let mut aq = BTreeMap::new();
        aq.insert("c1".to_string(), ActQuant::new(-1.5, 2.5, 8));
        qm.act_quant = Some(aq);
        qm
    }

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("qm_roundtrip.qtz");
        let qm = sample_qm();
        save_quantized(&path, &qm).unwrap();
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, vec![0.5, -0.5]);
        assert_eq!(back.bias_overrides["c1"].data, vec![0.1, 0.2]);
        let aq = &back.act_quant.unwrap()["c1"];
        assert_eq!((aq.min, aq.max, aq.bits), (-1.5, 2.5, 8));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_non_bundle() {
        let path = std::env::temp_dir().join("qm_bad.qtz");
        let mut plain = BTreeMap::new();
        plain.insert("x".to_string(), QtzValue::F32(Tensor::zeros(&[1])));
        write_qtz(&path, &plain).unwrap();
        assert!(load_quantized(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
