//! Quantized-model export/import: persist a [`QuantizedModel`] as a `.qtz`
//! bundle so quantization (expensive) and serving (cheap) can run in
//! different processes — the deployment hand-off of the framework.
//!
//! v3 layout (written by [`save_quantized`]):
//!   __meta.version        [2] or [3] (i32; 3 iff any i4 entry below)
//!   __meta.counts         [n_weights, n_biases, n_actquant] (i32)
//!   i4:<node>             nibble-packed weight codes (two per byte) for
//!                         layers quantized at <= 4 bits
//!   i8:<node>             raw integer weight codes (i8, grid multiples)
//!   scale:<node>          per-output-channel grid scales (f32, len cout)
//!   w:<node>              f32 fallback for layers without a clean grid
//!   b:<node>              corrected bias tensor (f32)
//!   aq:<node>             [min, max, bits] (f32 triple)
//!
//! The i8/i4 + scale pair is what the integer serving engine boots from —
//! i8 payloads are 4x smaller than v1 f32, i4 another 2x, and
//! dequantization (`scale[oc] * z`) reproduces the fake-quant f32 values
//! bit-exactly because it is the same multiplication
//! [`crate::quant::fake_quant`] performed: the unpacked nibble IS the i8
//! code. A layer gets `i4:` only when the pipeline recorded
//! `QuantizedModel::wbits <= 4` for it AND every code fits `[-8, 7]`;
//! loading restores `wbits` from the entry kind (i4 -> 4, i8 -> 8), which
//! is what makes the serve compiler pick the nibble-packed w4 kernels.
//! v1 bundles (f32 `w:` entries, no version tag) and v2 bundles (i8
//! only) still load bit-exactly; bundles with no i4 entry are still
//! written as v2 so older builds keep reading them.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::io::{read_qtz, write_qtz, QtzValue};
use crate::quant::ActQuant;
use crate::tensor::{I8Tensor, IntTensor, Tensor};

use super::pipeline::QuantizedModel;

/// Encode one weight tensor as grid codes if its recorded per-channel
/// scales reproduce it exactly within i8 range; `None` -> keep f32.
fn encode_i8(w: &Tensor, scales: &[f32]) -> Option<I8Tensor> {
    let cout = w.shape[0];
    if scales.len() != cout {
        return None;
    }
    let cols = w.numel() / cout;
    let mut data = vec![0i8; w.numel()];
    for oc in 0..cout {
        let s = scales[oc];
        if !(s > 0.0 && s.is_finite()) {
            return None;
        }
        for (d, &v) in data[oc * cols..(oc + 1) * cols].iter_mut().zip(&w.data[oc * cols..]) {
            let z = (v / s).round();
            // exact reproduction required: s * z must equal v bit-for-bit
            if !(-128.0..=127.0).contains(&z) || s * z != v {
                return None;
            }
            *d = z as i8;
        }
    }
    Some(I8Tensor::from_vec(&w.shape, data))
}

pub fn save_quantized(path: impl AsRef<Path>, qm: &QuantizedModel) -> Result<()> {
    let mut bundle: BTreeMap<String, QtzValue> = BTreeMap::new();
    let mut any_i4 = false;
    for (id, w) in &qm.weight_overrides {
        let enc = qm.scales.get(id).and_then(|sc| encode_i8(w, sc));
        match enc {
            Some(wi) => {
                // nibble-pack when the pipeline quantized this layer at
                // <= 4 bits; the grid guarantees codes in [-8, 7] then,
                // but verify anyway so a hand-built QuantizedModel with
                // inconsistent wbits degrades to i8 instead of panicking
                let sub_byte = qm.wbits.get(id).is_some_and(|&b| b <= 4)
                    && crate::tensor::int8::fits_i4(&wi.data);
                if sub_byte {
                    bundle.insert(format!("i4:{id}"), QtzValue::from_i4_codes(&wi.data, &wi.shape));
                    any_i4 = true;
                } else {
                    bundle.insert(format!("i8:{id}"), QtzValue::I8(wi));
                }
                bundle.insert(
                    format!("scale:{id}"),
                    QtzValue::F32(Tensor::from_vec(
                        &[qm.scales[id].len()],
                        qm.scales[id].clone(),
                    )),
                );
            }
            None => {
                bundle.insert(format!("w:{id}"), QtzValue::F32(w.clone()));
            }
        }
    }
    for (id, b) in &qm.bias_overrides {
        bundle.insert(format!("b:{id}"), QtzValue::F32(b.clone()));
    }
    let n_aq = qm.act_quant.as_ref().map(|m| m.len()).unwrap_or(0);
    if let Some(aq) = &qm.act_quant {
        for (id, q) in aq {
            bundle.insert(
                format!("aq:{id}"),
                QtzValue::F32(Tensor::from_vec(&[3], vec![q.min, q.max, q.bits as f32])),
            );
        }
    }
    // stay on v2 when nothing is nibble-packed so older builds keep
    // loading budget-free exports
    bundle.insert(
        "__meta.version".into(),
        QtzValue::I32(IntTensor::from_vec(&[1], vec![if any_i4 { 3 } else { 2 }])),
    );
    bundle.insert(
        "__meta.counts".into(),
        QtzValue::I32(IntTensor::from_vec(
            &[3],
            vec![
                qm.weight_overrides.len() as i32,
                qm.bias_overrides.len() as i32,
                n_aq as i32,
            ],
        )),
    );
    write_qtz(path, &bundle)
}

pub fn load_quantized(path: impl AsRef<Path>) -> Result<QuantizedModel> {
    let bundle = read_qtz(path)?;
    let counts = bundle
        .get("__meta.counts")
        .ok_or_else(|| anyhow::anyhow!("not a quantized-model bundle (no __meta.counts)"))?
        .as_i32()?
        .data
        .clone();
    let version = bundle
        .get("__meta.version")
        .and_then(|v| v.as_i32().ok())
        .and_then(|t| t.data.first().copied())
        .unwrap_or(1);
    if version > 3 {
        bail!("bundle version {version} is newer than this build understands");
    }
    let mut qm = QuantizedModel {
        weight_overrides: BTreeMap::new(),
        bias_overrides: BTreeMap::new(),
        act_quant: None,
        scales: BTreeMap::new(),
        wbits: BTreeMap::new(),
        stats: Vec::new(),
        layer_execs: 0,
    };
    let mut aq: BTreeMap<String, ActQuant> = BTreeMap::new();
    for (k, v) in &bundle {
        if let Some(id) = k.strip_prefix("w:") {
            qm.weight_overrides.insert(id.to_string(), v.as_f32()?.clone());
        } else if let Some(id) = k.strip_prefix("scale:") {
            qm.scales.insert(id.to_string(), v.as_f32()?.data.clone());
        } else if let Some(id) = k.strip_prefix("b:") {
            qm.bias_overrides.insert(id.to_string(), v.as_f32()?.clone());
        } else if let Some(id) = k.strip_prefix("aq:") {
            let t = v.as_f32()?;
            aq.insert(id.to_string(), ActQuant::new(t.data[0], t.data[1], t.data[2] as u32));
        }
    }
    // dequantize integer weight codes — i4 unpacks to the same i8 code
    // space first — (after the scale pass above, so the map iteration
    // order doesn't matter)
    for (k, v) in &bundle {
        let (id, wi, bits) = if let Some(id) = k.strip_prefix("i8:") {
            (id, v.as_i8()?.clone(), 8u32)
        } else if let Some(id) = k.strip_prefix("i4:") {
            (id, v.i4_to_i8()?, 4u32)
        } else {
            continue;
        };
        let sc = qm
            .scales
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("integer weights for {id} without scale:{id}"))?;
        let cout = *wi.shape.first().unwrap_or(&0);
        if cout == 0 {
            bail!("integer weights for {id} have empty shape {:?}", wi.shape);
        }
        if sc.len() != cout && sc.len() != 1 {
            bail!("scale:{id} has {} entries for {cout} output channels", sc.len());
        }
        let cols = wi.numel() / cout;
        let mut data = vec![0.0f32; wi.numel()];
        for oc in 0..cout {
            let s = if sc.len() == 1 { sc[0] } else { sc[oc] };
            for (d, &z) in data[oc * cols..(oc + 1) * cols]
                .iter_mut()
                .zip(&wi.data[oc * cols..])
            {
                *d = s * z as f32;
            }
        }
        qm.wbits.insert(id.to_string(), bits);
        qm.weight_overrides
            .insert(id.to_string(), Tensor::from_vec(&wi.shape, data));
    }
    if !aq.is_empty() {
        qm.act_quant = Some(aq);
    }
    if qm.weight_overrides.len() != counts[0] as usize {
        bail!(
            "corrupt bundle: {} weight tensors, meta says {}",
            qm.weight_overrides.len(),
            counts[0]
        );
    }
    Ok(qm)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_qm() -> QuantizedModel {
        let mut qm = QuantizedModel {
            weight_overrides: BTreeMap::new(),
            bias_overrides: BTreeMap::new(),
            act_quant: None,
            scales: BTreeMap::new(),
            wbits: BTreeMap::new(),
            stats: Vec::new(),
            layer_execs: 0,
        };
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 1, 1, 1], vec![0.5, -0.5]));
        qm.bias_overrides
            .insert("c1".into(), Tensor::from_vec(&[2], vec![0.1, 0.2]));
        let mut aq = BTreeMap::new();
        aq.insert("c1".to_string(), ActQuant::new(-1.5, 2.5, 8));
        qm.act_quant = Some(aq);
        qm
    }

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("qm_roundtrip.qtz");
        let qm = sample_qm();
        save_quantized(&path, &qm).unwrap();
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, vec![0.5, -0.5]);
        assert_eq!(back.bias_overrides["c1"].data, vec![0.1, 0.2]);
        let aq = &back.act_quant.unwrap()["c1"];
        assert_eq!((aq.min, aq.max, aq.bits), (-1.5, 2.5, 8));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn i8_roundtrip_is_bit_exact() {
        // weights on a per-channel grid round-trip through i8 codes with
        // bit-identical f32 values and 4x smaller weight payload
        let path = std::env::temp_dir().join("qm_i8_roundtrip.qtz");
        let mut qm = sample_qm();
        let scales = vec![0.013f32, 0.07];
        let zs: [i32; 8] = [-128, -7, 0, 127, 1, -1, 33, 100];
        let w: Vec<f32> = zs
            .iter()
            .enumerate()
            .map(|(i, &z)| scales[i / 4] * z as f32)
            .collect();
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 4], w.clone()));
        qm.scales.insert("c1".into(), scales.clone());
        save_quantized(&path, &qm).unwrap();
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, w, "dequant must be bit-exact");
        assert_eq!(back.scales["c1"], scales);
        // the bundle actually stores i8 codes, not f32
        let raw = crate::io::read_qtz(&path).unwrap();
        assert!(raw.contains_key("i8:c1"));
        assert!(!raw.contains_key("w:c1"));
        assert_eq!(raw["i8:c1"].as_i8().unwrap().data.len(), 8);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v1_bundles_still_load() {
        // hand-write an old-style bundle: f32 w:/b:/aq: and counts, no
        // version tag — the pre-i8 format
        let path = std::env::temp_dir().join("qm_v1_compat.qtz");
        let mut old: BTreeMap<String, QtzValue> = BTreeMap::new();
        old.insert(
            "w:c1".into(),
            QtzValue::F32(Tensor::from_vec(&[2, 1, 1, 1], vec![0.25, -0.75])),
        );
        old.insert("b:c1".into(), QtzValue::F32(Tensor::from_vec(&[2], vec![0.0, 1.0])));
        old.insert(
            "aq:c1".into(),
            QtzValue::F32(Tensor::from_vec(&[3], vec![-1.0, 1.0, 8.0])),
        );
        old.insert(
            "__meta.counts".into(),
            QtzValue::I32(IntTensor::from_vec(&[3], vec![1, 1, 1])),
        );
        write_qtz(&path, &old).unwrap();
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, vec![0.25, -0.75]);
        assert_eq!(back.bias_overrides["c1"].data, vec![0.0, 1.0]);
        assert!(back.scales.is_empty());
        assert_eq!(back.act_quant.unwrap()["c1"].bits, 8);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn off_grid_weights_fall_back_to_f32() {
        let path = std::env::temp_dir().join("qm_offgrid.qtz");
        let mut qm = sample_qm();
        // scales recorded but the weights are NOT multiples -> f32 path
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 1, 1, 1], vec![0.51, -0.52]));
        qm.scales.insert("c1".into(), vec![0.5, 0.5]);
        save_quantized(&path, &qm).unwrap();
        let raw = crate::io::read_qtz(&path).unwrap();
        assert!(raw.contains_key("w:c1"));
        assert!(!raw.contains_key("i8:c1"));
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, vec![0.51, -0.52]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v3_i4_roundtrip_is_bit_exact() {
        // a 4-bit layer nibble-packs: half the payload of i8, same f32
        // values back, wbits restored so the serve compiler goes w4
        let path = std::env::temp_dir().join("qm_i4_roundtrip.qtz");
        let mut qm = sample_qm();
        let scales = vec![0.013f32, 0.07];
        let zs: [i32; 8] = [-8, 7, 0, -1, 1, 3, -5, 6]; // full i4 corner set
        let w: Vec<f32> = zs
            .iter()
            .enumerate()
            .map(|(i, &z)| scales[i / 4] * z as f32)
            .collect();
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 4], w.clone()));
        qm.scales.insert("c1".into(), scales.clone());
        qm.wbits.insert("c1".into(), 4);
        save_quantized(&path, &qm).unwrap();
        let raw = crate::io::read_qtz(&path).unwrap();
        assert!(raw.contains_key("i4:c1"));
        assert!(!raw.contains_key("i8:c1"));
        assert_eq!(raw["__meta.version"].as_i32().unwrap().data, vec![3]);
        match &raw["i4:c1"] {
            QtzValue::I4(p, _) => assert_eq!(p.len(), 4, "8 codes in 4 bytes"),
            _ => panic!("expected i4 entry"),
        }
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.weight_overrides["c1"].data, w, "dequant must be bit-exact");
        assert_eq!(back.wbits.get("c1"), Some(&4));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v2_kept_when_no_layer_is_sub_byte() {
        // 8-bit-only exports must stay loadable by older builds: version
        // tag 2, no i4 entries, and the loader records wbits = 8
        let path = std::env::temp_dir().join("qm_v2_stable.qtz");
        let mut qm = sample_qm();
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 1, 1, 1], vec![0.5, -0.5]));
        qm.scales.insert("c1".into(), vec![0.25, 0.25]);
        qm.wbits.insert("c1".into(), 8);
        save_quantized(&path, &qm).unwrap();
        let raw = crate::io::read_qtz(&path).unwrap();
        assert_eq!(raw["__meta.version"].as_i32().unwrap().data, vec![2]);
        assert!(raw.contains_key("i8:c1"));
        let back = load_quantized(&path).unwrap();
        assert_eq!(back.wbits.get("c1"), Some(&8));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn inconsistent_wbits_degrade_to_i8() {
        // wbits says 4 but a code is outside [-8, 7]: the exporter must
        // fall back to i8 rather than panic in the packer
        let path = std::env::temp_dir().join("qm_wbits_lies.qtz");
        let mut qm = sample_qm();
        qm.weight_overrides
            .insert("c1".into(), Tensor::from_vec(&[2, 1, 1, 1], vec![5.0, -0.5]));
        qm.scales.insert("c1".into(), vec![0.5, 0.5]); // code 10 > 7
        qm.wbits.insert("c1".into(), 4);
        save_quantized(&path, &qm).unwrap();
        let raw = crate::io::read_qtz(&path).unwrap();
        assert!(raw.contains_key("i8:c1"));
        assert!(!raw.contains_key("i4:c1"));
        assert_eq!(raw["__meta.version"].as_i32().unwrap().data, vec![2]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_non_bundle() {
        let path = std::env::temp_dir().join("qm_bad.qtz");
        let mut plain = BTreeMap::new();
        plain.insert("x".to_string(), QtzValue::F32(Tensor::zeros(&[1])));
        write_qtz(&path, &plain).unwrap();
        assert!(load_quantized(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
