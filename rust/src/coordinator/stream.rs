//! Streaming calibration activations: incremental quantized-prefix
//! propagation, the O(L) heart of the PTQ pipeline.
//!
//! The paper's asymmetric reconstruction (eq. 25) needs, for every layer,
//! the layer input as seen through the *already-quantized prefix*. The
//! obvious implementation replays the whole network from the input for
//! each layer — O(L²) layer-forwards over the calibration set. This
//! module keeps, per calibration chunk, a **live activation frontier**
//! for two forward variants instead:
//!
//! * the **FP32 stream** (no overrides) — supplies X and the targets,
//! * the **quantized-prefix stream** — supplies X^, advanced with the
//!   override map as it exists at that point of the pipeline.
//!
//! After `quantize_layer` installs layer i's overrides, both streams are
//! advanced only through the *newly covered segment* of the graph
//! ([`crate::nn::Model::forward_segment`]); values whose last consumer
//! has run are evicted ([`crate::nn::Model::last_use`]), so resident
//! memory is `n_chunks × live-set` rather than `n_chunks × all taps`.
//! Every node executes exactly once per chunk per stream: O(L) total.
//!
//! **Correctness of lazy advancement.** A node's output depends only on
//! overrides of nodes at or before it, and the pipeline quantizes layers
//! in topological order — so by the time the quantized stream executes
//! node j, every override that could ever affect node j is already
//! installed. The streamed X^ is therefore bit-identical to a full
//! replay under the same override map (asserted per method by
//! `rust/tests/stream_pipeline.rs`).
//!
//! **Determinism.** Chunks advance independently (fanned out over
//! [`crate::util::parallel`]) and are sampled with RNGs forked serially
//! in chunk order, then assembled in chunk order — results are
//! bit-identical for every `PALLAS_THREADS`.
//!
//! ```
//! use adaround::coordinator::TapStore;
//! use adaround::nn::{ForwardOptions, Model};
//! use adaround::tensor::Tensor;
//! use adaround::util::Rng;
//!
//! let mut rng = Rng::new(5);
//! let model = Model::synthetic_chain(3, 4, false, &mut rng);
//! let calib = Tensor::full(&[4, 3, 8, 8], 0.5);
//! let mut store = TapStore::new(&model, &calib, 2);
//!
//! // first layer: no overrides yet, X^ == X and only the FP32 stream runs
//! let c1 = model.node("c1").unwrap().clone();
//! let s = store.sample_layer(&c1, &ForwardOptions::default(), false, 16, &mut rng);
//! assert_eq!(s.x_fp[0].rows(), 3 * 9); // im2col patch of the 3x3 stem
//! assert_eq!(s.x_fp[0].data, s.x_q[0].data);
//! assert_eq!(store.layer_execs(), 0); // the stem's input is the image
//!
//! // a later layer advances the frontier through c1 once per chunk
//! let c2 = model.node("c2").unwrap().clone();
//! let s2 = store.sample_layer(&c2, &ForwardOptions::default(), false, 16, &mut rng);
//! assert_eq!(s2.x_fp[0].rows(), 4 * 9);
//! assert_eq!(store.layer_execs(), 2); // c1 executed for each of the 2 chunks
//! ```

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::chunks;
use crate::nn::{ForwardOptions, Model, Node, Op};
use crate::tensor::Tensor;
use crate::util::{parallel, Rng};

use super::calib::{assemble_sample, collect_chunk_cols, LayerSample};

/// One forward variant's per-chunk execution state: how far every chunk
/// has advanced through the node list, and the values still live there.
struct ActStream {
    /// all nodes `< frontier` executed for every chunk
    frontier: usize,
    /// live node values per chunk (empty until first use — the quantized
    /// stream never materializes anything in symmetric mode)
    vals: Vec<BTreeMap<String, Tensor>>,
}

impl ActStream {
    fn new() -> ActStream {
        ActStream { frontier: 0, vals: Vec::new() }
    }
}

/// Streaming store of calibration activations for the layer-by-layer
/// reconstruction pipeline: the FP32 stream (replacing the former
/// all-taps-resident `FpTapCache`) and the quantized-prefix stream,
/// advanced segment-by-segment as layers get quantized.
pub struct TapStore<'a> {
    model: &'a Model,
    calib: &'a Tensor,
    chunk_list: Vec<(usize, usize)>,
    fp: ActStream,
    q: ActStream,
    /// Conv/Dense executions across both streams, all chunks — the
    /// pipeline's O(L) instrumentation.
    execs: AtomicU64,
}

/// Slice images [s, e) out of the [N,C,H,W] calibration tensor.
fn chunk_tensor(calib: &Tensor, s: usize, e: usize) -> Tensor {
    let per: usize = calib.shape[1..].iter().product();
    let mut shape = calib.shape.clone();
    shape[0] = e - s;
    Tensor::from_vec(&shape, calib.data[s * per..e * per].to_vec())
}

/// Advance one stream to the frontier cut `cut` (exclusive node index),
/// executing `frontier..cut` once per chunk with `opts`. Chunks fan out
/// across threads; each runs the same serial segment executor, so the
/// stored values never depend on scheduling.
fn advance(
    model: &Model,
    calib: &Tensor,
    chunk_list: &[(usize, usize)],
    stream: &mut ActStream,
    cut: usize,
    opts: &ForwardOptions,
) {
    if cut <= stream.frontier {
        return;
    }
    if stream.vals.is_empty() {
        stream.vals = chunk_list
            .iter()
            .map(|&(s, e)| {
                let xb = chunk_tensor(calib, s, e);
                let mut seed = BTreeMap::new();
                for nd in &model.nodes {
                    if matches!(nd.op, Op::Input) {
                        seed.insert(nd.id.clone(), xb.clone());
                    }
                }
                seed
            })
            .collect();
    }
    let range = stream.frontier..cut;
    let no_taps = BTreeSet::new();
    // one liveness map shared by every chunk's segment execution
    let last_use = model.last_use();
    parallel::par_chunks_mut(&mut stream.vals, 1, 1, |_ci, slot| {
        model.forward_segment_with(&mut slot[0], range.clone(), opts, &no_taps, &last_use);
    });
    stream.frontier = cut;
}

impl<'a> TapStore<'a> {
    /// Set up the streams over `calib`, cut into chunks of `chunk_imgs`
    /// images. Nothing is executed until the first [`Self::sample_layer`].
    pub fn new(model: &'a Model, calib: &'a Tensor, chunk_imgs: usize) -> TapStore<'a> {
        TapStore {
            model,
            calib,
            chunk_list: chunks(calib.shape[0], chunk_imgs).collect(),
            fp: ActStream::new(),
            q: ActStream::new(),
            execs: AtomicU64::new(0),
        }
    }

    /// Paired (X, X^) im2col column sample for `node`, read from the
    /// streams' live frontiers. `quant_opts` carries the override map
    /// accumulated so far; `prefix_quantized` = false skips the
    /// quantized stream entirely (X^ == X before any override, and
    /// always in symmetric mode). Must be called with `node`s in
    /// topological order — the frontier only moves forward.
    ///
    /// RNG discipline matches the full-replay sampler exactly: one fork
    /// per chunk, serially, before the parallel sampling fan-out.
    pub fn sample_layer(
        &mut self,
        node: &Node,
        quant_opts: &ForwardOptions,
        prefix_quantized: bool,
        col_budget: usize,
        rng: &mut Rng,
    ) -> LayerSample {
        self.sample_layer_input(node, 0, quant_opts, prefix_quantized, col_budget, rng)
    }

    /// [`Self::sample_layer`] generalized to any input index of `node`:
    /// multi-activation-input ops (attention MatMul) tap the activation
    /// feeding `node.inputs[input_idx]` instead of assuming `inputs[0]`.
    /// Sampling a second input whose producer sits *before* the frontier
    /// is fine as long as `node` itself has not executed — the value's
    /// last consumer is at or after `node`, so eviction cannot have
    /// touched it.
    pub fn sample_layer_input(
        &mut self,
        node: &Node,
        input_idx: usize,
        quant_opts: &ForwardOptions,
        prefix_quantized: bool,
        col_budget: usize,
        rng: &mut Rng,
    ) -> LayerSample {
        assert!(
            input_idx < node.inputs.len(),
            "node '{}' has {} inputs, no index {input_idx}",
            node.id,
            node.inputs.len()
        );
        let input_id = node.inputs[input_idx].as_str();
        let cut = self
            .model
            .node_index(input_id)
            .unwrap_or_else(|| panic!("layer input '{input_id}' not in graph"))
            + 1;
        // inception-style layers sharing an input give cut == frontier; a
        // cut BEHIND the frontier means out-of-order sampling (the fp
        // frontier is the furthest one — it advances on every sample) —
        // unless the consuming node is still pending, which keeps every
        // one of its inputs live regardless of how far the frontier moved
        let node_at = self
            .model
            .node_index(&node.id)
            .unwrap_or_else(|| panic!("node '{}' not in graph", node.id));
        assert!(
            cut >= self.fp.frontier || node_at >= self.fp.frontier,
            "layers must be sampled in topological order \
             (frontier {} past cut {cut}, and node '{}' already executed)",
            self.fp.frontier,
            node.id
        );
        let fp_opts = ForwardOptions { layer_counter: Some(&self.execs), ..Default::default() };
        advance(self.model, self.calib, &self.chunk_list, &mut self.fp, cut, &fp_opts);
        if prefix_quantized {
            let q_opts = ForwardOptions {
                weight_overrides: quant_opts.weight_overrides,
                bias_overrides: quant_opts.bias_overrides,
                act_quant: quant_opts.act_quant,
                layer_counter: Some(&self.execs),
            };
            advance(self.model, self.calib, &self.chunk_list, &mut self.q, cut, &q_opts);
        }
        let n_chunks = self.chunk_list.len();
        let per_chunk_budget = col_budget.div_ceil(n_chunks.max(1));
        let mut crngs: Vec<Rng> = (0..n_chunks).map(|ci| rng.fork(ci as u64)).collect();
        let fp_vals = &self.fp.vals;
        let q_vals = &self.q.vals;
        fn live<'v>(
            vals: &'v [BTreeMap<String, Tensor>],
            ci: usize,
            input_id: &str,
            node_id: &str,
        ) -> &'v Tensor {
            vals[ci].get(input_id).unwrap_or_else(|| {
                panic!("input '{input_id}' of node '{node_id}' not live at streaming frontier")
            })
        }
        let chunk_cols = parallel::par_map_rng(&mut crngs, 1, |ci, crng| {
            let fp_act = live(fp_vals, ci, input_id, &node.id);
            let q_act = if prefix_quantized {
                Some(live(q_vals, ci, input_id, &node.id))
            } else {
                None
            };
            collect_chunk_cols(node, fp_act, q_act, per_chunk_budget, crng)
        });
        assemble_sample(chunk_cols)
    }

    /// Total Conv/Dense node executions so far, across both streams and
    /// every chunk. O(L · n_chunks · 2) over a whole pipeline run — the
    /// number the `stream_pipeline` tests pin down.
    pub fn layer_execs(&self) -> u64 {
        self.execs.load(Ordering::Relaxed)
    }

    /// Current (fp, quantized) frontiers — diagnostics/tests.
    pub fn frontiers(&self) -> (usize, usize) {
        (self.fp.frontier, self.q.frontier)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::calib::{build_fp_cache, sample_layer_cached};

    fn deep() -> (Model, Tensor) {
        let mut rng = Rng::new(17);
        let model = Model::synthetic_chain(5, 4, true, &mut rng);
        let n = 5; // 2 chunks of (4, 1) at chunk_imgs = 4
        let calib = Tensor::from_vec(
            &[n, 3, 8, 8],
            (0..n * 3 * 64).map(|i| ((i % 23) as f32 - 11.0) * 0.07).collect(),
        );
        (model, calib)
    }

    /// Quantized-prefix override map: halve c1's weights.
    fn overrides(model: &Model) -> BTreeMap<String, Tensor> {
        let mut ov = BTreeMap::new();
        ov.insert("c1".to_string(), model.weight("c1").map(|v| v * 0.5));
        ov
    }

    #[test]
    fn streaming_matches_full_replay_per_layer() {
        let (model, calib) = deep();
        let ov = overrides(&model);
        let mut store = TapStore::new(&model, &calib, 4);
        let layers: Vec<Node> = model.quant_layers().into_iter().cloned().collect();
        let input_ids: BTreeSet<String> =
            layers.iter().map(|n| n.inputs[0].clone()).collect();
        let cache = build_fp_cache(&model, &calib, &input_ids, 4, None);
        for (i, node) in layers.iter().enumerate() {
            let quant_opts = ForwardOptions { weight_overrides: Some(&ov), ..Default::default() };
            let prefix = i > 0; // first layer pre-override, like the pipeline
            let mut srng = Rng::new(100 + i as u64);
            let a = store.sample_layer(node, &quant_opts, prefix, 32, &mut srng);
            let b = sample_layer_cached(&model, node, &calib, &quant_opts, prefix,
                                        Some(&cache), 32, 4, &mut Rng::new(100 + i as u64));
            for g in 0..a.x_fp.len() {
                assert_eq!(a.x_fp[g].data, b.x_fp[g].data, "X  differs at layer {}", node.id);
                assert_eq!(a.x_q[g].data, b.x_q[g].data, "X^ differs at layer {}", node.id);
            }
        }
    }

    #[test]
    fn frontier_advances_lazily_and_evicts() {
        let (model, calib) = deep();
        let ov = overrides(&model);
        let mut store = TapStore::new(&model, &calib, 4);
        assert_eq!(store.frontiers(), (0, 0));
        let c1 = model.node("c1").unwrap().clone();
        store.sample_layer(&c1, &ForwardOptions::default(), false, 8, &mut Rng::new(1));
        // c1's input is the image: fp frontier 1, q stream untouched
        assert_eq!(store.frontiers(), (1, 0));
        assert!(store.q.vals.is_empty(), "symmetric sampling must not seed the q stream");

        let c4 = model.node("c4").unwrap().clone();
        let quant_opts = ForwardOptions { weight_overrides: Some(&ov), ..Default::default() };
        store.sample_layer(&c4, &quant_opts, true, 8, &mut Rng::new(2));
        // c4 reads m1(5): both frontiers at 6, live sets match the analysis
        assert_eq!(store.frontiers(), (6, 6));
        for vals in store.fp.vals.iter().chain(&store.q.vals) {
            let keys: BTreeSet<String> = vals.keys().cloned().collect();
            assert_eq!(keys, model.live_at(6));
            assert!(!keys.contains("c1"), "dead taps must be evicted");
        }
    }

    /// Regression (single-input assumption): on the attention AV matmul
    /// the tap wiring must pick the tensor for the *requested* input
    /// index — probs for input 0, values for input 1 — not `inputs[0]`
    /// for everything.
    #[test]
    fn multi_input_sampling_taps_each_input() {
        let mut rng = Rng::new(5);
        let model = Model::synthetic_transformer(1, 2, 8, 6, &mut rng);
        let calib = crate::data::synthetic_tokens(4, 6, 32, &mut Rng::new(9));
        let mut store = TapStore::new(&model, &calib, 2);
        let av = model.node("b1.av").unwrap().clone();
        let s0 = store.sample_layer_input(
            &av, 0, &ForwardOptions::default(), false, 16, &mut Rng::new(1),
        );
        // input 0 = causal softmax probs [N, H, S, S]: columns of dim S
        assert_eq!(s0.x_fp[0].rows(), 6);
        assert!(s0.x_fp[0].data.iter().all(|&v| v >= 0.0), "probs are non-negative");
        // input 1 = V [N, S, D]: its producer sits BEFORE the frontier
        // now, but stays live because av itself has not executed
        let s1 = store.sample_layer_input(
            &av, 1, &ForwardOptions::default(), false, 16, &mut Rng::new(1),
        );
        assert_eq!(s1.x_fp[0].rows(), 8);
        assert!(s1.x_fp[0].data.iter().any(|&v| v < 0.0), "V is a different tensor");
    }

    #[test]
    #[should_panic(expected = "topological order")]
    fn out_of_order_sampling_still_panics() {
        let mut rng = Rng::new(5);
        let model = Model::synthetic_transformer(1, 2, 8, 6, &mut rng);
        let calib = crate::data::synthetic_tokens(4, 6, 32, &mut Rng::new(9));
        let mut store = TapStore::new(&model, &calib, 2);
        let wo = model.node("b1.wo").unwrap().clone();
        store.sample_layer(&wo, &ForwardOptions::default(), false, 8, &mut Rng::new(1));
        // b1.q executed when the frontier passed it — sampling it now is
        // a real ordering bug, multi-input relaxation or not
        let q = model.node("b1.q").unwrap().clone();
        store.sample_layer(&q, &ForwardOptions::default(), false, 8, &mut Rng::new(2));
    }

    #[test]
    fn layer_exec_count_is_linear() {
        let (model, calib) = deep(); // 6 quantizable layers, 2 chunks
        let ov = overrides(&model);
        let mut store = TapStore::new(&model, &calib, 4);
        let layers: Vec<Node> = model.quant_layers().into_iter().cloned().collect();
        for (i, node) in layers.iter().enumerate() {
            let quant_opts = ForwardOptions { weight_overrides: Some(&ov), ..Default::default() };
            store.sample_layer(node, &quant_opts, i > 0, 8, &mut Rng::new(i as u64));
        }
        let n_chunks = 2u64;
        let l = layers.len() as u64;
        // each stream executes each quantizable node at most once per chunk
        assert!(store.layer_execs() <= 2 * n_chunks * l,
                "layer execs {} not O(L)", store.layer_execs());
        assert!(store.layer_execs() > 0);
    }
}
