//! Rectified sigmoid + regularizer — the continuous-relaxation substrate
//! of AdaRound (paper eqs. 21-25; see PAPER.md), as an exact mirror of
//! `python/compile/kernels/relax.py` so both drivers agree bit-for-bit in
//! definition (floating-point roundoff aside).
//!
//! Equation map (Nagel et al., ICML 2020, §4):
//! * eq. (21): the relaxed per-layer objective
//!   `argmin_V ||Wx - W~x||_F^2 + lam * f_reg(V)` — assembled in
//!   [`super::problem::LayerProblem`], with this module supplying h and
//!   f_reg.
//! * eq. (22): soft-quantized weights
//!   `W~ = s * clip(floor(W/s) + h(V), n, p)` — the h(V) term is
//!   [`rect_sigmoid`]; at convergence h saturates to {0, 1} and eq. (22)
//!   collapses to the binary form of eq. (1)
//!   ([`crate::quant::rounding_mask`]).
//! * eq. (23): `h(V) = clip(sigmoid(V) * (zeta - gamma) + gamma, 0, 1)`
//!   with the paper's stretch constants zeta = 1.1, gamma = -0.1 —
//!   [`rect_sigmoid`] / [`rect_sigmoid_pair`].
//! * eq. (24): the pull-to-binary regularizer
//!   `f_reg(V) = sum 1 - |2 h(V) - 1|^beta`, beta annealed high -> low —
//!   [`f_reg_elem`] / [`f_reg_grad`] ([`super::schedule`] owns the
//!   annealing).
//! * eq. (25): the final asymmetric objective
//!   `argmin_V ||f_a(Wx) - f_a(W~x^)||_F^2 + lam * f_reg(V)` (quantized-
//!   prefix input x^, activation f_a folded in) — the form
//!   [`super::problem::LayerProblem::loss_grad_into`] optimizes and
//!   `recon_mse` reports.

pub const ZETA: f32 = 1.1;
pub const GAMMA: f32 = -0.1;

#[inline]
pub fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

/// h(V) = clip(sigmoid(V)(zeta - gamma) + gamma, 0, 1)   (eq. 23)
#[inline]
pub fn rect_sigmoid(v: f32) -> f32 {
    (sigmoid(v) * (ZETA - GAMMA) + GAMMA).clamp(0.0, 1.0)
}

/// dh/dV, zero in the rectified (clipped) region.
#[inline]
pub fn rect_sigmoid_grad(v: f32) -> f32 {
    let s = sigmoid(v);
    let raw = s * (ZETA - GAMMA) + GAMMA;
    if raw > 0.0 && raw < 1.0 {
        s * (1.0 - s) * (ZETA - GAMMA)
    } else {
        0.0
    }
}

/// (h(V), dh/dV) in one pass, evaluating the sigmoid once. Bit-identical
/// to calling [`rect_sigmoid`] and [`rect_sigmoid_grad`] separately —
/// the fused form the optimizer hot loop uses.
#[inline]
pub fn rect_sigmoid_pair(v: f32) -> (f32, f32) {
    let s = sigmoid(v);
    let raw = s * (ZETA - GAMMA) + GAMMA;
    let h = raw.clamp(0.0, 1.0);
    let dh = if raw > 0.0 && raw < 1.0 { s * (1.0 - s) * (ZETA - GAMMA) } else { 0.0 };
    (h, dh)
}

/// Per-element regularizer 1 - |2h-1|^beta  (eq. 24, summed by callers).
#[inline]
pub fn f_reg_elem(h: f32, beta: f32) -> f32 {
    1.0 - (2.0 * h - 1.0).abs().powf(beta)
}

/// d f_reg / dV (through h) at one element.
#[inline]
pub fn f_reg_grad(v: f32, beta: f32) -> f32 {
    let h = rect_sigmoid(v);
    let z = 2.0 * h - 1.0;
    let dh = rect_sigmoid_grad(v);
    if z == 0.0 {
        return 0.0;
    }
    -beta * z.abs().powf(beta - 1.0) * 2.0 * z.signum() * dh
}

/// Initialize V so h(V) = frac(w/s): soft quantization starts at FP32
/// (mirror of `relax.init_v_from_weights`).
pub fn init_v(w: f32, s: f32) -> f32 {
    let frac = (w / s - (w / s).floor()).clamp(1e-4, 1.0 - 1e-4);
    let p = (frac - GAMMA) / (ZETA - GAMMA);
    (p / (1.0 - p)).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{close, property};

    #[test]
    fn h_range_and_saturation() {
        property(71, 50, |g| {
            let v = g.f32(-40.0, 40.0);
            let h = rect_sigmoid(v);
            if !(0.0..=1.0).contains(&h) {
                return Err(format!("h({v}) = {h} out of range"));
            }
            Ok(())
        });
        assert_eq!(rect_sigmoid(12.0), 1.0);
        assert_eq!(rect_sigmoid(-12.0), 0.0);
    }

    #[test]
    fn grad_matches_finite_difference() {
        property(72, 40, |g| {
            let v = g.f32(-5.0, 5.0);
            let eps = 1e-3;
            let fd = (rect_sigmoid(v + eps) - rect_sigmoid(v - eps)) / (2.0 * eps);
            close(rect_sigmoid_grad(v), fd, 2e-3)
        });
    }

    #[test]
    fn f_reg_grad_matches_fd() {
        property(73, 40, |g| {
            let v = g.f32(-3.0, 3.0);
            let beta = g.f32(2.0, 12.0);
            let eps = 1e-3;
            let fd = (f_reg_elem(rect_sigmoid(v + eps), beta)
                - f_reg_elem(rect_sigmoid(v - eps), beta))
                / (2.0 * eps);
            close(f_reg_grad(v, beta), fd, 5e-2)
        });
    }

    #[test]
    fn pair_matches_separate_calls() {
        property(75, 60, |g| {
            let v = g.f32(-20.0, 20.0);
            let (h, dh) = rect_sigmoid_pair(v);
            if h.to_bits() != rect_sigmoid(v).to_bits() {
                return Err(format!("h mismatch at {v}"));
            }
            if dh.to_bits() != rect_sigmoid_grad(v).to_bits() {
                return Err(format!("dh mismatch at {v}"));
            }
            Ok(())
        });
    }

    #[test]
    fn init_v_inverse() {
        property(74, 40, |g| {
            let w = g.f32(-1.0, 1.0);
            let s = g.f32(0.01, 0.3);
            let v = init_v(w, s);
            let frac = (w / s - (w / s).floor()).clamp(1e-4, 1.0 - 1e-4);
            close(rect_sigmoid(v), frac, 1e-3)
        });
    }
}
