//! Pure-rust AdaRound driver: analytic gradient + Adam, minibatched over
//! the calibration columns. Mathematically identical to the PJRT/HLO step
//! (verified against it in `rust/tests/pjrt_integration.rs`).
//!
//! The inner loop is allocation-free: the index pool, gathered minibatch
//! and every gradient intermediate live in buffers allocated once per
//! layer ([`StepWorkspace`], [`gather_cols_into`],
//! [`crate::util::Rng::sample_indices_into`]), and the GEMMs inside
//! [`LayerProblem::loss_grad_into`] run row-parallel.

use anyhow::Result;

use crate::tensor::Tensor;
use crate::util::Rng;

use super::problem::{LayerProblem, StepWorkspace};
use super::schedule::AdaRoundConfig;
use super::{Adam, LayerResult, RoundingOptimizer};

#[derive(Default)]
pub struct NativeOptimizer;

/// Gather a column subset of X [cols, N] -> [cols, k] (allocates).
pub fn gather_cols(x: &Tensor, idx: &[usize]) -> Tensor {
    let mut out = Tensor::zeros(&[x.rows(), idx.len()]);
    gather_cols_into(x, idx, &mut out);
    out
}

/// Gather a column subset of X [cols, N] into a preallocated [cols, k].
pub fn gather_cols_into(x: &Tensor, idx: &[usize], out: &mut Tensor) {
    let (rows, n) = (x.rows(), x.cols());
    let k = idx.len();
    // slice compare, not vec![..]: this runs in the allocation-free loop
    assert_eq!(out.shape.as_slice(), [rows, k].as_slice(), "gather output shape");
    for r in 0..rows {
        let src = &x.data[r * n..(r + 1) * n];
        let dst = &mut out.data[r * k..(r + 1) * k];
        for (j, &i) in idx.iter().enumerate() {
            dst[j] = src[i];
        }
    }
}

impl RoundingOptimizer for NativeOptimizer {
    fn optimize(
        &mut self,
        prob: &LayerProblem,
        x: &Tensor,
        t: &Tensor,
        cfg: &AdaRoundConfig,
        rng: &mut Rng,
    ) -> Result<LayerResult> {
        let mut v = prob.init_v();
        let mut adam = Adam::new(v.numel());
        let ncols = x.cols();
        let batch = cfg.batch.min(ncols);
        let mse_before = prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), x, t);

        // everything the loop touches, allocated once
        let mut ws = StepWorkspace::new(prob.rows(), prob.cols(), batch);
        let mut xb = Tensor::zeros(&[prob.cols(), batch]);
        let mut tb = Tensor::zeros(&[prob.rows(), batch]);
        let mut pool: Vec<usize> = Vec::with_capacity(ncols);

        for it in 0..cfg.iters {
            let (beta, reg_on) = cfg.beta.at(it, cfg.iters);
            let lam = if reg_on { cfg.lambda } else { 0.0 };
            let k = rng.sample_indices_into(ncols, batch, &mut pool);
            gather_cols_into(x, &pool[..k], &mut xb);
            gather_cols_into(t, &pool[..k], &mut tb);
            prob.loss_grad_into(&v, &xb, &tb, beta, lam, &mut ws);
            adam.step(&mut v.data, &ws.grad, cfg.lr);
        }

        let mask = prob.mask_from_v(&v);
        let mse_after = prob.recon_mse(&prob.hard_weights(&mask), x, t);
        let near = prob.nearest_mask();
        let flipped = mask
            .data
            .iter()
            .zip(&near.data)
            .filter(|(a, b)| (*a - *b).abs() > 0.5)
            .count();
        Ok(LayerResult {
            flipped_frac: flipped as f64 / mask.numel() as f64,
            mask,
            v,
            mse_before,
            mse_after,
            iters: cfg.iters,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::problem::tests::random_problem;
    use super::*;
    use crate::util::parallel::with_threads;

    fn layer_data(seed: u64, prob: &LayerProblem, ncols: usize) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let cols = prob.cols();
        let x = Tensor::from_vec(
            &[cols, ncols],
            (0..cols * ncols).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let mut t = crate::tensor::matmul(&prob.w, &x);
        for r in 0..prob.rows() {
            for v in &mut t.data[r * ncols..(r + 1) * ncols] {
                *v += prob.bias[r];
            }
        }
        (x, t)
    }

    #[test]
    fn improves_over_nearest() {
        for (seed, relu) in [(1u64, false), (2, true)] {
            let prob = random_problem(seed, 8, 24, relu);
            let (x, t) = layer_data(seed + 5, &prob, 256);
            let cfg = AdaRoundConfig { iters: 400, batch: 96, ..Default::default() };
            let mut rng = Rng::new(seed);
            let res = NativeOptimizer.optimize(&prob, &x, &t, &cfg, &mut rng).unwrap();
            assert!(
                res.mse_after <= res.mse_before * 1.001,
                "relu={relu}: after {} vs before {}",
                res.mse_after,
                res.mse_before
            );
            // some weights should actually flip rounding direction (Fig. 3)
            assert!(res.flipped_frac > 0.0, "no weights flipped");
        }
    }

    #[test]
    fn converges_to_binary() {
        let prob = random_problem(7, 6, 16, false);
        let (x, t) = layer_data(8, &prob, 128);
        let cfg = AdaRoundConfig { iters: 600, batch: 64, ..Default::default() };
        let mut rng = Rng::new(9);
        let res = NativeOptimizer.optimize(&prob, &x, &t, &cfg, &mut rng).unwrap();
        let binary = res
            .v
            .data
            .iter()
            .map(|&v| super::super::relax::rect_sigmoid(v))
            .filter(|&h| h < 0.05 || h > 0.95)
            .count();
        let frac = binary as f64 / res.v.numel() as f64;
        assert!(frac > 0.75, "only {frac} of h converged to binary");
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = random_problem(11, 4, 12, true);
        let (x, t) = layer_data(12, &prob, 64);
        let cfg = AdaRoundConfig { iters: 100, batch: 32, ..Default::default() };
        let r1 = NativeOptimizer.optimize(&prob, &x, &t, &cfg, &mut Rng::new(5)).unwrap();
        let r2 = NativeOptimizer.optimize(&prob, &x, &t, &cfg, &mut Rng::new(5)).unwrap();
        assert_eq!(r1.mask.data, r2.mask.data);
    }

    #[test]
    fn bit_identical_across_threads() {
        // the full optimizer trajectory — V, mask and MSEs — must not
        // depend on PALLAS_THREADS (acceptance criterion of the parallel
        // compute core)
        let prob = random_problem(13, 16, 36, true);
        let (x, t) = layer_data(14, &prob, 160);
        let cfg = AdaRoundConfig { iters: 120, batch: 64, ..Default::default() };
        let run = |threads: usize| {
            with_threads(threads, || {
                NativeOptimizer.optimize(&prob, &x, &t, &cfg, &mut Rng::new(5)).unwrap()
            })
        };
        let r1 = run(1);
        let r4 = run(4);
        assert_eq!(r1.v.data, r4.v.data, "V trajectories diverged across thread counts");
        assert_eq!(r1.mask.data, r4.mask.data);
        assert_eq!(r1.mse_after.to_bits(), r4.mse_after.to_bits());
    }
}
