//! Pure-rust AdaRound driver: analytic gradient + Adam, minibatched over
//! the calibration columns. Mathematically identical to the PJRT/HLO step
//! (verified against it in `rust/tests/pjrt_integration.rs`).

use anyhow::Result;

use crate::tensor::Tensor;
use crate::util::Rng;

use super::problem::LayerProblem;
use super::schedule::AdaRoundConfig;
use super::{Adam, LayerResult, RoundingOptimizer};

#[derive(Default)]
pub struct NativeOptimizer;

/// Gather a column subset of X [cols, N] -> [cols, k].
pub fn gather_cols(x: &Tensor, idx: &[usize]) -> Tensor {
    let (rows, n) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(&[rows, idx.len()]);
    for r in 0..rows {
        let src = &x.data[r * n..(r + 1) * n];
        let dst = &mut out.data[r * idx.len()..(r + 1) * idx.len()];
        for (j, &i) in idx.iter().enumerate() {
            dst[j] = src[i];
        }
    }
    out
}

impl RoundingOptimizer for NativeOptimizer {
    fn optimize(
        &mut self,
        prob: &LayerProblem,
        x: &Tensor,
        t: &Tensor,
        cfg: &AdaRoundConfig,
        rng: &mut Rng,
    ) -> Result<LayerResult> {
        let mut v = prob.init_v();
        let mut adam = Adam::new(v.numel());
        let ncols = x.cols();
        let mse_before = prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), x, t);

        for it in 0..cfg.iters {
            let (beta, reg_on) = cfg.beta.at(it, cfg.iters);
            let lam = if reg_on { cfg.lambda } else { 0.0 };
            let idx = rng.sample_indices(ncols, cfg.batch.min(ncols));
            let xb = gather_cols(x, &idx);
            let tb = gather_cols(t, &idx);
            let (_, _, grad) = prob.loss_grad(&v, &xb, &tb, beta, lam);
            adam.step(&mut v.data, &grad.data, cfg.lr);
        }

        let mask = prob.mask_from_v(&v);
        let mse_after = prob.recon_mse(&prob.hard_weights(&mask), x, t);
        let near = prob.nearest_mask();
        let flipped = mask
            .data
            .iter()
            .zip(&near.data)
            .filter(|(a, b)| (*a - *b).abs() > 0.5)
            .count();
        Ok(LayerResult {
            flipped_frac: flipped as f64 / mask.numel() as f64,
            mask,
            v,
            mse_before,
            mse_after,
            iters: cfg.iters,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::problem::tests::random_problem;
    use super::*;

    fn layer_data(seed: u64, prob: &LayerProblem, ncols: usize) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let cols = prob.cols();
        let x = Tensor::from_vec(
            &[cols, ncols],
            (0..cols * ncols).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let mut t = crate::tensor::matmul(&prob.w, &x);
        for r in 0..prob.rows() {
            for v in &mut t.data[r * ncols..(r + 1) * ncols] {
                *v += prob.bias[r];
            }
        }
        (x, t)
    }

    #[test]
    fn improves_over_nearest() {
        for (seed, relu) in [(1u64, false), (2, true)] {
            let prob = random_problem(seed, 8, 24, relu);
            let (x, t) = layer_data(seed + 5, &prob, 256);
            let cfg = AdaRoundConfig { iters: 400, batch: 96, ..Default::default() };
            let mut rng = Rng::new(seed);
            let res = NativeOptimizer.optimize(&prob, &x, &t, &cfg, &mut rng).unwrap();
            assert!(
                res.mse_after <= res.mse_before * 1.001,
                "relu={relu}: after {} vs before {}",
                res.mse_after,
                res.mse_before
            );
            // some weights should actually flip rounding direction (Fig. 3)
            assert!(res.flipped_frac > 0.0, "no weights flipped");
        }
    }

    #[test]
    fn converges_to_binary() {
        let prob = random_problem(7, 6, 16, false);
        let (x, t) = layer_data(8, &prob, 128);
        let cfg = AdaRoundConfig { iters: 600, batch: 64, ..Default::default() };
        let mut rng = Rng::new(9);
        let res = NativeOptimizer.optimize(&prob, &x, &t, &cfg, &mut rng).unwrap();
        let binary = res
            .v
            .data
            .iter()
            .map(|&v| super::super::relax::rect_sigmoid(v))
            .filter(|&h| h < 0.05 || h > 0.95)
            .count();
        let frac = binary as f64 / res.v.numel() as f64;
        assert!(frac > 0.75, "only {frac} of h converged to binary");
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = random_problem(11, 4, 12, true);
        let (x, t) = layer_data(12, &prob, 64);
        let cfg = AdaRoundConfig { iters: 100, batch: 32, ..Default::default() };
        let r1 = NativeOptimizer.optimize(&prob, &x, &t, &cfg, &mut Rng::new(5)).unwrap();
        let r2 = NativeOptimizer.optimize(&prob, &x, &t, &cfg, &mut Rng::new(5)).unwrap();
        assert_eq!(r1.mask.data, r2.mask.data);
    }
}
