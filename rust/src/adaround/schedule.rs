//! AdaRound hyper-parameters and the beta annealing schedule (Fig. 2).

/// Annealing of the regularizer sharpness beta: high beta lets h move
/// freely to fit the MSE; low beta forces h to the {0,1} extremes.
#[derive(Clone, Copy, Debug)]
pub struct BetaSchedule {
    pub start: f32,
    pub end: f32,
    /// fraction of iterations with the regularizer disabled (warm start)
    pub warmup: f32,
}

impl Default for BetaSchedule {
    fn default() -> Self {
        BetaSchedule { start: 20.0, end: 2.0, warmup: 0.2 }
    }
}

impl BetaSchedule {
    /// (beta, reg_enabled) at iteration `it` of `total`.
    pub fn at(&self, it: usize, total: usize) -> (f32, bool) {
        let frac = it as f32 / total.max(1) as f32;
        if frac < self.warmup {
            return (self.start, false);
        }
        let t = (frac - self.warmup) / (1.0 - self.warmup);
        // cosine decay start -> end
        let beta = self.end + 0.5 * (self.start - self.end) * (1.0 + (std::f32::consts::PI * t).cos());
        (beta, true)
    }
}

/// Full AdaRound configuration (paper §5 experimental setup, scaled to
/// this testbed: micro-layers converge in far fewer iterations than
/// Resnet18's 10k).
#[derive(Clone, Copy, Debug)]
pub struct AdaRoundConfig {
    pub iters: usize,
    pub batch: usize,
    pub lr: f32,
    pub lambda: f32,
    pub beta: BetaSchedule,
    /// account for the activation function in the objective (eq. 25)
    pub use_relu: bool,
}

impl Default for AdaRoundConfig {
    fn default() -> Self {
        AdaRoundConfig {
            iters: 1200,
            batch: 192, // must match the AOT STEP_BATCH bucket for PJRT
            lr: 1e-2,
            lambda: 0.01,
            beta: BetaSchedule::default(),
            use_relu: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_disables_reg() {
        let s = BetaSchedule::default();
        let (b, on) = s.at(0, 100);
        assert_eq!(b, 20.0);
        assert!(!on);
        let (_, on2) = s.at(50, 100);
        assert!(on2);
    }

    #[test]
    fn monotone_decay_to_end() {
        let s = BetaSchedule::default();
        let mut prev = f32::INFINITY;
        for it in 20..100 {
            let (b, _) = s.at(it, 100);
            assert!(b <= prev + 1e-5);
            prev = b;
        }
        let (b_end, _) = s.at(99, 100);
        assert!(b_end < 2.2, "end beta {b_end}");
    }
}
