//! Straight-through-estimator baseline (paper Table 5).
//!
//! Optimizes continuous weights W' for the same reconstruction MSE, but
//! quantizes with round-to-nearest in the forward pass and passes the
//! gradient straight through (Bengio et al., 2013). Unlike AdaRound the
//! quantized weights can wander multiple grid steps; the paper finds the
//! biased STE gradient makes this *worse* than AdaRound.

use anyhow::Result;

use crate::tensor::{matmul, Tensor};
use crate::util::Rng;

use super::native::gather_cols;
use super::problem::LayerProblem;
use super::schedule::AdaRoundConfig;
use super::{Adam, LayerResult};

/// Returns the same LayerResult shape as the other drivers; `mask` holds
/// the *effective* rounding of the final W' relative to floor(W/s) clamped
/// to {0, 1} for reporting, while the quantized weights themselves are in
/// `v` (reused as storage for W_q).
pub fn optimize_ste(
    prob: &LayerProblem,
    x: &Tensor,
    t: &Tensor,
    cfg: &AdaRoundConfig,
    rng: &mut Rng,
) -> Result<LayerResult> {
    let (rows, cols) = (prob.rows(), prob.cols());
    let mut w = prob.w.clone(); // continuous shadow weights
    let mut adam = Adam::new(w.numel());
    let ncols = x.cols();
    let mse_before = prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), x, t);

    let quantize = |w: &Tensor| -> Tensor {
        let mut q = Tensor::zeros(&w.shape);
        for r in 0..rows {
            let s = prob.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                q.data[i] = s * (w.data[i] / s).round().clamp(prob.n, prob.p);
            }
        }
        q
    };

    for _ in 0..cfg.iters {
        let idx = rng.sample_indices(ncols, cfg.batch.min(ncols));
        let xb = gather_cols(x, &idx);
        let tb = gather_cols(t, &idx);
        let wq = quantize(&w);
        let mut y = matmul(&wq, &xb);
        // + bias
        let batch = y.cols();
        for r in 0..rows {
            let b = prob.bias.get(r).copied().unwrap_or(0.0);
            for v in &mut y.data[r * batch..(r + 1) * batch] {
                *v += b;
            }
        }
        let numel = (rows * batch) as f32;
        let mut dy = Tensor::zeros(&[rows, batch]);
        for i in 0..rows * batch {
            let (yi, ti) = (y.data[i], tb.data[i]);
            let (ya, ta) = if prob.relu { (yi.max(0.0), ti.max(0.0)) } else { (yi, ti) };
            let pass = if prob.relu && yi <= 0.0 { 0.0 } else { 1.0 };
            dy.data[i] = 2.0 * (ya - ta) * pass / numel;
        }
        // STE: dL/dW' = dL/dWq (identity through rounding; clip mask applied)
        let mut grad = crate::tensor::matmul::matmul_bt(&dy, &xb);
        for r in 0..rows {
            let s = prob.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let z = w.data[i] / s;
                if z < prob.n || z > prob.p {
                    grad.data[i] = 0.0; // outside grid: no gradient
                }
            }
        }
        adam.step(&mut w.data, &grad.data, cfg.lr);
    }

    let wq = quantize(&w);
    let mse_after = prob.recon_mse(&wq, x, t);
    // effective up/down mask relative to floor(W_fp32/s), clamped for report
    let near = prob.nearest_mask();
    let mut mask = Tensor::zeros(&w.shape);
    let mut flipped = 0usize;
    for r in 0..rows {
        let s = prob.s(r);
        for c in 0..cols {
            let i = r * cols + c;
            let steps = (wq.data[i] / s - (prob.w.data[i] / s).floor()).round();
            mask.data[i] = steps.clamp(0.0, 1.0);
            if (mask.data[i] - near.data[i]).abs() > 0.5 {
                flipped += 1;
            }
        }
    }
    Ok(LayerResult {
        flipped_frac: flipped as f64 / mask.numel() as f64,
        mask,
        v: wq,
        mse_before,
        mse_after,
        iters: cfg.iters,
    })
}

/// STE quantized weights from the result (stored in `v`).
pub fn ste_weights(res: &LayerResult) -> &Tensor {
    &res.v
}

#[cfg(test)]
mod tests {
    use super::super::problem::tests::random_problem;
    use super::*;

    #[test]
    fn ste_improves_over_nearest() {
        let prob = random_problem(31, 8, 24, false);
        let mut rng = Rng::new(32);
        let x = Tensor::from_vec(
            &[24, 256],
            (0..24 * 256).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let mut t = matmul(&prob.w, &x);
        for r in 0..8 {
            for v in &mut t.data[r * 256..(r + 1) * 256] {
                *v += prob.bias[r];
            }
        }
        let cfg = AdaRoundConfig { iters: 400, batch: 96, lr: 2e-3, ..Default::default() };
        let res = optimize_ste(&prob, &x, &t, &cfg, &mut rng).unwrap();
        assert!(
            res.mse_after <= res.mse_before * 1.001,
            "{} vs {}",
            res.mse_after,
            res.mse_before
        );
    }
}
