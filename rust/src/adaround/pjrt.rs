//! PJRT-driven AdaRound: the architecture's request-path driver.
//!
//! Each iteration executes ONE fused HLO module (Pallas soft-quant matmul
//! fwd/bwd + f_reg + Adam) compiled ahead of time from
//! `python/compile/model.py`. Rust only shuttles buffers and schedules —
//! no Python anywhere near this loop.

use anyhow::Result;

use crate::runtime::{Runtime, StepState};
use crate::tensor::Tensor;
use crate::util::Rng;

use super::native::gather_cols;
use super::problem::LayerProblem;
use super::schedule::AdaRoundConfig;
use super::{LayerResult, RoundingOptimizer};

pub struct PjrtOptimizer<'rt> {
    pub rt: &'rt Runtime,
}

impl<'rt> PjrtOptimizer<'rt> {
    pub fn new(rt: &'rt Runtime) -> Self {
        PjrtOptimizer { rt }
    }
}

impl<'rt> RoundingOptimizer for PjrtOptimizer<'rt> {
    fn optimize(
        &mut self,
        prob: &LayerProblem,
        x: &Tensor,
        t: &Tensor,
        cfg: &AdaRoundConfig,
        rng: &mut Rng,
    ) -> Result<LayerResult> {
        let (rows, cols) = (prob.rows(), prob.cols());
        let exec = self.rt.step_exec(rows, cols, prob.relu)?;
        // the HLO bucket fixes the minibatch width; cfg.batch is advisory
        let step_batch = exec.batch;
        let ncols = x.cols();

        let s_col = Tensor::from_vec(&[rows, 1], (0..rows).map(|r| prob.s(r)).collect());
        let b_col = Tensor::from_vec(&[rows, 1], prob.bias.clone());
        let mut state = StepState::new(prob.init_v());
        let mse_before = prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), x, t);

        for it in 0..cfg.iters {
            let (beta, reg_on) = cfg.beta.at(it, cfg.iters);
            let lam = if reg_on { cfg.lambda } else { 0.0 };
            // sample exactly the bucket's batch of columns (with repetition
            // if the calibration sample is smaller than the bucket)
            let idx: Vec<usize> = if ncols >= step_batch {
                rng.sample_indices(ncols, step_batch)
            } else {
                (0..step_batch).map(|_| rng.below(ncols)).collect()
            };
            let xb = gather_cols(x, &idx);
            let tb = gather_cols(t, &idx);
            exec.run(
                &mut state, &xb, &tb, &prob.w, &s_col, &b_col, beta, lam, cfg.lr, prob.n,
                prob.p,
            )?;
        }

        let mask = prob.mask_from_v(&state.v);
        let mse_after = prob.recon_mse(&prob.hard_weights(&mask), x, t);
        let near = prob.nearest_mask();
        let flipped = mask
            .data
            .iter()
            .zip(&near.data)
            .filter(|(a, b)| (*a - *b).abs() > 0.5)
            .count();
        Ok(LayerResult {
            flipped_frac: flipped as f64 / mask.numel() as f64,
            mask,
            v: state.v,
            mse_before,
            mse_after,
            iters: cfg.iters,
        })
    }
}
