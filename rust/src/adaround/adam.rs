//! Adam optimizer state (matches the constants in python/compile/model.py
//! so native and PJRT drivers take identical trajectories).

pub const B1: f32 = 0.9;
pub const B2: f32 = 0.999;
pub const EPS: f32 = 1e-8;

pub struct Adam {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: usize,
}

impl Adam {
    pub fn new(n: usize) -> Adam {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// One update step: params -= lr * mhat / (sqrt(vhat) + eps).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t as i32);
        let bc2 = 1.0 - B2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * g;
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + EPS);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_signed_lr() {
        // with zero moments, |update| == lr for any nonzero grad
        let mut adam = Adam::new(2);
        let mut p = vec![1.0, -1.0];
        adam.step(&mut p, &[0.5, -2.0], 0.1);
        assert!((p[0] - 0.9).abs() < 1e-5);
        assert!((p[1] + 0.9).abs() < 1e-5);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize (x-3)^2
        let mut adam = Adam::new(1);
        let mut p = vec![0.0f32];
        for _ in 0..2000 {
            let g = 2.0 * (p[0] - 3.0);
            adam.step(&mut p, &[g], 0.05);
        }
        assert!((p[0] - 3.0).abs() < 1e-2, "{}", p[0]);
    }
}
