//! Classical Hopfield-style baseline (paper Table 3, row 1):
//! h(V) = sigmoid(V / T) with temperature annealing as the *implicit*
//! regularizer, instead of the rectified sigmoid + explicit f_reg.

use anyhow::Result;

use crate::tensor::{matmul, Tensor};
use crate::util::Rng;

use super::native::gather_cols;
use super::problem::LayerProblem;
use super::schedule::AdaRoundConfig;
use super::{Adam, LayerResult};

/// Temperature schedule: exponential decay T_start -> T_end.
#[derive(Clone, Copy, Debug)]
pub struct TempSchedule {
    pub start: f32,
    pub end: f32,
}

impl Default for TempSchedule {
    fn default() -> Self {
        // found by the hyper-parameter search mirroring the paper's
        // "extensive search for the annealing schedule of T"
        TempSchedule { start: 1.0, end: 0.05 }
    }
}

impl TempSchedule {
    pub fn at(&self, it: usize, total: usize) -> f32 {
        let f = it as f32 / total.max(1) as f32;
        self.start * (self.end / self.start).powf(f)
    }
}

#[inline]
fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

/// Plain-sigmoid h with temperature; V initialized at logit(frac).
pub fn optimize_hopfield(
    prob: &LayerProblem,
    x: &Tensor,
    t: &Tensor,
    cfg: &AdaRoundConfig,
    temp: TempSchedule,
    rng: &mut Rng,
) -> Result<LayerResult> {
    let (rows, cols) = (prob.rows(), prob.cols());
    let ncols = x.cols();
    let mse_before = prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), x, t);

    // init h = frac(w/s) via plain logit
    let mut v = Tensor::zeros(&prob.w.shape);
    for r in 0..rows {
        let s = prob.s(r);
        for c in 0..cols {
            let i = r * cols + c;
            let frac = (prob.w.data[i] / s - (prob.w.data[i] / s).floor())
                .clamp(1e-4, 1.0 - 1e-4);
            v.data[i] = (frac / (1.0 - frac)).ln();
        }
    }
    let mut adam = Adam::new(v.numel());

    for it in 0..cfg.iters {
        let temp_now = temp.at(it, cfg.iters);
        let idx = rng.sample_indices(ncols, cfg.batch.min(ncols));
        let xb = gather_cols(x, &idx);
        let tb = gather_cols(t, &idx);
        let batch = xb.cols();

        // soft weights with h = sigmoid(V/T)
        let mut wq = Tensor::zeros(&prob.w.shape);
        let mut gate = Tensor::zeros(&prob.w.shape);
        for r in 0..rows {
            let s = prob.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let h = sigmoid(v.data[i] / temp_now);
                let z = (prob.w.data[i] / s).floor() + h;
                wq.data[i] = s * z.clamp(prob.n, prob.p);
                let inside = z >= prob.n && z <= prob.p;
                gate.data[i] =
                    if inside { s * h * (1.0 - h) / temp_now } else { 0.0 };
            }
        }
        let mut y = matmul(&wq, &xb);
        for r in 0..rows {
            let b = prob.bias.get(r).copied().unwrap_or(0.0);
            for vv in &mut y.data[r * batch..(r + 1) * batch] {
                *vv += b;
            }
        }
        let numel = (rows * batch) as f32;
        let mut dy = Tensor::zeros(&[rows, batch]);
        for i in 0..rows * batch {
            let (yi, ti) = (y.data[i], tb.data[i]);
            let (ya, ta) = if prob.relu { (yi.max(0.0), ti.max(0.0)) } else { (yi, ti) };
            let pass = if prob.relu && yi <= 0.0 { 0.0 } else { 1.0 };
            dy.data[i] = 2.0 * (ya - ta) * pass / numel;
        }
        let dwq = crate::tensor::matmul::matmul_bt(&dy, &xb);
        let grad: Vec<f32> = dwq
            .data
            .iter()
            .zip(&gate.data)
            .map(|(d, g)| d * g)
            .collect();
        adam.step(&mut v.data, &grad, cfg.lr);
    }

    // final temperature defines the hard rounding
    let t_end = temp.at(cfg.iters, cfg.iters);
    let mask = v.map(|x| (sigmoid(x / t_end) >= 0.5) as u8 as f32);
    let mse_after = prob.recon_mse(&prob.hard_weights(&mask), x, t);
    let near = prob.nearest_mask();
    let flipped = mask
        .data
        .iter()
        .zip(&near.data)
        .filter(|(a, b)| (*a - *b).abs() > 0.5)
        .count();
    Ok(LayerResult {
        flipped_frac: flipped as f64 / mask.numel() as f64,
        mask,
        v,
        mse_before,
        mse_after,
        iters: cfg.iters,
    })
}

/// Plain sigmoid h + explicit f_reg (Table 3, middle row): isolates the
/// effect of the *rectified* sigmoid by keeping everything else identical
/// to AdaRound.
pub fn optimize_sigmoid_freg(
    prob: &LayerProblem,
    x: &Tensor,
    t: &Tensor,
    cfg: &AdaRoundConfig,
    rng: &mut Rng,
) -> Result<LayerResult> {
    let (rows, cols) = (prob.rows(), prob.cols());
    let ncols = x.cols();
    let mse_before = prob.recon_mse(&prob.hard_weights(&prob.nearest_mask()), x, t);

    let mut v = Tensor::zeros(&prob.w.shape);
    for r in 0..rows {
        let s = prob.s(r);
        for c in 0..cols {
            let i = r * cols + c;
            let frac = (prob.w.data[i] / s - (prob.w.data[i] / s).floor())
                .clamp(1e-4, 1.0 - 1e-4);
            v.data[i] = (frac / (1.0 - frac)).ln();
        }
    }
    let mut adam = Adam::new(v.numel());

    for it in 0..cfg.iters {
        let (beta, reg_on) = cfg.beta.at(it, cfg.iters);
        let lam = if reg_on { cfg.lambda } else { 0.0 };
        let idx = rng.sample_indices(ncols, cfg.batch.min(ncols));
        let xb = gather_cols(x, &idx);
        let tb = gather_cols(t, &idx);
        let batch = xb.cols();

        let mut wq = Tensor::zeros(&prob.w.shape);
        let mut gate = Tensor::zeros(&prob.w.shape);
        let mut hval = Tensor::zeros(&prob.w.shape);
        for r in 0..rows {
            let s = prob.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let h = sigmoid(v.data[i]);
                hval.data[i] = h;
                let z = (prob.w.data[i] / s).floor() + h;
                wq.data[i] = s * z.clamp(prob.n, prob.p);
                let inside = z >= prob.n && z <= prob.p;
                gate.data[i] = if inside { s * h * (1.0 - h) } else { 0.0 };
            }
        }
        let mut y = matmul(&wq, &xb);
        for r in 0..rows {
            let b = prob.bias.get(r).copied().unwrap_or(0.0);
            for vv in &mut y.data[r * batch..(r + 1) * batch] {
                *vv += b;
            }
        }
        let numel = (rows * batch) as f32;
        let mut dy = Tensor::zeros(&[rows, batch]);
        for i in 0..rows * batch {
            let (yi, ti) = (y.data[i], tb.data[i]);
            let (ya, ta) = if prob.relu { (yi.max(0.0), ti.max(0.0)) } else { (yi, ti) };
            let pass = if prob.relu && yi <= 0.0 { 0.0 } else { 1.0 };
            dy.data[i] = 2.0 * (ya - ta) * pass / numel;
        }
        let dwq = crate::tensor::matmul::matmul_bt(&dy, &xb);
        let grad: Vec<f32> = (0..v.numel())
            .map(|i| {
                let mut g = dwq.data[i] * gate.data[i];
                if lam > 0.0 {
                    // d/dV [1 - |2h-1|^beta] with plain-sigmoid h
                    let h = hval.data[i];
                    let z = 2.0 * h - 1.0;
                    if z != 0.0 {
                        g += lam
                            * (-beta * z.abs().powf(beta - 1.0) * 2.0 * z.signum())
                            * h
                            * (1.0 - h);
                    }
                }
                g
            })
            .collect();
        adam.step(&mut v.data, &grad, cfg.lr);
    }

    let mask = v.map(|x| (sigmoid(x) >= 0.5) as u8 as f32);
    let mse_after = prob.recon_mse(&prob.hard_weights(&mask), x, t);
    let near = prob.nearest_mask();
    let flipped = mask
        .data
        .iter()
        .zip(&near.data)
        .filter(|(a, b)| (*a - *b).abs() > 0.5)
        .count();
    Ok(LayerResult {
        flipped_frac: flipped as f64 / mask.numel() as f64,
        mask,
        v,
        mse_before,
        mse_after,
        iters: cfg.iters,
    })
}

#[cfg(test)]
mod tests {
    use super::super::problem::tests::random_problem;
    use super::*;

    #[test]
    fn temperature_decays() {
        let t = TempSchedule::default();
        assert!(t.at(0, 100) > t.at(50, 100));
        assert!(t.at(50, 100) > t.at(100, 100) * 0.999);
        assert!((t.at(100, 100) - t.end).abs() < 1e-5);
    }

    #[test]
    fn hopfield_not_worse_than_nearest() {
        let prob = random_problem(41, 6, 18, false);
        let mut rng = Rng::new(42);
        let x = Tensor::from_vec(
            &[18, 192],
            (0..18 * 192).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let mut t = matmul(&prob.w, &x);
        for r in 0..6 {
            for v in &mut t.data[r * 192..(r + 1) * 192] {
                *v += prob.bias[r];
            }
        }
        let cfg = AdaRoundConfig { iters: 500, batch: 96, ..Default::default() };
        let res =
            optimize_hopfield(&prob, &x, &t, &cfg, TempSchedule::default(), &mut rng).unwrap();
        assert!(res.mse_after <= res.mse_before * 1.05);
    }
}
