//! Mixed-precision bit allocation: spend a global weight-bit budget
//! across layers by sensitivity.
//!
//! The paper quantizes every layer at the same width; serving wants the
//! opposite trade: most layers tolerate 4 bits, a few (first conv, final
//! classifier, anything with heavy-tailed weights) lose real accuracy.
//! This module turns per-layer sensitivity scores into a w8/w4
//! assignment under a *mean bits per weight* budget.
//!
//! The sensitivity proxy reuses the AdaRound machinery: for candidate
//! width `b`, the pipeline builds the layer's [`super::LayerProblem`]
//! on the b-bit grid and evaluates `recon_mse` of nearest-rounded
//! weights against the FP32 output on calibration columns. That is the
//! diagonal Gauss-Newton form Δwᵀ·(x xᵀ)·Δw from eq. (14) — the same
//! quadratic the rounding optimizer minimizes — so "cost of serving
//! this layer at b bits" and "objective AdaRound optimizes" agree by
//! construction. The allocator itself is pure and deterministic: greedy
//! upgrades from the cheapest width, best Δcost per budget-byte first.

use std::collections::BTreeMap;

/// Per-layer sensitivity curve: proxy loss at each candidate width.
#[derive(Clone, Debug)]
pub struct LayerSensitivity {
    pub id: String,
    /// number of weights — the layer's footprint in the budget
    pub params: usize,
    /// `(bits, proxy_cost)` pairs, ascending in bits. Cost is the
    /// Gauss-Newton reconstruction MSE of nearest rounding at that
    /// width (lower = layer tolerates the width better).
    pub cost: Vec<(u32, f64)>,
}

/// Result of [`allocate_bits`]: the chosen per-layer widths plus the
/// realized budget numbers for reporting.
#[derive(Clone, Debug)]
pub struct BitAllocation {
    pub bits: BTreeMap<String, u32>,
    /// parameter-weighted mean bits actually spent
    pub mean_bits: f64,
    /// sum of the chosen widths' proxy costs
    pub total_cost: f64,
}

/// Greedy budgeted allocation. Every layer starts at its cheapest
/// candidate width; while budget remains, apply the upgrade with the
/// best cost reduction per budget bit (`Δcost / (Δbits · params)`).
/// Ties break on input order, so the result is deterministic. A budget
/// below the all-minimum mean returns the all-minimum assignment; a
/// budget at or above the all-maximum mean saturates every layer.
pub fn allocate_bits(layers: &[LayerSensitivity], budget_mean_bits: f64) -> BitAllocation {
    let total_params: usize = layers.iter().map(|l| l.params).sum();
    // current choice index into each layer's cost curve
    let mut idx: Vec<usize> = vec![0; layers.len()];
    for layer in layers {
        assert!(!layer.cost.is_empty(), "layer {:?} has no candidate widths", layer.id);
        for w in layer.cost.windows(2) {
            assert!(w[0].0 < w[1].0, "layer {:?}: candidate widths must ascend", layer.id);
        }
    }
    let spent = |idx: &[usize]| -> f64 {
        layers
            .iter()
            .zip(idx)
            .map(|(l, &i)| l.cost[i].0 as f64 * l.params as f64)
            .sum()
    };
    let budget_bits = budget_mean_bits * total_params as f64;
    loop {
        // best available upgrade: one step up some layer's curve
        let mut best: Option<(usize, f64)> = None;
        let used = spent(&idx);
        for (l, layer) in layers.iter().enumerate() {
            let i = idx[l];
            if i + 1 >= layer.cost.len() {
                continue;
            }
            let (b0, c0) = layer.cost[i];
            let (b1, c1) = layer.cost[i + 1];
            let extra = (b1 - b0) as f64 * layer.params as f64;
            if used + extra > budget_bits + 1e-9 {
                continue; // doesn't fit in what's left
            }
            let gain = (c0 - c1) / extra.max(1.0);
            match best {
                Some((_, g)) if g >= gain => {}
                _ => best = Some((l, gain)),
            }
        }
        match best {
            Some((l, _)) => idx[l] += 1,
            None => break,
        }
    }
    let mut bits = BTreeMap::new();
    let mut total_cost = 0.0;
    for (layer, &i) in layers.iter().zip(&idx) {
        bits.insert(layer.id.clone(), layer.cost[i].0);
        total_cost += layer.cost[i].1;
    }
    let mean_bits = if total_params == 0 { 0.0 } else { spent(&idx) / total_params as f64 };
    BitAllocation { bits, mean_bits, total_cost }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(id: &str, params: usize, c4: f64, c8: f64) -> LayerSensitivity {
        LayerSensitivity {
            id: id.to_string(),
            params,
            cost: vec![(4, c4), (8, c8)],
        }
    }

    #[test]
    fn sensitive_layer_gets_the_budget() {
        // a hurts badly at 4 bits, b barely at all; budget mean 6 over
        // equal params affords exactly one upgrade
        let layers = vec![layer("a", 100, 50.0, 0.1), layer("b", 100, 0.5, 0.1)];
        let out = allocate_bits(&layers, 6.0);
        assert_eq!(out.bits["a"], 8);
        assert_eq!(out.bits["b"], 4);
        assert!((out.mean_bits - 6.0).abs() < 1e-9);
    }

    #[test]
    fn budget_extremes_saturate() {
        let layers = vec![layer("a", 10, 9.0, 1.0), layer("b", 30, 5.0, 1.0)];
        let low = allocate_bits(&layers, 4.0);
        assert!(low.bits.values().all(|&b| b == 4));
        let high = allocate_bits(&layers, 8.0);
        assert!(high.bits.values().all(|&b| b == 8));
        assert!((high.mean_bits - 8.0).abs() < 1e-9);
        // below-minimum budget degrades gracefully to all-minimum
        let floor = allocate_bits(&layers, 2.0);
        assert!(floor.bits.values().all(|&b| b == 4));
    }

    #[test]
    fn upgrade_prefers_gain_per_budget_bit() {
        // c's upgrade is cheap (few params) and removes real cost; d's
        // is bulky for the same absolute gain. Budget fits only c.
        let layers = vec![layer("c", 10, 2.0, 0.0), layer("d", 1000, 2.0, 0.0)];
        let out = allocate_bits(&layers, 4.1);
        assert_eq!(out.bits["c"], 8);
        assert_eq!(out.bits["d"], 4);
        assert!(out.total_cost < 2.5);
    }

    #[test]
    fn fractional_budget_partial_fill() {
        // four equal layers, mean 5 ⇒ exactly one of four upgrades fits;
        // the largest 4-bit cost wins, deterministically
        let layers = vec![
            layer("l0", 50, 1.0, 0.0),
            layer("l1", 50, 3.0, 0.0),
            layer("l2", 50, 2.0, 0.0),
            layer("l3", 50, 1.0, 0.0),
        ];
        let out = allocate_bits(&layers, 5.0);
        let n8 = out.bits.values().filter(|&&b| b == 8).count();
        assert_eq!(n8, 1);
        assert_eq!(out.bits["l1"], 8);
        assert!((out.mean_bits - 5.0).abs() < 1e-9);
    }
}
