//! AdaRound: the continuous-relaxation rounding optimizer (paper §3.3).
//!
//! Two interchangeable drivers run the same math:
//!
//! * [`NativeOptimizer`] — pure-rust analytic gradient + Adam (no PJRT),
//!   used as a verification oracle and a dependency-free fallback.
//! * [`PjrtOptimizer`] — executes the fused AOT HLO step artifact
//!   (`python/compile/model.py`) through the PJRT runtime; this is the
//!   architecture's request-path driver (L1 Pallas kernels inside).
//!
//! Plus the paper's ablation baselines: [`ste`] (straight-through
//! estimator, Table 5) and [`hopfield`] (sigmoid + temperature annealing,
//! Table 3).
//!
//! The code ↔ paper mapping for the eq. (21)-(25) region (relaxed
//! objective, soft-quantized weights, rectified sigmoid, regularizer,
//! asymmetric reconstruction) is spelled out equation-by-equation in
//! [`relax`]; [`problem`] assembles them into the per-layer loss and its
//! analytic gradient, and [`schedule`] anneals beta.

pub mod adam;
pub mod alloc;
pub mod hopfield;
pub mod native;
pub mod pjrt;
pub mod problem;
pub mod relax;
pub mod schedule;
pub mod ste;

pub use adam::Adam;
pub use alloc::{allocate_bits, BitAllocation, LayerSensitivity};
pub use native::{gather_cols, gather_cols_into, NativeOptimizer};
pub use pjrt::PjrtOptimizer;
pub use problem::{LayerProblem, StepWorkspace};
pub use schedule::{AdaRoundConfig, BetaSchedule};

use crate::tensor::Tensor;

/// Result of optimizing one layer (one group of a grouped conv).
pub struct LayerResult {
    /// converged continuous logits V
    pub v: Tensor,
    /// binary rounding mask h(V) >= 0.5
    pub mask: Tensor,
    /// reconstruction MSE before optimization (nearest rounding)
    pub mse_before: f64,
    /// reconstruction MSE after (AdaRound mask)
    pub mse_after: f64,
    /// fraction of weights whose rounding differs from nearest
    pub flipped_frac: f64,
    pub iters: usize,
}

/// Shared driver interface so the pipeline can swap native/PJRT.
pub trait RoundingOptimizer {
    fn optimize(
        &mut self,
        prob: &LayerProblem,
        x: &Tensor,
        t: &Tensor,
        cfg: &AdaRoundConfig,
        rng: &mut crate::util::Rng,
    ) -> anyhow::Result<LayerResult>;
}
