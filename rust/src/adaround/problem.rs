//! Per-layer reconstruction problem: the objective of eq. (25) with its
//! analytic gradient (the math the Pallas backward kernel implements).
//!
//! The gradient path comes in two flavors: the allocating [`LayerProblem::
//! loss_grad`] (kept for tests/oracles) and the allocation-free
//! [`LayerProblem::loss_grad_into`] driving the native optimizer — all
//! intermediates live in a caller-owned [`StepWorkspace`], the rectified
//! sigmoid and its derivative are evaluated once per element
//! ([`relax::rect_sigmoid_pair`]) and reused by the regularizer, and the
//! two GEMMs plus the exp/powf-heavy elementwise passes run row-parallel
//! ([`crate::util::parallel`]).

use crate::quant::QuantGrid;
use crate::tensor::matmul::{matmul_bt_into, matmul_into};
use crate::tensor::{matmul, Tensor};
use crate::util::parallel;

use super::relax;

/// Scratch buffers for one optimizer step at a fixed (rows, cols, batch)
/// geometry. Allocated once per layer; `loss_grad_into` then performs no
/// per-iteration heap allocation (with `PALLAS_THREADS=1`; worker spawns
/// allocate stacks, verified by `rust/tests/perf_invariants.rs`).
pub struct StepWorkspace {
    rows: usize,
    cols: usize,
    batch: usize,
    /// h(V) per element
    h: Vec<f32>,
    /// dh/dV per element
    dh: Vec<f32>,
    /// soft-quantized weights W~ [rows, cols]
    wq: Vec<f32>,
    /// gate G = s * clip_mask * h'(V) [rows, cols]
    gate: Vec<f32>,
    /// forward output Y = W~X + b [rows, batch]
    y: Vec<f32>,
    /// dL/dY [rows, batch]
    dy: Vec<f32>,
    /// dY X^T [rows, cols]
    dwq: Vec<f32>,
    /// dL/dV [rows, cols] — the step's result, fed to Adam
    pub grad: Vec<f32>,
    /// per-row regularizer partial sums (fixed-block reduction: the
    /// combine order never depends on the thread count)
    reg_part: Vec<f64>,
}

impl StepWorkspace {
    pub fn new(rows: usize, cols: usize, batch: usize) -> StepWorkspace {
        let rc = rows * cols;
        StepWorkspace {
            rows,
            cols,
            batch,
            h: vec![0.0; rc],
            dh: vec![0.0; rc],
            wq: vec![0.0; rc],
            gate: vec![0.0; rc],
            y: vec![0.0; rows * batch],
            dy: vec![0.0; rows * batch],
            dwq: vec![0.0; rc],
            grad: vec![0.0; rc],
            reg_part: vec![0.0; rows],
        }
    }
}

/// One GEMM-shaped rounding problem (a whole conv/dense layer, or one
/// group of a grouped conv).
pub struct LayerProblem {
    /// FP32 weights [rows, cols]
    pub w: Tensor,
    /// per-row scale (len rows, or broadcast len 1)
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
    pub n: f32,
    pub p: f32,
    /// apply ReLU inside the reconstruction objective
    pub relu: bool,
}

impl LayerProblem {
    pub fn new(w: Tensor, grid: &QuantGrid, row0: usize, bias: Vec<f32>, relu: bool) -> Self {
        let rows = w.shape[0];
        let scale = (0..rows).map(|r| grid.scale_for_row(row0 + r)).collect();
        LayerProblem { w, scale, bias, n: grid.n, p: grid.p, relu }
    }

    pub fn rows(&self) -> usize {
        self.w.shape[0]
    }

    pub fn cols(&self) -> usize {
        self.w.shape[1]
    }

    #[inline]
    pub fn s(&self, r: usize) -> f32 {
        if self.scale.len() == 1 { self.scale[0] } else { self.scale[r] }
    }

    /// V initialization (h(V) = frac(W/s), i.e. start at FP32 weights).
    pub fn init_v(&self) -> Tensor {
        let cols = self.cols();
        let mut v = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                v.data[r * cols + c] = relax::init_v(self.w.data[r * cols + c], s);
            }
        }
        v
    }

    /// Soft-quantized weights W~ = s clip(floor(W/s) + h(V), n, p).
    pub fn soft_weights(&self, v: &Tensor) -> Tensor {
        let cols = self.cols();
        let mut out = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let z = (self.w.data[i] / s).floor() + relax::rect_sigmoid(v.data[i]);
                out.data[i] = s * z.clamp(self.n, self.p);
            }
        }
        out
    }

    /// Hard weights from a binary mask.
    pub fn hard_weights(&self, mask: &Tensor) -> Tensor {
        let cols = self.cols();
        let mut out = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let z = (self.w.data[i] / s).floor() + mask.data[i];
                out.data[i] = s * z.clamp(self.n, self.p);
            }
        }
        out
    }

    /// Gate G = s * clip_mask * h'(V) (dW~/dV elementwise) — identical to
    /// the Pallas forward kernel's second output.
    pub fn gate(&self, v: &Tensor) -> Tensor {
        let cols = self.cols();
        let mut g = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let z = (self.w.data[i] / s).floor() + relax::rect_sigmoid(v.data[i]);
                let inside = z >= self.n && z <= self.p;
                g.data[i] = if inside { s * relax::rect_sigmoid_grad(v.data[i]) } else { 0.0 };
            }
        }
        g
    }

    /// Reconstruction MSE of hard weights against targets T on inputs X
    /// (the metric reported per layer): mean((f_a(W^X + b) - f_a(T))^2).
    /// Streams the activation through the accumulator — no copies of T/Y.
    pub fn recon_mse(&self, wq: &Tensor, x: &Tensor, t: &Tensor) -> f64 {
        let mut y = matmul(wq, x);
        self.add_bias(&mut y);
        assert_eq!(y.shape, t.shape, "recon_mse shape mismatch");
        let mut acc = 0.0f64;
        if self.relu {
            for (a, b) in y.data.iter().zip(&t.data) {
                let d = (a.max(0.0) - b.max(0.0)) as f64;
                acc += d * d;
            }
        } else {
            for (a, b) in y.data.iter().zip(&t.data) {
                let d = (a - b) as f64;
                acc += d * d;
            }
        }
        acc / y.numel() as f64
    }

    pub(crate) fn add_bias(&self, y: &mut Tensor) {
        if self.bias.is_empty() {
            return;
        }
        let batch = y.cols();
        for r in 0..y.rows() {
            let b = self.bias[r];
            for v in &mut y.data[r * batch..(r + 1) * batch] {
                *v += b;
            }
        }
    }

    /// Loss + dL/dV at V over a batch (X [cols, B], T [rows, B]).
    ///
    /// Allocating convenience wrapper over [`Self::loss_grad_into`];
    /// returns (loss, mse, grad).
    pub fn loss_grad(
        &self,
        v: &Tensor,
        x: &Tensor,
        t: &Tensor,
        beta: f32,
        lam: f32,
    ) -> (f64, f64, Tensor) {
        let mut ws = StepWorkspace::new(self.rows(), self.cols(), x.cols());
        let (loss, mse) = self.loss_grad_into(v, x, t, beta, lam, &mut ws);
        let grad = Tensor::from_vec(&v.shape, ws.grad);
        (loss, mse, grad)
    }

    /// Loss + dL/dV into `ws.grad`, with every intermediate in `ws`:
    ///
    ///   loss = mean((f_a(W~X + b) - f_a(T))^2) + lam * sum f_reg(V; beta)
    ///
    /// `lam = 0` disables the regularizer (warmup phase). Returns
    /// (loss, mse). The workspace geometry must match (rows, cols, B).
    pub fn loss_grad_into(
        &self,
        v: &Tensor,
        x: &Tensor,
        t: &Tensor,
        beta: f32,
        lam: f32,
        ws: &mut StepWorkspace,
    ) -> (f64, f64) {
        let rows = self.rows();
        let cols = self.cols();
        let batch = x.cols();
        assert_eq!(v.shape, self.w.shape);
        assert_eq!(x.rows(), cols);
        // slice compare, not vec![..]: this runs in the allocation-free loop
        assert_eq!(t.shape.as_slice(), [rows, batch].as_slice());
        assert_eq!(
            (ws.rows, ws.cols, ws.batch),
            (rows, cols, batch),
            "workspace geometry mismatch"
        );

        // exp-heavy: h(V), h'(V) once per element, row-parallel
        let vdata = &v.data;
        let exp_grain = ((1 << 11) / cols.max(1)).max(1);
        parallel::par_chunks2_mut(&mut ws.h, cols, &mut ws.dh, cols, exp_grain, |r, hrow, dhrow| {
            let vrow = &vdata[r * cols..(r + 1) * cols];
            for c in 0..cols {
                let (h, dh) = relax::rect_sigmoid_pair(vrow[c]);
                hrow[c] = h;
                dhrow[c] = dh;
            }
        });

        // soft weights + gate from (h, dh) — cheap arithmetic, fused pass
        let (href, dhref) = (&ws.h, &ws.dh);
        let wdata = &self.w.data;
        let cheap_grain = ((1 << 13) / cols.max(1)).max(1);
        parallel::par_chunks2_mut(
            &mut ws.wq,
            cols,
            &mut ws.gate,
            cols,
            cheap_grain,
            |r, wqrow, gaterow| {
                let s = self.s(r);
                let base = r * cols;
                for c in 0..cols {
                    let i = base + c;
                    let z = (wdata[i] / s).floor() + href[i];
                    let inside = z >= self.n && z <= self.p;
                    wqrow[c] = s * z.clamp(self.n, self.p);
                    gaterow[c] = if inside { s * dhref[i] } else { 0.0 };
                }
            },
        );

        // forward GEMM: Y = W~ X (+ bias)
        ws.y.fill(0.0);
        matmul_into(&ws.wq, &x.data, &mut ws.y, rows, cols, batch);
        if !self.bias.is_empty() {
            for r in 0..rows {
                let b = self.bias[r];
                for yv in &mut ws.y[r * batch..(r + 1) * batch] {
                    *yv += b;
                }
            }
        }

        // dY and mse (serial: cheap, and keeps the mse sum order fixed)
        let numel = (rows * batch) as f64;
        let mut mse = 0.0f64;
        for i in 0..rows * batch {
            let (yi, ti) = (ws.y[i], t.data[i]);
            let (ya, ta) = if self.relu { (yi.max(0.0), ti.max(0.0)) } else { (yi, ti) };
            let d = ya - ta;
            mse += (d as f64) * (d as f64);
            let pass = if self.relu && yi <= 0.0 { 0.0 } else { 1.0 };
            ws.dy[i] = 2.0 * d * pass / numel as f32;
        }
        mse /= numel;

        // backward GEMM: dW~ = dY X^T
        matmul_bt_into(&ws.dy, &x.data, &mut ws.dwq, rows, batch, cols);

        // dV = dW~ .* G + lam * f_reg' — powf-heavy, row-parallel with
        // per-row f64 partials so the reduction order is thread-count
        // independent
        let (gateref, dwqref) = (&ws.gate, &ws.dwq);
        parallel::par_chunks2_mut(
            &mut ws.grad,
            cols,
            &mut ws.reg_part,
            1,
            exp_grain,
            |r, grow, regslot| {
                let base = r * cols;
                let mut reg = 0.0f64;
                for c in 0..cols {
                    let i = base + c;
                    grow[c] = dwqref[i] * gateref[i];
                    if lam > 0.0 {
                        let z = 2.0 * href[i] - 1.0;
                        reg += (1.0 - z.abs().powf(beta)) as f64;
                        if z != 0.0 {
                            grow[c] +=
                                lam * (-beta * z.abs().powf(beta - 1.0) * 2.0 * z.signum() * dhref[i]);
                        }
                    }
                }
                regslot[0] = reg;
            },
        );
        let reg: f64 = ws.reg_part.iter().sum();
        let loss = mse + lam as f64 * reg;
        (loss, mse)
    }

    /// Binary mask from converged V: h(V) >= 0.5 rounds up.
    pub fn mask_from_v(&self, v: &Tensor) -> Tensor {
        v.map(|x| (relax::rect_sigmoid(x) >= 0.5) as u8 as f32)
    }

    /// Round-to-nearest mask for this problem.
    pub fn nearest_mask(&self) -> Tensor {
        let cols = self.cols();
        let mut m = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let frac = self.w.data[i] / s - (self.w.data[i] / s).floor();
                m.data[i] = (frac >= 0.5) as u8 as f32;
            }
        }
        m
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::parallel::with_threads;
    use crate::util::proptest::{close, property};
    use crate::util::Rng;

    pub(crate) fn random_problem(seed: u64, rows: usize, cols: usize, relu: bool) -> LayerProblem {
        let mut rng = Rng::new(seed);
        let w = Tensor::from_vec(
            &[rows, cols],
            (0..rows * cols).map(|_| rng.normal_f32(0.0, 0.3)).collect(),
        );
        let grid = QuantGrid::per_tensor(0.05, 4);
        let bias = (0..rows).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        LayerProblem::new(w, &grid, 0, bias, relu)
    }

    #[test]
    fn init_v_starts_at_fp32() {
        let prob = random_problem(1, 6, 10, false);
        let v = prob.init_v();
        let wq = prob.soft_weights(&v);
        // soft weights at init should be ~= original weights (within grid clip)
        for i in 0..wq.numel() {
            let w = prob.w.data[i];
            if (w / 0.05).abs() < 7.0 {
                assert!((wq.data[i] - w).abs() < 1e-3, "{} vs {}", wq.data[i], w);
            }
        }
    }

    #[test]
    fn grad_matches_finite_difference() {
        property(81, 8, |g| {
            let rows = g.int(2, 5);
            let cols = g.int(2, 8);
            let batch = g.int(3, 10);
            let relu = g.bool();
            let prob = random_problem(g.case as u64 + 10, rows, cols, relu);
            let mut rng = Rng::new(g.case as u64);
            let x = Tensor::from_vec(
                &[cols, batch],
                (0..cols * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
            let t = Tensor::from_vec(
                &[rows, batch],
                (0..rows * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
            let v = prob.init_v();
            let (beta, lam) = (g.f32(2.0, 10.0), 0.02f32);
            let (_, _, grad) = prob.loss_grad(&v, &x, &t, beta, lam);
            // FD check on a few coordinates
            for probe in 0..3 {
                let i = (probe * 7 + g.case) % v.numel();
                let eps = 1e-3;
                let mut vp = v.clone();
                vp.data[i] += eps;
                let mut vm = v.clone();
                vm.data[i] -= eps;
                let (lp, _, _) = prob.loss_grad(&vp, &x, &t, beta, lam);
                let (lm, _, _) = prob.loss_grad(&vm, &x, &t, beta, lam);
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                close(grad.data[i], fd, 0.05)?;
            }
            Ok(())
        });
    }

    #[test]
    fn loss_grad_into_matches_wrapper_and_legacy_pieces() {
        // the fused workspace path must agree with the composition of the
        // standalone soft_weights/gate implementations it replaced
        let prob = random_problem(21, 5, 9, true);
        let mut rng = Rng::new(22);
        let batch = 12;
        let x = Tensor::from_vec(
            &[9, batch],
            (0..9 * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let t = Tensor::from_vec(
            &[5, batch],
            (0..5 * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let v = prob.init_v();
        let mut ws = StepWorkspace::new(5, 9, batch);
        let (loss, mse) = prob.loss_grad_into(&v, &x, &t, 6.0, 0.02, &mut ws);
        assert!(loss.is_finite() && mse >= 0.0);
        // fused soft-weights/gate == standalone implementations, bitwise
        let wq_ref = prob.soft_weights(&v);
        let gate_ref = prob.gate(&v);
        assert_eq!(ws.wq, wq_ref.data);
        assert_eq!(ws.gate, gate_ref.data);
        // wrapper returns the same gradient
        let (loss2, mse2, grad2) = prob.loss_grad(&v, &x, &t, 6.0, 0.02);
        assert_eq!(ws.grad, grad2.data);
        assert_eq!(loss, loss2);
        assert_eq!(mse, mse2);
    }

    #[test]
    fn loss_grad_bit_identical_across_threads() {
        let prob = random_problem(31, 16, 48, true);
        let mut rng = Rng::new(32);
        let batch = 64;
        let x = Tensor::from_vec(
            &[48, batch],
            (0..48 * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let t = Tensor::from_vec(
            &[16, batch],
            (0..16 * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let v = prob.init_v();
        let run = |threads: usize| {
            with_threads(threads, || {
                let mut ws = StepWorkspace::new(16, 48, batch);
                let (loss, mse) = prob.loss_grad_into(&v, &x, &t, 4.0, 0.02, &mut ws);
                (loss, mse, ws.grad)
            })
        };
        let (l1, m1, g1) = run(1);
        let (l4, m4, g4) = run(4);
        assert_eq!(l1.to_bits(), l4.to_bits());
        assert_eq!(m1.to_bits(), m4.to_bits());
        assert_eq!(g1, g4);
    }

    #[test]
    fn recon_mse_matches_explicit_form() {
        // streaming recon_mse == materialized relu + Tensor::mse
        for relu in [false, true] {
            let prob = random_problem(41, 4, 7, relu);
            let mut rng = Rng::new(42);
            let x = Tensor::from_vec(
                &[7, 20],
                (0..7 * 20).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
            let t = Tensor::from_vec(
                &[4, 20],
                (0..4 * 20).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
            let wq = prob.hard_weights(&prob.nearest_mask());
            let got = prob.recon_mse(&wq, &x, &t);
            let mut y = matmul(&wq, &x);
            prob.add_bias(&mut y);
            let expect =
                if relu { y.relu().mse(&t.relu()) } else { y.mse(&t) };
            assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
        }
    }

    #[test]
    fn nearest_mask_reproduces_round() {
        let prob = random_problem(3, 4, 8, false);
        let mask = prob.nearest_mask();
        let wq = prob.hard_weights(&mask);
        for i in 0..wq.numel() {
            let expect = 0.05 * (prob.w.data[i] / 0.05).round().clamp(-8.0, 7.0);
            assert!((wq.data[i] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn gate_zero_when_clipped() {
        let grid = QuantGrid::per_tensor(0.01, 4);
        let w = Tensor::full(&[2, 2], 5.0); // way past the grid
        let prob = LayerProblem::new(w, &grid, 0, vec![0.0; 2], false);
        let v = Tensor::zeros(&[2, 2]);
        let g = prob.gate(&v);
        assert!(g.data.iter().all(|&x| x == 0.0));
    }
}
