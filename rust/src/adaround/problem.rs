//! Per-layer reconstruction problem: the objective of eq. (25) with its
//! analytic gradient (the math the Pallas backward kernel implements).

use crate::quant::QuantGrid;
use crate::tensor::{matmul, Tensor};

use super::relax;

/// One GEMM-shaped rounding problem (a whole conv/dense layer, or one
/// group of a grouped conv).
pub struct LayerProblem {
    /// FP32 weights [rows, cols]
    pub w: Tensor,
    /// per-row scale (len rows, or broadcast len 1)
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
    pub n: f32,
    pub p: f32,
    /// apply ReLU inside the reconstruction objective
    pub relu: bool,
}

impl LayerProblem {
    pub fn new(w: Tensor, grid: &QuantGrid, row0: usize, bias: Vec<f32>, relu: bool) -> Self {
        let rows = w.shape[0];
        let scale = (0..rows).map(|r| grid.scale_for_row(row0 + r)).collect();
        LayerProblem { w, scale, bias, n: grid.n, p: grid.p, relu }
    }

    pub fn rows(&self) -> usize {
        self.w.shape[0]
    }

    pub fn cols(&self) -> usize {
        self.w.shape[1]
    }

    #[inline]
    pub fn s(&self, r: usize) -> f32 {
        if self.scale.len() == 1 { self.scale[0] } else { self.scale[r] }
    }

    /// V initialization (h(V) = frac(W/s), i.e. start at FP32 weights).
    pub fn init_v(&self) -> Tensor {
        let cols = self.cols();
        let mut v = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                v.data[r * cols + c] = relax::init_v(self.w.data[r * cols + c], s);
            }
        }
        v
    }

    /// Soft-quantized weights W~ = s clip(floor(W/s) + h(V), n, p).
    pub fn soft_weights(&self, v: &Tensor) -> Tensor {
        let cols = self.cols();
        let mut out = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let z = (self.w.data[i] / s).floor() + relax::rect_sigmoid(v.data[i]);
                out.data[i] = s * z.clamp(self.n, self.p);
            }
        }
        out
    }

    /// Hard weights from a binary mask.
    pub fn hard_weights(&self, mask: &Tensor) -> Tensor {
        let cols = self.cols();
        let mut out = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let z = (self.w.data[i] / s).floor() + mask.data[i];
                out.data[i] = s * z.clamp(self.n, self.p);
            }
        }
        out
    }

    /// Gate G = s * clip_mask * h'(V) (dW~/dV elementwise) — identical to
    /// the Pallas forward kernel's second output.
    pub fn gate(&self, v: &Tensor) -> Tensor {
        let cols = self.cols();
        let mut g = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let z = (self.w.data[i] / s).floor() + relax::rect_sigmoid(v.data[i]);
                let inside = z >= self.n && z <= self.p;
                g.data[i] = if inside { s * relax::rect_sigmoid_grad(v.data[i]) } else { 0.0 };
            }
        }
        g
    }

    /// Reconstruction MSE of hard weights against targets T on inputs X
    /// (the metric reported per layer): mean((f_a(W^X + b) - f_a(T))^2).
    pub fn recon_mse(&self, wq: &Tensor, x: &Tensor, t: &Tensor) -> f64 {
        let mut y = matmul(wq, x);
        self.add_bias(&mut y);
        let (ya, ta) = if self.relu {
            (y.relu(), t.relu())
        } else {
            (y, t.clone())
        };
        ya.mse(&ta)
    }

    fn add_bias(&self, y: &mut Tensor) {
        if self.bias.is_empty() {
            return;
        }
        let batch = y.cols();
        for r in 0..y.rows() {
            let b = self.bias[r];
            for v in &mut y.data[r * batch..(r + 1) * batch] {
                *v += b;
            }
        }
    }

    /// Loss + dL/dV at V over a batch (X [cols, B], T [rows, B]).
    ///
    ///   loss = mean((f_a(W~X + b) - f_a(T))^2) + lam * sum f_reg(V; beta)
    ///
    /// `lam = 0` disables the regularizer (warmup phase). Returns
    /// (loss, mse, grad).
    pub fn loss_grad(
        &self,
        v: &Tensor,
        x: &Tensor,
        t: &Tensor,
        beta: f32,
        lam: f32,
    ) -> (f64, f64, Tensor) {
        let rows = self.rows();
        let batch = x.cols();
        let wq = self.soft_weights(v);
        let mut y = matmul(&wq, x);
        self.add_bias(&mut y);
        let numel = (rows * batch) as f64;

        // dY and mse
        let mut dy = Tensor::zeros(&[rows, batch]);
        let mut mse = 0.0f64;
        for i in 0..rows * batch {
            let (yi, ti) = (y.data[i], t.data[i]);
            let (ya, ta) = if self.relu { (yi.max(0.0), ti.max(0.0)) } else { (yi, ti) };
            let d = ya - ta;
            mse += (d as f64) * (d as f64);
            let pass = if self.relu && yi <= 0.0 { 0.0 } else { 1.0 };
            dy.data[i] = 2.0 * d * pass / numel as f32;
        }
        mse /= numel;

        // dV = (dY X^T) .* G  + lam * f_reg'
        let dwq = crate::tensor::matmul::matmul_bt(&dy, x); // [rows, cols]
        let gate = self.gate(v);
        let mut grad = Tensor::zeros(&v.shape);
        let mut reg = 0.0f64;
        for i in 0..grad.numel() {
            grad.data[i] = dwq.data[i] * gate.data[i];
            if lam > 0.0 {
                let h = relax::rect_sigmoid(v.data[i]);
                reg += relax::f_reg_elem(h, beta) as f64;
                grad.data[i] += lam * relax::f_reg_grad(v.data[i], beta);
            }
        }
        let loss = mse + lam as f64 * reg;
        (loss, mse, grad)
    }

    /// Binary mask from converged V: h(V) >= 0.5 rounds up.
    pub fn mask_from_v(&self, v: &Tensor) -> Tensor {
        v.map(|x| (relax::rect_sigmoid(x) >= 0.5) as u8 as f32)
    }

    /// Round-to-nearest mask for this problem.
    pub fn nearest_mask(&self) -> Tensor {
        let cols = self.cols();
        let mut m = Tensor::zeros(&self.w.shape);
        for r in 0..self.rows() {
            let s = self.s(r);
            for c in 0..cols {
                let i = r * cols + c;
                let frac = self.w.data[i] / s - (self.w.data[i] / s).floor();
                m.data[i] = (frac >= 0.5) as u8 as f32;
            }
        }
        m
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::proptest::{close, property};
    use crate::util::Rng;

    pub(crate) fn random_problem(seed: u64, rows: usize, cols: usize, relu: bool) -> LayerProblem {
        let mut rng = Rng::new(seed);
        let w = Tensor::from_vec(
            &[rows, cols],
            (0..rows * cols).map(|_| rng.normal_f32(0.0, 0.3)).collect(),
        );
        let grid = QuantGrid::per_tensor(0.05, 4);
        let bias = (0..rows).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        LayerProblem::new(w, &grid, 0, bias, relu)
    }

    #[test]
    fn init_v_starts_at_fp32() {
        let prob = random_problem(1, 6, 10, false);
        let v = prob.init_v();
        let wq = prob.soft_weights(&v);
        // soft weights at init should be ~= original weights (within grid clip)
        for i in 0..wq.numel() {
            let w = prob.w.data[i];
            if (w / 0.05).abs() < 7.0 {
                assert!((wq.data[i] - w).abs() < 1e-3, "{} vs {}", wq.data[i], w);
            }
        }
    }

    #[test]
    fn grad_matches_finite_difference() {
        property(81, 8, |g| {
            let rows = g.int(2, 5);
            let cols = g.int(2, 8);
            let batch = g.int(3, 10);
            let relu = g.bool();
            let prob = random_problem(g.case as u64 + 10, rows, cols, relu);
            let mut rng = Rng::new(g.case as u64);
            let x = Tensor::from_vec(
                &[cols, batch],
                (0..cols * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
            let t = Tensor::from_vec(
                &[rows, batch],
                (0..rows * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
            let v = prob.init_v();
            let (beta, lam) = (g.f32(2.0, 10.0), 0.02f32);
            let (_, _, grad) = prob.loss_grad(&v, &x, &t, beta, lam);
            // FD check on a few coordinates
            for probe in 0..3 {
                let i = (probe * 7 + g.case) % v.numel();
                let eps = 1e-3;
                let mut vp = v.clone();
                vp.data[i] += eps;
                let mut vm = v.clone();
                vm.data[i] -= eps;
                let (lp, _, _) = prob.loss_grad(&vp, &x, &t, beta, lam);
                let (lm, _, _) = prob.loss_grad(&vm, &x, &t, beta, lam);
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                close(grad.data[i], fd, 0.05)?;
            }
            Ok(())
        });
    }

    #[test]
    fn nearest_mask_reproduces_round() {
        let prob = random_problem(3, 4, 8, false);
        let mask = prob.nearest_mask();
        let wq = prob.hard_weights(&mask);
        for i in 0..wq.numel() {
            let expect = 0.05 * (prob.w.data[i] / 0.05).round().clamp(-8.0, 7.0);
            assert!((wq.data[i] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn gate_zero_when_clipped() {
        let grid = QuantGrid::per_tensor(0.01, 4);
        let w = Tensor::full(&[2, 2], 5.0); // way past the grid
        let prob = LayerProblem::new(w, &grid, 0, vec![0.0; 2], false);
        let v = Tensor::zeros(&[2, 2]);
        let g = prob.gate(&v);
        assert!(g.data.iter().all(|&x| x == 0.0));
    }
}
