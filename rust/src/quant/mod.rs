//! Quantization substrate: symmetric fixed-point grids, scale search,
//! rounding schemes, and activation quantizers.
//!
//! Terminology follows the paper (eq. 1): a weight w maps to
//! `s * clip(floor(w/s) + r, n, p)` with r in {0,1} the up/down choice,
//! `n = -2^(b-1)`, `p = 2^(b-1)-1`.

pub mod act;
pub mod grid;
pub mod rounding;

pub use act::ActQuant;
pub use grid::{GridMethod, QuantGrid};
pub use rounding::{nearest_mask, rounding_mask, RoundingMode};

use crate::tensor::Tensor;

/// Fake-quantize a GEMM-shaped weight matrix [rows, cols] with a binary
/// rounding mask (same shape). The grid's scale is per-row (per-channel)
/// or broadcast (per-tensor). This is eq. (1) with the mask as the free
/// up/down variable `r` — the paper's whole question is which mask to
/// feed it.
///
/// ```
/// use adaround::quant::{fake_quant, nearest_mask, QuantGrid};
/// use adaround::tensor::Tensor;
///
/// // 4-bit grid with step 0.1: representable points are 0.1 * z, z in [-8, 7]
/// let grid = QuantGrid::per_tensor(0.1, 4);
/// let w = Tensor::from_vec(&[1, 3], vec![0.12, -0.27, 5.0]);
/// let q = fake_quant(&w, &nearest_mask(&w, &grid), &grid);
/// assert!((q.data[0] - 0.1).abs() < 1e-6); // 0.12 rounds down
/// assert!((q.data[1] + 0.3).abs() < 1e-6); // -0.27 rounds to -0.3
/// assert!((q.data[2] - 0.7).abs() < 1e-6); // 5.0 clips at p = 7
///
/// // forcing every weight up instead changes the first entry to 0.2
/// let up = fake_quant(&w, &Tensor::full(&[1, 3], 1.0), &grid);
/// assert!((up.data[0] - 0.2).abs() < 1e-6);
/// ```
///
/// The row loop is a pure slice zip (div / floor / add / clamp / mul with
/// no indexing or branches), so LLVM auto-vectorizes it — `floor` and
/// `clamp` lower to packed round/min/max instructions. Same element math
/// as before, same results.
pub fn fake_quant(w: &Tensor, mask: &Tensor, grid: &QuantGrid) -> Tensor {
    assert_eq!(w.shape, mask.shape);
    let rows = w.shape[0];
    let cols: usize = w.numel() / rows;
    let mut out = Tensor::zeros(&w.shape);
    let (n, p) = (grid.n, grid.p);
    for r in 0..rows {
        let s = grid.scale_for_row(r);
        let wrow = &w.data[r * cols..(r + 1) * cols];
        let mrow = &mask.data[r * cols..(r + 1) * cols];
        let orow = &mut out.data[r * cols..(r + 1) * cols];
        for ((o, &wv), &mv) in orow.iter_mut().zip(wrow).zip(mrow) {
            let z = (wv / s).floor() + mv;
            *o = s * z.clamp(n, p);
        }
    }
    out
}

/// Round-to-nearest fake-quantization (the paper's baseline, eq. 1).
pub fn fake_quant_nearest(w: &Tensor, grid: &QuantGrid) -> Tensor {
    let mask = nearest_mask(w, grid);
    fake_quant(w, &mask, grid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::Rng;

    #[test]
    fn fake_quant_on_grid() {
        let grid = QuantGrid::per_tensor(0.1, 4);
        let w = Tensor::from_vec(&[1, 4], vec![0.12, -0.27, 0.61, 5.0]);
        let q = fake_quant_nearest(&w, &grid);
        // 0.12 -> 0.1, -0.27 -> -0.3, 0.61 -> 0.6, 5.0 -> clip at 7*0.1
        let expect = [0.1, -0.3, 0.6, 0.7];
        for (a, b) in q.data.iter().zip(expect) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn quantized_values_always_on_grid() {
        property(91, 25, |g| {
            let rows = g.int(1, 8);
            let cols = g.int(1, 24);
            let bits = *g.choice(&[2u32, 3, 4, 8]);
            let w = Tensor::from_vec(&[rows, cols], g.vec_normal(rows * cols, 0.0, 0.6));
            let per_channel = g.bool();
            let grid = QuantGrid::fit(&w, bits, GridMethod::MseW, per_channel, None);
            let mut rng = Rng::new(g.case as u64);
            let mode = *g.choice(&[RoundingMode::Nearest, RoundingMode::Floor,
                                   RoundingMode::Ceil, RoundingMode::Stochastic]);
            let mask = rounding_mask(&w, &grid, mode, &mut rng);
            for v in &mask.data {
                if *v != 0.0 && *v != 1.0 {
                    return Err(format!("mask not binary: {v}"));
                }
            }
            let q = fake_quant(&w, &mask, &grid);
            for r in 0..rows {
                let s = grid.scale_for_row(r);
                for c in 0..cols {
                    let v = q.at2(r, c);
                    let z = v / s;
                    if (z - z.round()).abs() > 1e-3 {
                        return Err(format!("{v} not on grid step {s}"));
                    }
                    if z < grid.n - 0.01 || z > grid.p + 0.01 {
                        return Err(format!("{z} outside [{}, {}]", grid.n, grid.p));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn nearest_error_bounded_by_half_step() {
        property(92, 25, |g| {
            let n = g.int(1, 40);
            let w = Tensor::from_vec(&[1, n], g.vec_normal(n, 0.0, 0.3));
            let grid = QuantGrid::fit(&w, 4, GridMethod::MinMax, false, None);
            let q = fake_quant_nearest(&w, &grid);
            let half = grid.scale[0] * 0.5 + 1e-6;
            for (a, b) in w.data.iter().zip(&q.data) {
                // min-max grid covers the range, so error <= half step
                if (a - b).abs() > half {
                    return Err(format!("|{a} - {b}| > {half}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn all_up_vs_all_down() {
        let grid = QuantGrid::per_tensor(0.1, 4);
        let w = Tensor::from_vec(&[1, 2], vec![0.14, -0.26]);
        let up = fake_quant(&w, &Tensor::full(&[1, 2], 1.0), &grid);
        let down = fake_quant(&w, &Tensor::full(&[1, 2], 0.0), &grid);
        assert!((up.data[0] - 0.2).abs() < 1e-6);
        assert!((down.data[0] - 0.1).abs() < 1e-6);
        assert!((up.data[1] + 0.2).abs() < 1e-6);
        assert!((down.data[1] + 0.3).abs() < 1e-6);
    }
}
