//! Rounding schemes (Table 1 of the paper): nearest / floor / ceil /
//! stochastic, all expressed as binary up/down masks over `floor(W/s)`.
//!
//! Paper mapping (Nagel et al., ICML 2020; see PAPER.md): the mask R
//! produced here is the binary variable of the rounding problem — eq. (1)
//! writes a quantized weight as `s * clip(floor(w/s) + r, n, p)` with
//! `r ∈ {0, 1}`, and the per-row local-MSE QUBO of eq. (20) optimizes
//! exactly this R (solved in [`crate::qubo`]). AdaRound's continuous
//! relaxation (eqs. 21-25, [`crate::adaround::relax`]) replaces R with
//! the rectified sigmoid h(V) during optimization and snaps back to a
//! binary mask of this form at the end.

use crate::tensor::Tensor;
use crate::util::Rng;

use super::QuantGrid;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundingMode {
    Nearest,
    Floor,
    Ceil,
    /// Round up with probability equal to the fractional part
    /// (Gupta et al., 2015).
    Stochastic,
}

impl RoundingMode {
    pub fn parse(s: &str) -> Option<RoundingMode> {
        match s {
            "nearest" => Some(RoundingMode::Nearest),
            "floor" => Some(RoundingMode::Floor),
            "ceil" => Some(RoundingMode::Ceil),
            "stochastic" => Some(RoundingMode::Stochastic),
            _ => None,
        }
    }
}

/// Binary mask R with R[i] = 1 iff weight i rounds up — the `r` of
/// eq. (1); [`crate::quant::fake_quant`] applies it.
///
/// The mode dispatch is hoisted out of the element loop; the nearest path
/// is a branch-free slice zip (div, floor, compare-select) that LLVM
/// auto-vectorizes, floor/ceil are fills, and only the stochastic path
/// stays scalar (it consumes the RNG stream element by element). Element
/// math is unchanged from the scalar version.
pub fn rounding_mask(w: &Tensor, grid: &QuantGrid, mode: RoundingMode, rng: &mut Rng) -> Tensor {
    let rows = w.shape[0];
    let cols = w.numel() / rows;
    let mut mask = Tensor::zeros(&w.shape);
    if mode == RoundingMode::Floor {
        return mask; // all zeros
    }
    if mode == RoundingMode::Ceil {
        mask.data.fill(1.0);
        return mask;
    }
    for r in 0..rows {
        let s = grid.scale_for_row(r);
        let wrow = &w.data[r * cols..(r + 1) * cols];
        let mrow = &mut mask.data[r * cols..(r + 1) * cols];
        match mode {
            RoundingMode::Nearest => {
                for (m, &wv) in mrow.iter_mut().zip(wrow) {
                    let t = wv / s;
                    *m = (t - t.floor() >= 0.5) as u8 as f32;
                }
            }
            RoundingMode::Stochastic => {
                for (m, &wv) in mrow.iter_mut().zip(wrow) {
                    let t = wv / s;
                    *m = rng.bernoulli((t - t.floor()) as f64) as u8 as f32;
                }
            }
            RoundingMode::Floor | RoundingMode::Ceil => unreachable!(),
        }
    }
    mask
}

/// Round-to-nearest mask (deterministic shortcut) — the eq. (1) baseline
/// the paper's Figure 1 shows is far from optimal at low bit-widths.
pub fn nearest_mask(w: &Tensor, grid: &QuantGrid) -> Tensor {
    let mut rng = Rng::new(0); // unused by Nearest
    rounding_mask(w, grid, RoundingMode::Nearest, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant;
    use crate::util::proptest::property;

    #[test]
    fn modes_basic() {
        let grid = QuantGrid::per_tensor(1.0, 4);
        let w = Tensor::from_vec(&[1, 3], vec![0.4, 0.6, -0.4]);
        let mut rng = Rng::new(0);
        let near = rounding_mask(&w, &grid, RoundingMode::Nearest, &mut rng);
        assert_eq!(near.data, vec![0.0, 1.0, 1.0]); // -0.4: floor=-1, frac=.6 -> up
        let fl = rounding_mask(&w, &grid, RoundingMode::Floor, &mut rng);
        assert_eq!(fl.data, vec![0.0; 3]);
        let ce = rounding_mask(&w, &grid, RoundingMode::Ceil, &mut rng);
        assert_eq!(ce.data, vec![1.0; 3]);
    }

    #[test]
    fn stochastic_is_unbiased() {
        let grid = QuantGrid::per_tensor(1.0, 8);
        let w = Tensor::from_vec(&[1, 1], vec![0.3]);
        let mut rng = Rng::new(42);
        let mut ups = 0;
        for _ in 0..5000 {
            let m = rounding_mask(&w, &grid, RoundingMode::Stochastic, &mut rng);
            ups += m.data[0] as usize;
        }
        let p = ups as f64 / 5000.0;
        assert!((p - 0.3).abs() < 0.03, "up-probability {p}");
    }

    #[test]
    fn nearest_minimizes_per_weight_error() {
        property(41, 20, |g| {
            let n = g.int(1, 32);
            let w = Tensor::from_vec(&[1, n], g.vec_normal(n, 0.0, 0.4));
            let grid = QuantGrid::per_tensor(g.f32(0.01, 0.2), 4);
            let near = fake_quant(&w, &nearest_mask(&w, &grid), &grid);
            for mode in [RoundingMode::Floor, RoundingMode::Ceil] {
                let mut rng = Rng::new(g.case as u64);
                let m = rounding_mask(&w, &grid, mode, &mut rng);
                let q = fake_quant(&w, &m, &grid);
                if w.mse(&near) > w.mse(&q) + 1e-9 {
                    return Err(format!("nearest not per-weight optimal vs {mode:?}"));
                }
            }
            Ok(())
        });
    }
}
