//! Activation fake-quantization (the paper's "w/ act quant" rows):
//! asymmetric uint quantizer with min/max range observed on the
//! calibration set (§5.2: "set the scaling factor for the activation
//! quantizers based on the minimum and maximum activations observed").

use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct ActQuant {
    pub min: f32,
    pub max: f32,
    pub bits: u32,
}

impl ActQuant {
    pub fn new(min: f32, max: f32, bits: u32) -> ActQuant {
        ActQuant { min: min.min(0.0), max: max.max(min + 1e-6), bits }
    }

    /// Calibrate from an observed activation tensor.
    pub fn calibrate(t: &Tensor, bits: u32) -> ActQuant {
        let (lo, hi) = t.min_max();
        ActQuant::new(lo, hi, bits)
    }

    /// Merge ranges across calibration chunks.
    pub fn merge(&self, other: &ActQuant) -> ActQuant {
        ActQuant::new(self.min.min(other.min), self.max.max(other.max), self.bits)
    }

    pub fn scale(&self) -> f32 {
        (self.max - self.min) / ((1u32 << self.bits) - 1) as f32
    }

    /// Fake-quantize: x -> min + s * clip(round((x - min)/s), 0, 2^b - 1).
    pub fn apply(&self, t: &Tensor) -> Tensor {
        let s = self.scale();
        let levels = ((1u32 << self.bits) - 1) as f32;
        t.map(|x| {
            let q = ((x - self.min) / s).round().clamp(0.0, levels);
            self.min + s * q
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn identity_on_grid_points() {
        let q = ActQuant::new(0.0, 255.0, 8);
        let t = Tensor::from_vec(&[1, 3], vec![0.0, 100.0, 255.0]);
        let out = q.apply(&t);
        for (a, b) in out.data.iter().zip(&t.data) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn error_bounded_by_half_step() {
        property(51, 20, |g| {
            let n = g.int(1, 64);
            let data = g.vec_normal(n, 0.0, 2.0);
            let t = Tensor::from_vec(&[1, n], data);
            let q = ActQuant::calibrate(&t, 8);
            let out = q.apply(&t);
            let half = q.scale() / 2.0 + 1e-5;
            for (a, b) in out.data.iter().zip(&t.data) {
                if (a - b).abs() > half {
                    return Err(format!("err {} > half-step {half}", (a - b).abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn clips_out_of_range() {
        let q = ActQuant::new(0.0, 1.0, 8);
        let t = Tensor::from_vec(&[1, 2], vec![-5.0, 5.0]);
        let out = q.apply(&t);
        assert!((out.data[0] - 0.0).abs() < 1e-6);
        assert!((out.data[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn merge_covers_both()
    {
        let a = ActQuant::new(-1.0, 2.0, 8);
        let b = ActQuant::new(-3.0, 1.0, 8);
        let m = a.merge(&b);
        assert_eq!((m.min, m.max), (-3.0, 2.0));
    }
}
