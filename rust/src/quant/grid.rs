//! Quantization-grid (scale) determination.
//!
//! The paper fixes the scale *before* optimizing rounding (§3.1) and
//! compares three choices in Table 6: Min-Max, weight-MSE and
//! preactivation-MSE. All three are implemented here, each in per-tensor
//! and per-channel (per output row) flavors.

use crate::tensor::{matmul, Tensor};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GridMethod {
    /// s = max|W| / p (uses the full range; no search)
    MinMax,
    /// s = argmin ||W - Wq(s)||_F^2 over a scale sweep (paper default)
    MseW,
    /// s = argmin ||W X - Wq(s) X||_F^2 over a scale sweep (needs samples)
    MseOut,
}

impl GridMethod {
    pub fn parse(s: &str) -> Option<GridMethod> {
        match s {
            "minmax" => Some(GridMethod::MinMax),
            "mse-w" | "msew" => Some(GridMethod::MseW),
            "mse-out" | "mseout" => Some(GridMethod::MseOut),
            _ => None,
        }
    }
}

/// A symmetric signed fixed-point grid. `scale` has one entry per output
/// row (per-channel) or a single entry (per-tensor).
#[derive(Clone, Debug)]
pub struct QuantGrid {
    pub scale: Vec<f32>,
    pub bits: u32,
    pub n: f32,
    pub p: f32,
}

impl QuantGrid {
    pub fn bounds(bits: u32) -> (f32, f32) {
        let half = 1i64 << (bits - 1);
        (-(half as f32), (half - 1) as f32)
    }

    pub fn per_tensor(scale: f32, bits: u32) -> QuantGrid {
        let (n, p) = Self::bounds(bits);
        QuantGrid { scale: vec![scale], bits, n, p }
    }

    pub fn per_channel(scales: Vec<f32>, bits: u32) -> QuantGrid {
        let (n, p) = Self::bounds(bits);
        QuantGrid { scale: scales, bits, n, p }
    }

    pub fn is_per_channel(&self) -> bool {
        self.scale.len() > 1
    }

    #[inline]
    pub fn scale_for_row(&self, r: usize) -> f32 {
        if self.scale.len() == 1 {
            self.scale[0]
        } else {
            self.scale[r]
        }
    }

    /// Fit a grid for a GEMM weight matrix [rows, cols].
    ///
    /// `x_sample`: im2col activation sample [cols, batch], required for
    /// `MseOut` (ignored otherwise).
    pub fn fit(
        w: &Tensor,
        bits: u32,
        method: GridMethod,
        per_channel: bool,
        x_sample: Option<&Tensor>,
    ) -> QuantGrid {
        if per_channel {
            let rows = w.shape[0];
            let cols = w.numel() / rows;
            let scales = (0..rows)
                .map(|r| {
                    let row = Tensor::from_vec(&[1, cols], w.data[r * cols..(r + 1) * cols].to_vec());
                    fit_scalar(&row, bits, method, x_sample)
                })
                .collect();
            QuantGrid::per_channel(scales, bits)
        } else {
            QuantGrid::per_tensor(fit_scalar(w, bits, method, x_sample), bits)
        }
    }

    /// Fit one scalar scale per contiguous block of `rows_per_group`
    /// output rows of a GEMM weight [rows, cols] — the per-head grids of
    /// attention Q/K/V projections (each head's row-block gets its own
    /// scale, broadcast to its rows so [`Self::scale_for_row`] stays
    /// row-indexed). `rows_per_group == rows` degenerates to the
    /// per-tensor fit; per-channel fitting supersedes this (one scale per
    /// row is strictly finer).
    pub fn fit_grouped(
        w: &Tensor,
        bits: u32,
        method: GridMethod,
        rows_per_group: usize,
        x_sample: Option<&Tensor>,
    ) -> QuantGrid {
        let rows = w.shape[0];
        let cols = w.numel() / rows;
        assert!(
            rows_per_group >= 1 && rows % rows_per_group == 0,
            "rows {rows} not divisible into groups of {rows_per_group}"
        );
        let mut scales = Vec::with_capacity(rows);
        for g in 0..rows / rows_per_group {
            let block = Tensor::from_vec(
                &[rows_per_group, cols],
                w.data[g * rows_per_group * cols..(g + 1) * rows_per_group * cols].to_vec(),
            );
            let s = fit_scalar(&block, bits, method, x_sample);
            scales.resize(scales.len() + rows_per_group, s);
        }
        QuantGrid::per_channel(scales, bits)
    }
}

/// Scale-candidate sweep resolution for the MSE searches.
const SWEEP: usize = 80;

fn fit_scalar(w: &Tensor, bits: u32, method: GridMethod, x_sample: Option<&Tensor>) -> f32 {
    let (_, p) = QuantGrid::bounds(bits);
    let amax = w.abs_max().max(1e-8);
    let s_max = amax / p;
    match method {
        GridMethod::MinMax => s_max,
        GridMethod::MseW => sweep(s_max, |s| {
            let g = QuantGrid::per_tensor(s, bits);
            let wq = super::fake_quant_nearest(w, &g);
            w.mse(&wq)
        }),
        GridMethod::MseOut => {
            let x = x_sample.expect("MseOut grid needs an activation sample");
            // row-major w may be [1, cols] (per-channel fit) or [rows, cols]
            let rows = w.shape[0];
            let cols = w.numel() / rows;
            let w2 = Tensor::from_vec(&[rows, cols], w.data.clone());
            let y_fp = matmul(&w2, x);
            sweep(s_max, |s| {
                let g = QuantGrid::per_tensor(s, bits);
                let wq = super::fake_quant_nearest(&w2, &g);
                y_fp.mse(&matmul(&wq, x))
            })
        }
    }
}

/// Golden-ratio-free simple sweep: scan SWEEP candidates in
/// [0.2 s_max, 1.05 s_max], return the argmin.
fn sweep(s_max: f32, cost: impl Fn(f32) -> f64) -> f32 {
    let mut best = (f64::INFINITY, s_max);
    for i in 0..SWEEP {
        let s = s_max * (0.2 + 0.85 * (i as f32 + 0.5) / SWEEP as f32);
        let c = cost(s);
        if c < best.0 {
            best = (c, s);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant_nearest;
    use crate::util::proptest::property;
    use crate::util::Rng;

    fn random_w(seed: u64, rows: usize, cols: usize) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::from_vec(&[rows, cols], (0..rows * cols).map(|_| r.normal_f32(0.0, 0.3)).collect())
    }

    #[test]
    fn bounds_4bit() {
        assert_eq!(QuantGrid::bounds(4), (-8.0, 7.0));
        assert_eq!(QuantGrid::bounds(8), (-128.0, 127.0));
    }

    #[test]
    fn minmax_covers_range() {
        let w = random_w(1, 4, 16);
        let g = QuantGrid::fit(&w, 4, GridMethod::MinMax, false, None);
        // largest |w| must land inside the grid (no clipping beyond 1 step)
        assert!(g.scale[0] * 7.0 >= w.abs_max() * 0.999);
    }

    #[test]
    fn mse_w_beats_minmax_on_mse() {
        let w = random_w(2, 8, 32);
        let gm = QuantGrid::fit(&w, 4, GridMethod::MinMax, false, None);
        let gs = QuantGrid::fit(&w, 4, GridMethod::MseW, false, None);
        let em = w.mse(&fake_quant_nearest(&w, &gm));
        let es = w.mse(&fake_quant_nearest(&w, &gs));
        assert!(es <= em * 1.0001, "mse-w {es} vs minmax {em}");
    }

    #[test]
    fn mse_out_valid_and_competitive() {
        let w = random_w(3, 6, 18);
        let mut r = Rng::new(9);
        let x = Tensor::from_vec(&[18, 40], (0..18 * 40).map(|_| r.normal_f32(0.0, 1.0)).collect());
        let g = QuantGrid::fit(&w, 4, GridMethod::MseOut, false, Some(&x));
        assert!(g.scale[0] > 0.0);
        let y = matmul(&w, &x);
        let gq = fake_quant_nearest(&w, &g);
        let gmm = QuantGrid::fit(&w, 4, GridMethod::MinMax, false, None);
        let q2 = fake_quant_nearest(&w, &gmm);
        assert!(y.mse(&matmul(&gq, &x)) <= y.mse(&matmul(&q2, &x)) * 1.0001);
    }

    #[test]
    fn grouped_fit_is_per_block() {
        // rows 0-1 small, rows 2-3 large: two groups must get distinct
        // scales, constant within each block
        let mut data = vec![0.1f32; 2 * 8];
        data.extend(vec![2.0f32; 2 * 8]);
        let w = Tensor::from_vec(&[4, 8], data);
        let g = QuantGrid::fit_grouped(&w, 4, GridMethod::MinMax, 2, None);
        assert_eq!(g.scale.len(), 4);
        assert_eq!(g.scale[0], g.scale[1]);
        assert_eq!(g.scale[2], g.scale[3]);
        assert!(g.scale[2] > g.scale[0] * 10.0, "blocks fit independently");
        // one group == per-tensor fit
        let gt = QuantGrid::fit(&w, 4, GridMethod::MinMax, false, None);
        let g1 = QuantGrid::fit_grouped(&w, 4, GridMethod::MinMax, 4, None);
        assert_eq!(g1.scale, vec![gt.scale[0]; 4]);
    }

    #[test]
    fn per_channel_no_worse_per_row() {
        property(31, 10, |g| {
            let rows = g.int(2, 6);
            let cols = g.int(4, 24);
            let w = Tensor::from_vec(&[rows, cols], g.vec_normal(rows * cols, 0.0, 0.5));
            let gt = QuantGrid::fit(&w, 4, GridMethod::MseW, false, None);
            let gc = QuantGrid::fit(&w, 4, GridMethod::MseW, true, None);
            let et = w.mse(&fake_quant_nearest(&w, &gt));
            let ec = w.mse(&fake_quant_nearest(&w, &gc));
            if ec <= et * 1.01 {
                Ok(())
            } else {
                Err(format!("per-channel {ec} worse than per-tensor {et}"))
            }
        });
    }
}
