//! Model graph: IR parsing (from the manifest JSON emitted by
//! `python/compile/models.py`) and the native forward executor.
//!
//! The architecture is defined exactly once, on the python side; rust
//! interprets the same IR, so zoo additions require no rust changes.

pub mod exec;
pub mod graph;

pub use exec::{ForwardOptions, Taps};
pub use graph::{LayerGeom, Model, Node, Op};
