//! Graph IR: mirrors the node schema documented in
//! `python/compile/models.py`.
//!
//! Besides parsing/validation this module provides the *topological
//! liveness analysis* the streaming calibration pipeline is built on
//! ([`Model::last_use`], [`Model::successor_counts`], [`Model::live_at`]):
//! for any frontier cut through the (already topologically ordered) node
//! list, it answers which node outputs must stay resident for execution
//! to resume from that cut.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;
use crate::util::{Json, Rng};

#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Input,
    Conv { k: usize, stride: usize, pad: usize, groups: usize, relu: bool },
    Dense { relu: bool },
    Add { relu: bool },
    Relu,
    AvgPool { k: usize, stride: usize },
    GPool,
    Upsample,
    Concat,
    /// Per-token normalization over the last dim; weights `<id>.w`
    /// (gamma, [D]) and `<id>.b` (beta, [D]).
    LayerNorm,
    /// Softmax over the last dim. `causal` masks key j > query i and
    /// requires square [.., S, S] scores.
    Softmax { causal: bool },
    /// Two-activation-input batched matmul. `transpose_b`: QK^T
    /// ([N,S,D] x [N,S,D] -> [N,H,S,S], scaled 1/sqrt(D/H)); otherwise
    /// probs · V ([N,H,S,S] x [N,S,D] -> [N,S,D]).
    MatMul { heads: usize, transpose_b: bool },
    Gelu,
    /// Token-id lookup: ids [N,1,1,S] against `<id>.w` [V, D] -> [N,S,D].
    Embedding,
}

#[derive(Clone, Debug)]
pub struct Node {
    pub id: String,
    pub op: Op,
    pub inputs: Vec<String>,
    pub cin: usize,
    pub cout: usize,
    /// Attention-head count for Dense projections whose output rows are
    /// per-head slices (Q/K/V). Drives per-head quantization grids and
    /// per-head reconstruction groups; 1 for every other layer.
    pub heads: usize,
}

/// Per-layer GEMM geometry of a quantizable (weight-bearing) node —
/// matches the AOT shape buckets (see `python/compile/aot.py`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerGeom {
    /// out channels per group (GEMM rows)
    pub rows: usize,
    /// im2col patch size: cin/groups * k * k (GEMM cols)
    pub cols: usize,
    pub groups: usize,
    /// whether the layer is followed by a ReLU (for asymmetric reconstruction)
    pub relu: bool,
}

/// Vocabulary size of [`Model::synthetic_transformer`]'s embedding table
/// (and the id range [`crate::data::synthetic_tokens`] draws from).
pub const TRANSFORMER_VOCAB: usize = 32;

#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub task: String,
    pub nodes: Vec<Node>,
    /// BN-folded FP32 weights: "<id>.w" [O, C/g, k, k] or [O, I], "<id>.b" [O]
    pub weights: BTreeMap<String, Tensor>,
}

impl Node {
    fn from_json(j: &Json) -> Result<Node> {
        let id = j.str_of("id")?.to_string();
        let op_name = j.str_of("op")?;
        let inputs = j
            .req("inputs")?
            .as_arr()
            .ok_or_else(|| anyhow!("inputs not array"))?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let mut cin = 0;
        let mut cout = 0;
        let op = match op_name {
            "input" => Op::Input,
            "conv" => {
                cin = j.usize_of("cin")?;
                cout = j.usize_of("cout")?;
                Op::Conv {
                    k: j.usize_of("k")?,
                    stride: j.usize_of("stride")?,
                    pad: j.usize_of("pad")?,
                    groups: j.usize_of("groups")?,
                    relu: j.bool_of("relu")?,
                }
            }
            "dense" => {
                cin = j.usize_of("cin")?;
                cout = j.usize_of("cout")?;
                Op::Dense { relu: j.bool_of("relu")? }
            }
            "add" => Op::Add { relu: j.bool_of("relu")? },
            "relu" => Op::Relu,
            "avgpool" => Op::AvgPool { k: j.usize_of("k")?, stride: j.usize_of("stride")? },
            "gpool" => Op::GPool,
            "upsample" => Op::Upsample,
            "concat" => Op::Concat,
            "layernorm" => Op::LayerNorm,
            "softmax" => Op::Softmax { causal: j.bool_of("causal").unwrap_or(false) },
            "matmul" => Op::MatMul {
                heads: j.usize_of("heads").unwrap_or(1),
                transpose_b: j.bool_of("transpose_b").unwrap_or(false),
            },
            "gelu" => Op::Gelu,
            "embedding" => {
                cin = j.usize_of("cin")?; // vocab size
                cout = j.usize_of("cout")?; // embedding dim
                Op::Embedding
            }
            other => bail!("unknown op '{other}'"),
        };
        let heads = j.usize_of("heads").unwrap_or(1);
        Ok(Node { id, op, inputs, cin, cout, heads })
    }

    pub fn is_quantizable(&self) -> bool {
        matches!(self.op, Op::Conv { .. } | Op::Dense { .. })
    }

    pub fn geom(&self) -> Option<LayerGeom> {
        match self.op {
            Op::Conv { k, groups, relu, .. } => Some(LayerGeom {
                rows: self.cout / groups,
                cols: (self.cin / groups) * k * k,
                groups,
                relu,
            }),
            // Dense with heads > 1 (attention Q/K/V projections) splits
            // its output rows into per-head GEMM groups so each head gets
            // its own quantization grid and reconstruction problem.
            Op::Dense { relu } => Some(LayerGeom {
                rows: self.cout / self.heads,
                cols: self.cin,
                groups: self.heads,
                relu,
            }),
            _ => None,
        }
    }
}

impl Model {
    /// Build from the manifest's per-model entry + loaded weight bundle.
    pub fn from_manifest(
        name: &str,
        entry: &Json,
        weights: BTreeMap<String, Tensor>,
    ) -> Result<Model> {
        let task = entry.str_of("task")?.to_string();
        let ir = entry
            .req("ir")?
            .as_arr()
            .ok_or_else(|| anyhow!("ir not array"))?;
        let nodes: Result<Vec<Node>> = ir.iter().map(Node::from_json).collect();
        let model = Model { name: name.to_string(), task, nodes: nodes?, weights };
        model.validate()?;
        Ok(model)
    }

    fn validate(&self) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        for nd in &self.nodes {
            for inp in &nd.inputs {
                if !seen.contains(inp.as_str()) {
                    bail!("node {} references undefined input {}", nd.id, inp);
                }
            }
            seen.insert(nd.id.as_str());
            let need = |keys: &[&str]| -> Result<()> {
                for suffix in keys {
                    let key = format!("{}{}", nd.id, suffix);
                    if !self.weights.contains_key(&key) {
                        bail!("missing weight {key}");
                    }
                }
                Ok(())
            };
            match &nd.op {
                Op::Conv { .. } | Op::Dense { .. } => need(&[".w", ".b"])?,
                Op::LayerNorm => need(&[".w", ".b"])?,
                Op::Embedding => need(&[".w"])?,
                Op::MatMul { heads, .. } => {
                    if nd.inputs.len() != 2 {
                        bail!(
                            "matmul node {} needs exactly 2 inputs, got {}",
                            nd.id,
                            nd.inputs.len()
                        );
                    }
                    if *heads == 0 {
                        bail!("matmul node {} has heads = 0", nd.id);
                    }
                }
                _ => {}
            }
            if nd.heads == 0 {
                bail!("node {} has heads = 0", nd.id);
            }
            if matches!(nd.op, Op::Dense { .. }) && nd.cout % nd.heads != 0 {
                bail!(
                    "dense node {}: cout {} not divisible by heads {}",
                    nd.id,
                    nd.cout,
                    nd.heads
                );
            }
        }
        Ok(())
    }

    /// Quantizable nodes in graph (topological) order.
    pub fn quant_layers(&self) -> Vec<&Node> {
        self.nodes.iter().filter(|n| n.is_quantizable()).collect()
    }

    pub fn node(&self, id: &str) -> Option<&Node> {
        self.nodes.iter().find(|n| n.id == id)
    }

    pub fn weight(&self, id: &str) -> &Tensor {
        &self.weights[&format!("{id}.w")]
    }

    pub fn bias(&self, id: &str) -> &Tensor {
        &self.weights[&format!("{id}.b")]
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.weights.values().map(|t| t.numel()).sum()
    }

    /// Position of a node in the (topological) node list.
    pub fn node_index(&self, id: &str) -> Option<usize> {
        self.nodes.iter().position(|n| n.id == id)
    }

    /// Number of consumers per node id (how many nodes list it as an
    /// input; duplicate uses by one node count once per mention). Nodes
    /// that never appear as an input — the network output, in a valid
    /// graph — are absent from the map. Count-based companion view of
    /// the liveness analysis for diagnostics/refcount-style callers; the
    /// segment executor itself evicts by [`Self::last_use`] index.
    pub fn successor_counts(&self) -> BTreeMap<String, usize> {
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for nd in &self.nodes {
            for inp in &nd.inputs {
                *counts.entry(inp.clone()).or_insert(0) += 1;
            }
        }
        counts
    }

    /// For each node id, the index of the LAST node that consumes it.
    /// Ids that are never consumed (the network output) are absent. The
    /// segment executor evicts a value the moment its last consumer has
    /// run; everything a later segment could still read survives.
    pub fn last_use(&self) -> BTreeMap<String, usize> {
        let mut last: BTreeMap<String, usize> = BTreeMap::new();
        for (j, nd) in self.nodes.iter().enumerate() {
            for inp in &nd.inputs {
                last.insert(inp.clone(), j); // ascending j: final insert wins
            }
        }
        last
    }

    /// Node ids that must be live at the frontier cut `at` (all nodes
    /// `< at` executed, `>= at` pending): produced before the cut and
    /// consumed at or after it, plus the network output once produced.
    pub fn live_at(&self, at: usize) -> BTreeSet<String> {
        let last = self.last_use();
        let mut live = BTreeSet::new();
        for (i, nd) in self.nodes.iter().enumerate().take(at) {
            let needed_later = last.get(&nd.id).is_some_and(|&j| j >= at);
            let is_output = i + 1 == self.nodes.len();
            if needed_later || is_output {
                live.insert(nd.id.clone());
            }
        }
        live
    }

    /// Synthetic deep conv classifier for tests/benches that must run
    /// without `make artifacts`: `depth` 3x3 convs (3→`ch` stem, then
    /// `ch`→`ch`) feeding gpool + a 10-way dense head, so
    /// `quant_layers().len() == depth + 1`. With `branchy` the early
    /// chain carries a residual Add and a channel Concat — the shapes the
    /// streaming liveness analysis has to keep alive across segments.
    /// Weights are He-init from `rng`; `depth >= 4` required if `branchy`.
    pub fn synthetic_chain(depth: usize, ch: usize, branchy: bool, rng: &mut Rng) -> Model {
        assert!(depth >= 1, "need at least one conv");
        assert!(!branchy || depth >= 4, "branchy layout needs depth >= 4");
        let conv = |id: &str, inputs: Vec<String>, cin: usize, cout: usize, relu: bool| Node {
            id: id.to_string(),
            op: Op::Conv { k: 3, stride: 1, pad: 1, groups: 1, relu },
            inputs,
            cin,
            cout,
            heads: 1,
        };
        let mut nodes = vec![Node {
            id: "in".into(),
            op: Op::Input,
            inputs: vec![],
            cin: 0,
            cout: 0,
            heads: 1,
        }];
        let mut weights = BTreeMap::new();
        let init = |w: &mut BTreeMap<String, Tensor>, id: &str, shape: &[usize], rng: &mut Rng| {
            let fan_in: usize = shape[1..].iter().product();
            let std = (2.0 / fan_in as f32).sqrt();
            let n: usize = shape.iter().product();
            w.insert(
                format!("{id}.w"),
                Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, std)).collect()),
            );
            let biases = (0..shape[0]).map(|_| rng.normal_f32(0.0, 0.01)).collect();
            w.insert(format!("{id}.b"), Tensor::from_vec(&[shape[0]], biases));
        };
        let mut prev = "in".to_string();
        for i in 1..=depth {
            let id = format!("c{i}");
            let cin = if i == 1 { 3 } else { ch };
            if branchy && i == 3 {
                // a1 = relu(c2 + c1): keeps c1 live past c2
                nodes.push(Node {
                    id: "a1".into(),
                    op: Op::Add { relu: true },
                    inputs: vec!["c2".into(), "c1".into()],
                    cin: 0,
                    cout: 0,
                    heads: 1,
                });
                prev = "a1".into();
            }
            if branchy && i == 4 {
                // m1 = concat(c3, a1): a second long-lived value + a
                // channel-doubled consumer
                nodes.push(Node {
                    id: "m1".into(),
                    op: Op::Concat,
                    inputs: vec!["c3".into(), "a1".into()],
                    cin: 0,
                    cout: 0,
                    heads: 1,
                });
                nodes.push(conv(&id, vec!["m1".into()], 2 * ch, ch, true));
                init(&mut weights, &id, &[ch, 2 * ch, 3, 3], rng);
                prev = id;
                continue;
            }
            // c2 stays pre-activation so the branchy Add has signal
            let relu = !(branchy && i == 2);
            nodes.push(conv(&id, vec![prev.clone()], cin, ch, relu));
            init(&mut weights, &id, &[ch, cin, 3, 3], rng);
            prev = id;
        }
        nodes.push(Node {
            id: "g".into(),
            op: Op::GPool,
            inputs: vec![prev],
            cin: 0,
            cout: 0,
            heads: 1,
        });
        nodes.push(Node {
            id: "d1".into(),
            op: Op::Dense { relu: false },
            inputs: vec!["g".into()],
            cin: ch,
            cout: 10,
            heads: 1,
        });
        init(&mut weights, "d1", &[10, ch], rng);
        let model = Model {
            name: format!("synth{depth}{}", if branchy { "b" } else { "" }),
            task: "cls".into(),
            nodes,
            weights,
        };
        model.validate().expect("synthetic chain is a valid graph");
        model
    }

    /// Synthetic pre-LN causal transformer encoder for tests/benches.
    ///
    /// Layout per block `b{i}`: `ln1 -> {q,k,v} -> qk (QK^T) -> sm
    /// (causal softmax) -> av (probs · V) -> wo -> r1 (residual) ->
    /// ln2 -> fc1 -> gelu -> fc2 -> r2 (residual)`, fed by an embedding
    /// lookup over [`TRANSFORMER_VOCAB`] tokens and closed by a final
    /// layernorm + gpool + 10-way dense head, so
    /// `quant_layers().len() == 6 * depth + 1`.
    ///
    /// The Q/K/V projections carry `heads` so their output rows split
    /// into per-head quantization groups; `wo`/`fc1`/`fc2` stay at
    /// heads=1 because their output rows are not per-head slices. The
    /// `ln1` output fans out to three consumers and `r1` to two — the
    /// multi-consumer shapes the streaming liveness eviction must keep
    /// alive across segments.
    pub fn synthetic_transformer(
        depth: usize,
        heads: usize,
        d_model: usize,
        seq: usize,
        rng: &mut Rng,
    ) -> Model {
        assert!(depth >= 1, "need at least one block");
        assert!(seq >= 2, "causal masking needs seq >= 2");
        assert!(heads >= 1 && d_model % heads == 0, "d_model must divide into heads");
        let mut nodes = vec![Node {
            id: "in".into(),
            op: Op::Input,
            inputs: vec![],
            cin: 0,
            cout: 0,
            heads: 1,
        }];
        let mut weights = BTreeMap::new();
        let dense_init =
            |w: &mut BTreeMap<String, Tensor>, id: &str, cout: usize, cin: usize, rng: &mut Rng| {
                let std = (2.0 / cin as f32).sqrt();
                w.insert(
                    format!("{id}.w"),
                    Tensor::from_vec(
                        &[cout, cin],
                        (0..cout * cin).map(|_| rng.normal_f32(0.0, std)).collect(),
                    ),
                );
                let biases = (0..cout).map(|_| rng.normal_f32(0.0, 0.01)).collect();
                w.insert(format!("{id}.b"), Tensor::from_vec(&[cout], biases));
            };
        let ln_init = |w: &mut BTreeMap<String, Tensor>, id: &str, d: usize, rng: &mut Rng| {
            let gamma = (0..d).map(|_| 1.0 + rng.normal_f32(0.0, 0.1)).collect();
            w.insert(format!("{id}.w"), Tensor::from_vec(&[d], gamma));
            let beta = (0..d).map(|_| rng.normal_f32(0.0, 0.05)).collect();
            w.insert(format!("{id}.b"), Tensor::from_vec(&[d], beta));
        };
        let dense = |id: &str, input: &str, cin: usize, cout: usize, heads: usize| Node {
            id: id.to_string(),
            op: Op::Dense { relu: false },
            inputs: vec![input.to_string()],
            cin,
            cout,
            heads,
        };
        let plain = |id: &str, op: Op, inputs: Vec<String>| Node {
            id: id.to_string(),
            op,
            inputs,
            cin: 0,
            cout: 0,
            heads: 1,
        };
        nodes.push(Node {
            id: "emb".into(),
            op: Op::Embedding,
            inputs: vec!["in".into()],
            cin: TRANSFORMER_VOCAB,
            cout: d_model,
            heads: 1,
        });
        let emb_std = 1.0 / (d_model as f32).sqrt();
        weights.insert(
            "emb.w".into(),
            Tensor::from_vec(
                &[TRANSFORMER_VOCAB, d_model],
                (0..TRANSFORMER_VOCAB * d_model)
                    .map(|_| rng.normal_f32(0.0, emb_std))
                    .collect(),
            ),
        );
        let ff = 2 * d_model;
        let mut prev = "emb".to_string();
        for b in 1..=depth {
            let id = |suffix: &str| format!("b{b}.{suffix}");
            nodes.push(plain(&id("ln1"), Op::LayerNorm, vec![prev.clone()]));
            ln_init(&mut weights, &id("ln1"), d_model, rng);
            for proj in ["q", "k", "v"] {
                nodes.push(dense(&id(proj), &id("ln1"), d_model, d_model, heads));
                dense_init(&mut weights, &id(proj), d_model, d_model, rng);
            }
            nodes.push(plain(
                &id("qk"),
                Op::MatMul { heads, transpose_b: true },
                vec![id("q"), id("k")],
            ));
            nodes.push(plain(&id("sm"), Op::Softmax { causal: true }, vec![id("qk")]));
            nodes.push(plain(
                &id("av"),
                Op::MatMul { heads, transpose_b: false },
                vec![id("sm"), id("v")],
            ));
            nodes.push(dense(&id("wo"), &id("av"), d_model, d_model, 1));
            dense_init(&mut weights, &id("wo"), d_model, d_model, rng);
            nodes.push(plain(&id("r1"), Op::Add { relu: false }, vec![id("wo"), prev.clone()]));
            nodes.push(plain(&id("ln2"), Op::LayerNorm, vec![id("r1")]));
            ln_init(&mut weights, &id("ln2"), d_model, rng);
            nodes.push(dense(&id("fc1"), &id("ln2"), d_model, ff, 1));
            dense_init(&mut weights, &id("fc1"), ff, d_model, rng);
            nodes.push(plain(&id("gelu"), Op::Gelu, vec![id("fc1")]));
            nodes.push(dense(&id("fc2"), &id("gelu"), ff, d_model, 1));
            dense_init(&mut weights, &id("fc2"), d_model, ff, rng);
            nodes.push(plain(&id("r2"), Op::Add { relu: false }, vec![id("fc2"), id("r1")]));
            prev = id("r2");
        }
        nodes.push(plain("lnf", Op::LayerNorm, vec![prev]));
        ln_init(&mut weights, "lnf", d_model, rng);
        nodes.push(plain("gp", Op::GPool, vec!["lnf".into()]));
        nodes.push(dense("head", "gp", d_model, 10, 1));
        dense_init(&mut weights, "head", 10, d_model, rng);
        let model = Model {
            name: format!("tfm{depth}h{heads}d{d_model}s{seq}"),
            task: "cls".into(),
            nodes,
            weights,
        };
        model.validate().expect("synthetic transformer is a valid graph");
        model
    }

    /// Weight matrix of a quantizable node reshaped to per-group GEMM form:
    /// `groups` matrices of [rows, cols] (a view-copy).
    pub fn weight_as_gemm(&self, id: &str) -> Vec<Tensor> {
        let node = self.node(id).expect("node");
        let geom = node.geom().expect("quantizable");
        let w = self.weight(id);
        let per = geom.rows * geom.cols;
        (0..geom.groups)
            .map(|g| {
                Tensor::from_vec(
                    &[geom.rows, geom.cols],
                    w.data[g * per..(g + 1) * per].to_vec(),
                )
            })
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny_model_json() -> Json {
        Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":4,
               "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
              {"id":"g1","op":"gpool","inputs":["c1"]},
              {"id":"d1","op":"dense","inputs":["g1"],"cin":4,"cout":2,"relu":false}
            ]}"#,
        )
        .unwrap()
    }

    pub(crate) fn tiny_weights() -> BTreeMap<String, Tensor> {
        let mut w = BTreeMap::new();
        w.insert("c1.w".into(), Tensor::full(&[4, 3, 3, 3], 0.1));
        w.insert("c1.b".into(), Tensor::zeros(&[4]));
        w.insert("d1.w".into(), Tensor::full(&[2, 4], 0.5));
        w.insert("d1.b".into(), Tensor::from_vec(&[2], vec![0.0, 1.0]));
        w
    }

    #[test]
    fn parse_and_validate() {
        let m = Model::from_manifest("tiny", &tiny_model_json(), tiny_weights()).unwrap();
        assert_eq!(m.nodes.len(), 4);
        assert_eq!(m.quant_layers().len(), 2);
        let g = m.node("c1").unwrap().geom().unwrap();
        assert_eq!((g.rows, g.cols, g.groups, g.relu), (4, 27, 1, true));
    }

    #[test]
    fn missing_weight_rejected() {
        let mut w = tiny_weights();
        w.remove("d1.b");
        assert!(Model::from_manifest("tiny", &tiny_model_json(), w).is_err());
    }

    #[test]
    fn undefined_input_rejected() {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"a","op":"relu","inputs":["ghost"]}]}"#,
        )
        .unwrap();
        assert!(Model::from_manifest("x", &j, BTreeMap::new()).is_err());
    }

    #[test]
    fn liveness_on_linear_chain() {
        let m = Model::from_manifest("tiny", &tiny_model_json(), tiny_weights()).unwrap();
        // in(0) -> c1(1) -> g1(2) -> d1(3)
        assert_eq!(m.node_index("in"), Some(0));
        assert_eq!(m.node_index("d1"), Some(3));
        assert_eq!(m.node_index("ghost"), None);
        let last = m.last_use();
        assert_eq!(last.get("in"), Some(&1));
        assert_eq!(last.get("c1"), Some(&2));
        assert_eq!(last.get("g1"), Some(&3));
        assert_eq!(last.get("d1"), None, "output is never consumed");
        let sc = m.successor_counts();
        assert_eq!(sc.get("c1"), Some(&1));
        assert_eq!(sc.get("d1"), None);
        // at cut 2 only c1 is live; the input image is already dead
        let only_c1: BTreeSet<String> = ["c1".to_string()].into();
        let only_d1: BTreeSet<String> = ["d1".to_string()].into();
        assert_eq!(m.live_at(2), only_c1);
        assert_eq!(m.live_at(4), only_d1);
    }

    #[test]
    fn liveness_on_branchy_graph() {
        let mut rng = Rng::new(3);
        let m = Model::synthetic_chain(5, 4, true, &mut rng);
        // in(0) c1(1) c2(2) a1(3) c3(4) m1(5) c4(6) c5(7) g(8) d1(9)
        assert_eq!(m.quant_layers().len(), 6);
        let last = m.last_use();
        // c1 feeds c2 AND the residual add
        assert_eq!(last.get("c1"), Some(&3));
        // a1 feeds c3 AND the concat
        assert_eq!(last.get("a1"), Some(&5));
        assert_eq!(m.successor_counts().get("a1"), Some(&2));
        // at the cut before c3 both the skip value and c2's output are gone,
        // but a1 survives for the concat
        let live = m.live_at(4);
        assert!(live.contains("a1"));
        assert!(!live.contains("c1") && !live.contains("c2"));
    }

    #[test]
    fn synthetic_chain_shapes() {
        let mut rng = Rng::new(7);
        let m = Model::synthetic_chain(6, 4, false, &mut rng);
        assert_eq!(m.quant_layers().len(), 7);
        assert!(m.weights.contains_key("c6.w"));
        assert_eq!(m.weight("c1").shape, vec![4, 3, 3, 3]);
        assert_eq!(m.weight("d1").shape, vec![10, 4]);
        let mb = Model::synthetic_chain(4, 4, true, &mut rng);
        assert_eq!(mb.weight("c4").shape, vec![4, 8, 3, 3], "concat doubles cin");
    }

    #[test]
    fn transformer_builder_shapes_and_fanout() {
        let mut rng = Rng::new(5);
        let m = Model::synthetic_transformer(2, 2, 8, 6, &mut rng);
        // 6 quantizable denses per block + the classification head
        assert_eq!(m.quant_layers().len(), 13);
        assert_eq!(m.weight("emb").shape, vec![TRANSFORMER_VOCAB, 8]);
        assert_eq!(m.weight("b1.q").shape, vec![8, 8]);
        assert_eq!(m.weight("b1.fc1").shape, vec![16, 8]);
        assert_eq!(m.weight("head").shape, vec![10, 8]);
        // ln1 fans out to q, k and v; r1 to ln2 and the block residual
        let sc = m.successor_counts();
        assert_eq!(sc.get("b1.ln1"), Some(&3));
        assert_eq!(sc.get("b1.r1"), Some(&2));
        // Q projection splits into per-head GEMM groups
        let g = m.node("b1.q").unwrap().geom().unwrap();
        assert_eq!((g.rows, g.cols, g.groups), (4, 8, 2));
        let gs = m.weight_as_gemm("b1.q");
        assert_eq!(gs.len(), 2);
        assert_eq!(gs[0].shape, vec![4, 8]);
        // wo stays a single group
        let gw = m.node("b1.wo").unwrap().geom().unwrap();
        assert_eq!((gw.rows, gw.groups), (8, 1));
        // the embedding is weight-bearing but NOT quantizable
        assert!(!m.node("emb").unwrap().is_quantizable());
    }

    #[test]
    fn transformer_liveness_spans_attention_block() {
        let mut rng = Rng::new(5);
        let m = Model::synthetic_transformer(1, 2, 8, 4, &mut rng);
        let last = m.last_use();
        // ln1 must survive until v (its last consumer of q/k/v)
        let v_at = m.node_index("b1.v").unwrap();
        assert_eq!(last.get("b1.ln1"), Some(&v_at));
        // the block input (emb) stays live across the whole attention
        // path for the r1 residual
        let r1_at = m.node_index("b1.r1").unwrap();
        assert_eq!(last.get("emb"), Some(&r1_at));
        // at a cut right before av, sm and v are live (av's inputs) and
        // emb is live (r1 residual), but q/k/qk are dead
        let av_at = m.node_index("b1.av").unwrap();
        let live = m.live_at(av_at);
        assert!(live.contains("b1.sm") && live.contains("b1.v") && live.contains("emb"));
        assert!(!live.contains("b1.q") && !live.contains("b1.k") && !live.contains("b1.qk"));
    }

    #[test]
    fn transformer_ops_parse_from_json() {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"e","op":"embedding","inputs":["in"],"cin":4,"cout":2},
              {"id":"n","op":"layernorm","inputs":["e"]},
              {"id":"q","op":"dense","inputs":["n"],"cin":2,"cout":2,"relu":false,"heads":2},
              {"id":"s","op":"matmul","inputs":["q","q"],"heads":2,"transpose_b":true},
              {"id":"p","op":"softmax","inputs":["s"],"causal":true},
              {"id":"g","op":"gelu","inputs":["p"]}
            ]}"#,
        )
        .unwrap();
        let mut w = BTreeMap::new();
        w.insert("e.w".into(), Tensor::zeros(&[4, 2]));
        w.insert("n.w".into(), Tensor::full(&[2], 1.0));
        w.insert("n.b".into(), Tensor::zeros(&[2]));
        w.insert("q.w".into(), Tensor::zeros(&[2, 2]));
        w.insert("q.b".into(), Tensor::zeros(&[2]));
        let m = Model::from_manifest("t", &j, w).unwrap();
        assert_eq!(m.node("q").unwrap().heads, 2);
        assert_eq!(m.node("s").unwrap().op, Op::MatMul { heads: 2, transpose_b: true });
        assert_eq!(m.node("p").unwrap().op, Op::Softmax { causal: true });
        assert_eq!(m.node("g").unwrap().op, Op::Gelu);
    }

    #[test]
    fn validate_rejects_bad_transformer_graphs() {
        // matmul with one input
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"s","op":"matmul","inputs":["in"],"heads":1}]}"#,
        )
        .unwrap();
        assert!(Model::from_manifest("t", &j, BTreeMap::new()).is_err());
        // dense whose cout doesn't divide into heads
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"d","op":"dense","inputs":["in"],"cin":4,"cout":6,"relu":false,"heads":4}]}"#,
        )
        .unwrap();
        let mut w = BTreeMap::new();
        w.insert("d.w".into(), Tensor::zeros(&[6, 4]));
        w.insert("d.b".into(), Tensor::zeros(&[6]));
        assert!(Model::from_manifest("t", &j, w).is_err());
        // layernorm without its gamma/beta weights
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"n","op":"layernorm","inputs":["in"]}]}"#,
        )
        .unwrap();
        assert!(Model::from_manifest("t", &j, BTreeMap::new()).is_err());
    }

    #[test]
    fn gemm_view_groups() {
        let mut w = tiny_weights();
        w.insert("c1.w".into(), Tensor::from_vec(&[4, 3, 3, 3],
            (0..108).map(|x| x as f32).collect()));
        let m = Model::from_manifest("tiny", &tiny_model_json(), w).unwrap();
        let gs = m.weight_as_gemm("c1");
        assert_eq!(gs.len(), 1);
        assert_eq!(gs[0].shape, vec![4, 27]);
        assert_eq!(gs[0].data[0], 0.0);
        assert_eq!(gs[0].data[27], 27.0);
    }
}
