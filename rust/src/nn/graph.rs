//! Graph IR: mirrors the node schema documented in
//! `python/compile/models.py`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;
use crate::util::Json;

#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Input,
    Conv { k: usize, stride: usize, pad: usize, groups: usize, relu: bool },
    Dense { relu: bool },
    Add { relu: bool },
    Relu,
    AvgPool { k: usize, stride: usize },
    GPool,
    Upsample,
    Concat,
}

#[derive(Clone, Debug)]
pub struct Node {
    pub id: String,
    pub op: Op,
    pub inputs: Vec<String>,
    pub cin: usize,
    pub cout: usize,
}

/// Per-layer GEMM geometry of a quantizable (weight-bearing) node —
/// matches the AOT shape buckets (see `python/compile/aot.py`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerGeom {
    /// out channels per group (GEMM rows)
    pub rows: usize,
    /// im2col patch size: cin/groups * k * k (GEMM cols)
    pub cols: usize,
    pub groups: usize,
    /// whether the layer is followed by a ReLU (for asymmetric reconstruction)
    pub relu: bool,
}

#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub task: String,
    pub nodes: Vec<Node>,
    /// BN-folded FP32 weights: "<id>.w" [O, C/g, k, k] or [O, I], "<id>.b" [O]
    pub weights: BTreeMap<String, Tensor>,
}

impl Node {
    fn from_json(j: &Json) -> Result<Node> {
        let id = j.str_of("id")?.to_string();
        let op_name = j.str_of("op")?;
        let inputs = j
            .req("inputs")?
            .as_arr()
            .ok_or_else(|| anyhow!("inputs not array"))?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let mut cin = 0;
        let mut cout = 0;
        let op = match op_name {
            "input" => Op::Input,
            "conv" => {
                cin = j.usize_of("cin")?;
                cout = j.usize_of("cout")?;
                Op::Conv {
                    k: j.usize_of("k")?,
                    stride: j.usize_of("stride")?,
                    pad: j.usize_of("pad")?,
                    groups: j.usize_of("groups")?,
                    relu: j.bool_of("relu")?,
                }
            }
            "dense" => {
                cin = j.usize_of("cin")?;
                cout = j.usize_of("cout")?;
                Op::Dense { relu: j.bool_of("relu")? }
            }
            "add" => Op::Add { relu: j.bool_of("relu")? },
            "relu" => Op::Relu,
            "avgpool" => Op::AvgPool { k: j.usize_of("k")?, stride: j.usize_of("stride")? },
            "gpool" => Op::GPool,
            "upsample" => Op::Upsample,
            "concat" => Op::Concat,
            other => bail!("unknown op '{other}'"),
        };
        Ok(Node { id, op, inputs, cin, cout })
    }

    pub fn is_quantizable(&self) -> bool {
        matches!(self.op, Op::Conv { .. } | Op::Dense { .. })
    }

    pub fn geom(&self) -> Option<LayerGeom> {
        match self.op {
            Op::Conv { k, groups, relu, .. } => Some(LayerGeom {
                rows: self.cout / groups,
                cols: (self.cin / groups) * k * k,
                groups,
                relu,
            }),
            Op::Dense { relu } => {
                Some(LayerGeom { rows: self.cout, cols: self.cin, groups: 1, relu })
            }
            _ => None,
        }
    }
}

impl Model {
    /// Build from the manifest's per-model entry + loaded weight bundle.
    pub fn from_manifest(
        name: &str,
        entry: &Json,
        weights: BTreeMap<String, Tensor>,
    ) -> Result<Model> {
        let task = entry.str_of("task")?.to_string();
        let ir = entry
            .req("ir")?
            .as_arr()
            .ok_or_else(|| anyhow!("ir not array"))?;
        let nodes: Result<Vec<Node>> = ir.iter().map(Node::from_json).collect();
        let model = Model { name: name.to_string(), task, nodes: nodes?, weights };
        model.validate()?;
        Ok(model)
    }

    fn validate(&self) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        for nd in &self.nodes {
            for inp in &nd.inputs {
                if !seen.contains(inp.as_str()) {
                    bail!("node {} references undefined input {}", nd.id, inp);
                }
            }
            seen.insert(nd.id.as_str());
            if nd.is_quantizable() {
                for suffix in [".w", ".b"] {
                    let key = format!("{}{}", nd.id, suffix);
                    if !self.weights.contains_key(&key) {
                        bail!("missing weight {key}");
                    }
                }
            }
        }
        Ok(())
    }

    /// Quantizable nodes in graph (topological) order.
    pub fn quant_layers(&self) -> Vec<&Node> {
        self.nodes.iter().filter(|n| n.is_quantizable()).collect()
    }

    pub fn node(&self, id: &str) -> Option<&Node> {
        self.nodes.iter().find(|n| n.id == id)
    }

    pub fn weight(&self, id: &str) -> &Tensor {
        &self.weights[&format!("{id}.w")]
    }

    pub fn bias(&self, id: &str) -> &Tensor {
        &self.weights[&format!("{id}.b")]
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.weights.values().map(|t| t.numel()).sum()
    }

    /// Weight matrix of a quantizable node reshaped to per-group GEMM form:
    /// `groups` matrices of [rows, cols] (a view-copy).
    pub fn weight_as_gemm(&self, id: &str) -> Vec<Tensor> {
        let node = self.node(id).expect("node");
        let geom = node.geom().expect("quantizable");
        let w = self.weight(id);
        let per = geom.rows * geom.cols;
        (0..geom.groups)
            .map(|g| {
                Tensor::from_vec(
                    &[geom.rows, geom.cols],
                    w.data[g * per..(g + 1) * per].to_vec(),
                )
            })
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny_model_json() -> Json {
        Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":4,
               "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
              {"id":"g1","op":"gpool","inputs":["c1"]},
              {"id":"d1","op":"dense","inputs":["g1"],"cin":4,"cout":2,"relu":false}
            ]}"#,
        )
        .unwrap()
    }

    pub(crate) fn tiny_weights() -> BTreeMap<String, Tensor> {
        let mut w = BTreeMap::new();
        w.insert("c1.w".into(), Tensor::full(&[4, 3, 3, 3], 0.1));
        w.insert("c1.b".into(), Tensor::zeros(&[4]));
        w.insert("d1.w".into(), Tensor::full(&[2, 4], 0.5));
        w.insert("d1.b".into(), Tensor::from_vec(&[2], vec![0.0, 1.0]));
        w
    }

    #[test]
    fn parse_and_validate() {
        let m = Model::from_manifest("tiny", &tiny_model_json(), tiny_weights()).unwrap();
        assert_eq!(m.nodes.len(), 4);
        assert_eq!(m.quant_layers().len(), 2);
        let g = m.node("c1").unwrap().geom().unwrap();
        assert_eq!((g.rows, g.cols, g.groups, g.relu), (4, 27, 1, true));
    }

    #[test]
    fn missing_weight_rejected() {
        let mut w = tiny_weights();
        w.remove("d1.b");
        assert!(Model::from_manifest("tiny", &tiny_model_json(), w).is_err());
    }

    #[test]
    fn undefined_input_rejected() {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"a","op":"relu","inputs":["ghost"]}]}"#,
        )
        .unwrap();
        assert!(Model::from_manifest("x", &j, BTreeMap::new()).is_err());
    }

    #[test]
    fn gemm_view_groups() {
        let mut w = tiny_weights();
        w.insert("c1.w".into(), Tensor::from_vec(&[4, 3, 3, 3],
            (0..108).map(|x| x as f32).collect()));
        let m = Model::from_manifest("tiny", &tiny_model_json(), w).unwrap();
        let gs = m.weight_as_gemm("c1");
        assert_eq!(gs.len(), 1);
        assert_eq!(gs[0].shape, vec![4, 27]);
        assert_eq!(gs[0].data[0], 0.0);
        assert_eq!(gs[0].data[27], 27.0);
    }
}
