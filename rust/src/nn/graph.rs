//! Graph IR: mirrors the node schema documented in
//! `python/compile/models.py`.
//!
//! Besides parsing/validation this module provides the *topological
//! liveness analysis* the streaming calibration pipeline is built on
//! ([`Model::last_use`], [`Model::successor_counts`], [`Model::live_at`]):
//! for any frontier cut through the (already topologically ordered) node
//! list, it answers which node outputs must stay resident for execution
//! to resume from that cut.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;
use crate::util::{Json, Rng};

#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Input,
    Conv { k: usize, stride: usize, pad: usize, groups: usize, relu: bool },
    Dense { relu: bool },
    Add { relu: bool },
    Relu,
    AvgPool { k: usize, stride: usize },
    GPool,
    Upsample,
    Concat,
}

#[derive(Clone, Debug)]
pub struct Node {
    pub id: String,
    pub op: Op,
    pub inputs: Vec<String>,
    pub cin: usize,
    pub cout: usize,
}

/// Per-layer GEMM geometry of a quantizable (weight-bearing) node —
/// matches the AOT shape buckets (see `python/compile/aot.py`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerGeom {
    /// out channels per group (GEMM rows)
    pub rows: usize,
    /// im2col patch size: cin/groups * k * k (GEMM cols)
    pub cols: usize,
    pub groups: usize,
    /// whether the layer is followed by a ReLU (for asymmetric reconstruction)
    pub relu: bool,
}

#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub task: String,
    pub nodes: Vec<Node>,
    /// BN-folded FP32 weights: "<id>.w" [O, C/g, k, k] or [O, I], "<id>.b" [O]
    pub weights: BTreeMap<String, Tensor>,
}

impl Node {
    fn from_json(j: &Json) -> Result<Node> {
        let id = j.str_of("id")?.to_string();
        let op_name = j.str_of("op")?;
        let inputs = j
            .req("inputs")?
            .as_arr()
            .ok_or_else(|| anyhow!("inputs not array"))?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let mut cin = 0;
        let mut cout = 0;
        let op = match op_name {
            "input" => Op::Input,
            "conv" => {
                cin = j.usize_of("cin")?;
                cout = j.usize_of("cout")?;
                Op::Conv {
                    k: j.usize_of("k")?,
                    stride: j.usize_of("stride")?,
                    pad: j.usize_of("pad")?,
                    groups: j.usize_of("groups")?,
                    relu: j.bool_of("relu")?,
                }
            }
            "dense" => {
                cin = j.usize_of("cin")?;
                cout = j.usize_of("cout")?;
                Op::Dense { relu: j.bool_of("relu")? }
            }
            "add" => Op::Add { relu: j.bool_of("relu")? },
            "relu" => Op::Relu,
            "avgpool" => Op::AvgPool { k: j.usize_of("k")?, stride: j.usize_of("stride")? },
            "gpool" => Op::GPool,
            "upsample" => Op::Upsample,
            "concat" => Op::Concat,
            other => bail!("unknown op '{other}'"),
        };
        Ok(Node { id, op, inputs, cin, cout })
    }

    pub fn is_quantizable(&self) -> bool {
        matches!(self.op, Op::Conv { .. } | Op::Dense { .. })
    }

    pub fn geom(&self) -> Option<LayerGeom> {
        match self.op {
            Op::Conv { k, groups, relu, .. } => Some(LayerGeom {
                rows: self.cout / groups,
                cols: (self.cin / groups) * k * k,
                groups,
                relu,
            }),
            Op::Dense { relu } => {
                Some(LayerGeom { rows: self.cout, cols: self.cin, groups: 1, relu })
            }
            _ => None,
        }
    }
}

impl Model {
    /// Build from the manifest's per-model entry + loaded weight bundle.
    pub fn from_manifest(
        name: &str,
        entry: &Json,
        weights: BTreeMap<String, Tensor>,
    ) -> Result<Model> {
        let task = entry.str_of("task")?.to_string();
        let ir = entry
            .req("ir")?
            .as_arr()
            .ok_or_else(|| anyhow!("ir not array"))?;
        let nodes: Result<Vec<Node>> = ir.iter().map(Node::from_json).collect();
        let model = Model { name: name.to_string(), task, nodes: nodes?, weights };
        model.validate()?;
        Ok(model)
    }

    fn validate(&self) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        for nd in &self.nodes {
            for inp in &nd.inputs {
                if !seen.contains(inp.as_str()) {
                    bail!("node {} references undefined input {}", nd.id, inp);
                }
            }
            seen.insert(nd.id.as_str());
            if nd.is_quantizable() {
                for suffix in [".w", ".b"] {
                    let key = format!("{}{}", nd.id, suffix);
                    if !self.weights.contains_key(&key) {
                        bail!("missing weight {key}");
                    }
                }
            }
        }
        Ok(())
    }

    /// Quantizable nodes in graph (topological) order.
    pub fn quant_layers(&self) -> Vec<&Node> {
        self.nodes.iter().filter(|n| n.is_quantizable()).collect()
    }

    pub fn node(&self, id: &str) -> Option<&Node> {
        self.nodes.iter().find(|n| n.id == id)
    }

    pub fn weight(&self, id: &str) -> &Tensor {
        &self.weights[&format!("{id}.w")]
    }

    pub fn bias(&self, id: &str) -> &Tensor {
        &self.weights[&format!("{id}.b")]
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.weights.values().map(|t| t.numel()).sum()
    }

    /// Position of a node in the (topological) node list.
    pub fn node_index(&self, id: &str) -> Option<usize> {
        self.nodes.iter().position(|n| n.id == id)
    }

    /// Number of consumers per node id (how many nodes list it as an
    /// input; duplicate uses by one node count once per mention). Nodes
    /// that never appear as an input — the network output, in a valid
    /// graph — are absent from the map. Count-based companion view of
    /// the liveness analysis for diagnostics/refcount-style callers; the
    /// segment executor itself evicts by [`Self::last_use`] index.
    pub fn successor_counts(&self) -> BTreeMap<String, usize> {
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for nd in &self.nodes {
            for inp in &nd.inputs {
                *counts.entry(inp.clone()).or_insert(0) += 1;
            }
        }
        counts
    }

    /// For each node id, the index of the LAST node that consumes it.
    /// Ids that are never consumed (the network output) are absent. The
    /// segment executor evicts a value the moment its last consumer has
    /// run; everything a later segment could still read survives.
    pub fn last_use(&self) -> BTreeMap<String, usize> {
        let mut last: BTreeMap<String, usize> = BTreeMap::new();
        for (j, nd) in self.nodes.iter().enumerate() {
            for inp in &nd.inputs {
                last.insert(inp.clone(), j); // ascending j: final insert wins
            }
        }
        last
    }

    /// Node ids that must be live at the frontier cut `at` (all nodes
    /// `< at` executed, `>= at` pending): produced before the cut and
    /// consumed at or after it, plus the network output once produced.
    pub fn live_at(&self, at: usize) -> BTreeSet<String> {
        let last = self.last_use();
        let mut live = BTreeSet::new();
        for (i, nd) in self.nodes.iter().enumerate().take(at) {
            let needed_later = last.get(&nd.id).is_some_and(|&j| j >= at);
            let is_output = i + 1 == self.nodes.len();
            if needed_later || is_output {
                live.insert(nd.id.clone());
            }
        }
        live
    }

    /// Synthetic deep conv classifier for tests/benches that must run
    /// without `make artifacts`: `depth` 3x3 convs (3→`ch` stem, then
    /// `ch`→`ch`) feeding gpool + a 10-way dense head, so
    /// `quant_layers().len() == depth + 1`. With `branchy` the early
    /// chain carries a residual Add and a channel Concat — the shapes the
    /// streaming liveness analysis has to keep alive across segments.
    /// Weights are He-init from `rng`; `depth >= 4` required if `branchy`.
    pub fn synthetic_chain(depth: usize, ch: usize, branchy: bool, rng: &mut Rng) -> Model {
        assert!(depth >= 1, "need at least one conv");
        assert!(!branchy || depth >= 4, "branchy layout needs depth >= 4");
        let conv = |id: &str, inputs: Vec<String>, cin: usize, cout: usize, relu: bool| Node {
            id: id.to_string(),
            op: Op::Conv { k: 3, stride: 1, pad: 1, groups: 1, relu },
            inputs,
            cin,
            cout,
        };
        let mut nodes = vec![Node {
            id: "in".into(),
            op: Op::Input,
            inputs: vec![],
            cin: 0,
            cout: 0,
        }];
        let mut weights = BTreeMap::new();
        let init = |w: &mut BTreeMap<String, Tensor>, id: &str, shape: &[usize], rng: &mut Rng| {
            let fan_in: usize = shape[1..].iter().product();
            let std = (2.0 / fan_in as f32).sqrt();
            let n: usize = shape.iter().product();
            w.insert(
                format!("{id}.w"),
                Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, std)).collect()),
            );
            let biases = (0..shape[0]).map(|_| rng.normal_f32(0.0, 0.01)).collect();
            w.insert(format!("{id}.b"), Tensor::from_vec(&[shape[0]], biases));
        };
        let mut prev = "in".to_string();
        for i in 1..=depth {
            let id = format!("c{i}");
            let cin = if i == 1 { 3 } else { ch };
            if branchy && i == 3 {
                // a1 = relu(c2 + c1): keeps c1 live past c2
                nodes.push(Node {
                    id: "a1".into(),
                    op: Op::Add { relu: true },
                    inputs: vec!["c2".into(), "c1".into()],
                    cin: 0,
                    cout: 0,
                });
                prev = "a1".into();
            }
            if branchy && i == 4 {
                // m1 = concat(c3, a1): a second long-lived value + a
                // channel-doubled consumer
                nodes.push(Node {
                    id: "m1".into(),
                    op: Op::Concat,
                    inputs: vec!["c3".into(), "a1".into()],
                    cin: 0,
                    cout: 0,
                });
                nodes.push(conv(&id, vec!["m1".into()], 2 * ch, ch, true));
                init(&mut weights, &id, &[ch, 2 * ch, 3, 3], rng);
                prev = id;
                continue;
            }
            // c2 stays pre-activation so the branchy Add has signal
            let relu = !(branchy && i == 2);
            nodes.push(conv(&id, vec![prev.clone()], cin, ch, relu));
            init(&mut weights, &id, &[ch, cin, 3, 3], rng);
            prev = id;
        }
        nodes.push(Node { id: "g".into(), op: Op::GPool, inputs: vec![prev], cin: 0, cout: 0 });
        nodes.push(Node {
            id: "d1".into(),
            op: Op::Dense { relu: false },
            inputs: vec!["g".into()],
            cin: ch,
            cout: 10,
        });
        init(&mut weights, "d1", &[10, ch], rng);
        let model = Model {
            name: format!("synth{depth}{}", if branchy { "b" } else { "" }),
            task: "cls".into(),
            nodes,
            weights,
        };
        model.validate().expect("synthetic chain is a valid graph");
        model
    }

    /// Weight matrix of a quantizable node reshaped to per-group GEMM form:
    /// `groups` matrices of [rows, cols] (a view-copy).
    pub fn weight_as_gemm(&self, id: &str) -> Vec<Tensor> {
        let node = self.node(id).expect("node");
        let geom = node.geom().expect("quantizable");
        let w = self.weight(id);
        let per = geom.rows * geom.cols;
        (0..geom.groups)
            .map(|g| {
                Tensor::from_vec(
                    &[geom.rows, geom.cols],
                    w.data[g * per..(g + 1) * per].to_vec(),
                )
            })
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny_model_json() -> Json {
        Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":4,
               "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
              {"id":"g1","op":"gpool","inputs":["c1"]},
              {"id":"d1","op":"dense","inputs":["g1"],"cin":4,"cout":2,"relu":false}
            ]}"#,
        )
        .unwrap()
    }

    pub(crate) fn tiny_weights() -> BTreeMap<String, Tensor> {
        let mut w = BTreeMap::new();
        w.insert("c1.w".into(), Tensor::full(&[4, 3, 3, 3], 0.1));
        w.insert("c1.b".into(), Tensor::zeros(&[4]));
        w.insert("d1.w".into(), Tensor::full(&[2, 4], 0.5));
        w.insert("d1.b".into(), Tensor::from_vec(&[2], vec![0.0, 1.0]));
        w
    }

    #[test]
    fn parse_and_validate() {
        let m = Model::from_manifest("tiny", &tiny_model_json(), tiny_weights()).unwrap();
        assert_eq!(m.nodes.len(), 4);
        assert_eq!(m.quant_layers().len(), 2);
        let g = m.node("c1").unwrap().geom().unwrap();
        assert_eq!((g.rows, g.cols, g.groups, g.relu), (4, 27, 1, true));
    }

    #[test]
    fn missing_weight_rejected() {
        let mut w = tiny_weights();
        w.remove("d1.b");
        assert!(Model::from_manifest("tiny", &tiny_model_json(), w).is_err());
    }

    #[test]
    fn undefined_input_rejected() {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"a","op":"relu","inputs":["ghost"]}]}"#,
        )
        .unwrap();
        assert!(Model::from_manifest("x", &j, BTreeMap::new()).is_err());
    }

    #[test]
    fn liveness_on_linear_chain() {
        let m = Model::from_manifest("tiny", &tiny_model_json(), tiny_weights()).unwrap();
        // in(0) -> c1(1) -> g1(2) -> d1(3)
        assert_eq!(m.node_index("in"), Some(0));
        assert_eq!(m.node_index("d1"), Some(3));
        assert_eq!(m.node_index("ghost"), None);
        let last = m.last_use();
        assert_eq!(last.get("in"), Some(&1));
        assert_eq!(last.get("c1"), Some(&2));
        assert_eq!(last.get("g1"), Some(&3));
        assert_eq!(last.get("d1"), None, "output is never consumed");
        let sc = m.successor_counts();
        assert_eq!(sc.get("c1"), Some(&1));
        assert_eq!(sc.get("d1"), None);
        // at cut 2 only c1 is live; the input image is already dead
        let only_c1: BTreeSet<String> = ["c1".to_string()].into();
        let only_d1: BTreeSet<String> = ["d1".to_string()].into();
        assert_eq!(m.live_at(2), only_c1);
        assert_eq!(m.live_at(4), only_d1);
    }

    #[test]
    fn liveness_on_branchy_graph() {
        let mut rng = Rng::new(3);
        let m = Model::synthetic_chain(5, 4, true, &mut rng);
        // in(0) c1(1) c2(2) a1(3) c3(4) m1(5) c4(6) c5(7) g(8) d1(9)
        assert_eq!(m.quant_layers().len(), 6);
        let last = m.last_use();
        // c1 feeds c2 AND the residual add
        assert_eq!(last.get("c1"), Some(&3));
        // a1 feeds c3 AND the concat
        assert_eq!(last.get("a1"), Some(&5));
        assert_eq!(m.successor_counts().get("a1"), Some(&2));
        // at the cut before c3 both the skip value and c2's output are gone,
        // but a1 survives for the concat
        let live = m.live_at(4);
        assert!(live.contains("a1"));
        assert!(!live.contains("c1") && !live.contains("c2"));
    }

    #[test]
    fn synthetic_chain_shapes() {
        let mut rng = Rng::new(7);
        let m = Model::synthetic_chain(6, 4, false, &mut rng);
        assert_eq!(m.quant_layers().len(), 7);
        assert!(m.weights.contains_key("c6.w"));
        assert_eq!(m.weight("c1").shape, vec![4, 3, 3, 3]);
        assert_eq!(m.weight("d1").shape, vec![10, 4]);
        let mb = Model::synthetic_chain(4, 4, true, &mut rng);
        assert_eq!(mb.weight("c4").shape, vec![4, 8, 3, 3], "concat doubles cin");
    }

    #[test]
    fn gemm_view_groups() {
        let mut w = tiny_weights();
        w.insert("c1.w".into(), Tensor::from_vec(&[4, 3, 3, 3],
            (0..108).map(|x| x as f32).collect()));
        let m = Model::from_manifest("tiny", &tiny_model_json(), w).unwrap();
        let gs = m.weight_as_gemm("c1");
        assert_eq!(gs.len(), 1);
        assert_eq!(gs[0].shape, vec![4, 27]);
        assert_eq!(gs[0].data[0], 0.0);
        assert_eq!(gs[0].data[27], 27.0);
    }
}
