//! Native forward executor for the graph IR.
//!
//! Supports per-layer weight overrides (quantized weights), activation
//! taps (capture intermediate tensors for calibration), and optional
//! activation fake-quantization — everything the PTQ pipeline needs to
//! build FP32 targets and quantized-prefix inputs.
//!
//! Execution is *segmented*: [`Model::forward_segment`] resumes from a
//! map of live node values instead of the network input, evicting each
//! value the moment its last consumer has run (the liveness analysis of
//! [`super::graph`]). [`Model::forward_collect`] is the whole-network
//! special case (seed the input, run segment `0..len`), so both paths
//! share one node evaluator, one conv workspace discipline and one
//! override/act-quant policy — the streaming calibration pipeline
//! (`coordinator/stream.rs`) produces bit-identical activations to a
//! full replay by construction.

use std::collections::{BTreeMap, BTreeSet};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::quant::ActQuant;
use crate::tensor::conv::{conv2d_with, Conv2dWorkspace};
use crate::tensor::{pool, Conv2dParams, Tensor};

use super::graph::{Model, Op};

/// Captured node outputs, keyed by node id.
pub type Taps = BTreeMap<String, Tensor>;

#[derive(Default)]
pub struct ForwardOptions<'a> {
    /// Replacement weights per node id ("<id>" -> 4-D/2-D weight tensor).
    pub weight_overrides: Option<&'a BTreeMap<String, Tensor>>,
    /// Replacement biases per node id (bias-correction baselines).
    pub bias_overrides: Option<&'a BTreeMap<String, Tensor>>,
    /// Activation quantizers per node id (applied to that node's output).
    pub act_quant: Option<&'a BTreeMap<String, ActQuant>>,
    /// When set, incremented once per executed Conv/Dense node — the
    /// instrumentation behind the streaming pipeline's O(L) layer-forward
    /// guarantee (asserted by `rust/tests/stream_pipeline.rs`, reported
    /// by `adaround quantize`).
    pub layer_counter: Option<&'a AtomicU64>,
}

impl Model {
    /// Plain forward pass: [N,3,32,32] -> logits [N,10] or [N,4,32,32].
    pub fn forward(&self, x: &Tensor, opts: &ForwardOptions) -> Tensor {
        self.forward_collect(x, opts, &BTreeSet::new()).0
    }

    /// Forward pass capturing the outputs of the nodes named in `want`.
    pub fn forward_collect(
        &self,
        x: &Tensor,
        opts: &ForwardOptions,
        want: &BTreeSet<String>,
    ) -> (Tensor, Taps) {
        let mut vals: BTreeMap<String, Tensor> = BTreeMap::new();
        for nd in &self.nodes {
            if matches!(nd.op, Op::Input) {
                vals.insert(nd.id.clone(), x.clone());
            }
        }
        let taps = self.forward_segment(&mut vals, 0..self.nodes.len(), opts, want);
        let last = self.nodes.last().unwrap().id.clone();
        (vals.remove(&last).expect("network output live at end of pass"), taps)
    }

    /// Execute the contiguous node range `range`, resuming from `vals` —
    /// the live node values at the frontier cut `range.start` (for
    /// `range.start == 0`, the values of the `Op::Input` nodes). On
    /// return `vals` holds exactly the values live at `range.end` (plus
    /// the network output once produced): every value is dropped the
    /// moment its last consumer has run, so peak memory tracks the
    /// graph's live set, not its depth. Outputs of nodes named in `want`
    /// are cloned into the returned [`Taps`] at production time
    /// (after activation fake-quant, like every consumer sees them).
    ///
    /// One im2col/GEMM workspace is shared by every conv in the segment,
    /// as in a whole-network pass. Panics if a required value is missing
    /// from `vals` (a non-contiguous resume or an unseeded input).
    pub fn forward_segment(
        &self,
        vals: &mut BTreeMap<String, Tensor>,
        range: Range<usize>,
        opts: &ForwardOptions,
        want: &BTreeSet<String>,
    ) -> Taps {
        self.forward_segment_with(vals, range, opts, want, &self.last_use())
    }

    /// [`Self::forward_segment`] with a caller-supplied liveness map
    /// ([`Model::last_use`]) so fan-outs running the same segment on many
    /// chunks (the streaming calibration store) amortize its construction
    /// instead of rebuilding it per chunk.
    pub fn forward_segment_with(
        &self,
        vals: &mut BTreeMap<String, Tensor>,
        range: Range<usize>,
        opts: &ForwardOptions,
        want: &BTreeSet<String>,
        last_use: &BTreeMap<String, usize>,
    ) -> Taps {
        let mut taps = Taps::new();
        // one im2col/GEMM workspace shared by every conv in this segment
        let mut conv_ws = Conv2dWorkspace::new();
        for j in range {
            let nd = &self.nodes[j];
            let out = match &nd.op {
                Op::Input => vals.remove(&nd.id).unwrap_or_else(|| {
                    panic!("input '{}' not seeded in segment values", nd.id)
                }),
                Op::Conv { k, stride, pad, groups, relu } => {
                    if let Some(c) = opts.layer_counter {
                        c.fetch_add(1, Ordering::Relaxed);
                    }
                    let inp = &vals[nd.inputs[0].as_str()];
                    let w = opts
                        .weight_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.weight(&nd.id));
                    let b = opts
                        .bias_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.bias(&nd.id));
                    let mut y = conv2d_with(
                        &mut conv_ws,
                        inp,
                        w,
                        Some(&b.data),
                        Conv2dParams { k: *k, stride: *stride, pad: *pad, groups: *groups },
                    );
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Dense { relu } => {
                    if let Some(c) = opts.layer_counter {
                        c.fetch_add(1, Ordering::Relaxed);
                    }
                    let inp = &vals[nd.inputs[0].as_str()]; // [N, C]
                    let w = opts
                        .weight_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.weight(&nd.id));
                    let b = opts
                        .bias_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.bias(&nd.id));
                    // y = inp @ w^T + b; w is stored [O, C] row-major,
                    // which is exactly matmul_bt's B^T layout — the
                    // register-blocked row-parallel kernel, no transpose
                    // materialization
                    let mut y = crate::tensor::matmul_bt(inp, w);
                    for r in 0..y.rows() {
                        for (v, bb) in y.row_mut(r).iter_mut().zip(&b.data) {
                            *v += bb;
                        }
                    }
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Add { relu } => {
                    let a = &vals[nd.inputs[0].as_str()];
                    let b = &vals[nd.inputs[1].as_str()];
                    let mut y = a.add(b);
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Relu => vals[nd.inputs[0].as_str()].relu(),
                Op::AvgPool { k, stride } => {
                    pool::avgpool2d(&vals[nd.inputs[0].as_str()], *k, *stride)
                }
                Op::GPool => pool::global_avgpool(&vals[nd.inputs[0].as_str()]),
                Op::Upsample => pool::upsample2x(&vals[nd.inputs[0].as_str()]),
                Op::Concat => {
                    let ins: Vec<&Tensor> =
                        nd.inputs.iter().map(|i| &vals[i.as_str()]).collect();
                    pool::concat_channels(&ins)
                }
            };
            let out = match opts.act_quant.and_then(|m| m.get(&nd.id)) {
                Some(q) => q.apply(&out),
                None => out,
            };
            if want.contains(&nd.id) {
                taps.insert(nd.id.clone(), out.clone());
            }
            vals.insert(nd.id.clone(), out);
            // evict every value this node consumed for the last time
            for inp in &nd.inputs {
                if last_use.get(inp) == Some(&j) {
                    vals.remove(inp);
                }
            }
        }
        taps
    }

    /// The node ids whose outputs feed each quantizable layer (its input
    /// activation); used to set up calibration taps.
    pub fn layer_input_ids(&self) -> BTreeMap<String, String> {
        self.quant_layers()
            .iter()
            .map(|nd| (nd.id.clone(), nd.inputs[0].clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::graph::tests::{tiny_model_json, tiny_weights};
    use super::*;
    use crate::util::Rng;

    fn tiny() -> Model {
        Model::from_manifest("tiny", &tiny_model_json(), tiny_weights()).unwrap()
    }

    #[test]
    fn forward_shapes() {
        let m = tiny();
        let x = Tensor::full(&[2, 3, 32, 32], 1.0);
        let y = m.forward(&x, &ForwardOptions::default());
        assert_eq!(y.shape, vec![2, 2]);
    }

    #[test]
    fn forward_values() {
        // all-ones input, 0.1 conv weights, relu, gpool, dense 0.5:
        // interior conv out = 27*0.1 = 2.7; borders smaller; gpool in (0,2.7];
        // dense row adds bias (0,1)
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let y = m.forward(&x, &ForwardOptions::default());
        assert!(y.data[0] > 0.0);
        assert!((y.data[1] - y.data[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn overrides_change_output() {
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let base = m.forward(&x, &ForwardOptions::default());
        let mut ov = BTreeMap::new();
        ov.insert("c1".to_string(), Tensor::zeros(&[4, 3, 3, 3]));
        let opts = ForwardOptions { weight_overrides: Some(&ov), ..Default::default() };
        let z = m.forward(&x, &opts);
        assert_ne!(base.data, z.data);
        assert!((z.data[1] - 1.0).abs() < 1e-6); // only dense bias remains
    }

    #[test]
    fn taps_capture_inputs() {
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let want: BTreeSet<String> = ["in".to_string(), "g1".to_string()].into();
        let (_, taps) = m.forward_collect(&x, &ForwardOptions::default(), &want);
        assert_eq!(taps["in"].shape, vec![1, 3, 32, 32]);
        assert_eq!(taps["g1"].shape, vec![1, 4]);
        let map = m.layer_input_ids();
        assert_eq!(map["c1"], "in");
        assert_eq!(map["d1"], "g1");
    }

    #[test]
    fn segments_match_whole_pass_and_evict_dead_values() {
        let mut rng = Rng::new(21);
        let m = Model::synthetic_chain(5, 4, true, &mut rng);
        let n: usize = 2;
        let x = Tensor::from_vec(
            &[n, 3, 8, 8],
            (0..n * 3 * 64).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect(),
        );
        let want: BTreeSet<String> = ["a1".to_string(), "g".to_string()].into();
        let (y_full, taps_full) = m.forward_collect(&x, &ForwardOptions::default(), &want);

        // same pass cut into three segments at arbitrary frontiers
        let mut vals = BTreeMap::new();
        vals.insert("in".to_string(), x.clone());
        let len = m.nodes.len();
        let mut taps_seg = Taps::new();
        for cut in [0..4usize, 4..7, 7..len] {
            let opts = ForwardOptions::default();
            taps_seg.extend(m.forward_segment(&mut vals, cut.clone(), &opts, &want));
            // the live map holds exactly the liveness analysis' answer
            // (plus the output once produced — live_at includes it)
            let keys: BTreeSet<String> = vals.keys().cloned().collect();
            assert_eq!(keys, m.live_at(cut.end), "live set at cut {}", cut.end);
        }
        let y_seg = vals.remove("d1").unwrap();
        assert_eq!(y_full.data, y_seg.data, "segmented == whole pass, bit-identical");
        assert_eq!(taps_full, taps_seg);
    }

    #[test]
    fn layer_counter_counts_conv_and_dense() {
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let ctr = AtomicU64::new(0);
        let opts = ForwardOptions { layer_counter: Some(&ctr), ..Default::default() };
        m.forward(&x, &opts);
        assert_eq!(ctr.load(Ordering::Relaxed), 2); // c1 + d1
        m.forward(&x, &opts);
        assert_eq!(ctr.load(Ordering::Relaxed), 4);
    }
}
