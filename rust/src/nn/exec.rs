//! Native forward executor for the graph IR.
//!
//! Supports per-layer weight overrides (quantized weights), activation
//! taps (capture intermediate tensors for calibration), and optional
//! activation fake-quantization — everything the PTQ pipeline needs to
//! build FP32 targets and quantized-prefix inputs.

use std::collections::{BTreeMap, BTreeSet};

use crate::quant::ActQuant;
use crate::tensor::conv::{conv2d_with, Conv2dWorkspace};
use crate::tensor::{pool, Conv2dParams, Tensor};

use super::graph::{Model, Op};

/// Captured node outputs, keyed by node id.
pub type Taps = BTreeMap<String, Tensor>;

#[derive(Default)]
pub struct ForwardOptions<'a> {
    /// Replacement weights per node id ("<id>" -> 4-D/2-D weight tensor).
    pub weight_overrides: Option<&'a BTreeMap<String, Tensor>>,
    /// Replacement biases per node id (bias-correction baselines).
    pub bias_overrides: Option<&'a BTreeMap<String, Tensor>>,
    /// Activation quantizers per node id (applied to that node's output).
    pub act_quant: Option<&'a BTreeMap<String, ActQuant>>,
}

impl Model {
    /// Plain forward pass: [N,3,32,32] -> logits [N,10] or [N,4,32,32].
    pub fn forward(&self, x: &Tensor, opts: &ForwardOptions) -> Tensor {
        self.forward_collect(x, opts, &BTreeSet::new()).0
    }

    /// Forward pass capturing the outputs of the nodes named in `want`.
    pub fn forward_collect(
        &self,
        x: &Tensor,
        opts: &ForwardOptions,
        want: &BTreeSet<String>,
    ) -> (Tensor, Taps) {
        let mut vals: BTreeMap<&str, Tensor> = BTreeMap::new();
        let mut taps = Taps::new();
        // one im2col/GEMM workspace shared by every conv in this pass
        let mut conv_ws = Conv2dWorkspace::new();
        for nd in &self.nodes {
            let out = match &nd.op {
                Op::Input => x.clone(),
                Op::Conv { k, stride, pad, groups, relu } => {
                    let inp = &vals[nd.inputs[0].as_str()];
                    let w = opts
                        .weight_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.weight(&nd.id));
                    let b = opts
                        .bias_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.bias(&nd.id));
                    let mut y = conv2d_with(
                        &mut conv_ws,
                        inp,
                        w,
                        Some(&b.data),
                        Conv2dParams { k: *k, stride: *stride, pad: *pad, groups: *groups },
                    );
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Dense { relu } => {
                    let inp = &vals[nd.inputs[0].as_str()]; // [N, C]
                    let w = opts
                        .weight_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.weight(&nd.id));
                    let b = opts
                        .bias_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.bias(&nd.id));
                    // y = inp @ w^T + b; w is stored [O, C] row-major,
                    // which is exactly matmul_bt's B^T layout — the
                    // register-blocked row-parallel kernel, no transpose
                    // materialization
                    let mut y = crate::tensor::matmul_bt(inp, w);
                    for r in 0..y.rows() {
                        for (v, bb) in y.row_mut(r).iter_mut().zip(&b.data) {
                            *v += bb;
                        }
                    }
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Add { relu } => {
                    let a = &vals[nd.inputs[0].as_str()];
                    let b = &vals[nd.inputs[1].as_str()];
                    let mut y = a.add(b);
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Relu => vals[nd.inputs[0].as_str()].relu(),
                Op::AvgPool { k, stride } => {
                    pool::avgpool2d(&vals[nd.inputs[0].as_str()], *k, *stride)
                }
                Op::GPool => pool::global_avgpool(&vals[nd.inputs[0].as_str()]),
                Op::Upsample => pool::upsample2x(&vals[nd.inputs[0].as_str()]),
                Op::Concat => {
                    let ins: Vec<&Tensor> =
                        nd.inputs.iter().map(|i| &vals[i.as_str()]).collect();
                    pool::concat_channels(&ins)
                }
            };
            let out = match opts.act_quant.and_then(|m| m.get(&nd.id)) {
                Some(q) => q.apply(&out),
                None => out,
            };
            if want.contains(&nd.id) {
                taps.insert(nd.id.clone(), out.clone());
            }
            vals.insert(nd.id.as_str(), out);
        }
        let last = self.nodes.last().unwrap().id.as_str();
        (vals.remove(last).unwrap(), taps)
    }

    /// The node ids whose outputs feed each quantizable layer (its input
    /// activation); used to set up calibration taps.
    pub fn layer_input_ids(&self) -> BTreeMap<String, String> {
        self.quant_layers()
            .iter()
            .map(|nd| (nd.id.clone(), nd.inputs[0].clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::graph::tests::{tiny_model_json, tiny_weights};
    use super::*;

    fn tiny() -> Model {
        Model::from_manifest("tiny", &tiny_model_json(), tiny_weights()).unwrap()
    }

    #[test]
    fn forward_shapes() {
        let m = tiny();
        let x = Tensor::full(&[2, 3, 32, 32], 1.0);
        let y = m.forward(&x, &ForwardOptions::default());
        assert_eq!(y.shape, vec![2, 2]);
    }

    #[test]
    fn forward_values() {
        // all-ones input, 0.1 conv weights, relu, gpool, dense 0.5:
        // interior conv out = 27*0.1 = 2.7; borders smaller; gpool in (0,2.7];
        // dense row adds bias (0,1)
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let y = m.forward(&x, &ForwardOptions::default());
        assert!(y.data[0] > 0.0);
        assert!((y.data[1] - y.data[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn overrides_change_output() {
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let base = m.forward(&x, &ForwardOptions::default());
        let mut ov = BTreeMap::new();
        ov.insert("c1".to_string(), Tensor::zeros(&[4, 3, 3, 3]));
        let opts = ForwardOptions {
            weight_overrides: Some(&ov), bias_overrides: None, act_quant: None };
        let z = m.forward(&x, &opts);
        assert_ne!(base.data, z.data);
        assert!((z.data[1] - 1.0).abs() < 1e-6); // only dense bias remains
    }

    #[test]
    fn taps_capture_inputs() {
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let want: BTreeSet<String> = ["in".to_string(), "g1".to_string()].into();
        let (_, taps) = m.forward_collect(&x, &ForwardOptions::default(), &want);
        assert_eq!(taps["in"].shape, vec![1, 3, 32, 32]);
        assert_eq!(taps["g1"].shape, vec![1, 4]);
        let map = m.layer_input_ids();
        assert_eq!(map["c1"], "in");
        assert_eq!(map["d1"], "g1");
    }
}
