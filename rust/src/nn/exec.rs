//! Native forward executor for the graph IR.
//!
//! Supports per-layer weight overrides (quantized weights), activation
//! taps (capture intermediate tensors for calibration), and optional
//! activation fake-quantization — everything the PTQ pipeline needs to
//! build FP32 targets and quantized-prefix inputs.
//!
//! Execution is *segmented*: [`Model::forward_segment`] resumes from a
//! map of live node values instead of the network input, evicting each
//! value the moment its last consumer has run (the liveness analysis of
//! [`super::graph`]). [`Model::forward_collect`] is the whole-network
//! special case (seed the input, run segment `0..len`), so both paths
//! share one node evaluator, one conv workspace discipline and one
//! override/act-quant policy — the streaming calibration pipeline
//! (`coordinator/stream.rs`) produces bit-identical activations to a
//! full replay by construction.

use std::collections::{BTreeMap, BTreeSet};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::quant::ActQuant;
use crate::tensor::conv::{conv2d_with, Conv2dWorkspace};
use crate::tensor::{attention, pool, Conv2dParams, Tensor};

use super::graph::{Model, Op};

/// Captured node outputs, keyed by node id.
pub type Taps = BTreeMap<String, Tensor>;

#[derive(Default)]
pub struct ForwardOptions<'a> {
    /// Replacement weights per node id ("<id>" -> 4-D/2-D weight tensor).
    pub weight_overrides: Option<&'a BTreeMap<String, Tensor>>,
    /// Replacement biases per node id (bias-correction baselines).
    pub bias_overrides: Option<&'a BTreeMap<String, Tensor>>,
    /// Activation quantizers per node id (applied to that node's output).
    pub act_quant: Option<&'a BTreeMap<String, ActQuant>>,
    /// When set, incremented once per executed Conv/Dense node — the
    /// instrumentation behind the streaming pipeline's O(L) layer-forward
    /// guarantee (asserted by `rust/tests/stream_pipeline.rs`, reported
    /// by `adaround quantize`).
    pub layer_counter: Option<&'a AtomicU64>,
}

impl Model {
    /// Plain forward pass: [N,3,32,32] -> logits [N,10] or [N,4,32,32].
    pub fn forward(&self, x: &Tensor, opts: &ForwardOptions) -> Tensor {
        self.forward_collect(x, opts, &BTreeSet::new()).0
    }

    /// Forward pass capturing the outputs of the nodes named in `want`.
    ///
    /// Single-input convenience wrapper over
    /// [`Self::forward_collect_multi`]: panics with the graph's input ids
    /// if the model has more than one `Op::Input` node — seeding them all
    /// with the same tensor is never what a multi-input graph means.
    pub fn forward_collect(
        &self,
        x: &Tensor,
        opts: &ForwardOptions,
        want: &BTreeSet<String>,
    ) -> (Tensor, Taps) {
        let input_ids: Vec<&str> = self
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Input))
            .map(|n| n.id.as_str())
            .collect();
        assert!(
            input_ids.len() == 1,
            "forward_collect needs exactly one Op::Input node, model '{}' has {:?}; \
             use forward_collect_multi to seed each input explicitly",
            self.name,
            input_ids
        );
        let mut inputs = BTreeMap::new();
        inputs.insert(input_ids[0].to_string(), x.clone());
        self.forward_collect_multi(&inputs, opts, want)
    }

    /// Forward pass over a graph with any number of `Op::Input` nodes,
    /// each seeded from `inputs` by node id. Panics if an input node is
    /// missing from the map or the map names an unknown input.
    pub fn forward_collect_multi(
        &self,
        inputs: &BTreeMap<String, Tensor>,
        opts: &ForwardOptions,
        want: &BTreeSet<String>,
    ) -> (Tensor, Taps) {
        let mut vals: BTreeMap<String, Tensor> = BTreeMap::new();
        for nd in &self.nodes {
            if matches!(nd.op, Op::Input) {
                let x = inputs.get(&nd.id).unwrap_or_else(|| {
                    panic!("no tensor provided for input node '{}'", nd.id)
                });
                vals.insert(nd.id.clone(), x.clone());
            }
        }
        for key in inputs.keys() {
            assert!(
                vals.contains_key(key),
                "'{key}' is not an Op::Input node of model '{}'",
                self.name
            );
        }
        let taps = self.forward_segment(&mut vals, 0..self.nodes.len(), opts, want);
        let last = self.nodes.last().unwrap().id.clone();
        (vals.remove(&last).expect("network output live at end of pass"), taps)
    }

    /// Execute the contiguous node range `range`, resuming from `vals` —
    /// the live node values at the frontier cut `range.start` (for
    /// `range.start == 0`, the values of the `Op::Input` nodes). On
    /// return `vals` holds exactly the values live at `range.end` (plus
    /// the network output once produced): every value is dropped the
    /// moment its last consumer has run, so peak memory tracks the
    /// graph's live set, not its depth. Outputs of nodes named in `want`
    /// are cloned into the returned [`Taps`] at production time
    /// (after activation fake-quant, like every consumer sees them).
    ///
    /// One im2col/GEMM workspace is shared by every conv in the segment,
    /// as in a whole-network pass. Panics if a required value is missing
    /// from `vals` (a non-contiguous resume or an unseeded input).
    pub fn forward_segment(
        &self,
        vals: &mut BTreeMap<String, Tensor>,
        range: Range<usize>,
        opts: &ForwardOptions,
        want: &BTreeSet<String>,
    ) -> Taps {
        self.forward_segment_with(vals, range, opts, want, &self.last_use())
    }

    /// [`Self::forward_segment`] with a caller-supplied liveness map
    /// ([`Model::last_use`]) so fan-outs running the same segment on many
    /// chunks (the streaming calibration store) amortize its construction
    /// instead of rebuilding it per chunk.
    pub fn forward_segment_with(
        &self,
        vals: &mut BTreeMap<String, Tensor>,
        range: Range<usize>,
        opts: &ForwardOptions,
        want: &BTreeSet<String>,
        last_use: &BTreeMap<String, usize>,
    ) -> Taps {
        let mut taps = Taps::new();
        // one im2col/GEMM workspace shared by every conv in this segment
        let mut conv_ws = Conv2dWorkspace::new();
        // missing upstream values name the node and input instead of the
        // opaque BTreeMap index panic
        fn need<'v>(
            vals: &'v BTreeMap<String, Tensor>,
            nd: &super::graph::Node,
            i: usize,
        ) -> &'v Tensor {
            vals.get(nd.inputs[i].as_str()).unwrap_or_else(|| {
                panic!(
                    "node '{}': missing upstream value '{}' (evicted or never produced)",
                    nd.id, nd.inputs[i]
                )
            })
        }
        for j in range {
            let nd = &self.nodes[j];
            let out = match &nd.op {
                Op::Input => vals.remove(&nd.id).unwrap_or_else(|| {
                    panic!("input '{}' not seeded in segment values", nd.id)
                }),
                Op::Conv { k, stride, pad, groups, relu } => {
                    if let Some(c) = opts.layer_counter {
                        c.fetch_add(1, Ordering::Relaxed);
                    }
                    let inp = need(vals, nd, 0);
                    let w = opts
                        .weight_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.weight(&nd.id));
                    let b = opts
                        .bias_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.bias(&nd.id));
                    assert_eq!(
                        b.data.len(),
                        w.shape[0],
                        "node '{}': bias len != out channels",
                        nd.id
                    );
                    let mut y = conv2d_with(
                        &mut conv_ws,
                        inp,
                        w,
                        Some(&b.data),
                        Conv2dParams { k: *k, stride: *stride, pad: *pad, groups: *groups },
                    );
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Dense { relu } => {
                    if let Some(c) = opts.layer_counter {
                        c.fetch_add(1, Ordering::Relaxed);
                    }
                    let inp = need(vals, nd, 0); // [N, C] or [N, S, C]
                    let w = opts
                        .weight_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.weight(&nd.id));
                    let b = opts
                        .bias_overrides
                        .and_then(|m| m.get(&nd.id))
                        .unwrap_or_else(|| self.bias(&nd.id));
                    // y = inp @ w^T + b; w is stored [O, C] row-major,
                    // which is exactly matmul_bt's B^T layout — the
                    // register-blocked row-parallel kernel, no transpose
                    // materialization. Inputs with more than 2 dims
                    // (token activations [N, S, C]) flatten their leading
                    // dims into GEMM rows; the last dim is the feature dim.
                    let cout = w.shape[0];
                    assert_eq!(
                        b.data.len(),
                        cout,
                        "node '{}': bias len {} != out features {}",
                        nd.id,
                        b.data.len(),
                        cout
                    );
                    let d_last = *inp.shape.last().expect("dense input has dims");
                    assert_eq!(
                        d_last, w.shape[1],
                        "node '{}': input feature dim != weight cols",
                        nd.id
                    );
                    let rows = inp.numel() / d_last;
                    let mut out_shape = inp.shape.clone();
                    *out_shape.last_mut().unwrap() = cout;
                    let mut y = Tensor::zeros(&out_shape);
                    crate::tensor::matmul_bt_into(
                        &inp.data, &w.data, &mut y.data, rows, d_last, cout,
                    );
                    for r in 0..rows {
                        let row = &mut y.data[r * cout..(r + 1) * cout];
                        for (v, bb) in row.iter_mut().zip(&b.data) {
                            *v += bb;
                        }
                    }
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Add { relu } => {
                    let a = need(vals, nd, 0);
                    let b = need(vals, nd, 1);
                    let mut y = a.add(b);
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Relu => need(vals, nd, 0).relu(),
                Op::AvgPool { k, stride } => pool::avgpool2d(need(vals, nd, 0), *k, *stride),
                Op::GPool => pool::global_avgpool(need(vals, nd, 0)),
                Op::Upsample => pool::upsample2x(need(vals, nd, 0)),
                Op::Concat => {
                    let ins: Vec<&Tensor> =
                        (0..nd.inputs.len()).map(|i| need(vals, nd, i)).collect();
                    pool::concat_channels(&ins)
                }
                Op::LayerNorm => {
                    let gamma = self.weight(&nd.id);
                    let beta = self.bias(&nd.id);
                    attention::layernorm(need(vals, nd, 0), &gamma.data, &beta.data)
                }
                Op::Softmax { causal } => {
                    attention::softmax_lastdim(need(vals, nd, 0), *causal)
                }
                Op::MatMul { heads, transpose_b } => {
                    let a = need(vals, nd, 0);
                    let b = need(vals, nd, 1);
                    if *transpose_b {
                        attention::attn_scores(a, b, *heads)
                    } else {
                        attention::attn_apply(a, b, *heads)
                    }
                }
                Op::Gelu => attention::gelu(need(vals, nd, 0)),
                Op::Embedding => {
                    attention::embedding_lookup(need(vals, nd, 0), self.weight(&nd.id))
                }
            };
            let out = match opts.act_quant.and_then(|m| m.get(&nd.id)) {
                Some(q) => q.apply(&out),
                None => out,
            };
            if want.contains(&nd.id) {
                taps.insert(nd.id.clone(), out.clone());
            }
            vals.insert(nd.id.clone(), out);
            // evict every value this node consumed for the last time
            for inp in &nd.inputs {
                if last_use.get(inp) == Some(&j) {
                    vals.remove(inp);
                }
            }
        }
        taps
    }

    /// The node ids whose outputs feed each quantizable layer, in the
    /// layer's input order; used to set up calibration taps. Every input
    /// is listed (not just `inputs[0]`) so multi-activation-input ops —
    /// the attention MatMuls, future two-input quantizable layers — tap
    /// the right tensor per input index.
    pub fn layer_input_ids(&self) -> BTreeMap<String, Vec<String>> {
        self.quant_layers()
            .iter()
            .map(|nd| (nd.id.clone(), nd.inputs.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::graph::tests::{tiny_model_json, tiny_weights};
    use super::*;
    use crate::util::Rng;

    fn tiny() -> Model {
        Model::from_manifest("tiny", &tiny_model_json(), tiny_weights()).unwrap()
    }

    #[test]
    fn forward_shapes() {
        let m = tiny();
        let x = Tensor::full(&[2, 3, 32, 32], 1.0);
        let y = m.forward(&x, &ForwardOptions::default());
        assert_eq!(y.shape, vec![2, 2]);
    }

    #[test]
    fn forward_values() {
        // all-ones input, 0.1 conv weights, relu, gpool, dense 0.5:
        // interior conv out = 27*0.1 = 2.7; borders smaller; gpool in (0,2.7];
        // dense row adds bias (0,1)
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let y = m.forward(&x, &ForwardOptions::default());
        assert!(y.data[0] > 0.0);
        assert!((y.data[1] - y.data[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn overrides_change_output() {
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let base = m.forward(&x, &ForwardOptions::default());
        let mut ov = BTreeMap::new();
        ov.insert("c1".to_string(), Tensor::zeros(&[4, 3, 3, 3]));
        let opts = ForwardOptions { weight_overrides: Some(&ov), ..Default::default() };
        let z = m.forward(&x, &opts);
        assert_ne!(base.data, z.data);
        assert!((z.data[1] - 1.0).abs() < 1e-6); // only dense bias remains
    }

    #[test]
    fn taps_capture_inputs() {
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let want: BTreeSet<String> = ["in".to_string(), "g1".to_string()].into();
        let (_, taps) = m.forward_collect(&x, &ForwardOptions::default(), &want);
        assert_eq!(taps["in"].shape, vec![1, 3, 32, 32]);
        assert_eq!(taps["g1"].shape, vec![1, 4]);
        let map = m.layer_input_ids();
        assert_eq!(map["c1"], vec!["in".to_string()]);
        assert_eq!(map["d1"], vec!["g1".to_string()]);
    }

    /// Regression (single-input assumption): `layer_input_ids` must list
    /// EVERY input of a layer in input order, not just `inputs[0]`.
    #[test]
    fn layer_input_ids_lists_all_inputs_in_order() {
        let mut rng = Rng::new(5);
        let m = Model::synthetic_transformer(1, 2, 8, 4, &mut rng);
        let map = m.layer_input_ids();
        assert_eq!(map["b1.q"], vec!["b1.ln1".to_string()]);
        assert_eq!(map["b1.wo"], vec!["b1.av".to_string()]);
        // and the graph's own two-input nodes keep both, ordered
        let av = m.node("b1.av").unwrap();
        assert_eq!(av.inputs, vec!["b1.sm".to_string(), "b1.v".to_string()]);
    }

    fn two_input_model() -> Model {
        use crate::util::Json;
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"ina","op":"input","inputs":[]},
              {"id":"inb","op":"input","inputs":[]},
              {"id":"s","op":"add","inputs":["ina","inb"],"relu":false}
            ]}"#,
        )
        .unwrap();
        Model::from_manifest("two", &j, BTreeMap::new()).unwrap()
    }

    /// Regression: `forward_collect` used to silently seed every input
    /// node with the same tensor on multi-input graphs.
    #[test]
    #[should_panic(expected = "use forward_collect_multi")]
    fn forward_collect_rejects_multi_input_graphs() {
        let m = two_input_model();
        m.forward_collect(
            &Tensor::full(&[1, 2], 1.0),
            &ForwardOptions::default(),
            &BTreeSet::new(),
        );
    }

    #[test]
    fn forward_collect_multi_seeds_each_input() {
        let m = two_input_model();
        let mut inputs = BTreeMap::new();
        inputs.insert("ina".to_string(), Tensor::full(&[1, 2], 1.0));
        inputs.insert("inb".to_string(), Tensor::full(&[1, 2], 10.0));
        let (y, _) =
            m.forward_collect_multi(&inputs, &ForwardOptions::default(), &BTreeSet::new());
        assert_eq!(y.data, vec![11.0, 11.0]);
    }

    #[test]
    #[should_panic(expected = "no tensor provided for input node 'inb'")]
    fn forward_collect_multi_requires_every_input() {
        let m = two_input_model();
        let mut inputs = BTreeMap::new();
        inputs.insert("ina".to_string(), Tensor::full(&[1, 2], 1.0));
        m.forward_collect_multi(&inputs, &ForwardOptions::default(), &BTreeSet::new());
    }

    /// Regression: the dense bias add used to zip-truncate silently when
    /// the bias was shorter than the output row.
    #[test]
    #[should_panic(expected = "bias len")]
    fn dense_bias_length_mismatch_panics() {
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let mut bov = BTreeMap::new();
        bov.insert("d1".to_string(), Tensor::zeros(&[1])); // d1 has cout=2
        let opts = ForwardOptions { bias_overrides: Some(&bov), ..Default::default() };
        m.forward(&x, &opts);
    }

    /// Regression: a missing upstream value names the node and input id
    /// instead of the BTreeMap's opaque index panic.
    #[test]
    #[should_panic(expected = "node 'c1': missing upstream value 'in'")]
    fn missing_upstream_value_names_node_and_input() {
        let m = tiny();
        let mut vals = BTreeMap::new(); // 'in' never seeded
        m.forward_segment(
            &mut vals,
            1..m.nodes.len(),
            &ForwardOptions::default(),
            &BTreeSet::new(),
        );
    }

    #[test]
    fn transformer_forward_shapes_and_segmenting() {
        let mut rng = Rng::new(5);
        let m = Model::synthetic_transformer(2, 2, 8, 6, &mut rng);
        let n = 3;
        let x = Tensor::from_vec(
            &[n, 1, 1, 6],
            (0..n * 6).map(|i| (i % 32) as f32).collect(),
        );
        let want: BTreeSet<String> = ["b1.sm".to_string(), "b2.r2".to_string()].into();
        let ctr = AtomicU64::new(0);
        let opts = ForwardOptions { layer_counter: Some(&ctr), ..Default::default() };
        let (y, taps) = m.forward_collect(&x, &opts, &want);
        assert_eq!(y.shape, vec![n, 10]);
        assert_eq!(taps["b1.sm"].shape, vec![n, 2, 6, 6]);
        assert_eq!(taps["b2.r2"].shape, vec![n, 6, 8]);
        assert_eq!(ctr.load(Ordering::Relaxed), 13, "6 denses per block + head");
        // causal probs: first query row attends only to key 0
        let sm = &taps["b1.sm"];
        assert_eq!(sm.data[0], 1.0);
        assert_eq!(sm.data[1], 0.0);

        // the same pass cut into segments through the attention block is
        // bit-identical and the live map matches the liveness analysis
        let mut vals = BTreeMap::new();
        vals.insert("in".to_string(), x.clone());
        let av_at = m.node_index("b1.av").unwrap();
        let len = m.nodes.len();
        let mut taps_seg = Taps::new();
        for cut in [0..av_at, av_at..av_at + 3, av_at + 3..len] {
            taps_seg.extend(m.forward_segment(
                &mut vals,
                cut.clone(),
                &ForwardOptions::default(),
                &want,
            ));
            let keys: BTreeSet<String> = vals.keys().cloned().collect();
            assert_eq!(keys, m.live_at(cut.end), "live set at cut {}", cut.end);
        }
        let y_seg = vals.remove("head").unwrap();
        assert_eq!(y.data, y_seg.data, "segmented == whole pass, bit-identical");
        assert_eq!(taps, taps_seg);
    }

    #[test]
    fn dense_generalizes_to_token_inputs() {
        // [N, S, C] through a dense == each token row through the same
        // dense as a [N*S, C] matrix
        let mut rng = Rng::new(9);
        let m = Model::synthetic_transformer(1, 1, 4, 4, &mut rng);
        let w = m.weight("b1.fc1");
        let b = m.bias("b1.fc1");
        let x3 = Tensor::from_vec(&[2, 3, 4], (0..24).map(|i| i as f32 * 0.1).collect());
        let mut vals = BTreeMap::new();
        vals.insert("b1.ln2".to_string(), x3.clone());
        let at = m.node_index("b1.fc1").unwrap();
        m.forward_segment(
            &mut vals,
            at..at + 1,
            &ForwardOptions::default(),
            &BTreeSet::new(),
        );
        let y3 = &vals["b1.fc1"];
        assert_eq!(y3.shape, vec![2, 3, 8]);
        let x2 = Tensor::from_vec(&[6, 4], x3.data.clone());
        let mut y2 = crate::tensor::matmul_bt(&x2, w);
        for r in 0..6 {
            for (v, bb) in y2.row_mut(r).iter_mut().zip(&b.data) {
                *v += bb;
            }
        }
        assert_eq!(y3.data, y2.data, "3-D dense == flattened 2-D GEMM bit-for-bit");
    }

    #[test]
    fn segments_match_whole_pass_and_evict_dead_values() {
        let mut rng = Rng::new(21);
        let m = Model::synthetic_chain(5, 4, true, &mut rng);
        let n: usize = 2;
        let x = Tensor::from_vec(
            &[n, 3, 8, 8],
            (0..n * 3 * 64).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect(),
        );
        let want: BTreeSet<String> = ["a1".to_string(), "g".to_string()].into();
        let (y_full, taps_full) = m.forward_collect(&x, &ForwardOptions::default(), &want);

        // same pass cut into three segments at arbitrary frontiers
        let mut vals = BTreeMap::new();
        vals.insert("in".to_string(), x.clone());
        let len = m.nodes.len();
        let mut taps_seg = Taps::new();
        for cut in [0..4usize, 4..7, 7..len] {
            let opts = ForwardOptions::default();
            taps_seg.extend(m.forward_segment(&mut vals, cut.clone(), &opts, &want));
            // the live map holds exactly the liveness analysis' answer
            // (plus the output once produced — live_at includes it)
            let keys: BTreeSet<String> = vals.keys().cloned().collect();
            assert_eq!(keys, m.live_at(cut.end), "live set at cut {}", cut.end);
        }
        let y_seg = vals.remove("d1").unwrap();
        assert_eq!(y_full.data, y_seg.data, "segmented == whole pass, bit-identical");
        assert_eq!(taps_full, taps_seg);
    }

    #[test]
    fn layer_counter_counts_conv_and_dense() {
        let m = tiny();
        let x = Tensor::full(&[1, 3, 32, 32], 1.0);
        let ctr = AtomicU64::new(0);
        let opts = ForwardOptions { layer_counter: Some(&ctr), ..Default::default() };
        m.forward(&x, &opts);
        assert_eq!(ctr.load(Ordering::Relaxed), 2); // c1 + d1
        m.forward(&x, &opts);
        assert_eq!(ctr.load(Ordering::Relaxed), 4);
    }
}
