//! `adaround` — the CLI entrypoint of the PTQ framework.

use adaround::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = adaround::cli::run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
