//! # AdaRound — post-training quantization framework
//!
//! A from-scratch reproduction of *"Up or Down? Adaptive Rounding for
//! Post-Training Quantization"* (Nagel et al., ICML 2020) as a deployable
//! three-layer system:
//!
//! * **Layer 3 (this crate)** — the PTQ coordinator: quantization grids,
//!   rounding search (QUBO / continuous relaxation), the sequential
//!   layer-reconstruction pipeline, baselines, evaluation, CLI.
//! * **Layer 2 (python/compile, build-time only)** — the per-layer AdaRound
//!   optimization step as a fused JAX graph, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels, build-time only)** — Pallas kernels
//!   for the soft-quantized matmul forward/backward hot-spot.
//!
//! Python never runs on the request path: the rust binary loads the HLO
//! artifacts through PJRT ([`runtime`]) and drives the optimization loop
//! itself ([`adaround::PjrtOptimizer`]), with a pure-rust fallback
//! ([`adaround::NativeOptimizer`]) implementing identical math.
//!
//! Quickstart (after `make artifacts`):
//!
//! ```bash
//! adaround quantize --model micro18 --bits 4
//! adaround table 7         # regenerate the paper's literature comparison
//! ```
//!
//! ## Threading
//!
//! The native compute core (GEMMs, conv, the AdaRound step, per-group
//! rounding, calibration forwards, the integer serving kernels) is
//! data-parallel over a lazy, persistent worker pool ([`util::parallel`]).
//! The thread count comes from the `PALLAS_THREADS` environment variable
//! (default: all available cores); results are **bit-identical for every
//! thread count** — work is split by item index and each item is computed
//! by the same serial code, with no reduction-order dependence. The
//! serving front-end layers request-level parallelism on top: a
//! [`serve::Batcher`] shards a read-only plan across N engines, each
//! running under an equal slice of the thread budget
//! (`docs/ARCHITECTURE.md` has the full picture, including the
//! determinism contract).
//!
//! ## Workspace API
//!
//! The optimizer hot loop is allocation-free: [`adaround::StepWorkspace`]
//! owns every per-step intermediate,
//! [`adaround::LayerProblem::loss_grad_into`] writes the gradient into it,
//! [`adaround::gather_cols_into`] and
//! [`util::Rng::sample_indices_into`] reuse minibatch buffers, and
//! [`tensor::Conv2dWorkspace`] / [`tensor::conv2d_with`] do the same for
//! the im2col + GEMM path of inference (see
//! `rust/tests/perf_invariants.rs` for the enforced contract).

pub mod adaround;
pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod io;
pub mod nn;
pub mod quant;
pub mod qubo;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

/// Default artifacts directory, overridable with `--artifacts` / `QTZ_ARTIFACTS`.
pub fn artifacts_dir() -> String {
    std::env::var("QTZ_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}
