//! Evaluation metrics: top-1 accuracy (classification), mean IoU
//! (segmentation), and the QUBO-cost/accuracy correlation of Fig. 1.

use crate::data::chunks;
use crate::nn::{ForwardOptions, Model};
use crate::tensor::{IntTensor, Tensor};

/// Top-1 accuracy (%) of a classifier on (x [N,3,H,W], y [N]).
pub fn top1(model: &Model, x: &Tensor, y: &IntTensor, opts: &ForwardOptions, batch: usize) -> f64 {
    let n = x.shape[0];
    let per: usize = x.shape[1..].iter().product();
    let mut correct = 0usize;
    for (s, e) in chunks(n, batch) {
        let xb = Tensor::from_vec(
            &[e - s, x.shape[1], x.shape[2], x.shape[3]],
            x.data[s * per..e * per].to_vec(),
        );
        let logits = model.forward(&xb, opts);
        let preds = logits.argmax_rows();
        for (i, p) in preds.iter().enumerate() {
            if *p as i32 == y.data[s + i] {
                correct += 1;
            }
        }
    }
    100.0 * correct as f64 / n as f64
}

/// Mean intersection-over-union (%) for segmentation logits [N,C,H,W]
/// against masks [N,H,W], averaged over classes present in union.
pub fn miou(
    model: &Model,
    x: &Tensor,
    y: &IntTensor,
    opts: &ForwardOptions,
    batch: usize,
    num_classes: usize,
) -> f64 {
    let n = x.shape[0];
    let per: usize = x.shape[1..].iter().product();
    let mut inter = vec![0usize; num_classes];
    let mut union = vec![0usize; num_classes];
    for (s, e) in chunks(n, batch) {
        let xb = Tensor::from_vec(
            &[e - s, x.shape[1], x.shape[2], x.shape[3]],
            x.data[s * per..e * per].to_vec(),
        );
        let logits = model.forward(&xb, opts); // [nb, C, H, W]
        let (nb, c, h, w) = (
            logits.shape[0],
            logits.shape[1],
            logits.shape[2],
            logits.shape[3],
        );
        let hw = h * w;
        for bi in 0..nb {
            for pos in 0..hw {
                // argmax over channel
                let mut best = 0usize;
                let mut bestv = f32::NEG_INFINITY;
                for ci in 0..c {
                    let v = logits.data[(bi * c + ci) * hw + pos];
                    if v > bestv {
                        bestv = v;
                        best = ci;
                    }
                }
                let gt = y.data[(s + bi) * hw + pos] as usize;
                if best == gt {
                    inter[gt] += 1;
                    union[gt] += 1;
                } else {
                    union[gt] += 1;
                    union[best] += 1;
                }
            }
        }
    }
    let mut acc = 0.0;
    let mut cnt = 0usize;
    for c in 0..num_classes {
        if union[c] > 0 {
            acc += inter[c] as f64 / union[c] as f64;
            cnt += 1;
        }
    }
    100.0 * acc / cnt.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Model;
    use crate::util::Json;
    use std::collections::BTreeMap;

    /// Model that just global-pools and multiplies by an identity-ish dense:
    /// prediction = argmax of channel means.
    fn passthrough_model() -> Model {
        let j = Json::parse(
            r#"{"task":"cls","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"g1","op":"gpool","inputs":["in"]},
              {"id":"d1","op":"dense","inputs":["g1"],"cin":3,"cout":3,"relu":false}
            ]}"#,
        )
        .unwrap();
        let mut w = BTreeMap::new();
        let mut eye = Tensor::zeros(&[3, 3]);
        for i in 0..3 {
            eye.set2(i, i, 1.0);
        }
        w.insert("d1.w".into(), eye);
        w.insert("d1.b".into(), Tensor::zeros(&[3]));
        Model::from_manifest("pass", &j, w).unwrap()
    }

    #[test]
    fn top1_on_trivial_classifier() {
        let m = passthrough_model();
        // image i has channel y_i brightest
        let mut x = Tensor::zeros(&[4, 3, 2, 2]);
        let labels = vec![0, 2, 1, 2];
        for (i, &l) in labels.iter().enumerate() {
            for p in 0..4 {
                x.data[(i * 3 + l as usize) * 4 + p] = 1.0;
            }
        }
        let y = IntTensor::from_vec(&[4], labels);
        let acc = top1(&m, &x, &y, &ForwardOptions::default(), 2);
        assert_eq!(acc, 100.0);
        // corrupt one label
        let y2 = IntTensor::from_vec(&[4], vec![1, 2, 1, 2]);
        let acc2 = top1(&m, &x, &y2, &ForwardOptions::default(), 3);
        assert_eq!(acc2, 75.0);
    }

    #[test]
    fn miou_perfect_and_partial() {
        // seg model: conv 1x1 identity from 3 channels to 3 "classes"
        let j = Json::parse(
            r#"{"task":"seg","ir":[
              {"id":"in","op":"input","inputs":[]},
              {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":3,
               "k":1,"stride":1,"pad":0,"groups":1,"relu":false}
            ]}"#,
        )
        .unwrap();
        let mut w = BTreeMap::new();
        let mut eye = Tensor::zeros(&[3, 3, 1, 1]);
        for i in 0..3 {
            eye.data[i * 3 + i] = 1.0;
        }
        w.insert("c1.w".into(), eye);
        w.insert("c1.b".into(), Tensor::zeros(&[3]));
        let m = Model::from_manifest("seg", &j, w).unwrap();
        let mut x = Tensor::zeros(&[1, 3, 2, 2]);
        // pixel p gets class p % 3 brightest
        let gt = vec![0, 1, 2, 0];
        for (p, &c) in gt.iter().enumerate() {
            x.data[c as usize * 4 + p] = 1.0;
        }
        let y = IntTensor::from_vec(&[1, 2, 2], gt);
        let m_val = miou(&m, &x, &y, &ForwardOptions::default(), 1, 3);
        assert!((m_val - 100.0).abs() < 1e-9);
    }
}
