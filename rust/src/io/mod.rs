//! Binary tensor-bundle I/O shared with the python build side.

pub mod qtz;

pub use qtz::{read_qtz, write_qtz, QtzValue};
