//! `.qtz` tensor-bundle reader/writer — exact mirror of
//! `python/compile/qtz.py` (see that file for the byte layout).
//!
//! Dtype codes: 0 = f32, 1 = i32, 2 = u8, 3 = i8 (added for the v2
//! quantized-model layout carrying raw integer weights; old bundles never
//! contain code 3 and keep loading unchanged), 4 = i4 (v3 bundles:
//! nibble-packed signed 4-bit codes, two per byte, low nibble first —
//! the payload is `ceil(numel/2)` bytes; see `docs/SERVING.md` for the
//! byte-level spec). Unknown codes produce a descriptive error, not a
//! panic, so bundles from newer tools fail loudly but cleanly.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::int8::{pack_i4, unpack_i4};
use crate::tensor::{I8Tensor, IntTensor, Tensor};

const MAGIC: &[u8; 4] = b"QTZ1";

/// A tensor of any supported dtype.
#[derive(Clone, Debug)]
pub enum QtzValue {
    F32(Tensor),
    I32(IntTensor),
    U8(Vec<u8>, Vec<usize>),
    I8(I8Tensor),
    /// Nibble-packed i4 codes: raw packed bytes plus the logical shape
    /// (`numel` codes in `ceil(numel/2)` bytes).
    I4(Vec<u8>, Vec<usize>),
}

impl QtzValue {
    /// Nibble-pack i8 codes (each in `[-8, 7]`) into an i4 entry.
    pub fn from_i4_codes(codes: &[i8], shape: &[usize]) -> QtzValue {
        assert_eq!(shape.iter().product::<usize>(), codes.len());
        QtzValue::I4(pack_i4(codes), shape.to_vec())
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            QtzValue::F32(t) => Ok(t),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&IntTensor> {
        match self {
            QtzValue::I32(t) => Ok(t),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_i8(&self) -> Result<&I8Tensor> {
        match self {
            QtzValue::I8(t) => Ok(t),
            _ => bail!("tensor is not i8"),
        }
    }

    /// The codes of an i4 entry, unpacked to an [`I8Tensor`] (i4 ⊂ i8;
    /// the nibble stream is the storage format, i8 the working one).
    pub fn i4_to_i8(&self) -> Result<I8Tensor> {
        match self {
            QtzValue::I4(raw, s) => {
                let n: usize = s.iter().product();
                Ok(I8Tensor::from_vec(s, unpack_i4(raw, n)))
            }
            _ => bail!("tensor is not i4"),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            QtzValue::F32(t) => &t.shape,
            QtzValue::I32(t) => &t.shape,
            QtzValue::U8(_, s) => s,
            QtzValue::I8(t) => &t.shape,
            QtzValue::I4(_, s) => s,
        }
    }
}

/// Bounds-checked cursor over a fully-read bundle. Every access verifies
/// the remaining length *before* touching (or allocating for) the bytes,
/// so a truncated or corrupted file produces a descriptive error instead
/// of a short-read panic or a multi-gigabyte allocation driven by a
/// garbage shape field.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    /// Take the next `n` bytes, or fail with what was wanted vs present.
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let have = self.buf.len() - self.pos;
        if have < n {
            bail!("truncated bundle: {what} needs {n} bytes, {have} remain");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

/// `numel * elem_size` with overflow detection — shape dims come straight
/// off disk, so the product of a hostile shape can overflow `usize`.
fn payload_len(shape: &[usize], elem: usize, name: &str) -> Result<usize> {
    let mut n = 1usize;
    for &d in shape {
        n = n
            .checked_mul(d)
            .with_context(|| format!("entry {name:?}: shape {shape:?} overflows"))?;
    }
    n.checked_mul(elem)
        .with_context(|| format!("entry {name:?}: payload size for shape {shape:?} overflows"))
}

/// Read a bundle into name -> tensor.
///
/// Hardened against malformed input: the whole file is read up front and
/// parsed from a slice with an explicit bounds check before every field
/// and every payload, so truncation at any byte offset, an undersized
/// payload, or a shape that lies about the payload size all surface as
/// clean `Err`s — never a panic, never an allocation sized by
/// unvalidated on-disk integers.
pub fn read_qtz(path: impl AsRef<Path>) -> Result<BTreeMap<String, QtzValue>> {
    let path = path.as_ref();
    let buf = std::fs::read(path).with_context(|| format!("open {path:?}"))?;
    parse_qtz(&buf, path)
}

fn parse_qtz(buf: &[u8], path: &Path) -> Result<BTreeMap<String, QtzValue>> {
    let mut c = Cursor::new(buf);
    let magic = c.take(4, "magic")?;
    if magic != MAGIC {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let count = c.u32("entry count")?;
    let mut out = BTreeMap::new();
    for i in 0..count {
        let entry = format!("entry {i} of {count}");
        let name_len = c.u16(&entry)? as usize;
        let name = String::from_utf8(c.take(name_len, &entry)?.to_vec())
            .with_context(|| format!("{entry}: name is not UTF-8"))?;
        let hdr = c.take(2, &name)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u32(&name)? as usize);
        }
        let value = match dtype {
            0 => {
                let raw = c.take(payload_len(&shape, 4, &name)?, &name)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                QtzValue::F32(Tensor::from_vec(&shape, data))
            }
            1 => {
                let raw = c.take(payload_len(&shape, 4, &name)?, &name)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                QtzValue::I32(IntTensor::from_vec(&shape, data))
            }
            2 => {
                let raw = c.take(payload_len(&shape, 1, &name)?, &name)?;
                QtzValue::U8(raw.to_vec(), shape)
            }
            3 => {
                let raw = c.take(payload_len(&shape, 1, &name)?, &name)?;
                let data = raw.iter().map(|&b| b as i8).collect();
                QtzValue::I8(I8Tensor::from_vec(&shape, data))
            }
            4 => {
                let raw = c.take(payload_len(&shape, 1, &name)?.div_ceil(2), &name)?;
                QtzValue::I4(raw.to_vec(), shape)
            }
            d => bail!(
                "{path:?}: entry {name:?} has unknown dtype code {d} \
                 (this build understands 0=f32, 1=i32, 2=u8, 3=i8, 4=i4); \
                 the bundle was likely written by a newer tool"
            ),
        };
        out.insert(name, value);
    }
    Ok(out)
}

/// Write a bundle (used by tests and the quantized-model export).
pub fn write_qtz(path: impl AsRef<Path>, tensors: &BTreeMap<String, QtzValue>) -> Result<()> {
    let file = std::fs::File::create(path.as_ref())?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, v) in tensors {
        w.write_all(&(name.len() as u16).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        let (code, shape): (u8, &[usize]) = match v {
            QtzValue::F32(t) => (0, &t.shape),
            QtzValue::I32(t) => (1, &t.shape),
            QtzValue::U8(_, s) => (2, s),
            QtzValue::I8(t) => (3, &t.shape),
            QtzValue::I4(_, s) => (4, s),
        };
        w.write_all(&[code, shape.len() as u8])?;
        for &d in shape {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        match v {
            QtzValue::F32(t) => {
                for x in &t.data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            QtzValue::I32(t) => {
                for x in &t.data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            QtzValue::U8(raw, _) => w.write_all(raw)?,
            QtzValue::I8(t) => {
                let raw: Vec<u8> = t.data.iter().map(|&x| x as u8).collect();
                w.write_all(&raw)?;
            }
            QtzValue::I4(raw, s) => {
                let n: usize = s.iter().product();
                assert_eq!(raw.len(), n.div_ceil(2), "i4 payload length");
                w.write_all(raw)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("qtz_test_rt.qtz");
        let mut m = BTreeMap::new();
        m.insert(
            "w".to_string(),
            QtzValue::F32(Tensor::from_vec(&[2, 3], vec![1., -2., 3.5, 0., 5., 6.])),
        );
        m.insert(
            "y".to_string(),
            QtzValue::I32(IntTensor::from_vec(&[4], vec![0, 1, -5, 9])),
        );
        m.insert("m".to_string(), QtzValue::U8(vec![7, 8], vec![2]));
        write_qtz(&dir, &m).unwrap();
        let back = read_qtz(&dir).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back["w"].as_f32().unwrap().data, vec![1., -2., 3.5, 0., 5., 6.]);
        assert_eq!(back["y"].as_i32().unwrap().data, vec![0, 1, -5, 9]);
        assert_eq!(back["m"].shape(), &[2]);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn i8_roundtrip() {
        let dir = std::env::temp_dir().join("qtz_test_i8.qtz");
        let mut m = BTreeMap::new();
        m.insert(
            "z".to_string(),
            QtzValue::I8(I8Tensor::from_vec(&[2, 3], vec![-128, -1, 0, 1, 64, 127])),
        );
        write_qtz(&dir, &m).unwrap();
        let back = read_qtz(&dir).unwrap();
        assert_eq!(back["z"].as_i8().unwrap().data, vec![-128, -1, 0, 1, 64, 127]);
        assert_eq!(back["z"].shape(), &[2, 3]);
        assert!(back["z"].as_f32().is_err());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn i4_roundtrip_even_and_odd() {
        let dir = std::env::temp_dir().join("qtz_test_i4.qtz");
        // odd numel exercises the pad nibble, corners exercise ±7/−8
        let codes: Vec<i8> = vec![-8, 7, -1, 0, 3, -5, 6];
        let mut m = BTreeMap::new();
        m.insert("q".to_string(), QtzValue::from_i4_codes(&codes, &[7]));
        m.insert("e".to_string(), QtzValue::from_i4_codes(&codes[..6], &[2, 3]));
        write_qtz(&dir, &m).unwrap();
        let back = read_qtz(&dir).unwrap();
        assert_eq!(back["q"].i4_to_i8().unwrap().data, codes);
        assert_eq!(back["e"].i4_to_i8().unwrap().data, &codes[..6]);
        assert_eq!(back["e"].shape(), &[2, 3]);
        assert!(back["q"].as_i8().is_err(), "i4 is a distinct dtype");
        // payload is half-size: 7 codes -> 4 bytes, 6 codes -> 3 bytes
        match &back["q"] {
            QtzValue::I4(raw, _) => assert_eq!(raw.len(), 4),
            _ => panic!("expected i4"),
        }
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn unknown_future_dtype_is_a_clear_error() {
        let dir = std::env::temp_dir().join("qtz_test_future.qtz");
        // hand-rolled bundle with one entry of dtype code 9
        let mut raw: Vec<u8> = Vec::new();
        raw.extend_from_slice(b"QTZ1");
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&1u16.to_le_bytes());
        raw.push(b'x');
        raw.push(9); // dtype
        raw.push(1); // ndim
        raw.extend_from_slice(&2u32.to_le_bytes());
        raw.extend_from_slice(&[0, 0]);
        std::fs::write(&dir, &raw).unwrap();
        let err = read_qtz(&dir).unwrap_err().to_string();
        assert!(err.contains("unknown dtype code 9"), "got: {err}");
        assert!(err.contains("newer tool"), "got: {err}");
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("qtz_test_bad.qtz");
        std::fs::write(&dir, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(read_qtz(&dir).is_err());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn truncation_at_every_offset_is_a_clean_error() {
        // a valid two-entry bundle, then every proper prefix of it must
        // fail with a descriptive error (and the full file must load)
        let dir = std::env::temp_dir().join("qtz_test_trunc.qtz");
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), QtzValue::F32(Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.])));
        m.insert("q".to_string(), QtzValue::from_i4_codes(&[-3, 5, 1], &[3]));
        write_qtz(&dir, &m).unwrap();
        let full = std::fs::read(&dir).unwrap();
        for cut in 0..full.len() {
            let err = parse_qtz(&full[..cut], Path::new("t.qtz")).unwrap_err().to_string();
            assert!(
                err.contains("truncated") || err.contains("bad magic"),
                "prefix {cut}/{}: unexpected error {err:?}",
                full.len()
            );
        }
        assert_eq!(parse_qtz(&full, Path::new("t.qtz")).unwrap().len(), 2);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn hostile_shape_does_not_allocate() {
        // shape [u32::MAX, u32::MAX, u32::MAX] would overflow (or try to
        // allocate exabytes); the parser must reject it before touching
        // the payload
        let mut raw: Vec<u8> = Vec::new();
        raw.extend_from_slice(b"QTZ1");
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&1u16.to_le_bytes());
        raw.push(b'x');
        raw.push(0); // dtype f32
        raw.push(3); // ndim
        for _ in 0..3 {
            raw.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        let err = parse_qtz(&raw, Path::new("t.qtz")).unwrap_err().to_string();
        assert!(
            err.contains("overflows") || err.contains("truncated"),
            "got: {err}"
        );
        // a merely-huge (non-overflowing) shape must also fail cleanly:
        // declared payload far exceeds the file
        let mut raw2: Vec<u8> = Vec::new();
        raw2.extend_from_slice(b"QTZ1");
        raw2.extend_from_slice(&1u32.to_le_bytes());
        raw2.extend_from_slice(&1u16.to_le_bytes());
        raw2.push(b'x');
        raw2.push(2); // dtype u8
        raw2.push(1); // ndim
        raw2.extend_from_slice(&(1u32 << 30).to_le_bytes());
        raw2.extend_from_slice(&[0u8; 8]); // only 8 payload bytes present
        let err2 = parse_qtz(&raw2, Path::new("t.qtz")).unwrap_err().to_string();
        assert!(err2.contains("truncated"), "got: {err2}");
    }
}
