//! Runtime-dispatched i8 GEMM micro-kernels over ahead-of-time packed
//! weights — the serving engine's hot loop.
//!
//! Two kernel shapes cover the integer engine:
//!
//! * **conv** ([`gemm_conv_packed_into`]): `C[m,n] = A_i8[m,k] · B_u8[k,n]`
//!   with A = packed weights and B = im2col columns. Vectorized over the
//!   position axis `n` with the weight pair broadcast, two output rows per
//!   register tile.
//! * **dense** ([`gemm_dense_packed_into`]): `C[m,n] = A_u8[m,k] · W^T`
//!   with W = packed weight rows. Vectorized over the reduction axis `k`,
//!   four weight rows sharing one streaming pass of the activation row.
//!
//! The AVX2 path is built on `vpmaddwd` (`_mm256_madd_epi16`) after
//! explicit u8→i16 / i8→i16 widening. Every 16-bit product of a u8
//! activation and an i8 weight fits i16 (|255·−128| = 32640), and each
//! `vpmaddwd` pair-sum fits i32, so — unlike the classic `vpmaddubsw`
//! trick, which saturates at i16 — **every intermediate is exact**. i32
//! accumulation then wraps mod 2³², under which addition is associative
//! and commutative, so any blocking/vector width/ISA produces
//! bit-identical accumulators. That is the determinism contract: the
//! portable fallback mirrors the same K-blocking and is bit-for-bit equal
//! to the AVX2 path on every input (proved against the scalar reference
//! in `rust/tests/int8_kernels.rs`, including near-`i32::MIN` accumulator
//! edges), so `PALLAS_NO_SIMD=1` is a pure performance knob.
//!
//! Packing ([`PackedConv`], [`PackedDense`]) happens once at plan-compile
//! time ([`crate::serve::plan`]); the batcher's hot loop does zero
//! repacking. Layout invariants (zero padding, block alignment) are
//! re-checked by `debug_assert!`s in the serve kernels so a layout bug
//! fails loudly in tests instead of silently corrupting accumulators.
//!
//! ## Int4 (w4) variants
//!
//! [`PackedConv4`] / [`PackedDense4`] store weights as two's-complement
//! nibbles, two per byte (codes in `[-8, 7]`): byte `j` of a K-run holds
//! weight `2j` in the **low** nibble and weight `2j+1` in the **high**
//! nibble. The K-blocking is identical to the w8 layouts ([`CONV_KB`]
//! pairs map 1:1 onto nibble pairs; [`DENSE_KB`] weights become
//! `DENSE_KB/2` bytes per block), so the w4 GEMM cores are the existing
//! cores with a nibble→i8 unpack epilogue in front of the same
//! `vpmaddwd` feed: sign-extension is shift-left-then-arithmetic-
//! shift-right (`(b << 4) >> 4` for the low nibble, `b >> 4` for the
//! high), done on i16 lanes in the dense AVX2 path and scalar-side for
//! the broadcast conv pair. Every unpacked value is the exact i8 code,
//! so the exact-intermediate argument above applies unchanged and
//! w4 SIMD == w4 portable == scalar-on-unpacked-weights, bit for bit.

#![allow(clippy::needless_range_loop)]

use std::ops::Range;
use std::sync::OnceLock;

use super::{i4_hi, i4_lo, pack_i4};
use crate::util::parallel;

/// K blocking of the conv kernel: weights are consumed as `vpmaddwd`
/// pairs, so packed conv rows are zero-padded to a multiple of 2.
pub const CONV_KB: usize = 2;
/// K blocking of the dense kernel: one 128-bit load widened to 16×i16.
pub const DENSE_KB: usize = 16;
/// Dense register tile: weight rows interleaved (and zero-row padded) in
/// quads so four dot products share one activation stream.
pub const DENSE_NR: usize = 4;

fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

/// Which micro-kernel implementation to run. Selected once per process by
/// [`select`]; engines capture the choice at construction so every worker
/// thread of a forward uses the same implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// `vpmaddwd`-based x86_64 path (requires AVX2; the GEMM entry points
    /// demote it to [`Kernel::Portable`] on CPUs without it, so passing it
    /// is always safe).
    Avx2,
    /// Chunked scalar path with the identical blocking; compiles on every
    /// ISA and auto-vectorizes reasonably. Bit-identical to [`Kernel::Avx2`].
    Portable,
}

impl Kernel {
    /// Stable label used by `serve-bench` and the bench entry names.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Avx2 => "avx2",
            Kernel::Portable => "portable",
        }
    }
}

/// CPUID-level availability of the AVX2 path (ignores `PALLAS_NO_SIMD`).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// `PALLAS_NO_SIMD` contract: any non-empty value other than `0` disables
/// the SIMD path (so `PALLAS_NO_SIMD=1`, `=true`, `=yes` all work).
pub fn no_simd_requested(v: Option<&str>) -> bool {
    matches!(v.map(str::trim), Some(s) if !s.is_empty() && s != "0")
}

/// One uncached dispatch decision: `PALLAS_NO_SIMD` wins, then CPU
/// feature detection. Exposed for tests that exercise the env contract;
/// production paths go through the cached [`select`].
pub fn select_uncached() -> Kernel {
    if no_simd_requested(std::env::var("PALLAS_NO_SIMD").ok().as_deref()) {
        Kernel::Portable
    } else if avx2_available() {
        Kernel::Avx2
    } else {
        Kernel::Portable
    }
}

/// The process-wide kernel choice, detected once and cached.
pub fn select() -> Kernel {
    static K: OnceLock<Kernel> = OnceLock::new();
    *K.get_or_init(select_uncached)
}

/// Demote a requested kernel to one this CPU can actually run: the GEMM
/// entry points are safe functions, so a caller-supplied
/// [`Kernel::Avx2`] must never reach target-feature code on a machine
/// without AVX2 (that would be UB) — it falls back to the portable path,
/// which is bit-identical anyway.
fn usable(kern: Kernel) -> Kernel {
    match kern {
        Kernel::Avx2 if avx2_available() => Kernel::Avx2,
        _ => Kernel::Portable,
    }
}

// ---------------------------------------------------------------------------
// Packed weight layouts
// ---------------------------------------------------------------------------

/// Conv weights packed for [`gemm_conv_packed_into`]: row-major `[rows]`
/// rows of `kp` bytes each, where `kp` is `k` rounded up to [`CONV_KB`]
/// and the pad byte is zero. Rows stay contiguous (no row interleaving),
/// so a grouped conv can hand any `[r0, r1)` row range to the kernel by
/// plain slicing — the `par_grouped_rows_mut` fan-out cuts at group
/// boundaries exactly as before.
#[derive(Clone, Debug)]
pub struct PackedConv {
    pub rows: usize,
    /// logical reduction length (im2col patch size)
    pub k: usize,
    /// padded row stride in bytes (`k` rounded up to [`CONV_KB`])
    pub kp: usize,
    pub data: Vec<i8>,
}

impl PackedConv {
    pub fn pack(w: &[i8], rows: usize, k: usize) -> PackedConv {
        assert_eq!(w.len(), rows * k, "conv pack: {} weights for {rows}x{k}", w.len());
        let kp = round_up(k.max(1), CONV_KB);
        let mut data = vec![0i8; rows * kp];
        for r in 0..rows {
            data[r * kp..r * kp + k].copy_from_slice(&w[r * k..(r + 1) * k]);
        }
        PackedConv { rows, k, kp, data }
    }

    /// The packed bytes of rows `r.start..r.end` (group slicing).
    pub fn row_slice(&self, r: Range<usize>) -> &[i8] {
        &self.data[r.start * self.kp..r.end * self.kp]
    }

    /// Layout invariants: stride math and zeroed K padding. O(weights) —
    /// meant for `debug_assert!` at kernel entry, not the hot loop.
    pub fn layout_ok(&self) -> bool {
        self.kp == round_up(self.k.max(1), CONV_KB)
            && self.data.len() == self.rows * self.kp
            && (0..self.rows).all(|r| {
                self.data[r * self.kp + self.k..(r + 1) * self.kp].iter().all(|&z| z == 0)
            })
    }
}

/// Dense weights `[n, k]` packed for [`gemm_dense_packed_into`]:
/// row quads interleaved at [`DENSE_KB`] granularity. With
/// `nb = kp / DENSE_KB` blocks per row, the block for (quad `q`, k-block
/// `t`, lane `r`) lives at byte offset `((q·nb + t)·DENSE_NR + r)·DENSE_KB`
/// — i.e. the four rows of a quad alternate K-blocks, so the kernel's four
/// accumulators read one contiguous 64-byte span per k-step. `k` pads to
/// `kp` (zero bytes), `n` pads to `np` (all-zero rows).
#[derive(Clone, Debug)]
pub struct PackedDense {
    /// logical output count (rows of the original weight matrix)
    pub n: usize,
    /// logical reduction length
    pub k: usize,
    /// padded reduction length (multiple of [`DENSE_KB`])
    pub kp: usize,
    /// padded row count (multiple of [`DENSE_NR`])
    pub np: usize,
    pub data: Vec<i8>,
}

impl PackedDense {
    pub fn pack(w: &[i8], n: usize, k: usize) -> PackedDense {
        assert_eq!(w.len(), n * k, "dense pack: {} weights for {n}x{k}", w.len());
        let kp = round_up(k.max(1), DENSE_KB);
        let np = round_up(n.max(1), DENSE_NR);
        let nb = kp / DENSE_KB;
        let mut data = vec![0i8; np * kp];
        for j in 0..n {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for t in 0..nb {
                let k0 = t * DENSE_KB;
                if k0 >= k {
                    break;
                }
                let kend = k.min(k0 + DENSE_KB);
                let base = ((q * nb + t) * DENSE_NR + r) * DENSE_KB;
                data[base..base + (kend - k0)].copy_from_slice(&w[j * k + k0..j * k + kend]);
            }
        }
        PackedDense { n, k, kp, np, data }
    }

    /// Layout invariants: stride math, zeroed K padding of every real row
    /// and all-zero pad rows. O(weights); for `debug_assert!` use.
    pub fn layout_ok(&self) -> bool {
        let nb = self.kp / DENSE_KB;
        if self.kp != round_up(self.k.max(1), DENSE_KB)
            || self.np != round_up(self.n.max(1), DENSE_NR)
            || self.data.len() != self.np * self.kp
        {
            return false;
        }
        for j in 0..self.np {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for t in 0..nb {
                let base = ((q * nb + t) * DENSE_NR + r) * DENSE_KB;
                let blk = &self.data[base..base + DENSE_KB];
                for (tt, &z) in blk.iter().enumerate() {
                    let kk = t * DENSE_KB + tt;
                    if (j >= self.n || kk >= self.k) && z != 0 {
                        return false;
                    }
                }
            }
        }
        true
    }
}

/// Logical weight `kk` of a nibble-packed K-run (low nibble first).
#[inline]
fn nibble(bytes: &[u8], kk: usize) -> i8 {
    let b = bytes[kk / 2];
    if kk % 2 == 0 { i4_lo(b) } else { i4_hi(b) }
}

/// Conv weights nibble-packed for [`gemm_conv4_packed_into`]: the
/// [`PackedConv`] layout at half the bytes. Rows are zero-padded to `kp`
/// (a [`CONV_KB`] multiple, so every row is a whole number of bytes) and
/// stored as `kp/2` bytes each; pad nibbles are zero. Rows stay
/// contiguous, so grouped convs slice `[r0, r1)` exactly as in w8.
#[derive(Clone, Debug)]
pub struct PackedConv4 {
    pub rows: usize,
    /// logical reduction length (im2col patch size)
    pub k: usize,
    /// padded logical row length (`k` rounded up to [`CONV_KB`]); the
    /// byte stride per row is `kp / 2`
    pub kp: usize,
    pub data: Vec<u8>,
}

impl PackedConv4 {
    /// Packs codes that must already fit `[-8, 7]` (panics otherwise —
    /// the plan compiler checks range before choosing the w4 layout).
    pub fn pack(w: &[i8], rows: usize, k: usize) -> PackedConv4 {
        assert_eq!(w.len(), rows * k, "conv4 pack: {} weights for {rows}x{k}", w.len());
        let kp = round_up(k.max(1), CONV_KB);
        let mut row = vec![0i8; kp];
        let mut data = Vec::with_capacity(rows * kp / 2);
        for r in 0..rows {
            row[..k].copy_from_slice(&w[r * k..(r + 1) * k]);
            data.extend_from_slice(&pack_i4(&row));
        }
        PackedConv4 { rows, k, kp, data }
    }

    /// The packed bytes of rows `r.start..r.end` (group slicing).
    pub fn row_slice(&self, r: Range<usize>) -> &[u8] {
        let stride = self.kp / 2;
        &self.data[r.start * stride..r.end * stride]
    }

    /// Layout invariants: stride math and zeroed pad nibbles. O(weights);
    /// for `debug_assert!` at kernel entry.
    pub fn layout_ok(&self) -> bool {
        let stride = self.kp / 2;
        self.kp == round_up(self.k.max(1), CONV_KB)
            && self.data.len() == self.rows * stride
            && (0..self.rows).all(|r| {
                let row = &self.data[r * stride..(r + 1) * stride];
                (self.k..self.kp).all(|kk| nibble(row, kk) == 0)
            })
    }
}

/// Dense weights `[n, k]` nibble-packed for [`gemm_dense4_packed_into`]:
/// the [`PackedDense`] quad-interleave with each [`DENSE_KB`]-weight
/// block stored as `DENSE_KB/2` bytes, so the block for (quad `q`,
/// k-block `t`, lane `r`) lives at byte offset
/// `((q·nb + t)·DENSE_NR + r)·DENSE_KB/2`. Padding (K bytes and whole
/// pad rows) is zero nibbles, exactly as in w8.
#[derive(Clone, Debug)]
pub struct PackedDense4 {
    /// logical output count (rows of the original weight matrix)
    pub n: usize,
    /// logical reduction length
    pub k: usize,
    /// padded reduction length (multiple of [`DENSE_KB`])
    pub kp: usize,
    /// padded row count (multiple of [`DENSE_NR`])
    pub np: usize,
    pub data: Vec<u8>,
}

impl PackedDense4 {
    /// Packs codes that must already fit `[-8, 7]` (panics otherwise).
    pub fn pack(w: &[i8], n: usize, k: usize) -> PackedDense4 {
        assert_eq!(w.len(), n * k, "dense4 pack: {} weights for {n}x{k}", w.len());
        let kp = round_up(k.max(1), DENSE_KB);
        let np = round_up(n.max(1), DENSE_NR);
        let nb = kp / DENSE_KB;
        let mut blk = [0i8; DENSE_KB];
        let mut data = vec![0u8; np * kp / 2];
        for j in 0..n {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for t in 0..nb {
                let k0 = t * DENSE_KB;
                if k0 >= k {
                    break;
                }
                let kend = k.min(k0 + DENSE_KB);
                blk.fill(0);
                blk[..kend - k0].copy_from_slice(&w[j * k + k0..j * k + kend]);
                let base = ((q * nb + t) * DENSE_NR + r) * (DENSE_KB / 2);
                data[base..base + DENSE_KB / 2].copy_from_slice(&pack_i4(&blk));
            }
        }
        PackedDense4 { n, k, kp, np, data }
    }

    /// Layout invariants: stride math, zeroed pad nibbles of every real
    /// row and all-zero pad rows. O(weights); for `debug_assert!` use.
    pub fn layout_ok(&self) -> bool {
        let nb = self.kp / DENSE_KB;
        if self.kp != round_up(self.k.max(1), DENSE_KB)
            || self.np != round_up(self.n.max(1), DENSE_NR)
            || self.data.len() != self.np * self.kp / 2
        {
            return false;
        }
        for j in 0..self.np {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for t in 0..nb {
                let base = ((q * nb + t) * DENSE_NR + r) * (DENSE_KB / 2);
                let blk = &self.data[base..base + DENSE_KB / 2];
                for tt in 0..DENSE_KB {
                    let kk = t * DENSE_KB + tt;
                    if (j >= self.n || kk >= self.k) && nibble(blk, tt) != 0 {
                        return false;
                    }
                }
            }
        }
        true
    }
}

// ---------------------------------------------------------------------------
// GEMM entry points (parallel over output rows, overwrite semantics)
// ---------------------------------------------------------------------------

/// `C[m,n] = A · B` for packed conv weights `a` (`m` rows of `kp` bytes,
/// logical reduction `k`), u8 im2col block `b` (`[k, n]` row-major) and
/// i32 output `c` (`[m, n]`, overwritten). Row-parallel over the worker
/// pool with the same grain as the scalar GEMM; inside a pool worker the
/// nested call runs serially, so the grouped-conv fan-out keeps its
/// existing split.
#[allow(clippy::too_many_arguments)]
pub fn gemm_conv_packed_into(
    kern: Kernel,
    a: &[i8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
) {
    debug_assert!(k >= 1, "conv GEMM needs a nonempty reduction");
    debug_assert_eq!(a.len(), m * kp, "packed A length");
    debug_assert_eq!(kp, round_up(k.max(1), CONV_KB), "conv K padding");
    debug_assert_eq!(b.len(), k * n, "B shape");
    debug_assert_eq!(c.len(), m * n, "C shape");
    if m == 0 || n == 0 {
        return;
    }
    let kern = usable(kern);
    parallel::par_ranges_mut(c, n, super::row_grain(k, n), |rows, span| {
        let aspan = &a[rows.start * kp..rows.end * kp];
        match kern {
            Kernel::Avx2 => {
                // SAFETY: usable() only lets Avx2 through when the CPU
                // has it, so the target feature is present.
                #[cfg(target_arch = "x86_64")]
                unsafe {
                    conv_span_avx2(aspan, rows.end - rows.start, k, kp, b, span, n);
                }
                #[cfg(not(target_arch = "x86_64"))]
                conv_span_portable(aspan, rows.end - rows.start, k, kp, b, span, n);
            }
            Kernel::Portable => conv_span_portable(aspan, rows.end - rows.start, k, kp, b, span, n),
        }
    });
}

/// `C[m,n] = A · W^T` for u8 activations `a` (`[m, k]` row-major), packed
/// dense weights `w` (`n = w.n` outputs) and i32 output `c` (`[m, w.n]`,
/// overwritten). Row-parallel over images.
pub fn gemm_dense_packed_into(kern: Kernel, a: &[u8], w: &PackedDense, c: &mut [i32], m: usize) {
    let (k, nout) = (w.k, w.n);
    debug_assert_eq!(a.len(), m * k, "A shape");
    debug_assert_eq!(c.len(), m * nout, "C shape");
    if m == 0 || nout == 0 {
        return;
    }
    let kern = usable(kern);
    parallel::par_ranges_mut(c, nout, super::row_grain(k, nout), |rows, span| {
        for i in rows.clone() {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut span[(i - rows.start) * nout..(i - rows.start + 1) * nout];
            match kern {
                Kernel::Avx2 => {
                    // SAFETY: usable() only lets Avx2 through when the
                    // CPU has it.
                    #[cfg(target_arch = "x86_64")]
                    unsafe {
                        dense_row_avx2(arow, w, crow);
                    }
                    #[cfg(not(target_arch = "x86_64"))]
                    dense_row_portable(arow, w, crow);
                }
                Kernel::Portable => dense_row_portable(arow, w, crow),
            }
        }
    });
}

/// w4 conv GEMM: like [`gemm_conv_packed_into`], but `a` holds
/// nibble-packed rows of `kp/2` bytes ([`PackedConv4`] row slices). The
/// unpacked nibble is the exact i8 code, so the output is bit-identical
/// to the w8 GEMM over the same codes.
#[allow(clippy::too_many_arguments)]
pub fn gemm_conv4_packed_into(
    kern: Kernel,
    a: &[u8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
) {
    debug_assert!(k >= 1, "conv GEMM needs a nonempty reduction");
    debug_assert_eq!(a.len(), m * kp / 2, "packed4 A length");
    debug_assert_eq!(kp, round_up(k.max(1), CONV_KB), "conv K padding");
    debug_assert_eq!(b.len(), k * n, "B shape");
    debug_assert_eq!(c.len(), m * n, "C shape");
    if m == 0 || n == 0 {
        return;
    }
    let kern = usable(kern);
    let stride = kp / 2;
    parallel::par_ranges_mut(c, n, super::row_grain(k, n), |rows, span| {
        let aspan = &a[rows.start * stride..rows.end * stride];
        match kern {
            Kernel::Avx2 => {
                // SAFETY: usable() only lets Avx2 through when the CPU
                // has it, so the target feature is present.
                #[cfg(target_arch = "x86_64")]
                unsafe {
                    conv4_span_avx2(aspan, rows.end - rows.start, k, kp, b, span, n);
                }
                #[cfg(not(target_arch = "x86_64"))]
                conv4_span_portable(aspan, rows.end - rows.start, k, kp, b, span, n);
            }
            Kernel::Portable => {
                conv4_span_portable(aspan, rows.end - rows.start, k, kp, b, span, n)
            }
        }
    });
}

/// w4 dense GEMM: like [`gemm_dense_packed_into`] over a nibble-packed
/// quad layout. Bit-identical to the w8 GEMM over the same codes.
pub fn gemm_dense4_packed_into(kern: Kernel, a: &[u8], w: &PackedDense4, c: &mut [i32], m: usize) {
    let (k, nout) = (w.k, w.n);
    debug_assert_eq!(a.len(), m * k, "A shape");
    debug_assert_eq!(c.len(), m * nout, "C shape");
    if m == 0 || nout == 0 {
        return;
    }
    let kern = usable(kern);
    parallel::par_ranges_mut(c, nout, super::row_grain(k, nout), |rows, span| {
        for i in rows.clone() {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut span[(i - rows.start) * nout..(i - rows.start + 1) * nout];
            match kern {
                Kernel::Avx2 => {
                    // SAFETY: usable() only lets Avx2 through when the
                    // CPU has it.
                    #[cfg(target_arch = "x86_64")]
                    unsafe {
                        dense4_row_avx2(arow, w, crow);
                    }
                    #[cfg(not(target_arch = "x86_64"))]
                    dense4_row_portable(arow, w, crow);
                }
                Kernel::Portable => dense4_row_portable(arow, w, crow),
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Portable cores (the reference blocking; bit-identical to AVX2 because
// every product is exact and i32 accumulation commutes mod 2^32)
// ---------------------------------------------------------------------------

/// One row span of the conv GEMM: for each row, stream B row-by-row and
/// fan the broadcast weight into the i32 C row (the scalar GEMM's loop
/// order, which auto-vectorizes to widening multiply-adds).
fn conv_span_portable(a: &[i8], m: usize, k: usize, kp: usize, b: &[u8], c: &mut [i32], n: usize) {
    for i in 0..m {
        let arow = &a[i * kp..i * kp + k];
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0);
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv = cv.wrapping_add(av * bv as i32);
            }
        }
    }
}

/// One output row of the dense GEMM over the packed quad layout: walk the
/// interleaved K-blocks exactly as the AVX2 core does (weight padding is
/// zero, so only `kk < k` activation reads are needed).
fn dense_row_portable(arow: &[u8], w: &PackedDense, crow: &mut [i32]) {
    let (k, nb) = (w.k, w.kp / DENSE_KB);
    for (j, cv) in crow.iter_mut().enumerate() {
        let (q, r) = (j / DENSE_NR, j % DENSE_NR);
        let mut s = 0i32;
        for t in 0..nb {
            let base = ((q * nb + t) * DENSE_NR + r) * DENSE_KB;
            let blk = &w.data[base..base + DENSE_KB];
            let k0 = t * DENSE_KB;
            let kend = k.min(k0 + DENSE_KB);
            for kk in k0..kend {
                s = s.wrapping_add(arow[kk] as i32 * blk[kk - k0] as i32);
            }
        }
        *cv = s;
    }
}

/// One row span of the w4 conv GEMM: identical loop order to
/// [`conv_span_portable`], the weight decoded from its nibble on the fly.
fn conv4_span_portable(a: &[u8], m: usize, k: usize, kp: usize, b: &[u8], c: &mut [i32], n: usize) {
    let stride = kp / 2;
    for i in 0..m {
        let arow = &a[i * stride..(i + 1) * stride];
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0);
        for kk in 0..k {
            let av = nibble(arow, kk);
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv = cv.wrapping_add(av * bv as i32);
            }
        }
    }
}

/// One output row of the w4 dense GEMM: walks the nibble-packed quad
/// blocks with the same K-blocking as [`dense_row_portable`].
fn dense4_row_portable(arow: &[u8], w: &PackedDense4, crow: &mut [i32]) {
    let (k, nb) = (w.k, w.kp / DENSE_KB);
    for (j, cv) in crow.iter_mut().enumerate() {
        let (q, r) = (j / DENSE_NR, j % DENSE_NR);
        let mut s = 0i32;
        for t in 0..nb {
            let base = ((q * nb + t) * DENSE_NR + r) * (DENSE_KB / 2);
            let blk = &w.data[base..base + DENSE_KB / 2];
            let k0 = t * DENSE_KB;
            let kend = k.min(k0 + DENSE_KB);
            for kk in k0..kend {
                s = s.wrapping_add(arow[kk] as i32 * nibble(blk, kk - k0) as i32);
            }
        }
        *cv = s;
    }
}

// ---------------------------------------------------------------------------
// AVX2 cores
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    use super::{i4_hi, i4_lo, nibble, PackedDense, PackedDense4, DENSE_KB, DENSE_NR};

    /// Broadcast the (sign-extended) weight pair at `a[off], a[off+1]` as
    /// `[a0, a1, a0, a1, ...]` i16 lanes — the second `vpmaddwd` operand.
    /// The packed row stride is even, so `off + 1` is always in bounds
    /// (the pad byte is zero).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn weight_pair(a: &[i8], off: usize) -> __m256i {
        let a0 = *a.get_unchecked(off) as i16 as u16 as u32;
        let a1 = *a.get_unchecked(off + 1) as i16 as u16 as u32;
        _mm256_set1_epi32(((a1 << 16) | a0) as i32)
    }

    /// Conv GEMM row span: 2 output rows × 32 positions per register
    /// tile, reduction consumed as `vpmaddwd` pairs. B rows `k0`/`k0+1`
    /// are byte-interleaved in registers (`vpunpck[lh]bw`), widened to
    /// i16 and paired against the broadcast weights — all products exact,
    /// see the module docs.
    #[target_feature(enable = "avx2")]
    pub unsafe fn conv_span(
        a: &[i8],
        m: usize,
        k: usize,
        kp: usize,
        b: &[u8],
        c: &mut [i32],
        n: usize,
    ) {
        let n32 = n - n % 32;
        let kpairs = kp / 2;
        let bp = b.as_ptr();
        let mut i = 0;
        while i < m {
            let mr = if m - i >= 2 { 2 } else { 1 };
            let mut j = 0;
            while j < n32 {
                let mut acc = [[_mm256_setzero_si256(); 4]; 2];
                for t in 0..kpairs {
                    let k0 = 2 * t;
                    // the pad pair of an odd K clamps its B row index;
                    // its weight lane is the zero pad byte, so the
                    // duplicated row contributes nothing
                    let k1 = (k0 + 1).min(k - 1);
                    let b0 = _mm256_loadu_si256(bp.add(k0 * n + j) as *const __m256i);
                    let b1 = _mm256_loadu_si256(bp.add(k1 * n + j) as *const __m256i);
                    let lo = _mm256_unpacklo_epi8(b0, b1);
                    let hi = _mm256_unpackhi_epi8(b0, b1);
                    // pair-interleaved positions: lo/hi 128-bit lanes hold
                    // j+0..7, j+8..15, j+16..23, j+24..31 in that order
                    let w0 = _mm256_cvtepu8_epi16(_mm256_castsi256_si128(lo));
                    let w1 = _mm256_cvtepu8_epi16(_mm256_castsi256_si128(hi));
                    let w2 = _mm256_cvtepu8_epi16(_mm256_extracti128_si256(lo, 1));
                    let w3 = _mm256_cvtepu8_epi16(_mm256_extracti128_si256(hi, 1));
                    for r in 0..mr {
                        let ap = weight_pair(a, (i + r) * kp + k0);
                        acc[r][0] = _mm256_add_epi32(acc[r][0], _mm256_madd_epi16(w0, ap));
                        acc[r][1] = _mm256_add_epi32(acc[r][1], _mm256_madd_epi16(w1, ap));
                        acc[r][2] = _mm256_add_epi32(acc[r][2], _mm256_madd_epi16(w2, ap));
                        acc[r][3] = _mm256_add_epi32(acc[r][3], _mm256_madd_epi16(w3, ap));
                    }
                }
                for r in 0..mr {
                    let crow = c.as_mut_ptr().add((i + r) * n + j);
                    _mm256_storeu_si256(crow as *mut __m256i, acc[r][0]);
                    _mm256_storeu_si256(crow.add(8) as *mut __m256i, acc[r][1]);
                    _mm256_storeu_si256(crow.add(16) as *mut __m256i, acc[r][2]);
                    _mm256_storeu_si256(crow.add(24) as *mut __m256i, acc[r][3]);
                }
                j += 32;
            }
            // position tail: exact scalar (integer products commute with
            // the vector body, so the seam is bit-invisible)
            for r in 0..mr {
                let arow = &a[(i + r) * kp..(i + r) * kp + k];
                for jj in n32..n {
                    let mut s = 0i32;
                    for (kk, &av) in arow.iter().enumerate() {
                        s = s.wrapping_add(av as i32 * *b.get_unchecked(kk * n + jj) as i32);
                    }
                    *c.get_unchecked_mut((i + r) * n + jj) = s;
                }
            }
            i += mr;
        }
    }

    /// Broadcast the sign-extended nibble pair in byte `a[off]` as
    /// `[lo, hi, lo, hi, ...]` i16 lanes. One packed byte *is* one
    /// `vpmaddwd` weight pair (CONV_KB == 2 nibbles), so the w4 conv
    /// core is the w8 core with this decode in front.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn weight_pair4(a: &[u8], off: usize) -> __m256i {
        let b = *a.get_unchecked(off);
        let a0 = i4_lo(b) as i16 as u16 as u32;
        let a1 = i4_hi(b) as i16 as u16 as u32;
        _mm256_set1_epi32(((a1 << 16) | a0) as i32)
    }

    /// w4 conv GEMM row span: the [`conv_span`] register tile (2 rows ×
    /// 32 positions, `vpmaddwd` pairs) with the weight pair decoded from
    /// one packed byte. Same blocking, exact products — bit-identical.
    #[target_feature(enable = "avx2")]
    pub unsafe fn conv4_span(
        a: &[u8],
        m: usize,
        k: usize,
        kp: usize,
        b: &[u8],
        c: &mut [i32],
        n: usize,
    ) {
        let n32 = n - n % 32;
        let kpairs = kp / 2; // also the byte stride per packed row
        let bp = b.as_ptr();
        let mut i = 0;
        while i < m {
            let mr = if m - i >= 2 { 2 } else { 1 };
            let mut j = 0;
            while j < n32 {
                let mut acc = [[_mm256_setzero_si256(); 4]; 2];
                for t in 0..kpairs {
                    let k0 = 2 * t;
                    // odd-K pad pair: clamp the B row; the pad nibble is
                    // zero, so the duplicated row contributes nothing
                    let k1 = (k0 + 1).min(k - 1);
                    let b0 = _mm256_loadu_si256(bp.add(k0 * n + j) as *const __m256i);
                    let b1 = _mm256_loadu_si256(bp.add(k1 * n + j) as *const __m256i);
                    let lo = _mm256_unpacklo_epi8(b0, b1);
                    let hi = _mm256_unpackhi_epi8(b0, b1);
                    let w0 = _mm256_cvtepu8_epi16(_mm256_castsi256_si128(lo));
                    let w1 = _mm256_cvtepu8_epi16(_mm256_castsi256_si128(hi));
                    let w2 = _mm256_cvtepu8_epi16(_mm256_extracti128_si256(lo, 1));
                    let w3 = _mm256_cvtepu8_epi16(_mm256_extracti128_si256(hi, 1));
                    for r in 0..mr {
                        let ap = weight_pair4(a, (i + r) * kpairs + t);
                        acc[r][0] = _mm256_add_epi32(acc[r][0], _mm256_madd_epi16(w0, ap));
                        acc[r][1] = _mm256_add_epi32(acc[r][1], _mm256_madd_epi16(w1, ap));
                        acc[r][2] = _mm256_add_epi32(acc[r][2], _mm256_madd_epi16(w2, ap));
                        acc[r][3] = _mm256_add_epi32(acc[r][3], _mm256_madd_epi16(w3, ap));
                    }
                }
                for r in 0..mr {
                    let crow = c.as_mut_ptr().add((i + r) * n + j);
                    _mm256_storeu_si256(crow as *mut __m256i, acc[r][0]);
                    _mm256_storeu_si256(crow.add(8) as *mut __m256i, acc[r][1]);
                    _mm256_storeu_si256(crow.add(16) as *mut __m256i, acc[r][2]);
                    _mm256_storeu_si256(crow.add(24) as *mut __m256i, acc[r][3]);
                }
                j += 32;
            }
            // position tail: exact scalar over decoded nibbles
            for r in 0..mr {
                let arow = &a[(i + r) * kpairs..(i + r + 1) * kpairs];
                for jj in n32..n {
                    let mut s = 0i32;
                    for kk in 0..k {
                        s = s.wrapping_add(
                            nibble(arow, kk) as i32 * *b.get_unchecked(kk * n + jj) as i32,
                        );
                    }
                    *c.get_unchecked_mut((i + r) * n + jj) = s;
                }
            }
            i += mr;
        }
    }

    /// Wrapping horizontal sum of the 8 i32 lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256(v, 1));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
        _mm_cvtsi128_si32(s)
    }

    /// Dense GEMM, one activation row: four packed weight rows per quad
    /// share each widened 16-byte activation block; the K tail reads a
    /// zero-padded stack copy (matching the zero K padding of the packed
    /// rows, so tail products vanish on both operands).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dense_row(arow: &[u8], w: &PackedDense, crow: &mut [i32]) {
        let (k, kp) = (w.k, w.kp);
        let nb = kp / DENSE_KB;
        let full = k / DENSE_KB;
        let tail = k % DENSE_KB;
        let mut tailbuf = [0u8; DENSE_KB];
        if tail > 0 {
            tailbuf[..tail].copy_from_slice(&arow[full * DENSE_KB..]);
        }
        let wp = w.data.as_ptr();
        for q in 0..w.np / DENSE_NR {
            let mut acc = [_mm256_setzero_si256(); 4];
            let base = q * nb * (DENSE_NR * DENSE_KB);
            for t in 0..nb {
                let av = if t < full {
                    _mm_loadu_si128(arow.as_ptr().add(t * DENSE_KB) as *const __m128i)
                } else {
                    _mm_loadu_si128(tailbuf.as_ptr() as *const __m128i)
                };
                let a16 = _mm256_cvtepu8_epi16(av);
                let blk = wp.add(base + t * DENSE_NR * DENSE_KB);
                for r in 0..4 {
                    let w16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                        blk.add(r * DENSE_KB) as *const __m128i
                    ));
                    acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(a16, w16));
                }
            }
            for r in 0..4 {
                let j = q * DENSE_NR + r;
                if j < crow.len() {
                    *crow.get_unchecked_mut(j) = hsum_epi32(acc[r]);
                }
            }
        }
    }

    /// The nibble→i8 unpack epilogue: 8 packed bytes → 16 sign-extended
    /// i16 weight lanes in logical order, ready for `vpmaddwd`. Each
    /// byte is duplicated (`vpunpcklbw x,x`), widened to 16-bit lanes,
    /// the target nibble is shifted to the top four bits (`vpmullw` by
    /// alternating `1<<12` / `1<<8` — a per-lane left shift mod 2¹⁶),
    /// and an arithmetic right shift by 12 sign-extends it: the
    /// shift-left-then-arithmetic-shift-right idiom on the madd lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn nibbles_to_i16(p: *const u8) -> __m256i {
        let x = _mm_loadl_epi64(p as *const __m128i);
        let dup = _mm_unpacklo_epi8(x, x);
        let v = _mm256_cvtepu8_epi16(dup);
        // even i16 lanes (low nibbles) multiply by 1<<12, odd lanes
        // (high nibbles) by 1<<8
        let mul = _mm256_set1_epi32(((1 << 8) << 16) | (1 << 12));
        _mm256_srai_epi16(_mm256_mullo_epi16(v, mul), 12)
    }

    /// w4 dense GEMM, one activation row: [`dense_row`] with each
    /// 16-weight block decoded from 8 packed bytes by [`nibbles_to_i16`].
    /// Block loads are exact (`DENSE_KB/2` = 8 bytes per block, blocks
    /// contiguous), so there is no overread.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dense4_row(arow: &[u8], w: &PackedDense4, crow: &mut [i32]) {
        const KB2: usize = DENSE_KB / 2;
        let (k, kp) = (w.k, w.kp);
        let nb = kp / DENSE_KB;
        let full = k / DENSE_KB;
        let tail = k % DENSE_KB;
        let mut tailbuf = [0u8; DENSE_KB];
        if tail > 0 {
            tailbuf[..tail].copy_from_slice(&arow[full * DENSE_KB..]);
        }
        let wp = w.data.as_ptr();
        for q in 0..w.np / DENSE_NR {
            let mut acc = [_mm256_setzero_si256(); 4];
            let base = q * nb * (DENSE_NR * KB2);
            for t in 0..nb {
                let av = if t < full {
                    _mm_loadu_si128(arow.as_ptr().add(t * DENSE_KB) as *const __m128i)
                } else {
                    _mm_loadu_si128(tailbuf.as_ptr() as *const __m128i)
                };
                let a16 = _mm256_cvtepu8_epi16(av);
                let blk = wp.add(base + t * DENSE_NR * KB2);
                for r in 0..4 {
                    let w16 = nibbles_to_i16(blk.add(r * KB2));
                    acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(a16, w16));
                }
            }
            for r in 0..4 {
                let j = q * DENSE_NR + r;
                if j < crow.len() {
                    *crow.get_unchecked_mut(j) = hsum_epi32(acc[r]);
                }
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
use avx2::{
    conv4_span as conv4_span_avx2, conv_span as conv_span_avx2, dense4_row as dense4_row_avx2,
    dense_row as dense_row_avx2,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_simd_env_contract() {
        assert!(!no_simd_requested(None));
        assert!(!no_simd_requested(Some("")));
        assert!(!no_simd_requested(Some("0")));
        assert!(!no_simd_requested(Some(" 0 ")));
        assert!(no_simd_requested(Some("1")));
        assert!(no_simd_requested(Some("true")));
        assert!(no_simd_requested(Some("yes")));
    }

    #[test]
    fn select_is_consistent_with_detection() {
        let k = select();
        if k == Kernel::Avx2 {
            assert!(avx2_available(), "selected AVX2 without CPU support");
        }
        assert_eq!(k, select(), "cached selection must be stable");
    }

    #[test]
    fn conv_pack_layout() {
        let w: Vec<i8> = (0..3 * 5).map(|v| v as i8 - 7).collect();
        let p = PackedConv::pack(&w, 3, 5);
        assert_eq!((p.rows, p.k, p.kp), (3, 5, 6));
        assert!(p.layout_ok());
        for r in 0..3 {
            assert_eq!(&p.data[r * 6..r * 6 + 5], &w[r * 5..(r + 1) * 5]);
            assert_eq!(p.data[r * 6 + 5], 0, "pad byte of row {r}");
        }
        assert_eq!(p.row_slice(1..3).len(), 2 * 6);
        // even K needs no padding
        let q = PackedConv::pack(&w[..12], 3, 4);
        assert_eq!(q.kp, 4);
        assert!(q.layout_ok());
        // a corrupted pad byte must fail the invariant
        let mut bad = p.clone();
        bad.data[5] = 1;
        assert!(!bad.layout_ok());
    }

    #[test]
    fn conv4_pack_layout() {
        // odd K exercises the pad nibble
        let w: Vec<i8> = (0..3 * 5).map(|v| (v % 16 - 8) as i8).collect();
        let p = PackedConv4::pack(&w, 3, 5);
        assert_eq!((p.rows, p.k, p.kp), (3, 5, 6));
        assert_eq!(p.data.len(), 3 * 3);
        assert!(p.layout_ok());
        for r in 0..3 {
            let row = p.row_slice(r..r + 1);
            for kk in 0..5 {
                assert_eq!(nibble(row, kk), w[r * 5 + kk], "row {r} k {kk}");
            }
            assert_eq!(nibble(row, 5), 0, "pad nibble of row {r}");
        }
        // a corrupted pad nibble (high nibble of row 0's last byte) must
        // fail the invariant
        let mut bad = p;
        bad.data[2] |= 0xF0;
        assert!(!bad.layout_ok());
    }

    #[test]
    fn dense4_pack_layout_roundtrip() {
        // n and k both off the block sizes: 6 rows (np 8), k 21 (kp 32)
        let (n, k) = (6usize, 21usize);
        let w: Vec<i8> = (0..n * k).map(|v| (v % 16 - 8) as i8).collect();
        let p = PackedDense4::pack(&w, n, k);
        assert_eq!((p.np, p.kp), (8, 32));
        assert_eq!(p.data.len(), 8 * 32 / 2);
        assert!(p.layout_ok());
        let nb = p.kp / DENSE_KB;
        // every logical weight must be recoverable from the quad layout
        for j in 0..n {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for kk in 0..k {
                let (t, tt) = (kk / DENSE_KB, kk % DENSE_KB);
                let base = ((q * nb + t) * DENSE_NR + r) * (DENSE_KB / 2);
                let got = nibble(&p.data[base..base + DENSE_KB / 2], tt);
                assert_eq!(got, w[j * k + kk], "row {j} k {kk}");
            }
        }
        // a corrupted pad row must fail the invariant (row 6 is padding)
        let mut bad = p;
        let (q, r) = (6 / DENSE_NR, 6 % DENSE_NR);
        bad.data[((q * nb) * DENSE_NR + r) * (DENSE_KB / 2)] = 3;
        assert!(!bad.layout_ok());
    }

    #[test]
    fn w4_gemms_match_w8_over_same_codes() {
        // identical codes through the w8 and w4 paths must agree exactly
        let mut state = 12345u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u8
        };
        let (m, k, n) = (5usize, 27usize, 37usize);
        let w: Vec<i8> = (0..m * k).map(|_| (next() % 16) as i8 - 8).collect();
        let b: Vec<u8> = (0..k * n).map(|_| next()).collect();
        let p8 = PackedConv::pack(&w, m, k);
        let p4 = PackedConv4::pack(&w, m, k);
        let mut c8 = vec![0i32; m * n];
        let mut c4 = vec![0i32; m * n];
        gemm_conv_packed_into(Kernel::Portable, &p8.data, m, k, p8.kp, &b, &mut c8, n);
        gemm_conv4_packed_into(Kernel::Portable, &p4.data, m, k, p4.kp, &b, &mut c4, n);
        assert_eq!(c8, c4, "conv w4 != w8");

        let (mm, kk, nn) = (3usize, 21usize, 6usize);
        let wd: Vec<i8> = (0..nn * kk).map(|_| (next() % 16) as i8 - 8).collect();
        let a: Vec<u8> = (0..mm * kk).map(|_| next()).collect();
        let d8 = PackedDense::pack(&wd, nn, kk);
        let d4 = PackedDense4::pack(&wd, nn, kk);
        let mut c8 = vec![0i32; mm * nn];
        let mut c4 = vec![0i32; mm * nn];
        gemm_dense_packed_into(Kernel::Portable, &a, &d8, &mut c8, mm);
        gemm_dense4_packed_into(Kernel::Portable, &a, &d4, &mut c4, mm);
        assert_eq!(c8, c4, "dense w4 != w8");
    }

    #[test]
    fn dense_pack_layout_roundtrip() {
        // n and k both off the block sizes: 6 rows (np 8), k 21 (kp 32)
        let (n, k) = (6usize, 21usize);
        let w: Vec<i8> = (0..n * k).map(|v| (v as i32 % 251 - 125) as i8).collect();
        let p = PackedDense::pack(&w, n, k);
        assert_eq!((p.np, p.kp), (8, 32));
        assert!(p.layout_ok());
        let nb = p.kp / DENSE_KB;
        // every logical weight must be recoverable from the quad layout
        for j in 0..n {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for kk in 0..k {
                let (t, tt) = (kk / DENSE_KB, kk % DENSE_KB);
                let byte = p.data[((q * nb + t) * DENSE_NR + r) * DENSE_KB + tt];
                assert_eq!(byte, w[j * k + kk], "row {j} k {kk}");
            }
        }
        // a corrupted pad row must fail the invariant (row 6 is padding)
        let mut bad = p.clone();
        let (q, r) = (6 / DENSE_NR, 6 % DENSE_NR);
        bad.data[((q * nb) * DENSE_NR + r) * DENSE_KB] = 3;
        assert!(!bad.layout_ok());
    }
}
