//! AVX-512 VNNI cores: `vpdpwssd` (`_mm512_dpwssd_epi32`) over
//! explicitly widened i16 operands, 64 positions (conv) / 32 reduction
//! lanes (dense) per register pass.
//!
//! Exactness: `vpdpwssd` multiplies signed 16-bit lanes into exact i32
//! products, sums adjacent pairs, and accumulates into i32 **without
//! saturation** — the same pair-sum `vpmaddwd` computes, fused with the
//! accumulate. Operands are the identical u8→i16 / i8→i16 widenings the
//! AVX2 path feeds `vpmaddwd` (|255·−128| = 32640 fits i16; a pair-sum
//! fits i32), so every intermediate is exact and the i32 accumulator
//! wraps mod 2³² exactly like every other variant. The saturating
//! `vpdpwssds` form is never used. Bit-identical by the module-docs
//! argument; proved against scalar in `rust/tests/int8_kernels.rs`.
//!
//! This module only compiles when `build.rs` emitted `pallas_avx512`
//! (rustc ≥ 1.89, where the AVX-512 intrinsics are stable); the dispatch
//! layer additionally requires F/BW/VNNI at runtime
//! (`avx512_available`).
//!
//! Blocking configs mirror AVX2: conv `c0` = 2-row tile, `c1` = 1-row;
//! dense `c0` = one accumulator quartet, `c1` = two interleaved quartets
//! folded at the end.

#![allow(clippy::too_many_arguments)]

use core::arch::x86_64::*;

use super::{i4_hi, i4_lo, nibble, PackedDense, PackedDense4, DENSE_KB, DENSE_NR};

/// Broadcast the (sign-extended) weight pair at `a[off], a[off+1]` as
/// `[a0, a1, a0, a1, ...]` i16 lanes — the second `vpdpwssd` operand.
/// The packed row stride is even, so `off + 1` is always in bounds
/// (the pad byte is zero).
#[inline]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn weight_pair(a: &[i8], off: usize) -> __m512i {
    let a0 = *a.get_unchecked(off) as i16 as u16 as u32;
    let a1 = *a.get_unchecked(off + 1) as i16 as u16 as u32;
    _mm512_set1_epi32(((a1 << 16) | a0) as i32)
}

/// Broadcast the sign-extended nibble pair in byte `a[off]` — one packed
/// byte is one weight pair, exactly as in the AVX2 core.
#[inline]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn weight_pair4(a: &[u8], off: usize) -> __m512i {
    let b = *a.get_unchecked(off);
    let a0 = i4_lo(b) as i16 as u16 as u32;
    let a1 = i4_hi(b) as i16 as u16 as u32;
    _mm512_set1_epi32(((a1 << 16) | a0) as i32)
}

/// Store the two 256-bit halves of one accumulator at two (possibly
/// non-adjacent) C offsets — the 512-bit byte interleave works per
/// 128-bit lane, so each accumulator holds two position octets 16 apart.
#[inline]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn store_halves(acc: __m512i, p0: *mut i32, p1: *mut i32) {
    _mm256_storeu_si256(p0 as *mut __m256i, _mm512_castsi512_si256(acc));
    _mm256_storeu_si256(p1 as *mut __m256i, _mm512_extracti64x4_epi64(acc, 1));
}

/// Conv GEMM row span: `tile` output rows × 64 positions per register
/// pass. B rows `k0`/`k0+1` are byte-interleaved (`vpunpck[lh]bw`, which
/// interleaves within each 128-bit lane), widened to i16 and fed to
/// `vpdpwssd` against the broadcast weight pair. The per-lane interleave
/// means accumulator `q` holds positions `j+8q..j+8q+8` and
/// `j+8q+16·…` — see [`store_halves`] and the offsets below.
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn conv_span(
    a: &[i8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
    cfg: u8,
) {
    let tile = if cfg == 0 { 2 } else { 1 };
    let n64 = n - n % 64;
    let kpairs = kp / 2;
    let bp = b.as_ptr();
    let mut i = 0;
    while i < m {
        let mr = (m - i).min(tile);
        let mut j = 0;
        while j < n64 {
            let mut acc = [[_mm512_setzero_si512(); 4]; 2];
            for t in 0..kpairs {
                let k0 = 2 * t;
                // odd-K pad pair: clamp the B row; the weight lane is the
                // zero pad byte, so the duplicated row contributes nothing
                let k1 = (k0 + 1).min(k - 1);
                let b0 = _mm512_loadu_si512(bp.add(k0 * n + j) as *const _);
                let b1 = _mm512_loadu_si512(bp.add(k1 * n + j) as *const _);
                let lo = _mm512_unpacklo_epi8(b0, b1);
                let hi = _mm512_unpackhi_epi8(b0, b1);
                // 256-bit quarters of the interleave, each widened to 32
                // i16 lanes (16 position pairs): w0 = positions j+0..8 and
                // j+16..24, w1 = j+8.. and j+24.., w2 = j+32.. and j+48..,
                // w3 = j+40.. and j+56..
                let w0 = _mm512_cvtepu8_epi16(_mm512_castsi512_si256(lo));
                let w1 = _mm512_cvtepu8_epi16(_mm512_castsi512_si256(hi));
                let w2 = _mm512_cvtepu8_epi16(_mm512_extracti64x4_epi64(lo, 1));
                let w3 = _mm512_cvtepu8_epi16(_mm512_extracti64x4_epi64(hi, 1));
                for r in 0..mr {
                    let ap = weight_pair(a, (i + r) * kp + k0);
                    acc[r][0] = _mm512_dpwssd_epi32(acc[r][0], w0, ap);
                    acc[r][1] = _mm512_dpwssd_epi32(acc[r][1], w1, ap);
                    acc[r][2] = _mm512_dpwssd_epi32(acc[r][2], w2, ap);
                    acc[r][3] = _mm512_dpwssd_epi32(acc[r][3], w3, ap);
                }
            }
            for r in 0..mr {
                let crow = c.as_mut_ptr().add((i + r) * n + j);
                store_halves(acc[r][0], crow, crow.add(16));
                store_halves(acc[r][1], crow.add(8), crow.add(24));
                store_halves(acc[r][2], crow.add(32), crow.add(48));
                store_halves(acc[r][3], crow.add(40), crow.add(56));
            }
            j += 64;
        }
        // position tail: exact scalar (integer products commute with the
        // vector body, so the seam is bit-invisible)
        for r in 0..mr {
            let arow = &a[(i + r) * kp..(i + r) * kp + k];
            for jj in n64..n {
                let mut s = 0i32;
                for (kk, &av) in arow.iter().enumerate() {
                    s = s.wrapping_add(av as i32 * *b.get_unchecked(kk * n + jj) as i32);
                }
                *c.get_unchecked_mut((i + r) * n + jj) = s;
            }
        }
        i += mr;
    }
}

/// w4 conv GEMM row span: [`conv_span`] with the weight pair decoded
/// from one packed byte. Same blocking, exact products — bit-identical.
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn conv4_span(
    a: &[u8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
    cfg: u8,
) {
    let tile = if cfg == 0 { 2 } else { 1 };
    let n64 = n - n % 64;
    let kpairs = kp / 2; // also the byte stride per packed row
    let bp = b.as_ptr();
    let mut i = 0;
    while i < m {
        let mr = (m - i).min(tile);
        let mut j = 0;
        while j < n64 {
            let mut acc = [[_mm512_setzero_si512(); 4]; 2];
            for t in 0..kpairs {
                let k0 = 2 * t;
                let k1 = (k0 + 1).min(k - 1);
                let b0 = _mm512_loadu_si512(bp.add(k0 * n + j) as *const _);
                let b1 = _mm512_loadu_si512(bp.add(k1 * n + j) as *const _);
                let lo = _mm512_unpacklo_epi8(b0, b1);
                let hi = _mm512_unpackhi_epi8(b0, b1);
                let w0 = _mm512_cvtepu8_epi16(_mm512_castsi512_si256(lo));
                let w1 = _mm512_cvtepu8_epi16(_mm512_castsi512_si256(hi));
                let w2 = _mm512_cvtepu8_epi16(_mm512_extracti64x4_epi64(lo, 1));
                let w3 = _mm512_cvtepu8_epi16(_mm512_extracti64x4_epi64(hi, 1));
                for r in 0..mr {
                    let ap = weight_pair4(a, (i + r) * kpairs + t);
                    acc[r][0] = _mm512_dpwssd_epi32(acc[r][0], w0, ap);
                    acc[r][1] = _mm512_dpwssd_epi32(acc[r][1], w1, ap);
                    acc[r][2] = _mm512_dpwssd_epi32(acc[r][2], w2, ap);
                    acc[r][3] = _mm512_dpwssd_epi32(acc[r][3], w3, ap);
                }
            }
            for r in 0..mr {
                let crow = c.as_mut_ptr().add((i + r) * n + j);
                store_halves(acc[r][0], crow, crow.add(16));
                store_halves(acc[r][1], crow.add(8), crow.add(24));
                store_halves(acc[r][2], crow.add(32), crow.add(48));
                store_halves(acc[r][3], crow.add(40), crow.add(56));
            }
            j += 64;
        }
        // position tail: exact scalar over decoded nibbles
        for r in 0..mr {
            let arow = &a[(i + r) * kpairs..(i + r + 1) * kpairs];
            for jj in n64..n {
                let mut s = 0i32;
                for kk in 0..k {
                    s = s.wrapping_add(
                        nibble(arow, kk) as i32 * *b.get_unchecked(kk * n + jj) as i32,
                    );
                }
                *c.get_unchecked_mut((i + r) * n + jj) = s;
            }
        }
        i += mr;
    }
}

/// Wrapping horizontal sum of the 16 i32 lanes (explicit halving adds —
/// all wrap, no saturate).
#[inline]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn hsum_epi32(v: __m512i) -> i32 {
    let s = _mm256_add_epi32(_mm512_castsi512_si256(v), _mm512_extracti64x4_epi64(v, 1));
    let s = _mm_add_epi32(_mm256_castsi256_si128(s), _mm256_extracti128_si256(s, 1));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
    _mm_cvtsi128_si32(s)
}

/// Dense GEMM, one activation row: the AVX2 quad layout consumed two
/// K-blocks (32 bytes) per `vpdpwssd`, weight blocks of one lane loaded
/// as a 128-bit pair (they sit `DENSE_NR·DENSE_KB` = 64 bytes apart in
/// the interleave). An odd trailing block and the K tail fall back to
/// exact scalar per lane. `cfg 1` interleaves two accumulator quartets
/// over alternating block pairs.
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn dense_row(arow: &[u8], w: &PackedDense, crow: &mut [i32], cfg: u8) {
    let k = w.k;
    let nb = w.kp / DENSE_KB;
    let pairs = nb / 2;
    let wp = w.data.as_ptr();
    // staged 32-byte activation window for the final (partial) pair:
    // bytes past k are zero, matching the zero K padding of the pack
    let mut pairbuf = [0u8; 2 * DENSE_KB];
    for q in 0..w.np / DENSE_NR {
        let mut acc = [_mm512_setzero_si512(); 4];
        let mut acc2 = [_mm512_setzero_si512(); 4];
        let base = q * nb * (DENSE_NR * DENSE_KB);
        for p in 0..pairs {
            let a0 = 2 * p * DENSE_KB;
            let av = if a0 + 2 * DENSE_KB <= k {
                _mm256_loadu_si256(arow.as_ptr().add(a0) as *const __m256i)
            } else {
                pairbuf.fill(0);
                pairbuf[..k - a0].copy_from_slice(&arow[a0..]);
                _mm256_loadu_si256(pairbuf.as_ptr() as *const __m256i)
            };
            let a16 = _mm512_cvtepu8_epi16(av);
            let blk = wp.add(base + 2 * p * DENSE_NR * DENSE_KB);
            for r in 0..4 {
                let w0 = _mm_loadu_si128(blk.add(r * DENSE_KB) as *const __m128i);
                let w1 = _mm_loadu_si128(
                    blk.add(DENSE_NR * DENSE_KB + r * DENSE_KB) as *const __m128i
                );
                let w16 = _mm512_cvtepi8_epi16(_mm256_set_m128i(w1, w0));
                if cfg != 0 && p % 2 == 1 {
                    acc2[r] = _mm512_dpwssd_epi32(acc2[r], a16, w16);
                } else {
                    acc[r] = _mm512_dpwssd_epi32(acc[r], a16, w16);
                }
            }
        }
        for r in 0..4 {
            let j = q * DENSE_NR + r;
            if j < crow.len() {
                let mut s = hsum_epi32(_mm512_add_epi32(acc[r], acc2[r]));
                if nb % 2 == 1 {
                    // odd trailing block: exact scalar over its real K range
                    let t = nb - 1;
                    let bb = base + (t * DENSE_NR + r) * DENSE_KB;
                    let k0 = t * DENSE_KB;
                    for kk in k0..k.min(k0 + DENSE_KB) {
                        s = s.wrapping_add(arow[kk] as i32 * w.data[bb + (kk - k0)] as i32);
                    }
                }
                *crow.get_unchecked_mut(j) = s;
            }
        }
    }
}

/// The nibble→i8 unpack epilogue at 512-bit width: 16 packed bytes → 32
/// sign-extended i16 weight lanes in logical order (byte duplication,
/// u8→i16 widening, per-lane shift-left via `vpmullw`, arithmetic shift
/// right by 12 — the same idiom as the AVX2 core, twice as wide).
#[inline]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn nibbles_to_i16(x: __m128i) -> __m512i {
    let dup = _mm256_set_m128i(_mm_unpackhi_epi8(x, x), _mm_unpacklo_epi8(x, x));
    let v = _mm512_cvtepu8_epi16(dup);
    // even i16 lanes (low nibbles) multiply by 1<<12, odd lanes (high
    // nibbles) by 1<<8
    let mul = _mm512_set1_epi32(((1 << 8) << 16) | (1 << 12));
    _mm512_srai_epi16(_mm512_mullo_epi16(v, mul), 12)
}

/// w4 dense GEMM, one activation row: [`dense_row`] with each 32-weight
/// block pair decoded from 16 packed bytes (two 8-byte lane blocks,
/// `DENSE_NR·DENSE_KB/2` = 32 bytes apart) by [`nibbles_to_i16`].
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn dense4_row(arow: &[u8], w: &PackedDense4, crow: &mut [i32], cfg: u8) {
    const KB2: usize = DENSE_KB / 2;
    let k = w.k;
    let nb = w.kp / DENSE_KB;
    let pairs = nb / 2;
    let wp = w.data.as_ptr();
    let mut pairbuf = [0u8; 2 * DENSE_KB];
    for q in 0..w.np / DENSE_NR {
        let mut acc = [_mm512_setzero_si512(); 4];
        let mut acc2 = [_mm512_setzero_si512(); 4];
        let base = q * nb * (DENSE_NR * KB2);
        for p in 0..pairs {
            let a0 = 2 * p * DENSE_KB;
            let av = if a0 + 2 * DENSE_KB <= k {
                _mm256_loadu_si256(arow.as_ptr().add(a0) as *const __m256i)
            } else {
                pairbuf.fill(0);
                pairbuf[..k - a0].copy_from_slice(&arow[a0..]);
                _mm256_loadu_si256(pairbuf.as_ptr() as *const __m256i)
            };
            let a16 = _mm512_cvtepu8_epi16(av);
            let blk = wp.add(base + 2 * p * DENSE_NR * KB2);
            for r in 0..4 {
                let w0 = _mm_loadl_epi64(blk.add(r * KB2) as *const __m128i);
                let w1 = _mm_loadl_epi64(blk.add(DENSE_NR * KB2 + r * KB2) as *const __m128i);
                let w16 = nibbles_to_i16(_mm_unpacklo_epi64(w0, w1));
                if cfg != 0 && p % 2 == 1 {
                    acc2[r] = _mm512_dpwssd_epi32(acc2[r], a16, w16);
                } else {
                    acc[r] = _mm512_dpwssd_epi32(acc[r], a16, w16);
                }
            }
        }
        for r in 0..4 {
            let j = q * DENSE_NR + r;
            if j < crow.len() {
                let mut s = hsum_epi32(_mm512_add_epi32(acc[r], acc2[r]));
                if nb % 2 == 1 {
                    let t = nb - 1;
                    let bb = base + (t * DENSE_NR + r) * KB2;
                    let blk = &w.data[bb..bb + KB2];
                    let k0 = t * DENSE_KB;
                    for kk in k0..k.min(k0 + DENSE_KB) {
                        s = s.wrapping_add(arow[kk] as i32 * nibble(blk, kk - k0) as i32);
                    }
                }
                *crow.get_unchecked_mut(j) = s;
            }
        }
    }
}
