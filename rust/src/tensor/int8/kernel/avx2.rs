//! AVX2 cores: `vpmaddwd` (`_mm256_madd_epi16`) after explicit u8→i16 /
//! i8→i16 widening — every product exact, wrap accumulation in i32 (see
//! the `kernel` module docs for the full argument).
//!
//! Blocking configs: conv `c0` tiles 2 output rows per 32-position
//! register pass, `c1` tiles 1 (less register pressure, wins on small
//! row counts). Dense `c0` runs one accumulator quartet over the
//! K-blocks, `c1` interleaves two quartets over alternating blocks and
//! folds them (hides madd latency on long K). Both only reorder
//! wrap-mod-2³² adds, so they are bit-identical.

#![allow(clippy::too_many_arguments)]

use core::arch::x86_64::*;

use super::{i4_hi, i4_lo, nibble, PackedDense, PackedDense4, DENSE_KB, DENSE_NR};

/// Broadcast the (sign-extended) weight pair at `a[off], a[off+1]` as
/// `[a0, a1, a0, a1, ...]` i16 lanes — the second `vpmaddwd` operand.
/// The packed row stride is even, so `off + 1` is always in bounds
/// (the pad byte is zero).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn weight_pair(a: &[i8], off: usize) -> __m256i {
    let a0 = *a.get_unchecked(off) as i16 as u16 as u32;
    let a1 = *a.get_unchecked(off + 1) as i16 as u16 as u32;
    _mm256_set1_epi32(((a1 << 16) | a0) as i32)
}

/// Conv GEMM row span: `tile` output rows × 32 positions per register
/// pass, reduction consumed as `vpmaddwd` pairs. B rows `k0`/`k0+1`
/// are byte-interleaved in registers (`vpunpck[lh]bw`), widened to
/// i16 and paired against the broadcast weights — all products exact,
/// see the module docs.
#[target_feature(enable = "avx2")]
pub unsafe fn conv_span(
    a: &[i8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
    cfg: u8,
) {
    let tile = if cfg == 0 { 2 } else { 1 };
    let n32 = n - n % 32;
    let kpairs = kp / 2;
    let bp = b.as_ptr();
    let mut i = 0;
    while i < m {
        let mr = (m - i).min(tile);
        let mut j = 0;
        while j < n32 {
            let mut acc = [[_mm256_setzero_si256(); 4]; 2];
            for t in 0..kpairs {
                let k0 = 2 * t;
                // the pad pair of an odd K clamps its B row index;
                // its weight lane is the zero pad byte, so the
                // duplicated row contributes nothing
                let k1 = (k0 + 1).min(k - 1);
                let b0 = _mm256_loadu_si256(bp.add(k0 * n + j) as *const __m256i);
                let b1 = _mm256_loadu_si256(bp.add(k1 * n + j) as *const __m256i);
                let lo = _mm256_unpacklo_epi8(b0, b1);
                let hi = _mm256_unpackhi_epi8(b0, b1);
                // pair-interleaved positions: lo/hi 128-bit lanes hold
                // j+0..7, j+8..15, j+16..23, j+24..31 in that order
                let w0 = _mm256_cvtepu8_epi16(_mm256_castsi256_si128(lo));
                let w1 = _mm256_cvtepu8_epi16(_mm256_castsi256_si128(hi));
                let w2 = _mm256_cvtepu8_epi16(_mm256_extracti128_si256(lo, 1));
                let w3 = _mm256_cvtepu8_epi16(_mm256_extracti128_si256(hi, 1));
                for r in 0..mr {
                    let ap = weight_pair(a, (i + r) * kp + k0);
                    acc[r][0] = _mm256_add_epi32(acc[r][0], _mm256_madd_epi16(w0, ap));
                    acc[r][1] = _mm256_add_epi32(acc[r][1], _mm256_madd_epi16(w1, ap));
                    acc[r][2] = _mm256_add_epi32(acc[r][2], _mm256_madd_epi16(w2, ap));
                    acc[r][3] = _mm256_add_epi32(acc[r][3], _mm256_madd_epi16(w3, ap));
                }
            }
            for r in 0..mr {
                let crow = c.as_mut_ptr().add((i + r) * n + j);
                _mm256_storeu_si256(crow as *mut __m256i, acc[r][0]);
                _mm256_storeu_si256(crow.add(8) as *mut __m256i, acc[r][1]);
                _mm256_storeu_si256(crow.add(16) as *mut __m256i, acc[r][2]);
                _mm256_storeu_si256(crow.add(24) as *mut __m256i, acc[r][3]);
            }
            j += 32;
        }
        // position tail: exact scalar (integer products commute with
        // the vector body, so the seam is bit-invisible)
        for r in 0..mr {
            let arow = &a[(i + r) * kp..(i + r) * kp + k];
            for jj in n32..n {
                let mut s = 0i32;
                for (kk, &av) in arow.iter().enumerate() {
                    s = s.wrapping_add(av as i32 * *b.get_unchecked(kk * n + jj) as i32);
                }
                *c.get_unchecked_mut((i + r) * n + jj) = s;
            }
        }
        i += mr;
    }
}

/// Broadcast the sign-extended nibble pair in byte `a[off]` as
/// `[lo, hi, lo, hi, ...]` i16 lanes. One packed byte *is* one
/// `vpmaddwd` weight pair (CONV_KB == 2 nibbles), so the w4 conv
/// core is the w8 core with this decode in front.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn weight_pair4(a: &[u8], off: usize) -> __m256i {
    let b = *a.get_unchecked(off);
    let a0 = i4_lo(b) as i16 as u16 as u32;
    let a1 = i4_hi(b) as i16 as u16 as u32;
    _mm256_set1_epi32(((a1 << 16) | a0) as i32)
}

/// w4 conv GEMM row span: the [`conv_span`] register tile (`vpmaddwd`
/// pairs) with the weight pair decoded from one packed byte. Same
/// blocking, exact products — bit-identical.
#[target_feature(enable = "avx2")]
pub unsafe fn conv4_span(
    a: &[u8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
    cfg: u8,
) {
    let tile = if cfg == 0 { 2 } else { 1 };
    let n32 = n - n % 32;
    let kpairs = kp / 2; // also the byte stride per packed row
    let bp = b.as_ptr();
    let mut i = 0;
    while i < m {
        let mr = (m - i).min(tile);
        let mut j = 0;
        while j < n32 {
            let mut acc = [[_mm256_setzero_si256(); 4]; 2];
            for t in 0..kpairs {
                let k0 = 2 * t;
                // odd-K pad pair: clamp the B row; the pad nibble is
                // zero, so the duplicated row contributes nothing
                let k1 = (k0 + 1).min(k - 1);
                let b0 = _mm256_loadu_si256(bp.add(k0 * n + j) as *const __m256i);
                let b1 = _mm256_loadu_si256(bp.add(k1 * n + j) as *const __m256i);
                let lo = _mm256_unpacklo_epi8(b0, b1);
                let hi = _mm256_unpackhi_epi8(b0, b1);
                let w0 = _mm256_cvtepu8_epi16(_mm256_castsi256_si128(lo));
                let w1 = _mm256_cvtepu8_epi16(_mm256_castsi256_si128(hi));
                let w2 = _mm256_cvtepu8_epi16(_mm256_extracti128_si256(lo, 1));
                let w3 = _mm256_cvtepu8_epi16(_mm256_extracti128_si256(hi, 1));
                for r in 0..mr {
                    let ap = weight_pair4(a, (i + r) * kpairs + t);
                    acc[r][0] = _mm256_add_epi32(acc[r][0], _mm256_madd_epi16(w0, ap));
                    acc[r][1] = _mm256_add_epi32(acc[r][1], _mm256_madd_epi16(w1, ap));
                    acc[r][2] = _mm256_add_epi32(acc[r][2], _mm256_madd_epi16(w2, ap));
                    acc[r][3] = _mm256_add_epi32(acc[r][3], _mm256_madd_epi16(w3, ap));
                }
            }
            for r in 0..mr {
                let crow = c.as_mut_ptr().add((i + r) * n + j);
                _mm256_storeu_si256(crow as *mut __m256i, acc[r][0]);
                _mm256_storeu_si256(crow.add(8) as *mut __m256i, acc[r][1]);
                _mm256_storeu_si256(crow.add(16) as *mut __m256i, acc[r][2]);
                _mm256_storeu_si256(crow.add(24) as *mut __m256i, acc[r][3]);
            }
            j += 32;
        }
        // position tail: exact scalar over decoded nibbles
        for r in 0..mr {
            let arow = &a[(i + r) * kpairs..(i + r + 1) * kpairs];
            for jj in n32..n {
                let mut s = 0i32;
                for kk in 0..k {
                    s = s.wrapping_add(
                        nibble(arow, kk) as i32 * *b.get_unchecked(kk * n + jj) as i32,
                    );
                }
                *c.get_unchecked_mut((i + r) * n + jj) = s;
            }
        }
        i += mr;
    }
}

/// Wrapping horizontal sum of the 8 i32 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256(v, 1));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
    _mm_cvtsi128_si32(s)
}

/// The widened activation block `t` (K tail reads a zero-padded stack
/// copy, matching the zero K padding of the packed rows, so tail
/// products vanish on both operands).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn act_block(arow: &[u8], tailbuf: &[u8; DENSE_KB], t: usize, full: usize) -> __m256i {
    let av = if t < full {
        _mm_loadu_si128(arow.as_ptr().add(t * DENSE_KB) as *const __m128i)
    } else {
        _mm_loadu_si128(tailbuf.as_ptr() as *const __m128i)
    };
    _mm256_cvtepu8_epi16(av)
}

/// Dense GEMM, one activation row: four packed weight rows per quad
/// share each widened 16-byte activation block. `cfg 1` interleaves a
/// second accumulator quartet over alternating K-blocks.
#[target_feature(enable = "avx2")]
pub unsafe fn dense_row(arow: &[u8], w: &PackedDense, crow: &mut [i32], cfg: u8) {
    let (k, kp) = (w.k, w.kp);
    let nb = kp / DENSE_KB;
    let full = k / DENSE_KB;
    let tail = k % DENSE_KB;
    let mut tailbuf = [0u8; DENSE_KB];
    if tail > 0 {
        tailbuf[..tail].copy_from_slice(&arow[full * DENSE_KB..]);
    }
    let wp = w.data.as_ptr();
    for q in 0..w.np / DENSE_NR {
        let mut acc = [_mm256_setzero_si256(); 4];
        let mut acc2 = [_mm256_setzero_si256(); 4];
        let base = q * nb * (DENSE_NR * DENSE_KB);
        for t in 0..nb {
            let a16 = act_block(arow, &tailbuf, t, full);
            let blk = wp.add(base + t * DENSE_NR * DENSE_KB);
            for r in 0..4 {
                let w16 =
                    _mm256_cvtepi8_epi16(_mm_loadu_si128(blk.add(r * DENSE_KB) as *const __m128i));
                if cfg != 0 && t % 2 == 1 {
                    acc2[r] = _mm256_add_epi32(acc2[r], _mm256_madd_epi16(a16, w16));
                } else {
                    acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(a16, w16));
                }
            }
        }
        for r in 0..4 {
            let j = q * DENSE_NR + r;
            if j < crow.len() {
                *crow.get_unchecked_mut(j) = hsum_epi32(_mm256_add_epi32(acc[r], acc2[r]));
            }
        }
    }
}

/// The nibble→i8 unpack epilogue: 8 packed bytes → 16 sign-extended
/// i16 weight lanes in logical order, ready for `vpmaddwd`. Each
/// byte is duplicated (`vpunpcklbw x,x`), widened to 16-bit lanes,
/// the target nibble is shifted to the top four bits (`vpmullw` by
/// alternating `1<<12` / `1<<8` — a per-lane left shift mod 2¹⁶),
/// and an arithmetic right shift by 12 sign-extends it: the
/// shift-left-then-arithmetic-shift-right idiom on the madd lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn nibbles_to_i16(p: *const u8) -> __m256i {
    let x = _mm_loadl_epi64(p as *const __m128i);
    let dup = _mm_unpacklo_epi8(x, x);
    let v = _mm256_cvtepu8_epi16(dup);
    // even i16 lanes (low nibbles) multiply by 1<<12, odd lanes
    // (high nibbles) by 1<<8
    let mul = _mm256_set1_epi32(((1 << 8) << 16) | (1 << 12));
    _mm256_srai_epi16(_mm256_mullo_epi16(v, mul), 12)
}

/// w4 dense GEMM, one activation row: [`dense_row`] with each
/// 16-weight block decoded from 8 packed bytes by [`nibbles_to_i16`].
/// Block loads are exact (`DENSE_KB/2` = 8 bytes per block, blocks
/// contiguous), so there is no overread.
#[target_feature(enable = "avx2")]
pub unsafe fn dense4_row(arow: &[u8], w: &PackedDense4, crow: &mut [i32], cfg: u8) {
    const KB2: usize = DENSE_KB / 2;
    let (k, kp) = (w.k, w.kp);
    let nb = kp / DENSE_KB;
    let full = k / DENSE_KB;
    let tail = k % DENSE_KB;
    let mut tailbuf = [0u8; DENSE_KB];
    if tail > 0 {
        tailbuf[..tail].copy_from_slice(&arow[full * DENSE_KB..]);
    }
    let wp = w.data.as_ptr();
    for q in 0..w.np / DENSE_NR {
        let mut acc = [_mm256_setzero_si256(); 4];
        let mut acc2 = [_mm256_setzero_si256(); 4];
        let base = q * nb * (DENSE_NR * KB2);
        for t in 0..nb {
            let a16 = act_block(arow, &tailbuf, t, full);
            let blk = wp.add(base + t * DENSE_NR * KB2);
            for r in 0..4 {
                let w16 = nibbles_to_i16(blk.add(r * KB2));
                if cfg != 0 && t % 2 == 1 {
                    acc2[r] = _mm256_add_epi32(acc2[r], _mm256_madd_epi16(a16, w16));
                } else {
                    acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(a16, w16));
                }
            }
        }
        for r in 0..4 {
            let j = q * DENSE_NR + r;
            if j < crow.len() {
                *crow.get_unchecked_mut(j) = hsum_epi32(_mm256_add_epi32(acc[r], acc2[r]));
            }
        }
    }
}
