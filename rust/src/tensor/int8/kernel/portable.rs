//! Portable scalar cores — the reference blocking; bit-identical to the
//! SIMD variants because every product is exact and i32 accumulation
//! commutes mod 2³² (see the `kernel` module docs).
//!
//! Blocking configs: conv `c0` streams B row-by-row fanning one
//! broadcast weight into the C row (the layout that auto-vectorizes to
//! widening multiply-adds); conv `c1` fuses each `CONV_KB` weight pair
//! into one pass over the C row (half the C traffic, mirrors the SIMD
//! pair consumption). Dense `c0` accumulates every K-block into one
//! scalar; dense `c1` keeps two running partials over alternating blocks
//! and folds them at the end. All configs reorder wrap-adds only.

#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use super::{nibble, PackedDense, PackedDense4, DENSE_KB, DENSE_NR};

/// One row span of the conv GEMM; `cfg` picks the K consumption order.
pub fn conv_span(
    a: &[i8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
    cfg: u8,
) {
    if cfg == 0 {
        conv_span_stream(a, m, k, kp, b, c, n);
    } else {
        conv_span_paired(a, m, k, kp, b, c, n);
    }
}

/// `c0`: for each row, stream B row-by-row and fan the broadcast weight
/// into the i32 C row (the scalar GEMM's loop order).
fn conv_span_stream(a: &[i8], m: usize, k: usize, kp: usize, b: &[u8], c: &mut [i32], n: usize) {
    for i in 0..m {
        let arow = &a[i * kp..i * kp + k];
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0);
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv = cv.wrapping_add(av * bv as i32);
            }
        }
    }
}

/// `c1`: consume K as weight pairs, two B rows fused per C pass —
/// the scalar mirror of the SIMD pair consumption.
fn conv_span_paired(a: &[i8], m: usize, k: usize, kp: usize, b: &[u8], c: &mut [i32], n: usize) {
    for i in 0..m {
        let arow = &a[i * kp..i * kp + k];
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0);
        let mut kk = 0;
        while kk + 1 < k {
            let (a0, a1) = (arow[kk] as i32, arow[kk + 1] as i32);
            if a0 != 0 || a1 != 0 {
                let b0 = &b[kk * n..(kk + 1) * n];
                let b1 = &b[(kk + 1) * n..(kk + 2) * n];
                for ((cv, &v0), &v1) in crow.iter_mut().zip(b0.iter()).zip(b1.iter()) {
                    *cv = cv.wrapping_add(a0 * v0 as i32).wrapping_add(a1 * v1 as i32);
                }
            }
            kk += 2;
        }
        if kk < k {
            let a0 = arow[kk] as i32;
            if a0 != 0 {
                let b0 = &b[kk * n..(kk + 1) * n];
                for (cv, &v0) in crow.iter_mut().zip(b0.iter()) {
                    *cv = cv.wrapping_add(a0 * v0 as i32);
                }
            }
        }
    }
}

/// Wrap-sum of one packed dense K-block against the activation row
/// (weight padding is zero, so only `kk < k` activation reads happen).
#[inline]
fn dense_block(arow: &[u8], w: &PackedDense, q: usize, r: usize, t: usize, nb: usize) -> i32 {
    let base = ((q * nb + t) * DENSE_NR + r) * DENSE_KB;
    let blk = &w.data[base..base + DENSE_KB];
    let k0 = t * DENSE_KB;
    let kend = w.k.min(k0 + DENSE_KB);
    let mut s = 0i32;
    for kk in k0..kend {
        s = s.wrapping_add(arow[kk] as i32 * blk[kk - k0] as i32);
    }
    s
}

/// One output row of the dense GEMM over the packed quad layout: walk the
/// interleaved K-blocks exactly as the SIMD cores do. `cfg 1` folds
/// alternating blocks through a second partial (wrap-add associative, so
/// bit-identical).
pub fn dense_row(arow: &[u8], w: &PackedDense, crow: &mut [i32], cfg: u8) {
    let nb = w.kp / DENSE_KB;
    for (j, cv) in crow.iter_mut().enumerate() {
        let (q, r) = (j / DENSE_NR, j % DENSE_NR);
        let (mut s0, mut s1) = (0i32, 0i32);
        for t in 0..nb {
            let s = dense_block(arow, w, q, r, t, nb);
            if cfg != 0 && t % 2 == 1 {
                s1 = s1.wrapping_add(s);
            } else {
                s0 = s0.wrapping_add(s);
            }
        }
        *cv = s0.wrapping_add(s1);
    }
}

/// One row span of the w4 conv GEMM; identical loop orders to
/// [`conv_span`], the weight decoded from its nibble on the fly (`c1`
/// decodes both nibbles of a packed byte per fused pass).
pub fn conv4_span(
    a: &[u8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
    cfg: u8,
) {
    let stride = kp / 2;
    for i in 0..m {
        let arow = &a[i * stride..(i + 1) * stride];
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0);
        if cfg == 0 {
            for kk in 0..k {
                let av = nibble(arow, kk);
                if av == 0 {
                    continue;
                }
                let av = av as i32;
                let brow = &b[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv = cv.wrapping_add(av * bv as i32);
                }
            }
        } else {
            let mut kk = 0;
            while kk + 1 < k {
                let (a0, a1) = (nibble(arow, kk) as i32, nibble(arow, kk + 1) as i32);
                if a0 != 0 || a1 != 0 {
                    let b0 = &b[kk * n..(kk + 1) * n];
                    let b1 = &b[(kk + 1) * n..(kk + 2) * n];
                    for ((cv, &v0), &v1) in crow.iter_mut().zip(b0.iter()).zip(b1.iter()) {
                        *cv = cv.wrapping_add(a0 * v0 as i32).wrapping_add(a1 * v1 as i32);
                    }
                }
                kk += 2;
            }
            if kk < k {
                let a0 = nibble(arow, kk) as i32;
                if a0 != 0 {
                    let b0 = &b[kk * n..(kk + 1) * n];
                    for (cv, &v0) in crow.iter_mut().zip(b0.iter()) {
                        *cv = cv.wrapping_add(a0 * v0 as i32);
                    }
                }
            }
        }
    }
}

/// Wrap-sum of one nibble-packed dense K-block against the activation
/// row.
#[inline]
fn dense4_block(arow: &[u8], w: &PackedDense4, q: usize, r: usize, t: usize, nb: usize) -> i32 {
    let base = ((q * nb + t) * DENSE_NR + r) * (DENSE_KB / 2);
    let blk = &w.data[base..base + DENSE_KB / 2];
    let k0 = t * DENSE_KB;
    let kend = w.k.min(k0 + DENSE_KB);
    let mut s = 0i32;
    for kk in k0..kend {
        s = s.wrapping_add(arow[kk] as i32 * nibble(blk, kk - k0) as i32);
    }
    s
}

/// One output row of the w4 dense GEMM: walks the nibble-packed quad
/// blocks with the same K-blocking (and `cfg` partials) as [`dense_row`].
pub fn dense4_row(arow: &[u8], w: &PackedDense4, crow: &mut [i32], cfg: u8) {
    let nb = w.kp / DENSE_KB;
    for (j, cv) in crow.iter_mut().enumerate() {
        let (q, r) = (j / DENSE_NR, j % DENSE_NR);
        let (mut s0, mut s1) = (0i32, 0i32);
        for t in 0..nb {
            let s = dense4_block(arow, w, q, r, t, nb);
            if cfg != 0 && t % 2 == 1 {
                s1 = s1.wrapping_add(s);
            } else {
                s0 = s0.wrapping_add(s);
            }
        }
        *cv = s0.wrapping_add(s1);
    }
}
