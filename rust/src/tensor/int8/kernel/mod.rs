//! Runtime-dispatched i8 GEMM micro-kernels over ahead-of-time packed
//! weights — the serving engine's hot loop.
//!
//! Two kernel shapes cover the integer engine:
//!
//! * **conv** ([`gemm_conv_packed_into`]): `C[m,n] = A_i8[m,k] · B_u8[k,n]`
//!   with A = packed weights and B = im2col columns. Vectorized over the
//!   position axis `n` with the weight pair broadcast, two output rows per
//!   register tile.
//! * **dense** ([`gemm_dense_packed_into`]): `C[m,n] = A_u8[m,k] · W^T`
//!   with W = packed weight rows. Vectorized over the reduction axis `k`,
//!   four weight rows sharing one streaming pass of the activation row.
//!
//! ## ISA variants and the exactness contract
//!
//! Four implementations share the fixed pack layouts, one per submodule:
//!
//! * [`Kernel::Avx2`] (`avx2` module): `vpmaddwd` (`_mm256_madd_epi16`)
//!   after explicit u8→i16 / i8→i16 widening.
//! * [`Kernel::Avx512`] (`avx512`): `vpdpwssd` (AVX-512 VNNI) over the
//!   same widened i16 operands at twice the register width. VNNI's
//!   word-to-dword form multiplies i16 lanes to i32, pair-sums, and
//!   accumulates **without saturation** — the `vpdpwssds` saturating
//!   sibling and the u8×i8 `vpdpbusd` byte form (whose quad-sum can
//!   overflow i16 pairs… it cannot, but its saturating sibling exists to
//!   be confused with) are never used. Compiled only on toolchains that
//!   ship stable AVX-512 intrinsics (rustc ≥ 1.89, probed by `build.rs`
//!   via the `pallas_avx512` cfg).
//! * [`Kernel::Neon`] (`neon`): AArch64 `smlal`/`smlal2`
//!   (`vmlal_s16`) widening multiply-accumulates over the same i16
//!   operands, 128-bit registers.
//! * [`Kernel::Portable`] ([`portable`]): chunked scalar path with the
//!   identical blocking; compiles on every ISA and auto-vectorizes
//!   reasonably.
//!
//! Every 16-bit product of a u8 activation and an i8 weight fits i16
//! (|255·−128| = 32640), every pair-sum fits i32, so — unlike the classic
//! `vpmaddubsw` trick, which saturates at i16 — **every intermediate is
//! exact** on every path. i32 accumulation then wraps mod 2³², under
//! which addition is associative and commutative, so any
//! blocking/vector width/ISA produces bit-identical accumulators. That is
//! the determinism contract: all variants are bit-for-bit equal on every
//! input (proved against the scalar reference in
//! `rust/tests/int8_kernels.rs`, including near-`i32::MIN` accumulator
//! edges), so `PALLAS_NO_SIMD=1` — and every autotune outcome — is a pure
//! performance knob.
//!
//! ## Per-shape dispatch
//!
//! A GEMM call takes a [`GemmChoice`]: a [`Kernel`] plus a small blocking
//! config index (`cfg < GEMM_CFGS`, e.g. row-tile height for conv,
//! accumulator interleave for dense). [`select`] still provides the
//! process-wide heuristic default (used when no plan is involved), but
//! the serving plan compiler runs the [`autotune`] micro-tuner on each
//! layer's actual packed shape and caches the winning choice per op in
//! the `QuantizedPlan` — the hot loop then pays zero dispatch overhead
//! beyond reading the cached enum. Blocking configs only reorder
//! wrap-mod-2³² additions, so they are bit-identical by the argument
//! above.
//!
//! Packing ([`PackedConv`], [`PackedDense`]) happens once at plan-compile
//! time ([`crate::serve::plan`]); the batcher's hot loop does zero
//! repacking. The pack layouts are **fixed across variants** — an
//! autotune or env override can never change bytes in memory, only the
//! loop structure that reads them. Layout invariants (zero padding, block
//! alignment) are re-checked by `debug_assert!`s in the serve kernels so
//! a layout bug fails loudly in tests instead of silently corrupting
//! accumulators.
//!
//! ## Int4 (w4) variants
//!
//! [`PackedConv4`] / [`PackedDense4`] store weights as two's-complement
//! nibbles, two per byte (codes in `[-8, 7]`): byte `j` of a K-run holds
//! weight `2j` in the **low** nibble and weight `2j+1` in the **high**
//! nibble. The K-blocking is identical to the w8 layouts ([`CONV_KB`]
//! pairs map 1:1 onto nibble pairs; [`DENSE_KB`] weights become
//! `DENSE_KB/2` bytes per block), so the w4 GEMM cores are the existing
//! cores with a nibble→i8 unpack epilogue in front of the same widened
//! multiply-accumulate feed: sign-extension is shift-left-then-
//! arithmetic-shift-right (`(b << 4) >> 4` for the low nibble, `b >> 4`
//! for the high). Every unpacked value is the exact i8 code, so the
//! exact-intermediate argument above applies unchanged and
//! w4 SIMD == w4 portable == scalar-on-unpacked-weights, bit for bit.

#![allow(clippy::needless_range_loop)]

use std::ops::Range;
use std::sync::OnceLock;

use super::{i4_hi, i4_lo, pack_i4};
use crate::util::parallel;

pub mod autotune;
pub mod portable;

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(all(target_arch = "x86_64", pallas_avx512))]
pub mod avx512;
#[cfg(target_arch = "aarch64")]
pub mod neon;

/// K blocking of the conv kernel: weights are consumed as widened i16
/// pairs, so packed conv rows are zero-padded to a multiple of 2.
pub const CONV_KB: usize = 2;
/// K blocking of the dense kernel: one 128-bit load widened to 16×i16.
pub const DENSE_KB: usize = 16;
/// Dense register tile: weight rows interleaved (and zero-row padded) in
/// quads so four dot products share one activation stream.
pub const DENSE_NR: usize = 4;

/// Blocking configs per kernel variant (`GemmChoice::cfg < GEMM_CFGS`):
/// `c0` is each variant's default loop structure, `c1` an alternate
/// tile/interleave (conv: 1-row tile instead of 2; dense: dual
/// interleaved accumulators; portable conv: fused k-pair pass). All
/// configs read the same packed bytes and differ only in add order,
/// which wrap-mod-2³² accumulation makes bit-invisible.
pub const GEMM_CFGS: u8 = 2;

/// Blocking configs available for one kernel (currently uniform; the
/// autotuner iterates `0..cfg_count(k)` so per-variant counts can grow).
pub const fn cfg_count(_kern: Kernel) -> u8 {
    GEMM_CFGS
}

fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

/// Which micro-kernel implementation to run. The serving plan caches one
/// [`GemmChoice`] per op (autotuned at compile time); [`select`] provides
/// the process-wide heuristic default for everything else.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// `vpdpwssd`-based x86_64 path (requires AVX-512 F/BW/VNNI **and** a
    /// rustc ≥ 1.89 build; the GEMM entry points demote it on CPUs or
    /// builds without it, so passing it is always safe).
    Avx512,
    /// `vpmaddwd`-based x86_64 path (requires AVX2; demoted to
    /// [`Kernel::Portable`] on CPUs without it).
    Avx2,
    /// `smlal`-based AArch64 NEON path (baseline on aarch64 targets;
    /// demoted to portable elsewhere).
    Neon,
    /// Chunked scalar path with the identical blocking; compiles on every
    /// ISA and auto-vectorizes reasonably. Bit-identical to every SIMD
    /// variant.
    Portable,
}

impl Kernel {
    /// Stable label used by `serve-bench`, `/metrics` and the bench entry
    /// names.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Avx512 => "avx512",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
            Kernel::Portable => "portable",
        }
    }

    /// Inverse of [`Kernel::name`] (used by the `PALLAS_KERNEL` override).
    pub fn from_name(s: &str) -> Option<Kernel> {
        match s.trim() {
            "avx512" => Some(Kernel::Avx512),
            "avx2" => Some(Kernel::Avx2),
            "neon" => Some(Kernel::Neon),
            "portable" => Some(Kernel::Portable),
            _ => None,
        }
    }

    /// All variants in dispatch-precedence order (widest ISA first).
    pub fn all() -> [Kernel; 4] {
        [Kernel::Avx512, Kernel::Avx2, Kernel::Neon, Kernel::Portable]
    }

    /// CPUID/toolchain availability of this variant on the running
    /// machine (ignores `PALLAS_NO_SIMD`; portable is always available).
    pub fn available(self) -> bool {
        match self {
            Kernel::Avx512 => avx512_available(),
            Kernel::Avx2 => avx2_available(),
            Kernel::Neon => neon_available(),
            Kernel::Portable => true,
        }
    }
}

/// One dispatchable GEMM configuration: an ISA variant plus its blocking
/// config. `From<Kernel>` yields the variant's default blocking (`cfg 0`),
/// so call sites that only care about the ISA keep passing a [`Kernel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmChoice {
    pub kernel: Kernel,
    /// Blocking config index, `< cfg_count(kernel)` (clamped on entry).
    pub cfg: u8,
}

impl GemmChoice {
    pub fn new(kernel: Kernel, cfg: u8) -> GemmChoice {
        GemmChoice { kernel, cfg }
    }

    /// The process-wide heuristic choice ([`select`] at default blocking)
    /// — what every GEMM ran before per-op autotuning, and what
    /// `PALLAS_AUTOTUNE=0` pins plans to.
    pub fn heuristic() -> GemmChoice {
        GemmChoice { kernel: select(), cfg: 0 }
    }

    /// Stable label for bench output and metrics, e.g. `avx2.c0`.
    pub fn label(self) -> String {
        format!("{}.c{}", self.kernel.name(), self.cfg)
    }
}

impl From<Kernel> for GemmChoice {
    fn from(kernel: Kernel) -> GemmChoice {
        GemmChoice { kernel, cfg: 0 }
    }
}

/// CPUID-level availability of the AVX2 path (ignores `PALLAS_NO_SIMD`).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Availability of the AVX-512 VNNI path: requires the F/BW/VNNI feature
/// trio on the CPU *and* a build whose toolchain ships stable AVX-512
/// intrinsics (`pallas_avx512`, emitted by `build.rs` on rustc ≥ 1.89).
/// Ignores `PALLAS_NO_SIMD`.
pub fn avx512_available() -> bool {
    #[cfg(all(target_arch = "x86_64", pallas_avx512))]
    {
        is_x86_feature_detected!("avx512f")
            && is_x86_feature_detected!("avx512bw")
            && is_x86_feature_detected!("avx512vnni")
    }
    #[cfg(not(all(target_arch = "x86_64", pallas_avx512)))]
    {
        false
    }
}

/// Availability of the NEON path: advanced SIMD is baseline on the
/// aarch64 targets we compile the variant for.
pub fn neon_available() -> bool {
    cfg!(target_arch = "aarch64")
}

/// `PALLAS_NO_SIMD` contract: any non-empty value other than `0` disables
/// the SIMD paths (so `PALLAS_NO_SIMD=1`, `=true`, `=yes` all work).
pub fn no_simd_requested(v: Option<&str>) -> bool {
    matches!(v.map(str::trim), Some(s) if !s.is_empty() && s != "0")
}

/// `PALLAS_KERNEL` contract: force one named variant
/// (`avx512|avx2|neon|portable`) as the heuristic selection *and* the
/// only autotune candidate — the CI forced-variant sweep runs the whole
/// suite once per variant this way. Unknown or unavailable names demote
/// exactly like any caller-supplied kernel (bit-identical, never UB);
/// `PALLAS_NO_SIMD` still wins.
pub fn forced_kernel(v: Option<&str>) -> Option<Kernel> {
    Kernel::from_name(v?)
}

/// One uncached dispatch decision: `PALLAS_NO_SIMD` wins, then a
/// `PALLAS_KERNEL` override, then CPU feature detection widest-first.
/// Exposed for tests that exercise the env contract; production paths go
/// through the cached [`select`].
pub fn select_uncached() -> Kernel {
    if no_simd_requested(std::env::var("PALLAS_NO_SIMD").ok().as_deref()) {
        return Kernel::Portable;
    }
    if let Some(k) = forced_kernel(std::env::var("PALLAS_KERNEL").ok().as_deref()) {
        return usable_kernel(k);
    }
    *Kernel::all().iter().find(|k| k.available()).unwrap_or(&Kernel::Portable)
}

/// The process-wide heuristic kernel choice, detected once and cached.
pub fn select() -> Kernel {
    static K: OnceLock<Kernel> = OnceLock::new();
    *K.get_or_init(select_uncached)
}

/// Demote a requested kernel to one this CPU/build can actually run: the
/// GEMM entry points are safe functions, so a caller-supplied SIMD
/// variant must never reach target-feature code on a machine without it
/// (that would be UB) — it falls down the precedence ladder to the widest
/// available path, which is bit-identical anyway.
fn usable_kernel(kern: Kernel) -> Kernel {
    match kern {
        Kernel::Avx512 if avx512_available() => Kernel::Avx512,
        Kernel::Avx512 | Kernel::Avx2 if avx2_available() => Kernel::Avx2,
        Kernel::Neon if neon_available() => Kernel::Neon,
        _ => Kernel::Portable,
    }
}

/// [`usable_kernel`] plus a blocking-config clamp; applied once per GEMM
/// entry so the dispatch match below never sees an impossible choice.
fn usable(ch: GemmChoice) -> GemmChoice {
    let kernel = usable_kernel(ch.kernel);
    GemmChoice { kernel, cfg: ch.cfg.min(cfg_count(kernel).saturating_sub(1)) }
}

// ---------------------------------------------------------------------------
// Packed weight layouts
// ---------------------------------------------------------------------------

/// Conv weights packed for [`gemm_conv_packed_into`]: row-major `[rows]`
/// rows of `kp` bytes each, where `kp` is `k` rounded up to [`CONV_KB`]
/// and the pad byte is zero. Rows stay contiguous (no row interleaving),
/// so a grouped conv can hand any `[r0, r1)` row range to the kernel by
/// plain slicing — the `par_grouped_rows_mut` fan-out cuts at group
/// boundaries exactly as before.
#[derive(Clone, Debug)]
pub struct PackedConv {
    pub rows: usize,
    /// logical reduction length (im2col patch size)
    pub k: usize,
    /// padded row stride in bytes (`k` rounded up to [`CONV_KB`])
    pub kp: usize,
    pub data: Vec<i8>,
}

impl PackedConv {
    pub fn pack(w: &[i8], rows: usize, k: usize) -> PackedConv {
        assert_eq!(w.len(), rows * k, "conv pack: {} weights for {rows}x{k}", w.len());
        let kp = round_up(k.max(1), CONV_KB);
        let mut data = vec![0i8; rows * kp];
        for r in 0..rows {
            data[r * kp..r * kp + k].copy_from_slice(&w[r * k..(r + 1) * k]);
        }
        PackedConv { rows, k, kp, data }
    }

    /// The packed bytes of rows `r.start..r.end` (group slicing).
    pub fn row_slice(&self, r: Range<usize>) -> &[i8] {
        &self.data[r.start * self.kp..r.end * self.kp]
    }

    /// Layout invariants: stride math and zeroed K padding. O(weights) —
    /// meant for `debug_assert!` at kernel entry, not the hot loop.
    pub fn layout_ok(&self) -> bool {
        self.kp == round_up(self.k.max(1), CONV_KB)
            && self.data.len() == self.rows * self.kp
            && (0..self.rows).all(|r| {
                self.data[r * self.kp + self.k..(r + 1) * self.kp].iter().all(|&z| z == 0)
            })
    }
}

/// Dense weights `[n, k]` packed for [`gemm_dense_packed_into`]:
/// row quads interleaved at [`DENSE_KB`] granularity. With
/// `nb = kp / DENSE_KB` blocks per row, the block for (quad `q`, k-block
/// `t`, lane `r`) lives at byte offset `((q·nb + t)·DENSE_NR + r)·DENSE_KB`
/// — i.e. the four rows of a quad alternate K-blocks, so the kernel's four
/// accumulators read one contiguous 64-byte span per k-step. `k` pads to
/// `kp` (zero bytes), `n` pads to `np` (all-zero rows).
#[derive(Clone, Debug)]
pub struct PackedDense {
    /// logical output count (rows of the original weight matrix)
    pub n: usize,
    /// logical reduction length
    pub k: usize,
    /// padded reduction length (multiple of [`DENSE_KB`])
    pub kp: usize,
    /// padded row count (multiple of [`DENSE_NR`])
    pub np: usize,
    pub data: Vec<i8>,
}

impl PackedDense {
    pub fn pack(w: &[i8], n: usize, k: usize) -> PackedDense {
        assert_eq!(w.len(), n * k, "dense pack: {} weights for {n}x{k}", w.len());
        let kp = round_up(k.max(1), DENSE_KB);
        let np = round_up(n.max(1), DENSE_NR);
        let nb = kp / DENSE_KB;
        let mut data = vec![0i8; np * kp];
        for j in 0..n {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for t in 0..nb {
                let k0 = t * DENSE_KB;
                if k0 >= k {
                    break;
                }
                let kend = k.min(k0 + DENSE_KB);
                let base = ((q * nb + t) * DENSE_NR + r) * DENSE_KB;
                data[base..base + (kend - k0)].copy_from_slice(&w[j * k + k0..j * k + kend]);
            }
        }
        PackedDense { n, k, kp, np, data }
    }

    /// Layout invariants: stride math, zeroed K padding of every real row
    /// and all-zero pad rows. O(weights); for `debug_assert!` use.
    pub fn layout_ok(&self) -> bool {
        let nb = self.kp / DENSE_KB;
        if self.kp != round_up(self.k.max(1), DENSE_KB)
            || self.np != round_up(self.n.max(1), DENSE_NR)
            || self.data.len() != self.np * self.kp
        {
            return false;
        }
        for j in 0..self.np {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for t in 0..nb {
                let base = ((q * nb + t) * DENSE_NR + r) * DENSE_KB;
                let blk = &self.data[base..base + DENSE_KB];
                for (tt, &z) in blk.iter().enumerate() {
                    let kk = t * DENSE_KB + tt;
                    if (j >= self.n || kk >= self.k) && z != 0 {
                        return false;
                    }
                }
            }
        }
        true
    }
}

/// Logical weight `kk` of a nibble-packed K-run (low nibble first).
#[inline]
fn nibble(bytes: &[u8], kk: usize) -> i8 {
    let b = bytes[kk / 2];
    if kk % 2 == 0 { i4_lo(b) } else { i4_hi(b) }
}

/// Conv weights nibble-packed for [`gemm_conv4_packed_into`]: the
/// [`PackedConv`] layout at half the bytes. Rows are zero-padded to `kp`
/// (a [`CONV_KB`] multiple, so every row is a whole number of bytes) and
/// stored as `kp/2` bytes each; pad nibbles are zero. Rows stay
/// contiguous, so grouped convs slice `[r0, r1)` exactly as in w8.
#[derive(Clone, Debug)]
pub struct PackedConv4 {
    pub rows: usize,
    /// logical reduction length (im2col patch size)
    pub k: usize,
    /// padded logical row length (`k` rounded up to [`CONV_KB`]); the
    /// byte stride per row is `kp / 2`
    pub kp: usize,
    pub data: Vec<u8>,
}

impl PackedConv4 {
    /// Packs codes that must already fit `[-8, 7]` (panics otherwise —
    /// the plan compiler checks range before choosing the w4 layout).
    pub fn pack(w: &[i8], rows: usize, k: usize) -> PackedConv4 {
        assert_eq!(w.len(), rows * k, "conv4 pack: {} weights for {rows}x{k}", w.len());
        let kp = round_up(k.max(1), CONV_KB);
        let mut row = vec![0i8; kp];
        let mut data = Vec::with_capacity(rows * kp / 2);
        for r in 0..rows {
            row[..k].copy_from_slice(&w[r * k..(r + 1) * k]);
            data.extend_from_slice(&pack_i4(&row));
        }
        PackedConv4 { rows, k, kp, data }
    }

    /// The packed bytes of rows `r.start..r.end` (group slicing).
    pub fn row_slice(&self, r: Range<usize>) -> &[u8] {
        let stride = self.kp / 2;
        &self.data[r.start * stride..r.end * stride]
    }

    /// Layout invariants: stride math and zeroed pad nibbles. O(weights);
    /// for `debug_assert!` at kernel entry.
    pub fn layout_ok(&self) -> bool {
        let stride = self.kp / 2;
        self.kp == round_up(self.k.max(1), CONV_KB)
            && self.data.len() == self.rows * stride
            && (0..self.rows).all(|r| {
                let row = &self.data[r * stride..(r + 1) * stride];
                (self.k..self.kp).all(|kk| nibble(row, kk) == 0)
            })
    }
}

/// Dense weights `[n, k]` nibble-packed for [`gemm_dense4_packed_into`]:
/// the [`PackedDense`] quad-interleave with each [`DENSE_KB`]-weight
/// block stored as `DENSE_KB/2` bytes, so the block for (quad `q`,
/// k-block `t`, lane `r`) lives at byte offset
/// `((q·nb + t)·DENSE_NR + r)·DENSE_KB/2`. Padding (K bytes and whole
/// pad rows) is zero nibbles, exactly as in w8.
#[derive(Clone, Debug)]
pub struct PackedDense4 {
    /// logical output count (rows of the original weight matrix)
    pub n: usize,
    /// logical reduction length
    pub k: usize,
    /// padded reduction length (multiple of [`DENSE_KB`])
    pub kp: usize,
    /// padded row count (multiple of [`DENSE_NR`])
    pub np: usize,
    pub data: Vec<u8>,
}

impl PackedDense4 {
    /// Packs codes that must already fit `[-8, 7]` (panics otherwise).
    pub fn pack(w: &[i8], n: usize, k: usize) -> PackedDense4 {
        assert_eq!(w.len(), n * k, "dense4 pack: {} weights for {n}x{k}", w.len());
        let kp = round_up(k.max(1), DENSE_KB);
        let np = round_up(n.max(1), DENSE_NR);
        let nb = kp / DENSE_KB;
        let mut blk = [0i8; DENSE_KB];
        let mut data = vec![0u8; np * kp / 2];
        for j in 0..n {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for t in 0..nb {
                let k0 = t * DENSE_KB;
                if k0 >= k {
                    break;
                }
                let kend = k.min(k0 + DENSE_KB);
                blk.fill(0);
                blk[..kend - k0].copy_from_slice(&w[j * k + k0..j * k + kend]);
                let base = ((q * nb + t) * DENSE_NR + r) * (DENSE_KB / 2);
                data[base..base + DENSE_KB / 2].copy_from_slice(&pack_i4(&blk));
            }
        }
        PackedDense4 { n, k, kp, np, data }
    }

    /// Layout invariants: stride math, zeroed pad nibbles of every real
    /// row and all-zero pad rows. O(weights); for `debug_assert!` use.
    pub fn layout_ok(&self) -> bool {
        let nb = self.kp / DENSE_KB;
        if self.kp != round_up(self.k.max(1), DENSE_KB)
            || self.np != round_up(self.n.max(1), DENSE_NR)
            || self.data.len() != self.np * self.kp / 2
        {
            return false;
        }
        for j in 0..self.np {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for t in 0..nb {
                let base = ((q * nb + t) * DENSE_NR + r) * (DENSE_KB / 2);
                let blk = &self.data[base..base + DENSE_KB / 2];
                for tt in 0..DENSE_KB {
                    let kk = t * DENSE_KB + tt;
                    if (j >= self.n || kk >= self.k) && nibble(blk, tt) != 0 {
                        return false;
                    }
                }
            }
        }
        true
    }
}

// ---------------------------------------------------------------------------
// Variant dispatch (one cold match per row span; demoted by usable())
// ---------------------------------------------------------------------------

fn conv_span_dispatch(
    ch: GemmChoice,
    a: &[i8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
) {
    match ch.kernel {
        // SAFETY (all SIMD arms): usable() only lets a variant through
        // when the CPU/build has it, so the target features are present.
        #[cfg(all(target_arch = "x86_64", pallas_avx512))]
        Kernel::Avx512 => unsafe { avx512::conv_span(a, m, k, kp, b, c, n, ch.cfg) },
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { avx2::conv_span(a, m, k, kp, b, c, n, ch.cfg) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::conv_span(a, m, k, kp, b, c, n, ch.cfg) },
        _ => portable::conv_span(a, m, k, kp, b, c, n, ch.cfg),
    }
}

fn conv4_span_dispatch(
    ch: GemmChoice,
    a: &[u8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
) {
    match ch.kernel {
        // SAFETY (all SIMD arms): usable() guarantees availability.
        #[cfg(all(target_arch = "x86_64", pallas_avx512))]
        Kernel::Avx512 => unsafe { avx512::conv4_span(a, m, k, kp, b, c, n, ch.cfg) },
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { avx2::conv4_span(a, m, k, kp, b, c, n, ch.cfg) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::conv4_span(a, m, k, kp, b, c, n, ch.cfg) },
        _ => portable::conv4_span(a, m, k, kp, b, c, n, ch.cfg),
    }
}

fn dense_row_dispatch(ch: GemmChoice, arow: &[u8], w: &PackedDense, crow: &mut [i32]) {
    match ch.kernel {
        // SAFETY (all SIMD arms): usable() guarantees availability.
        #[cfg(all(target_arch = "x86_64", pallas_avx512))]
        Kernel::Avx512 => unsafe { avx512::dense_row(arow, w, crow, ch.cfg) },
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { avx2::dense_row(arow, w, crow, ch.cfg) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::dense_row(arow, w, crow, ch.cfg) },
        _ => portable::dense_row(arow, w, crow, ch.cfg),
    }
}

fn dense4_row_dispatch(ch: GemmChoice, arow: &[u8], w: &PackedDense4, crow: &mut [i32]) {
    match ch.kernel {
        // SAFETY (all SIMD arms): usable() guarantees availability.
        #[cfg(all(target_arch = "x86_64", pallas_avx512))]
        Kernel::Avx512 => unsafe { avx512::dense4_row(arow, w, crow, ch.cfg) },
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { avx2::dense4_row(arow, w, crow, ch.cfg) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::dense4_row(arow, w, crow, ch.cfg) },
        _ => portable::dense4_row(arow, w, crow, ch.cfg),
    }
}

// ---------------------------------------------------------------------------
// GEMM entry points (parallel over output rows, overwrite semantics)
// ---------------------------------------------------------------------------

/// `C[m,n] = A · B` for packed conv weights `a` (`m` rows of `kp` bytes,
/// logical reduction `k`), u8 im2col block `b` (`[k, n]` row-major) and
/// i32 output `c` (`[m, n]`, overwritten). Row-parallel over the worker
/// pool with the same grain as the scalar GEMM; inside a pool worker the
/// nested call runs serially, so the grouped-conv fan-out keeps its
/// existing split. `kern` is either a bare [`Kernel`] (default blocking)
/// or a full [`GemmChoice`] from the plan's autotune cache.
#[allow(clippy::too_many_arguments)]
pub fn gemm_conv_packed_into(
    kern: impl Into<GemmChoice>,
    a: &[i8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
) {
    debug_assert!(k >= 1, "conv GEMM needs a nonempty reduction");
    debug_assert_eq!(a.len(), m * kp, "packed A length");
    debug_assert_eq!(kp, round_up(k.max(1), CONV_KB), "conv K padding");
    debug_assert_eq!(b.len(), k * n, "B shape");
    debug_assert_eq!(c.len(), m * n, "C shape");
    if m == 0 || n == 0 {
        return;
    }
    let ch = usable(kern.into());
    parallel::par_ranges_mut(c, n, super::row_grain(k, n), |rows, span| {
        let aspan = &a[rows.start * kp..rows.end * kp];
        conv_span_dispatch(ch, aspan, rows.end - rows.start, k, kp, b, span, n);
    });
}

/// `C[m,n] = A · W^T` for u8 activations `a` (`[m, k]` row-major), packed
/// dense weights `w` (`n = w.n` outputs) and i32 output `c` (`[m, w.n]`,
/// overwritten). Row-parallel over images.
pub fn gemm_dense_packed_into(
    kern: impl Into<GemmChoice>,
    a: &[u8],
    w: &PackedDense,
    c: &mut [i32],
    m: usize,
) {
    let (k, nout) = (w.k, w.n);
    debug_assert_eq!(a.len(), m * k, "A shape");
    debug_assert_eq!(c.len(), m * nout, "C shape");
    if m == 0 || nout == 0 {
        return;
    }
    let ch = usable(kern.into());
    parallel::par_ranges_mut(c, nout, super::row_grain(k, nout), |rows, span| {
        for i in rows.clone() {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut span[(i - rows.start) * nout..(i - rows.start + 1) * nout];
            dense_row_dispatch(ch, arow, w, crow);
        }
    });
}

/// w4 conv GEMM: like [`gemm_conv_packed_into`], but `a` holds
/// nibble-packed rows of `kp/2` bytes ([`PackedConv4`] row slices). The
/// unpacked nibble is the exact i8 code, so the output is bit-identical
/// to the w8 GEMM over the same codes.
#[allow(clippy::too_many_arguments)]
pub fn gemm_conv4_packed_into(
    kern: impl Into<GemmChoice>,
    a: &[u8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
) {
    debug_assert!(k >= 1, "conv GEMM needs a nonempty reduction");
    debug_assert_eq!(a.len(), m * kp / 2, "packed4 A length");
    debug_assert_eq!(kp, round_up(k.max(1), CONV_KB), "conv K padding");
    debug_assert_eq!(b.len(), k * n, "B shape");
    debug_assert_eq!(c.len(), m * n, "C shape");
    if m == 0 || n == 0 {
        return;
    }
    let ch = usable(kern.into());
    let stride = kp / 2;
    parallel::par_ranges_mut(c, n, super::row_grain(k, n), |rows, span| {
        let aspan = &a[rows.start * stride..rows.end * stride];
        conv4_span_dispatch(ch, aspan, rows.end - rows.start, k, kp, b, span, n);
    });
}

/// w4 dense GEMM: like [`gemm_dense_packed_into`] over a nibble-packed
/// quad layout. Bit-identical to the w8 GEMM over the same codes.
pub fn gemm_dense4_packed_into(
    kern: impl Into<GemmChoice>,
    a: &[u8],
    w: &PackedDense4,
    c: &mut [i32],
    m: usize,
) {
    let (k, nout) = (w.k, w.n);
    debug_assert_eq!(a.len(), m * k, "A shape");
    debug_assert_eq!(c.len(), m * nout, "C shape");
    if m == 0 || nout == 0 {
        return;
    }
    let ch = usable(kern.into());
    parallel::par_ranges_mut(c, nout, super::row_grain(k, nout), |rows, span| {
        for i in rows.clone() {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut span[(i - rows.start) * nout..(i - rows.start + 1) * nout];
            dense4_row_dispatch(ch, arow, w, crow);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_simd_env_contract() {
        assert!(!no_simd_requested(None));
        assert!(!no_simd_requested(Some("")));
        assert!(!no_simd_requested(Some("0")));
        assert!(!no_simd_requested(Some(" 0 ")));
        assert!(no_simd_requested(Some("1")));
        assert!(no_simd_requested(Some("true")));
        assert!(no_simd_requested(Some("yes")));
    }

    #[test]
    fn forced_kernel_env_contract() {
        assert_eq!(forced_kernel(None), None);
        assert_eq!(forced_kernel(Some("")), None);
        assert_eq!(forced_kernel(Some("sse9")), None);
        assert_eq!(forced_kernel(Some("portable")), Some(Kernel::Portable));
        assert_eq!(forced_kernel(Some(" avx2 ")), Some(Kernel::Avx2));
        assert_eq!(forced_kernel(Some("avx512")), Some(Kernel::Avx512));
        assert_eq!(forced_kernel(Some("neon")), Some(Kernel::Neon));
        for k in Kernel::all() {
            assert_eq!(Kernel::from_name(k.name()), Some(k), "name/from_name roundtrip");
        }
    }

    #[test]
    fn select_is_consistent_with_detection() {
        let k = select();
        assert!(k.available(), "selected {} without CPU/build support", k.name());
        assert_eq!(k, select(), "cached selection must be stable");
    }

    #[test]
    fn usable_demotes_down_the_ladder() {
        // whatever the machine, usable() must land on an available kernel
        // and clamp the blocking config into range
        for k in Kernel::all() {
            for cfg in 0..=GEMM_CFGS {
                let ch = usable(GemmChoice::new(k, cfg));
                assert!(ch.kernel.available(), "usable() returned unavailable {}", ch.kernel.name());
                assert!(ch.cfg < cfg_count(ch.kernel), "cfg not clamped");
            }
        }
        // portable never demotes
        assert_eq!(usable(GemmChoice::new(Kernel::Portable, 0)).kernel, Kernel::Portable);
        // the Kernel -> GemmChoice adapter picks the default blocking
        let ch: GemmChoice = Kernel::Portable.into();
        assert_eq!(ch, GemmChoice::new(Kernel::Portable, 0));
        assert_eq!(ch.label(), "portable.c0");
    }

    #[test]
    fn conv_pack_layout() {
        let w: Vec<i8> = (0..3 * 5).map(|v| v as i8 - 7).collect();
        let p = PackedConv::pack(&w, 3, 5);
        assert_eq!((p.rows, p.k, p.kp), (3, 5, 6));
        assert!(p.layout_ok());
        for r in 0..3 {
            assert_eq!(&p.data[r * 6..r * 6 + 5], &w[r * 5..(r + 1) * 5]);
            assert_eq!(p.data[r * 6 + 5], 0, "pad byte of row {r}");
        }
        assert_eq!(p.row_slice(1..3).len(), 2 * 6);
        // even K needs no padding
        let q = PackedConv::pack(&w[..12], 3, 4);
        assert_eq!(q.kp, 4);
        assert!(q.layout_ok());
        // a corrupted pad byte must fail the invariant
        let mut bad = p.clone();
        bad.data[5] = 1;
        assert!(!bad.layout_ok());
    }

    #[test]
    fn conv4_pack_layout() {
        // odd K exercises the pad nibble
        let w: Vec<i8> = (0..3 * 5).map(|v| (v % 16 - 8) as i8).collect();
        let p = PackedConv4::pack(&w, 3, 5);
        assert_eq!((p.rows, p.k, p.kp), (3, 5, 6));
        assert_eq!(p.data.len(), 3 * 3);
        assert!(p.layout_ok());
        for r in 0..3 {
            let row = p.row_slice(r..r + 1);
            for kk in 0..5 {
                assert_eq!(nibble(row, kk), w[r * 5 + kk], "row {r} k {kk}");
            }
            assert_eq!(nibble(row, 5), 0, "pad nibble of row {r}");
        }
        // a corrupted pad nibble (high nibble of row 0's last byte) must
        // fail the invariant
        let mut bad = p;
        bad.data[2] |= 0xF0;
        assert!(!bad.layout_ok());
    }

    #[test]
    fn dense4_pack_layout_roundtrip() {
        // n and k both off the block sizes: 6 rows (np 8), k 21 (kp 32)
        let (n, k) = (6usize, 21usize);
        let w: Vec<i8> = (0..n * k).map(|v| (v % 16 - 8) as i8).collect();
        let p = PackedDense4::pack(&w, n, k);
        assert_eq!((p.np, p.kp), (8, 32));
        assert_eq!(p.data.len(), 8 * 32 / 2);
        assert!(p.layout_ok());
        let nb = p.kp / DENSE_KB;
        // every logical weight must be recoverable from the quad layout
        for j in 0..n {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for kk in 0..k {
                let (t, tt) = (kk / DENSE_KB, kk % DENSE_KB);
                let base = ((q * nb + t) * DENSE_NR + r) * (DENSE_KB / 2);
                let got = nibble(&p.data[base..base + DENSE_KB / 2], tt);
                assert_eq!(got, w[j * k + kk], "row {j} k {kk}");
            }
        }
        // a corrupted pad row must fail the invariant (row 6 is padding)
        let mut bad = p;
        let (q, r) = (6 / DENSE_NR, 6 % DENSE_NR);
        bad.data[((q * nb) * DENSE_NR + r) * (DENSE_KB / 2)] = 3;
        assert!(!bad.layout_ok());
    }

    #[test]
    fn w4_gemms_match_w8_over_same_codes() {
        // identical codes through the w8 and w4 paths must agree exactly
        let mut state = 12345u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u8
        };
        let (m, k, n) = (5usize, 27usize, 37usize);
        let w: Vec<i8> = (0..m * k).map(|_| (next() % 16) as i8 - 8).collect();
        let b: Vec<u8> = (0..k * n).map(|_| next()).collect();
        let p8 = PackedConv::pack(&w, m, k);
        let p4 = PackedConv4::pack(&w, m, k);
        let mut c8 = vec![0i32; m * n];
        let mut c4 = vec![0i32; m * n];
        gemm_conv_packed_into(Kernel::Portable, &p8.data, m, k, p8.kp, &b, &mut c8, n);
        gemm_conv4_packed_into(Kernel::Portable, &p4.data, m, k, p4.kp, &b, &mut c4, n);
        assert_eq!(c8, c4, "conv w4 != w8");

        let (mm, kk, nn) = (3usize, 21usize, 6usize);
        let wd: Vec<i8> = (0..nn * kk).map(|_| (next() % 16) as i8 - 8).collect();
        let a: Vec<u8> = (0..mm * kk).map(|_| next()).collect();
        let d8 = PackedDense::pack(&wd, nn, kk);
        let d4 = PackedDense4::pack(&wd, nn, kk);
        let mut c8 = vec![0i32; mm * nn];
        let mut c4 = vec![0i32; mm * nn];
        gemm_dense_packed_into(Kernel::Portable, &a, &d8, &mut c8, mm);
        gemm_dense4_packed_into(Kernel::Portable, &a, &d4, &mut c4, mm);
        assert_eq!(c8, c4, "dense w4 != w8");
    }

    #[test]
    fn dense_pack_layout_roundtrip() {
        // n and k both off the block sizes: 6 rows (np 8), k 21 (kp 32)
        let (n, k) = (6usize, 21usize);
        let w: Vec<i8> = (0..n * k).map(|v| (v as i32 % 251 - 125) as i8).collect();
        let p = PackedDense::pack(&w, n, k);
        assert_eq!((p.np, p.kp), (8, 32));
        assert!(p.layout_ok());
        let nb = p.kp / DENSE_KB;
        // every logical weight must be recoverable from the quad layout
        for j in 0..n {
            let (q, r) = (j / DENSE_NR, j % DENSE_NR);
            for kk in 0..k {
                let (t, tt) = (kk / DENSE_KB, kk % DENSE_KB);
                let byte = p.data[((q * nb + t) * DENSE_NR + r) * DENSE_KB + tt];
                assert_eq!(byte, w[j * k + kk], "row {j} k {kk}");
            }
        }
        // a corrupted pad row must fail the invariant (row 6 is padding)
        let mut bad = p.clone();
        let (q, r) = (6 / DENSE_NR, 6 % DENSE_NR);
        bad.data[((q * nb) * DENSE_NR + r) * DENSE_KB] = 3;
        assert!(!bad.layout_ok());
    }
}
