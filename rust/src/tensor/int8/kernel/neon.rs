//! AArch64 NEON cores: `smlal`/`smlal2` (`vmlal_s16`/`vmlal_high_s16`)
//! widening multiply-accumulates over explicitly widened i16 operands,
//! 16 positions (conv) / 16 reduction lanes (dense) per register pass.
//!
//! Exactness: `smlal` multiplies signed 16-bit lanes into exact i32
//! products and accumulates with plain (wrapping) i32 adds — no
//! saturation anywhere (the saturating `sqdmlal` family is never used).
//! Operands are the same u8→i16 / i8→i16 widenings every other variant
//! feeds its multiplier, so the module-docs exactness argument applies
//! unchanged and the NEON path is bit-identical to scalar.
//!
//! Blocking configs mirror AVX2: conv `c0` = 2-row tile, `c1` = 1-row;
//! dense `c0` = one accumulator quartet over the K-blocks, `c1` = two
//! interleaved quartets folded at the end.

#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use core::arch::aarch64::*;

use super::{nibble, PackedDense, PackedDense4, DENSE_KB, DENSE_NR};

/// Conv GEMM row span: `tile` output rows × 16 positions per pass; each
/// reduction step widens one 16-byte B row and fans the broadcast i16
/// weight into four i32 accumulators via `smlal`/`smlal2`.
#[target_feature(enable = "neon")]
pub unsafe fn conv_span(
    a: &[i8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
    cfg: u8,
) {
    let tile = if cfg == 0 { 2 } else { 1 };
    let n16 = n - n % 16;
    let bp = b.as_ptr();
    let mut i = 0;
    while i < m {
        let mr = (m - i).min(tile);
        let mut j = 0;
        while j < n16 {
            let mut acc = [[vdupq_n_s32(0); 4]; 2];
            for kk in 0..k {
                let bv = vld1q_u8(bp.add(kk * n + j));
                let blo = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(bv)));
                let bhi = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(bv)));
                for r in 0..mr {
                    let av = *a.get_unchecked((i + r) * kp + kk);
                    if av == 0 {
                        continue;
                    }
                    let wd = vdup_n_s16(av as i16);
                    let wq = vdupq_n_s16(av as i16);
                    acc[r][0] = vmlal_s16(acc[r][0], vget_low_s16(blo), wd);
                    acc[r][1] = vmlal_high_s16(acc[r][1], blo, wq);
                    acc[r][2] = vmlal_s16(acc[r][2], vget_low_s16(bhi), wd);
                    acc[r][3] = vmlal_high_s16(acc[r][3], bhi, wq);
                }
            }
            for r in 0..mr {
                let crow = c.as_mut_ptr().add((i + r) * n + j);
                vst1q_s32(crow, acc[r][0]);
                vst1q_s32(crow.add(4), acc[r][1]);
                vst1q_s32(crow.add(8), acc[r][2]);
                vst1q_s32(crow.add(12), acc[r][3]);
            }
            j += 16;
        }
        // position tail: exact scalar
        for r in 0..mr {
            let arow = &a[(i + r) * kp..(i + r) * kp + k];
            for jj in n16..n {
                let mut s = 0i32;
                for (kk, &av) in arow.iter().enumerate() {
                    s = s.wrapping_add(av as i32 * *b.get_unchecked(kk * n + jj) as i32);
                }
                *c.get_unchecked_mut((i + r) * n + jj) = s;
            }
        }
        i += mr;
    }
}

/// w4 conv GEMM row span: [`conv_span`] with the weight decoded from its
/// nibble on the fly. Same blocking, exact products — bit-identical.
#[target_feature(enable = "neon")]
pub unsafe fn conv4_span(
    a: &[u8],
    m: usize,
    k: usize,
    kp: usize,
    b: &[u8],
    c: &mut [i32],
    n: usize,
    cfg: u8,
) {
    let tile = if cfg == 0 { 2 } else { 1 };
    let n16 = n - n % 16;
    let stride = kp / 2;
    let bp = b.as_ptr();
    let mut i = 0;
    while i < m {
        let mr = (m - i).min(tile);
        let mut j = 0;
        while j < n16 {
            let mut acc = [[vdupq_n_s32(0); 4]; 2];
            for kk in 0..k {
                let bv = vld1q_u8(bp.add(kk * n + j));
                let blo = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(bv)));
                let bhi = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(bv)));
                for r in 0..mr {
                    let arow = &a[(i + r) * stride..(i + r + 1) * stride];
                    let av = nibble(arow, kk);
                    if av == 0 {
                        continue;
                    }
                    let wd = vdup_n_s16(av as i16);
                    let wq = vdupq_n_s16(av as i16);
                    acc[r][0] = vmlal_s16(acc[r][0], vget_low_s16(blo), wd);
                    acc[r][1] = vmlal_high_s16(acc[r][1], blo, wq);
                    acc[r][2] = vmlal_s16(acc[r][2], vget_low_s16(bhi), wd);
                    acc[r][3] = vmlal_high_s16(acc[r][3], bhi, wq);
                }
            }
            for r in 0..mr {
                let crow = c.as_mut_ptr().add((i + r) * n + j);
                vst1q_s32(crow, acc[r][0]);
                vst1q_s32(crow.add(4), acc[r][1]);
                vst1q_s32(crow.add(8), acc[r][2]);
                vst1q_s32(crow.add(12), acc[r][3]);
            }
            j += 16;
        }
        // position tail: exact scalar over decoded nibbles
        for r in 0..mr {
            let arow = &a[(i + r) * stride..(i + r + 1) * stride];
            for jj in n16..n {
                let mut s = 0i32;
                for kk in 0..k {
                    s = s.wrapping_add(
                        nibble(arow, kk) as i32 * *b.get_unchecked(kk * n + jj) as i32,
                    );
                }
                *c.get_unchecked_mut((i + r) * n + jj) = s;
            }
        }
        i += mr;
    }
}

/// One K-block's contribution to one weight lane: widen 16 activation
/// bytes and 16 weight bytes to i16 and chain four widening
/// multiply-accumulates into an i32x4 partial.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn block_mlal(alo: int16x8_t, ahi: int16x8_t, wv: int8x16_t) -> int32x4_t {
    let wlo = vmovl_s8(vget_low_s8(wv));
    let whi = vmovl_s8(vget_high_s8(wv));
    let mut con = vmull_s16(vget_low_s16(alo), vget_low_s16(wlo));
    con = vmlal_high_s16(con, alo, wlo);
    con = vmlal_s16(con, vget_low_s16(ahi), vget_low_s16(whi));
    vmlal_high_s16(con, ahi, whi)
}

/// Dense GEMM, one activation row: four packed weight rows per quad
/// share each widened 16-byte activation block; the K tail reads a
/// zero-padded stack copy (matching the zero K padding of the packed
/// rows). `cfg 1` folds alternating blocks through a second quartet.
#[target_feature(enable = "neon")]
pub unsafe fn dense_row(arow: &[u8], w: &PackedDense, crow: &mut [i32], cfg: u8) {
    let (k, kp) = (w.k, w.kp);
    let nb = kp / DENSE_KB;
    let full = k / DENSE_KB;
    let tail = k % DENSE_KB;
    let mut tailbuf = [0u8; DENSE_KB];
    if tail > 0 {
        tailbuf[..tail].copy_from_slice(&arow[full * DENSE_KB..]);
    }
    let wp = w.data.as_ptr();
    for q in 0..w.np / DENSE_NR {
        let mut acc = [vdupq_n_s32(0); 4];
        let mut acc2 = [vdupq_n_s32(0); 4];
        let base = q * nb * (DENSE_NR * DENSE_KB);
        for t in 0..nb {
            let ap = if t < full { arow.as_ptr().add(t * DENSE_KB) } else { tailbuf.as_ptr() };
            let av = vld1q_u8(ap);
            let alo = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(av)));
            let ahi = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(av)));
            let blk = wp.add(base + t * DENSE_NR * DENSE_KB);
            for r in 0..4 {
                let wv = vld1q_s8(blk.add(r * DENSE_KB));
                let con = block_mlal(alo, ahi, wv);
                if cfg != 0 && t % 2 == 1 {
                    acc2[r] = vaddq_s32(acc2[r], con);
                } else {
                    acc[r] = vaddq_s32(acc[r], con);
                }
            }
        }
        for r in 0..4 {
            let j = q * DENSE_NR + r;
            if j < crow.len() {
                *crow.get_unchecked_mut(j) = vaddvq_s32(vaddq_s32(acc[r], acc2[r]));
            }
        }
    }
}

/// The nibble→i8 unpack epilogue: 8 packed bytes → 16 sign-extended i8
/// weights in logical order (low nibble first), via the
/// shift-left-then-arithmetic-shift-right idiom on i8 lanes and a
/// low/high zip.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn nibbles_to_i8(p: *const u8) -> int8x16_t {
    let bytes = vreinterpret_s8_u8(vld1_u8(p));
    let lo = vshr_n_s8(vshl_n_s8(bytes, 4), 4);
    let hi = vshr_n_s8(bytes, 4);
    vcombine_s8(vzip1_s8(lo, hi), vzip2_s8(lo, hi))
}

/// w4 dense GEMM, one activation row: [`dense_row`] with each 16-weight
/// block decoded from 8 packed bytes by [`nibbles_to_i8`].
#[target_feature(enable = "neon")]
pub unsafe fn dense4_row(arow: &[u8], w: &PackedDense4, crow: &mut [i32], cfg: u8) {
    const KB2: usize = DENSE_KB / 2;
    let (k, kp) = (w.k, w.kp);
    let nb = kp / DENSE_KB;
    let full = k / DENSE_KB;
    let tail = k % DENSE_KB;
    let mut tailbuf = [0u8; DENSE_KB];
    if tail > 0 {
        tailbuf[..tail].copy_from_slice(&arow[full * DENSE_KB..]);
    }
    let wp = w.data.as_ptr();
    for q in 0..w.np / DENSE_NR {
        let mut acc = [vdupq_n_s32(0); 4];
        let mut acc2 = [vdupq_n_s32(0); 4];
        let base = q * nb * (DENSE_NR * KB2);
        for t in 0..nb {
            let ap = if t < full { arow.as_ptr().add(t * DENSE_KB) } else { tailbuf.as_ptr() };
            let av = vld1q_u8(ap);
            let alo = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(av)));
            let ahi = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(av)));
            let blk = wp.add(base + t * DENSE_NR * KB2);
            for r in 0..4 {
                let wv = nibbles_to_i8(blk.add(r * KB2));
                let con = block_mlal(alo, ahi, wv);
                if cfg != 0 && t % 2 == 1 {
                    acc2[r] = vaddq_s32(acc2[r], con);
                } else {
                    acc[r] = vaddq_s32(acc[r], con);
                }
            }
        }
        for r in 0..4 {
            let j = q * DENSE_NR + r;
            if j < crow.len() {
                *crow.get_unchecked_mut(j) = vaddvq_s32(vaddq_s32(acc[r], acc2[r]));
            }
        }
    }
}
