//! Compile-time micro-autotuner: time every available kernel variant ×
//! blocking config on a layer's *actual packed shape* and return the
//! fastest. Runs once per distinct shape during `compile_plan` (the plan
//! compiler memoizes by shape), never in the serving hot loop — the
//! winning [`GemmChoice`] is cached per op in the `QuantizedPlan`.
//!
//! Because every candidate is bit-identical (module docs), the tuner is
//! free to pick by time alone: a "wrong" pick under timer noise costs
//! only performance, never correctness or determinism of results.
//! `PALLAS_AUTOTUNE=0` skips tuning entirely (plans pin the
//! [`GemmChoice::heuristic`] choice); `PALLAS_KERNEL=<name>` narrows the
//! candidate set to one variant's blocking configs; `PALLAS_NO_SIMD=1`
//! narrows it to portable.
//!
//! Cost control: shapes are shrunk toward a fixed MAC budget before
//! timing (fewer rows first, then fewer positions — K is never cut, it
//! is what distinguishes the blocking configs), warmup 1 + min-of-3
//! timed reps per candidate, everything forced serial via
//! `with_threads(1)` so pool scheduling noise cannot leak into the
//! measurement. A full candidate sweep for one shape is a few
//! milliseconds; `QuantizedPlan::autotune_ms` reports the total.

use std::time::Instant;

use super::{
    cfg_count, forced_kernel, gemm_conv4_packed_into, gemm_conv_packed_into,
    gemm_dense4_packed_into, gemm_dense_packed_into, no_simd_requested, usable, GemmChoice,
    Kernel, PackedConv, PackedConv4, PackedDense, PackedDense4,
};
use crate::util::parallel;

/// Nominal batch (GEMM row count) dense layers are tuned at — the
/// serving batcher's typical fill, not `max_batch`, so the tuned choice
/// reflects steady-state traffic.
pub const TUNE_BATCH: usize = 8;

/// Total MACs one timed rep targets; shapes shrink toward this so a deep
/// layer doesn't stall plan compilation (64 candidates × a 150M-MAC conv
/// would be seconds per layer).
const MAC_BUDGET: usize = 1 << 19;
/// Timed reps per candidate (min taken); one extra warmup rep runs first.
const REPS: usize = 3;

/// The candidate set on this machine: every available variant × its
/// blocking configs, honoring `PALLAS_NO_SIMD` and `PALLAS_KERNEL`.
/// Deterministic order (widest ISA first), so ties break identically
/// across runs on the same machine.
pub fn candidates() -> Vec<GemmChoice> {
    let kernels: Vec<Kernel> = if no_simd_requested(std::env::var("PALLAS_NO_SIMD").ok().as_deref())
    {
        vec![Kernel::Portable]
    } else if let Some(k) = forced_kernel(std::env::var("PALLAS_KERNEL").ok().as_deref()) {
        vec![usable(GemmChoice::from(k)).kernel]
    } else {
        Kernel::all().into_iter().filter(|k| k.available()).collect()
    };
    kernels
        .into_iter()
        .flat_map(|k| (0..cfg_count(k)).map(move |cfg| GemmChoice::new(k, cfg)))
        .collect()
}

/// Deterministic synthetic fill (LCG) — the tuner must not perturb or
/// depend on any global RNG state.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u8 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (self.0 >> 33) as u8
    }
}

/// Shrink `(rows, cols)` toward [`MAC_BUDGET`] for reduction length `k`,
/// never below the given floors (the floors keep at least one full SIMD
/// tile in play so the measurement exercises the vector body).
fn shrink(mut rows: usize, mut cols: usize, k: usize, row_floor: usize, col_floor: usize) -> (usize, usize) {
    while rows * k * cols > MAC_BUDGET && rows > row_floor {
        rows = (rows / 2).max(row_floor);
    }
    while rows * k * cols > MAC_BUDGET && cols > col_floor {
        cols = (cols / 2).max(col_floor);
    }
    (rows, cols)
}

/// Warmup + min-of-[`REPS`] wall time of `run`, serial.
fn time_min(mut run: impl FnMut()) -> f64 {
    parallel::with_threads(1, &mut run);
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let t0 = Instant::now();
        parallel::with_threads(1, &mut run);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn pick(cands: &[GemmChoice], mut run: impl FnMut(GemmChoice)) -> GemmChoice {
    let mut best = (cands[0], f64::INFINITY);
    for &ch in cands {
        let t = time_min(|| run(ch));
        if t < best.1 {
            best = (ch, t);
        }
    }
    best.0
}

/// Tune the conv GEMM for a layer with `rows` output channels, reduction
/// `k` (im2col patch) and `npos` output positions, in the weight dtype
/// the plan packed (`w4`). Returns the heuristic choice immediately when
/// there is only one candidate.
pub fn tune_conv(rows: usize, k: usize, npos: usize, w4: bool) -> GemmChoice {
    let cands = candidates();
    if cands.len() == 1 {
        return cands[0];
    }
    let (m, n) = shrink(rows.max(1), npos.max(1), k.max(1), 2, 64.min(npos.max(1)));
    let k = k.max(1);
    let mut lcg = Lcg(0x9e3779b97f4a7c15);
    let w: Vec<i8> = (0..m * k)
        .map(|_| if w4 { (lcg.next() % 16) as i8 - 8 } else { lcg.next() as i8 })
        .collect();
    let b: Vec<u8> = (0..k * n).map(|_| lcg.next()).collect();
    let mut c = vec![0i32; m * n];
    if w4 {
        let p = PackedConv4::pack(&w, m, k);
        pick(&cands, |ch| gemm_conv4_packed_into(ch, &p.data, m, k, p.kp, &b, &mut c, n))
    } else {
        let p = PackedConv::pack(&w, m, k);
        pick(&cands, |ch| gemm_conv_packed_into(ch, &p.data, m, k, p.kp, &b, &mut c, n))
    }
}

/// Tune the dense GEMM for a layer with `nout` outputs and reduction
/// `k`, at the nominal serving batch [`TUNE_BATCH`].
pub fn tune_dense(nout: usize, k: usize, w4: bool) -> GemmChoice {
    let cands = candidates();
    if cands.len() == 1 {
        return cands[0];
    }
    let (m, n) = shrink(TUNE_BATCH, nout.max(1), k.max(1), 1, 4.min(nout.max(1)));
    let k = k.max(1);
    let mut lcg = Lcg(0xd1b54a32d192ed03);
    let w: Vec<i8> = (0..n * k)
        .map(|_| if w4 { (lcg.next() % 16) as i8 - 8 } else { lcg.next() as i8 })
        .collect();
    let a: Vec<u8> = (0..m * k).map(|_| lcg.next()).collect();
    let mut c = vec![0i32; m * n];
    if w4 {
        let p = PackedDense4::pack(&w, n, k);
        pick(&cands, |ch| gemm_dense4_packed_into(ch, &a, &p, &mut c, m))
    } else {
        let p = PackedDense::pack(&w, n, k);
        pick(&cands, |ch| gemm_dense_packed_into(ch, &a, &p, &mut c, m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_cover_available_variants_with_all_cfgs() {
        use super::super::GEMM_CFGS;
        let cands = candidates();
        assert!(!cands.is_empty());
        for ch in &cands {
            assert!(ch.kernel.available(), "unavailable candidate {}", ch.kernel.name());
            assert!(ch.cfg < cfg_count(ch.kernel));
        }
        // portable is always a candidate unless PALLAS_KERNEL pins
        // another variant (the env is not set under `cargo test` unless
        // the CI sweep sets it — then the forced variant must be the
        // only kernel present)
        let kernels: std::collections::BTreeSet<&str> =
            cands.iter().map(|c| c.kernel.name()).collect();
        match forced_kernel(std::env::var("PALLAS_KERNEL").ok().as_deref()) {
            Some(_) => assert_eq!(kernels.len(), 1, "forced sweep must pin one variant"),
            None => assert!(kernels.contains("portable")),
        }
        // every candidate must carry each cfg of its kernel
        for k in kernels {
            let n = cands.iter().filter(|c| c.kernel.name() == k).count();
            assert_eq!(n as u8, GEMM_CFGS, "cfg sweep for {k}");
        }
    }

    #[test]
    fn tuner_returns_usable_choices_fast() {
        let t0 = std::time::Instant::now();
        for &w4 in &[false, true] {
            let ch = tune_conv(8, 27, 196, w4);
            assert!(ch.kernel.available());
            let ch = tune_dense(10, 64, w4);
            assert!(ch.kernel.available());
        }
        // generous bound: 4 tunes of budgeted shapes must stay well
        // under a second even on a loaded CI box
        assert!(t0.elapsed().as_secs_f64() < 10.0, "tuner too slow");
    }

    #[test]
    fn shrink_respects_budget_and_floors() {
        let (r, c) = shrink(64, 512, 4608, 2, 64);
        assert!(r >= 2 && c >= 64);
        // K is preserved by construction; rows shrink first
        assert!(r < 64, "rows should shrink under a 151M-MAC shape");
        let (r, c) = shrink(4, 16, 9, 2, 16);
        assert_eq!((r, c), (4, 16), "under-budget shapes are untouched");
    }
}
