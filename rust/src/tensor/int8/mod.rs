//! Integer tensor substrate for the serving engine: i8 weights, u8
//! activations, i32 accumulators.
//!
//! The serving convention follows the standard asymmetric scheme: an
//! activation tensor holds `q: u8` with `real = scale * (q - zero_point)`,
//! weights hold `z: i8` with `real = scale * z` (symmetric, per output
//! channel). GEMMs accumulate in i32 and requantize back to u8 at the
//! layer boundary ([`crate::serve`]).
//!
//! The GEMM kernels mirror the f32 kernels in [`super::matmul`]: output
//! rows split into contiguous per-thread spans over
//! [`crate::util::parallel`], serial per-item code, so results are
//! identical for any `PALLAS_THREADS` (trivially bit-exact here — integer
//! arithmetic has no reduction-order sensitivity, but the splitting rule
//! is kept anyway for uniformity).
//!
//! The scalar GEMMs in this file ([`gemm_i8_into`], [`gemm_u8_bt_into`])
//! are the *reference* implementations — simple, unpacked, and the oracle
//! the differential harness (`rust/tests/int8_kernels.rs`) checks against.
//! The serving engine's hot loop runs the runtime-dispatched packed
//! micro-kernels in [`kernel`] instead, which are bit-identical to these
//! by construction.

pub mod kernel;

use crate::util::parallel;

/// Row-major dense i8 tensor (quantized weights).
#[derive(Clone, Debug, PartialEq)]
pub struct I8Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
}

impl I8Tensor {
    pub fn zeros(shape: &[usize]) -> I8Tensor {
        let n: usize = shape.iter().product();
        I8Tensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i8>) -> I8Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} != data len {}", shape, data.len());
        I8Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Row-major dense u8 tensor (quantized activations).
#[derive(Clone, Debug, PartialEq)]
pub struct U8Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl U8Tensor {
    pub fn zeros(shape: &[usize]) -> U8Tensor {
        let n: usize = shape.iter().product();
        U8Tensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn full(shape: &[usize], v: u8) -> U8Tensor {
        let n: usize = shape.iter().product();
        U8Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<u8>) -> U8Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} != data len {}", shape, data.len());
        U8Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// True iff every code fits the signed 4-bit range `[-8, 7]` — the
/// precondition for the nibble-packed w4 layouts and `.qtz` i4 entries.
pub fn fits_i4(codes: &[i8]) -> bool {
    codes.iter().all(|&z| (-8..=7).contains(&z))
}

/// Sign-extend the **low** nibble of `b`: shift-left-then-arithmetic-
/// shift-right, the scalar form of the SIMD unpack epilogue.
#[inline]
pub fn i4_lo(b: u8) -> i8 {
    ((b << 4) as i8) >> 4
}

/// Sign-extend the **high** nibble of `b`.
#[inline]
pub fn i4_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

/// Pack i4 codes (each in `[-8, 7]`, checked) two per byte: element `2j`
/// in the low nibble of byte `j`, element `2j+1` in the high nibble. An
/// odd tail leaves the final high nibble zero, so `n.div_ceil(2)` bytes
/// always reproduce exactly `n` codes.
pub fn pack_i4(codes: &[i8]) -> Vec<u8> {
    assert!(fits_i4(codes), "i4 pack: code outside [-8, 7]");
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    for (j, pair) in codes.chunks(2).enumerate() {
        let lo = (pair[0] as u8) & 0x0F;
        let hi = if pair.len() == 2 { (pair[1] as u8) & 0x0F } else { 0 };
        out[j] = (hi << 4) | lo;
    }
    out
}

/// Unpack `n` i4 codes from the nibble stream written by [`pack_i4`].
pub fn unpack_i4(packed: &[u8], n: usize) -> Vec<i8> {
    assert_eq!(packed.len(), n.div_ceil(2), "i4 unpack: {} bytes for {n} codes", packed.len());
    (0..n)
        .map(|j| {
            let b = packed[j / 2];
            if j % 2 == 0 { i4_lo(b) } else { i4_hi(b) }
        })
        .collect()
}

/// Don't spawn a worker for less than ~256k MACs of row work (integer MACs
/// are cheaper than f32 FMA, so the grain sits above the f32 kernel's).
const MIN_PAR_MACS: usize = 1 << 18;

pub(crate) fn row_grain(k: usize, n: usize) -> usize {
    (MIN_PAR_MACS / (k * n).max(1)).max(1)
}

/// C += A @ B with A i8 [m,k], B u8 [k,n], C i32 [m,n] — the conv GEMM of
/// the integer engine (A = weights, B = im2col columns). Same k-streaming
/// loop order as [`super::matmul::matmul_into`]: within a row span, each
/// B row is widened once and fanned into the i32 C row, which stays hot.
pub fn gemm_i8_into(a: &[i8], b: &[u8], c: &mut [i32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    parallel::par_ranges_mut(c, n, row_grain(k, n), |rows, span| {
        for i in rows.clone() {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut span[(i - rows.start) * n..(i - rows.start + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0 {
                    continue;
                }
                let av = av as i32;
                let brow = &b[kk * n..(kk + 1) * n];
                // widening multiply-accumulate over the row; vectorizes to
                // packed 8->32 widening + 32-bit multiply-add
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv as i32;
                }
            }
        }
    });
}

/// C[i,j] = dot(A_row_i_u8, B_row_j_i8) for A [m,k] u8, B [n,k] i8 —
/// C = A @ B^T, the dense-layer form (activations x weight rows). Four
/// weight rows share one streaming pass over the activation row, as in
/// [`super::matmul::matmul_bt_into`].
pub fn gemm_u8_bt_into(a: &[u8], bt: &[i8], c: &mut [i32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(bt.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    parallel::par_ranges_mut(c, n, row_grain(k, n), |rows, span| {
        for i in rows.clone() {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut span[(i - rows.start) * n..(i - rows.start + 1) * n];
            dot_rows_u8_i8(arow, bt, crow, k, n);
        }
    });
}

/// One output row of A @ B^T: crow[j] = dot(arow, bt[j]).
fn dot_rows_u8_i8(arow: &[u8], bt: &[i8], crow: &mut [i32], k: usize, n: usize) {
    let arow = &arow[..k];
    let n4 = n - n % 4;
    let mut j = 0;
    while j < n4 {
        let b0 = &bt[j * k..][..k];
        let b1 = &bt[(j + 1) * k..][..k];
        let b2 = &bt[(j + 2) * k..][..k];
        let b3 = &bt[(j + 3) * k..][..k];
        let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
        for t in 0..k {
            let av = arow[t] as i32;
            s0 += av * b0[t] as i32;
            s1 += av * b1[t] as i32;
            s2 += av * b2[t] as i32;
            s3 += av * b3[t] as i32;
        }
        crow[j] = s0;
        crow[j + 1] = s1;
        crow[j + 2] = s2;
        crow[j + 3] = s3;
        j += 4;
    }
    while j < n {
        let brow = &bt[j * k..][..k];
        let mut acc = 0i32;
        for t in 0..k {
            acc += arow[t] as i32 * brow[t] as i32;
        }
        crow[j] = acc;
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::with_threads;
    use crate::util::Rng;

    fn rnd_i8(n: usize, rng: &mut Rng) -> Vec<i8> {
        (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
    }

    fn rnd_u8(n: usize, rng: &mut Rng) -> Vec<u8> {
        (0..n).map(|_| rng.below(256) as u8).collect()
    }

    fn naive_gemm(a: &[i8], b: &[u8], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i64;
                for t in 0..k {
                    acc += a[i * k + t] as i64 * b[t * n + j] as i64;
                }
                c[i * n + j] = acc as i32;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1usize, 1usize, 1usize), (3, 7, 5), (16, 64, 33), (8, 128, 100)] {
            let a = rnd_i8(m * k, &mut rng);
            let b = rnd_u8(k * n, &mut rng);
            let mut c = vec![0i32; m * n];
            gemm_i8_into(&a, &b, &mut c, m, k, n);
            assert_eq!(c, naive_gemm(&a, &b, m, k, n), "gemm {m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_accumulates() {
        let a = vec![2i8, -3];
        let b = vec![1u8, 4];
        let mut c = vec![10i32];
        gemm_i8_into(&a, &b, &mut c, 1, 2, 1);
        assert_eq!(c, vec![10 + 2 - 12]);
    }

    #[test]
    fn bt_matches_transposed_gemm() {
        let mut rng = Rng::new(2);
        for (m, k, n) in [(2usize, 9usize, 6usize), (5, 40, 13), (1, 3, 1)] {
            let a = rnd_u8(m * k, &mut rng);
            let bt = rnd_i8(n * k, &mut rng);
            let mut c = vec![0i32; m * n];
            gemm_u8_bt_into(&a, &bt, &mut c, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0i32;
                    for t in 0..k {
                        acc += a[i * k + t] as i32 * bt[j * k + t] as i32;
                    }
                    assert_eq!(c[i * n + j], acc, "bt gemm {m}x{k}x{n} at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn identical_across_threads() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (37, 130, 220);
        let a = rnd_i8(m * k, &mut rng);
        let b = rnd_u8(k * n, &mut rng);
        let run = |threads: usize| {
            with_threads(threads, || {
                let mut c = vec![0i32; m * n];
                gemm_i8_into(&a, &b, &mut c, m, k, n);
                c
            })
        };
        assert_eq!(run(1), run(4));
        let bt = rnd_i8(n * k, &mut rng);
        let au = rnd_u8(m * k, &mut rng);
        let run_bt = |threads: usize| {
            with_threads(threads, || {
                let mut c = vec![0i32; m * n];
                gemm_u8_bt_into(&au, &bt, &mut c, m, k, n);
                c
            })
        };
        assert_eq!(run_bt(1), run_bt(4));
    }

    #[test]
    fn i4_codec_roundtrips_all_codes() {
        // every code value, even and odd lengths, including the -8/7 corners
        let codes: Vec<i8> = (-8..=7).collect();
        for n in 0..codes.len() {
            let sub = &codes[..n];
            let packed = pack_i4(sub);
            assert_eq!(packed.len(), n.div_ceil(2));
            assert_eq!(unpack_i4(&packed, n), sub, "roundtrip n={n}");
            if n % 2 == 1 {
                assert_eq!(packed[n / 2] >> 4, 0, "odd tail pad nibble must be zero");
            }
        }
        assert_eq!(i4_lo(0xF8), -8);
        assert_eq!(i4_lo(0x07), 7);
        assert_eq!(i4_hi(0x80), -8);
        assert_eq!(i4_hi(0x7F), 7);
        assert_eq!(i4_hi(0xFF), -1);
    }

    #[test]
    #[should_panic(expected = "i4 pack")]
    fn i4_pack_rejects_out_of_range() {
        pack_i4(&[8]);
    }

    #[test]
    fn tensor_constructors() {
        let t = I8Tensor::from_vec(&[2, 2], vec![1, -2, 3, -4]);
        assert_eq!(t.numel(), 4);
        let u = U8Tensor::full(&[3], 7);
        assert_eq!(u.data, vec![7, 7, 7]);
        assert_eq!(U8Tensor::zeros(&[2, 3]).numel(), 6);
    }
}
