//! Pooling / resize ops used by the model graph executor.

use super::Tensor;

/// Average pooling, VALID padding: input [N,C,H,W] -> [N,C,H/s,W/s].
pub fn avgpool2d(input: &Tensor, k: usize, stride: usize) -> Tensor {
    let (n, c, h, w) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    let ho = (h - k) / stride + 1;
    let wo = (w - k) / stride + 1;
    let mut out = Tensor::zeros(&[n, c, ho, wo]);
    let inv = 1.0 / (k * k) as f32;
    for ni in 0..n {
        for ci in 0..c {
            let src = &input.data[((ni * c + ci) * h * w)..((ni * c + ci + 1) * h * w)];
            let dst = &mut out.data[((ni * c + ci) * ho * wo)..((ni * c + ci + 1) * ho * wo)];
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = 0.0f32;
                    for ky in 0..k {
                        for kx in 0..k {
                            acc += src[(oy * stride + ky) * w + ox * stride + kx];
                        }
                    }
                    dst[oy * wo + ox] = acc * inv;
                }
            }
        }
    }
    out
}

/// Global average pool: [N,C,H,W] -> [N,C], or (transformer path)
/// [N,S,D] -> [N,D] — mean over the sequence dim.
pub fn global_avgpool(input: &Tensor) -> Tensor {
    if input.ndim() == 3 {
        let (n, s, d) = (input.shape[0], input.shape[1], input.shape[2]);
        let mut out = Tensor::zeros(&[n, d]);
        for ni in 0..n {
            for si in 0..s {
                let src = &input.data[(ni * s + si) * d..(ni * s + si + 1) * d];
                for (o, &v) in out.data[ni * d..(ni + 1) * d].iter_mut().zip(src) {
                    *o += v;
                }
            }
        }
        let inv = 1.0 / s as f32;
        for v in &mut out.data {
            *v *= inv;
        }
        return out;
    }
    let (n, c, h, w) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    let hw = (h * w) as f32;
    let mut out = Tensor::zeros(&[n, c]);
    for ni in 0..n {
        for ci in 0..c {
            let src = &input.data[((ni * c + ci) * h * w)..((ni * c + ci + 1) * h * w)];
            out.data[ni * c + ci] = src.iter().sum::<f32>() / hw;
        }
    }
    out
}

/// Nearest-neighbor x2 upsample: [N,C,H,W] -> [N,C,2H,2W].
pub fn upsample2x(input: &Tensor) -> Tensor {
    let (n, c, h, w) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    let mut out = Tensor::zeros(&[n, c, 2 * h, 2 * w]);
    for nc in 0..n * c {
        let src = &input.data[nc * h * w..(nc + 1) * h * w];
        let dst = &mut out.data[nc * 4 * h * w..(nc + 1) * 4 * h * w];
        for y in 0..2 * h {
            for x in 0..2 * w {
                dst[y * 2 * w + x] = src[(y / 2) * w + x / 2];
            }
        }
    }
    out
}

/// Channel concat: all inputs [N,Ci,H,W] -> [N, sum Ci, H, W].
pub fn concat_channels(inputs: &[&Tensor]) -> Tensor {
    let (n, h, w) = (inputs[0].shape[0], inputs[0].shape[2], inputs[0].shape[3]);
    let ctot: usize = inputs.iter().map(|t| t.shape[1]).sum();
    let mut out = Tensor::zeros(&[n, ctot, h, w]);
    let hw = h * w;
    for ni in 0..n {
        let mut coff = 0;
        for t in inputs {
            let ci = t.shape[1];
            let src = &t.data[ni * ci * hw..(ni + 1) * ci * hw];
            let dst = &mut out.data[(ni * ctot + coff) * hw..(ni * ctot + coff + ci) * hw];
            dst.copy_from_slice(src);
            coff += ci;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avgpool_2x2() {
        let input = Tensor::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let out = avgpool2d(&input, 2, 2);
        assert_eq!(out.shape, vec![1, 1, 1, 1]);
        assert!((out.data[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn gpool() {
        let input = Tensor::from_vec(&[1, 2, 1, 2], vec![1., 3., 10., 20.]);
        let out = global_avgpool(&input);
        assert_eq!(out.shape, vec![1, 2]);
        assert_eq!(out.data, vec![2.0, 15.0]);
    }

    #[test]
    fn upsample_nearest() {
        let input = Tensor::from_vec(&[1, 1, 1, 2], vec![1., 2.]);
        let out = upsample2x(&input);
        assert_eq!(out.shape, vec![1, 1, 2, 4]);
        assert_eq!(out.data, vec![1., 1., 2., 2., 1., 1., 2., 2.]);
    }

    #[test]
    fn concat() {
        let a = Tensor::full(&[2, 1, 1, 1], 1.0);
        let b = Tensor::full(&[2, 2, 1, 1], 2.0);
        let out = concat_channels(&[&a, &b]);
        assert_eq!(out.shape, vec![2, 3, 1, 1]);
        assert_eq!(out.data, vec![1., 2., 2., 1., 2., 2.]);
    }
}
