//! Dense f32 tensor substrate (row-major, contiguous).
//!
//! Everything the PTQ pipeline computes natively — im2col convolution,
//! pooling, fake-quant, QUBO matrices — runs on this minimal tensor type.
//! The matmul kernel in [`matmul`] is the native-engine hot path and is
//! tuned in the perf pass (see EXPERIMENTS.md §Perf).

pub mod attention;
pub mod conv;
pub mod int8;
pub mod matmul;
pub mod pool;

pub use attention::{
    attn_apply, attn_scores, embedding_lookup, gelu, layernorm, softmax_lastdim,
};
pub use conv::{conv2d, conv2d_with, im2col, im2col_into, Conv2dParams, Conv2dWorkspace};
pub use int8::{I8Tensor, U8Tensor};
pub use matmul::{matmul, matmul_acc, matmul_bt, matmul_bt_into, matmul_into};

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} != data len {}", shape, data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Reinterpret shape (cheap; panics if element count differs).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // ---- 2-D helpers (rows x cols) ----

    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[1]
    }

    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        let cols = self.shape[1];
        self.data[r * cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    /// A^T for 2-D tensors. Parallel over output rows (each worker gathers
    /// one strided column of the source); split by row index, so the
    /// result is identical for any thread count.
    pub fn transpose2(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[c, r]);
        if r == 0 || c == 0 {
            return out;
        }
        let src = &self.data;
        let grain = ((1 << 14) / r.max(1)).max(1);
        crate::util::parallel::par_chunks_mut(&mut out.data, r, grain, |j, orow| {
            for (i, o) in orow.iter_mut().enumerate() {
                *o = src[i * c + j];
            }
        });
        out
    }

    // ---- elementwise / reductions ----

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn relu(&self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    pub fn relu_inplace(&mut self) {
        self.map_inplace(|x| x.max(0.0));
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn add_inplace(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn scale(&self, k: f32) -> Tensor {
        self.map(|x| x * k)
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        self.sum() / self.numel() as f64
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn min_max(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in &self.data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        (lo, hi)
    }

    /// Mean squared difference — the workhorse metric of the paper.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        let mut acc = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = (a - b) as f64;
            acc += d * d;
        }
        acc / self.numel() as f64
    }

    /// Frobenius norm squared of the difference (paper's ||.||_F^2).
    pub fn frob2(&self, other: &Tensor) -> f64 {
        self.mse(other) * self.numel() as f64
    }

    /// argmax along the last axis of a 2-D tensor (per row).
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows())
            .map(|r| {
                let row = self.row(r);
                let mut best = 0;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }
}

/// Integer tensor for labels / masks.
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> IntTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        IntTensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        let tt = t.transpose2();
        assert_eq!(tt.shape, vec![3, 2]);
        assert_eq!(tt.at2(2, 1), 6.0);
    }

    #[test]
    fn elementwise() {
        let a = Tensor::from_vec(&[2, 2], vec![1., -2., 3., -4.]);
        let b = Tensor::from_vec(&[2, 2], vec![1., 1., 1., 1.]);
        assert_eq!(a.add(&b).data, vec![2., -1., 4., -3.]);
        assert_eq!(a.relu().data, vec![1., 0., 3., 0.]);
        assert_eq!(a.abs_max(), 4.0);
        assert_eq!(a.min_max(), (-4.0, 3.0));
    }

    #[test]
    fn mse_and_frob() {
        let a = Tensor::from_vec(&[1, 4], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[1, 4], vec![1., 2., 3., 2.]);
        assert!((a.mse(&b) - 1.0).abs() < 1e-12);
        assert!((a.frob2(&b) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.2, 5.0, -1.0, 2.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }
}
