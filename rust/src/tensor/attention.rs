//! Transformer op math: layernorm, softmax (with causal masking), GELU,
//! batched multi-head attention matmuls and embedding lookup.
//!
//! Activation layout contract (see docs/ARCHITECTURE.md):
//!
//! * token ids enter as f32 `[N, 1, 1, S]` (4-D, so the calibration
//!   pipeline's image-chunk slicing applies unchanged),
//! * [`embedding_lookup`] produces `[N, S, D]`,
//! * [`attn_scores`] (QK^T, scaled by 1/sqrt(D/H)) produces `[N, H, S, S]`,
//! * [`attn_apply`] (probs · V) merges the heads back to `[N, S, D]`.
//!
//! All loops here are serial per tensor: the calibration streams already
//! fan out across chunks ([`crate::util::parallel`]), so keeping the op
//! bodies serial avoids nested pools and makes bit-identical execution
//! trivial at any `PALLAS_THREADS`.

use super::Tensor;

/// LayerNorm epsilon (matches the usual transformer default).
pub const LN_EPS: f32 = 1e-5;

/// Per-token LayerNorm over the last dimension:
/// y = (x - mean) / sqrt(var + eps) * gamma + beta.
pub fn layernorm(x: &Tensor, gamma: &[f32], beta: &[f32]) -> Tensor {
    let d = *x.shape.last().expect("layernorm needs >= 1 dim");
    assert_eq!(gamma.len(), d, "layernorm gamma len {} != feature dim {d}", gamma.len());
    assert_eq!(beta.len(), d, "layernorm beta len {} != feature dim {d}", beta.len());
    let rows = x.numel() / d.max(1);
    let mut out = Tensor::zeros(&x.shape);
    for r in 0..rows {
        let src = &x.data[r * d..(r + 1) * d];
        let dst = &mut out.data[r * d..(r + 1) * d];
        let mean = src.iter().sum::<f32>() / d as f32;
        let var = src.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for i in 0..d {
            dst[i] = (src[i] - mean) * inv * gamma[i] + beta[i];
        }
    }
    out
}

/// Softmax over the last dimension. With `causal` the tensor's last two
/// dims must be a square `[S, S]` (query x key); entries with key index
/// j > query index i are masked out before normalization.
pub fn softmax_lastdim(t: &Tensor, causal: bool) -> Tensor {
    let d = *t.shape.last().expect("softmax needs >= 1 dim");
    if causal {
        assert!(
            t.ndim() >= 2 && t.shape[t.ndim() - 2] == d,
            "causal softmax needs square [.., S, S] scores, got {:?}",
            t.shape
        );
    }
    let rows = t.numel() / d.max(1);
    let mut out = Tensor::zeros(&t.shape);
    for r in 0..rows {
        let src = &t.data[r * d..(r + 1) * d];
        let dst = &mut out.data[r * d..(r + 1) * d];
        // within each [S, S] square, row r % d is query index i
        let keep = if causal { (r % d) + 1 } else { d };
        let m = src[..keep].iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for i in 0..keep {
            let e = (src[i] - m).exp();
            dst[i] = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for v in &mut dst[..keep] {
            *v *= inv;
        }
        // masked tail stays exactly 0.0
    }
    out
}

/// GELU, tanh approximation (Hendrycks & Gimpel):
/// 0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3))).
pub fn gelu(x: &Tensor) -> Tensor {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    x.map(|v| 0.5 * v * (1.0 + (C * (v + 0.044_715 * v * v * v)).tanh()))
}

/// Scaled multi-head attention scores: Q, K `[N, S, D]` with D = H * Dh
/// -> scores `[N, H, S, S]`, scores[n,h,i,j] = Q_nh[i] · K_nh[j] / sqrt(Dh).
pub fn attn_scores(q: &Tensor, k: &Tensor, heads: usize) -> Tensor {
    assert_eq!(q.ndim(), 3, "attn_scores expects [N,S,D] queries, got {:?}", q.shape);
    assert_eq!(q.shape, k.shape, "Q {:?} vs K {:?} shape mismatch", q.shape, k.shape);
    let (n, s, d) = (q.shape[0], q.shape[1], q.shape[2]);
    assert!(heads >= 1 && d % heads == 0, "d_model {d} not divisible by {heads} heads");
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = Tensor::zeros(&[n, heads, s, s]);
    for ni in 0..n {
        for h in 0..heads {
            let h0 = h * dh;
            for i in 0..s {
                let qrow = &q.data[(ni * s + i) * d + h0..(ni * s + i) * d + h0 + dh];
                let orow = &mut out.data[((ni * heads + h) * s + i) * s..][..s];
                for (j, o) in orow.iter_mut().enumerate() {
                    let krow = &k.data[(ni * s + j) * d + h0..(ni * s + j) * d + h0 + dh];
                    let mut acc = 0.0f32;
                    for t in 0..dh {
                        acc += qrow[t] * krow[t];
                    }
                    *o = acc * scale;
                }
            }
        }
    }
    out
}

/// Attention application: probs `[N, H, S, S]` x V `[N, S, D]` (D = H * Dh)
/// -> `[N, S, D]` with the heads merged back into the feature dim.
pub fn attn_apply(p: &Tensor, v: &Tensor, heads: usize) -> Tensor {
    assert_eq!(p.ndim(), 4, "attn_apply expects [N,H,S,S] probs, got {:?}", p.shape);
    assert_eq!(v.ndim(), 3, "attn_apply expects [N,S,D] values, got {:?}", v.shape);
    let (n, h, s) = (p.shape[0], p.shape[1], p.shape[2]);
    let d = v.shape[2];
    assert_eq!(h, heads, "probs carry {h} heads, op declares {heads}");
    assert_eq!(p.shape[3], s, "probs must be square [.., S, S], got {:?}", p.shape);
    assert_eq!(v.shape[0], n, "batch mismatch: probs {:?} vs values {:?}", p.shape, v.shape);
    assert_eq!(v.shape[1], s, "seq mismatch: probs {:?} vs values {:?}", p.shape, v.shape);
    assert!(d % heads == 0, "d_model {d} not divisible by {heads} heads");
    let dh = d / heads;
    let mut out = Tensor::zeros(&[n, s, d]);
    for ni in 0..n {
        for hi in 0..heads {
            let h0 = hi * dh;
            for i in 0..s {
                let prow = &p.data[((ni * heads + hi) * s + i) * s..][..s];
                let orow = &mut out.data[(ni * s + i) * d + h0..(ni * s + i) * d + h0 + dh];
                for (j, &pj) in prow.iter().enumerate() {
                    if pj == 0.0 {
                        continue; // causal mask tail
                    }
                    let vrow = &v.data[(ni * s + j) * d + h0..(ni * s + j) * d + h0 + dh];
                    for t in 0..dh {
                        orow[t] += pj * vrow[t];
                    }
                }
            }
        }
    }
    out
}

/// Embedding lookup: f32 token ids (any shape with leading batch dim N;
/// the calibration layout is `[N, 1, 1, S]`) against a `[V, D]` table ->
/// `[N, S, D]`. Ids are rounded to the nearest integer and must land in
/// `[0, V)`.
pub fn embedding_lookup(ids: &Tensor, table: &Tensor) -> Tensor {
    assert_eq!(table.ndim(), 2, "embedding table must be [V, D], got {:?}", table.shape);
    let (vocab, d) = (table.shape[0], table.shape[1]);
    let n = ids.shape[0];
    let s = ids.numel() / n.max(1);
    let mut out = Tensor::zeros(&[n, s, d]);
    for (tok, &raw) in ids.data.iter().enumerate() {
        let id = raw.round();
        assert!(
            id >= 0.0 && (id as usize) < vocab,
            "token id {raw} out of vocabulary [0, {vocab})"
        );
        let row = &table.data[(id as usize) * d..(id as usize + 1) * d];
        out.data[tok * d..(tok + 1) * d].copy_from_slice(row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layernorm_normalizes_rows() {
        let x = Tensor::from_vec(&[2, 4], vec![1., 2., 3., 4., -2., 0., 2., 4.]);
        let y = layernorm(&x, &[1.0; 4], &[0.0; 4]);
        for r in 0..2 {
            let row = &y.data[r * 4..(r + 1) * 4];
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn layernorm_affine_params_apply() {
        let x = Tensor::from_vec(&[1, 2], vec![-1.0, 1.0]);
        let y = layernorm(&x, &[2.0, 2.0], &[10.0, 10.0]);
        // normalized row is [-1, 1] (up to eps): y = 2*z + 10
        assert!((y.data[0] - 8.0).abs() < 1e-3);
        assert!((y.data[1] - 12.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 1.0, -2.0, 5.0, 5.0, 5.0]);
        let p = softmax_lastdim(&t, false);
        for r in 0..2 {
            let s: f32 = p.data[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!((p.data[3] - 1.0 / 3.0).abs() < 1e-5, "uniform row stays uniform");
    }

    #[test]
    fn causal_softmax_masks_future_keys() {
        // one [3, 3] square: row i may only attend to keys <= i
        let t = Tensor::from_vec(&[1, 3, 3], vec![9.0; 9]);
        let p = softmax_lastdim(&t, true);
        assert!((p.data[0] - 1.0).abs() < 1e-6);
        assert_eq!(p.data[1], 0.0);
        assert_eq!(p.data[2], 0.0);
        assert!((p.data[3] - 0.5).abs() < 1e-6);
        assert_eq!(p.data[5], 0.0);
        for v in &p.data[6..9] {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "square")]
    fn causal_softmax_rejects_non_square() {
        softmax_lastdim(&Tensor::zeros(&[2, 3, 4]), true);
    }

    #[test]
    fn gelu_reference_points() {
        let x = Tensor::from_vec(&[1, 3], vec![0.0, 1.0, -10.0]);
        let y = gelu(&x);
        assert_eq!(y.data[0], 0.0);
        assert!((y.data[1] - 0.8412).abs() < 1e-3, "gelu(1) ~ 0.8412, got {}", y.data[1]);
        assert!(y.data[2].abs() < 1e-3, "gelu(-10) ~ 0");
    }

    #[test]
    fn attn_scores_match_naive_single_head() {
        // N=1, S=2, D=2, H=1: scores[i][j] = q_i . k_j / sqrt(2)
        let q = Tensor::from_vec(&[1, 2, 2], vec![1., 0., 0., 2.]);
        let k = Tensor::from_vec(&[1, 2, 2], vec![3., 1., -1., 4.]);
        let s = attn_scores(&q, &k, 1);
        assert_eq!(s.shape, vec![1, 1, 2, 2]);
        let r2 = (2.0f32).sqrt();
        assert!((s.data[0] - 3.0 / r2).abs() < 1e-5);
        assert!((s.data[1] - (-1.0) / r2).abs() < 1e-5);
        assert!((s.data[2] - 2.0 / r2).abs() < 1e-5);
        assert!((s.data[3] - 8.0 / r2).abs() < 1e-5);
    }

    #[test]
    fn attn_scores_heads_use_disjoint_feature_slices() {
        // D=2, H=2: head 0 sees feature 0 only, head 1 feature 1 only
        let q = Tensor::from_vec(&[1, 1, 2], vec![2.0, 5.0]);
        let k = Tensor::from_vec(&[1, 1, 2], vec![3.0, 7.0]);
        let s = attn_scores(&q, &k, 2);
        assert_eq!(s.shape, vec![1, 2, 1, 1]);
        assert!((s.data[0] - 6.0).abs() < 1e-5); // dh=1 -> scale 1
        assert!((s.data[1] - 35.0).abs() < 1e-5);
    }

    #[test]
    fn attn_apply_mixes_values_per_head() {
        // uniform probs over 2 positions, H=1: out = mean of V rows
        let p = Tensor::from_vec(&[1, 1, 2, 2], vec![0.5, 0.5, 0.5, 0.5]);
        let v = Tensor::from_vec(&[1, 2, 2], vec![2., 4., 6., 8.]);
        let y = attn_apply(&p, &v, 1);
        assert_eq!(y.shape, vec![1, 2, 2]);
        assert_eq!(y.data, vec![4., 6., 4., 6.]);
    }

    #[test]
    fn attn_roundtrip_identity_probs() {
        // delta probs (attend to self) reproduce V exactly, multi-head
        let v = Tensor::from_vec(&[1, 2, 4], (0..8).map(|i| i as f32).collect());
        let p = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 0., 0., 1., 1., 0., 0., 1.]);
        let y = attn_apply(&p, &v, 2);
        assert_eq!(y.data, v.data);
    }

    #[test]
    fn embedding_looks_up_rows() {
        let table = Tensor::from_vec(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let ids = Tensor::from_vec(&[2, 1, 1, 2], vec![2.0, 0.0, 1.0, 1.0]);
        let e = embedding_lookup(&ids, &table);
        assert_eq!(e.shape, vec![2, 2, 2]);
        assert_eq!(e.data, vec![20., 21., 0., 1., 10., 11., 10., 11.]);
    }

    #[test]
    #[should_panic(expected = "out of vocabulary")]
    fn embedding_rejects_out_of_range_ids() {
        let table = Tensor::from_vec(&[2, 1], vec![0.0, 1.0]);
        embedding_lookup(&Tensor::from_vec(&[1, 1, 1, 1], vec![5.0]), &table);
    }
}
