//! Blocked, row-parallel matmul — the native-engine hot kernel.
//!
//! C[M,N] = A[M,K] * B[K,N], row-major. Output rows are split into
//! contiguous per-thread spans ([`crate::util::parallel`]); within a span
//! the k-k-j loop order streams B rows sequentially and accumulates into a
//! C row that stays hot in L1, with K-blocking keeping the active slice of
//! B in L2 across the span's rows. The inner j-loop auto-vectorizes (the
//! build sets `-C target-cpu=native`).
//!
//! Every C element is accumulated in ascending-k order by exactly one
//! thread, so results are bit-identical for any `PALLAS_THREADS` value
//! (including the serial path) — see `bit_identical_across_threads`.

use crate::util::parallel;

use super::Tensor;

/// Cache block over K. 64 rows of B x 4KB/row ~ 256KB fits typical L2.
const KB: usize = 64;

/// Don't spawn a worker for less than ~128k flops of row work.
const MIN_PAR_FLOPS: usize = 1 << 17;

/// Rows per thread below which parallelism isn't worth the dispatch
/// (shared with the conv fan-out, which parallelizes over the same
/// output rows).
pub(crate) fn row_grain(k: usize, n: usize) -> usize {
    (MIN_PAR_FLOPS / (2 * k * n).max(1)).max(1)
}

/// C = A @ B (allocates C).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into(&a.data, &b.data, &mut c.data, m, k, n);
    c
}

/// C += A @ B into an existing buffer.
pub fn matmul_acc(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!(c.shape, vec![m, n]);
    matmul_into(&a.data, &b.data, &mut c.data, m, k, n);
}

/// Raw-slice core, C += A @ B (also used by the adaround native optimizer
/// and the conv GEMM on workspace views). Row-parallel.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    parallel::par_ranges_mut(c, n, row_grain(k, n), |rows, span| {
        matmul_rows(a, b, span, rows.start, rows.end, k, n);
    });
}

/// Serial kernel for one contiguous row span [r0, r1); `c` holds exactly
/// those rows. Same K-blocked loop order as the original single-core
/// kernel, so the serial path is unchanged and each element's FP
/// accumulation order (ascending k) is thread-count independent.
fn matmul_rows(a: &[f32], b: &[f32], c: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for i in r0..r1 {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                // auto-vectorized fused multiply-add over the row
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// C = A @ B^T (B given row-major as [N,K]); useful for dY @ X^T in the
/// native AdaRound backward where X is stored [K,batch].
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut c = Tensor::zeros(&[m, n]);
    matmul_bt_into(&a.data, &b.data, &mut c.data, m, k, n);
    c
}

/// Raw-slice core, C = A @ B^T with B^T given row-major as [N,K].
/// Row-parallel with a register-blocked 4-wide micro-kernel: four B rows
/// share one streaming pass over the A row, quadrupling arithmetic
/// intensity per load. Overwrites `c`.
pub fn matmul_bt_into(a: &[f32], bt: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(bt.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    parallel::par_ranges_mut(c, n, row_grain(k, n), |rows, span| {
        for i in rows.clone() {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut span[(i - rows.start) * n..(i - rows.start + 1) * n];
            matmul_bt_row(arow, bt, crow, k, n);
        }
    });
}

/// One output row of A @ B^T: crow[j] = dot(arow, bt[j]).
fn matmul_bt_row(arow: &[f32], bt: &[f32], crow: &mut [f32], k: usize, n: usize) {
    let arow = &arow[..k];
    let n4 = n - n % 4;
    let mut j = 0;
    while j < n4 {
        // 4-wide register block: independent accumulators, each summed in
        // ascending-k order (bit-identical to the scalar loop per element)
        let b0 = &bt[j * k..][..k];
        let b1 = &bt[(j + 1) * k..][..k];
        let b2 = &bt[(j + 2) * k..][..k];
        let b3 = &bt[(j + 3) * k..][..k];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for t in 0..k {
            let av = arow[t];
            s0 += av * b0[t];
            s1 += av * b1[t];
            s2 += av * b2[t];
            s3 += av * b3[t];
        }
        crow[j] = s0;
        crow[j + 1] = s1;
        crow[j + 2] = s2;
        crow[j + 3] = s3;
        j += 4;
    }
    while j < n {
        let brow = &bt[j * k..][..k];
        let mut acc = 0.0f32;
        for t in 0..k {
            acc += arow[t] * brow[t];
        }
        crow[j] = acc;
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::with_threads;
    use crate::util::proptest::{close, property};
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc += (a.at2(i, kk) * b.at2(kk, j)) as f64;
                }
                c.set2(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn small_exact() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn identity() {
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.set2(i, i, 1.0);
        }
        let mut r = Rng::new(0);
        let a = Tensor::from_vec(&[4, 4], (0..16).map(|_| r.normal_f32(0.0, 1.0)).collect());
        let c = matmul(&a, &eye);
        for (x, y) in c.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn property_matches_naive() {
        property(11, 30, |g| {
            let m = g.int(1, 40);
            let k = g.int(1, 90);
            let n = g.int(1, 70);
            let a = Tensor::from_vec(&[m, k], g.vec_normal(m * k, 0.0, 1.0));
            let b = Tensor::from_vec(&[k, n], g.vec_normal(k * n, 0.0, 1.0));
            let c = matmul(&a, &b);
            let cn = naive(&a, &b);
            for (x, y) in c.data.iter().zip(&cn.data) {
                close(*x, *y, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_bt_matches() {
        property(12, 20, |g| {
            let m = g.int(1, 20);
            let k = g.int(1, 40);
            let n = g.int(1, 20);
            let a = Tensor::from_vec(&[m, k], g.vec_normal(m * k, 0.0, 1.0));
            let bt = Tensor::from_vec(&[n, k], g.vec_normal(n * k, 0.0, 1.0));
            let c1 = matmul_bt(&a, &bt);
            let c2 = matmul(&a, &bt.transpose2());
            for (x, y) in c1.data.iter().zip(&c2.data) {
                close(*x, *y, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn acc_accumulates() {
        let a = Tensor::from_vec(&[1, 2], vec![1., 1.]);
        let b = Tensor::from_vec(&[2, 1], vec![2., 3.]);
        let mut c = Tensor::full(&[1, 1], 10.0);
        matmul_acc(&a, &b, &mut c);
        assert_eq!(c.data[0], 15.0);
    }

    #[test]
    fn bit_identical_across_threads() {
        // the determinism contract: 1 vs 4 threads, bit-for-bit equal
        let mut r = Rng::new(42);
        // sizes chosen to exceed the parallel grain so threads actually spawn
        let (m, k, n) = (37, 130, 220);
        let a = Tensor::from_vec(&[m, k], (0..m * k).map(|_| r.normal_f32(0.0, 1.0)).collect());
        let b = Tensor::from_vec(&[k, n], (0..k * n).map(|_| r.normal_f32(0.0, 1.0)).collect());
        let bt = b.transpose2();
        let c1 = with_threads(1, || matmul(&a, &b));
        let c4 = with_threads(4, || matmul(&a, &b));
        assert_eq!(c1.data, c4.data, "matmul differs across thread counts");
        let d1 = with_threads(1, || matmul_bt(&a, &bt));
        let d4 = with_threads(4, || matmul_bt(&a, &bt));
        assert_eq!(d1.data, d4.data, "matmul_bt differs across thread counts");
    }
}
