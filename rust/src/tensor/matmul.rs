//! Blocked single-core matmul — the native-engine hot kernel.
//!
//! C[M,N] = A[M,K] * B[K,N], row-major. The i-k-j loop order streams B rows
//! sequentially and accumulates into a C row that stays hot in L1; the
//! inner j-loop auto-vectorizes (the build sets `-C target-cpu=native`).
//! K-blocking keeps the active slice of B in L2 for large N.

use super::Tensor;

/// Cache block over K. 64 rows of B x 4KB/row ~ 256KB fits typical L2.
const KB: usize = 64;

/// C = A @ B (allocates C).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into(&a.data, &b.data, &mut c.data, m, k, n);
    c
}

/// C += A @ B into an existing buffer.
pub fn matmul_acc(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!(c.shape, vec![m, n]);
    matmul_into(&a.data, &b.data, &mut c.data, m, k, n);
}

/// Raw-slice core (also used by the adaround native optimizer on views).
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                // auto-vectorized fused multiply-add over the row
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// C = A @ B^T (B given row-major as [N,K]); useful for dY @ X^T in the
/// native AdaRound backward where X is stored [K,batch].
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            crow[j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{close, property};
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc += (a.at2(i, kk) * b.at2(kk, j)) as f64;
                }
                c.set2(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn small_exact() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn identity() {
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.set2(i, i, 1.0);
        }
        let mut r = Rng::new(0);
        let a = Tensor::from_vec(&[4, 4], (0..16).map(|_| r.normal_f32(0.0, 1.0)).collect());
        let c = matmul(&a, &eye);
        for (x, y) in c.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn property_matches_naive() {
        property(11, 30, |g| {
            let m = g.int(1, 40);
            let k = g.int(1, 90);
            let n = g.int(1, 70);
            let a = Tensor::from_vec(&[m, k], g.vec_normal(m * k, 0.0, 1.0));
            let b = Tensor::from_vec(&[k, n], g.vec_normal(k * n, 0.0, 1.0));
            let c = matmul(&a, &b);
            let cn = naive(&a, &b);
            for (x, y) in c.data.iter().zip(&cn.data) {
                close(*x, *y, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_bt_matches() {
        property(12, 20, |g| {
            let m = g.int(1, 20);
            let k = g.int(1, 40);
            let n = g.int(1, 20);
            let a = Tensor::from_vec(&[m, k], g.vec_normal(m * k, 0.0, 1.0));
            let bt = Tensor::from_vec(&[n, k], g.vec_normal(n * k, 0.0, 1.0));
            let c1 = matmul_bt(&a, &bt);
            let c2 = matmul(&a, &bt.transpose2());
            for (x, y) in c1.data.iter().zip(&c2.data) {
                close(*x, *y, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn acc_accumulates() {
        let a = Tensor::from_vec(&[1, 2], vec![1., 1.]);
        let b = Tensor::from_vec(&[2, 1], vec![2., 3.]);
        let mut c = Tensor::full(&[1, 1], 10.0);
        matmul_acc(&a, &b, &mut c);
        assert_eq!(c.data[0], 15.0);
    }
}
