//! Convolution via im2col + matmul (NCHW / OIHW, zero padding).
//!
//! im2col column layout matches the AOT shape buckets: a conv with
//! `cout` filters over `cin/groups`-channel k x k patches becomes
//! W[cout/g, cin/g*k*k] @ X[cin/g*k*k, N*Ho*Wo] per group — identical to
//! the geometry the Pallas/HLO artifacts were lowered for, so the same
//! im2col feeds both the native engine and the PJRT engine.

use super::{matmul::matmul_into, Tensor};

#[derive(Clone, Copy, Debug)]
pub struct Conv2dParams {
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
}

pub fn out_size(h: usize, k: usize, stride: usize, pad: usize) -> usize {
    (h + 2 * pad - k) / stride + 1
}

/// Extract im2col patches for ONE group from input [N, C, H, W].
///
/// Returns [cg*k*k, N*Ho*Wo] where cg = channels per group; column order is
/// (n, ho, wo) fastest-last, matching the output scatter in [`conv2d`].
pub fn im2col(
    input: &Tensor,
    group: usize,
    p: Conv2dParams,
) -> Tensor {
    let (n, c, h, w) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    let cg = c / p.groups;
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(w, p.k, p.stride, p.pad));
    let npos = n * ho * wo;
    let rows = cg * p.k * p.k;
    let mut out = Tensor::zeros(&[rows, npos]);
    let c0 = group * cg;
    for ci in 0..cg {
        for ky in 0..p.k {
            for kx in 0..p.k {
                let r = (ci * p.k + ky) * p.k + kx;
                let orow = &mut out.data[r * npos..(r + 1) * npos];
                let mut col = 0usize;
                for ni in 0..n {
                    let base = ((ni * c + c0 + ci) * h) * w;
                    for oy in 0..ho {
                        let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                        if iy < 0 || iy >= h as isize {
                            col += wo;
                            continue;
                        }
                        let irow = base + iy as usize * w;
                        for ox in 0..wo {
                            let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                            if ix >= 0 && ix < w as isize {
                                orow[col] = input.data[irow + ix as usize];
                            }
                            col += 1;
                        }
                    }
                }
            }
        }
    }
    out
}

/// conv2d: input [N,C,H,W], weight [O, C/g, k, k], bias [O] -> [N,O,Ho,Wo].
pub fn conv2d(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    p: Conv2dParams,
) -> Tensor {
    let (n, _c, h, w) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    let o = weight.shape[0];
    let og = o / p.groups;
    let patch = weight.shape[1] * weight.shape[2] * weight.shape[3];
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(w, p.k, p.stride, p.pad));
    let npos = n * ho * wo;
    let mut out = Tensor::zeros(&[n, o, ho, wo]);
    let mut gemm_out = vec![0.0f32; og * npos];
    for g in 0..p.groups {
        let cols = im2col(input, g, p);
        let wslice = &weight.data[g * og * patch..(g + 1) * og * patch];
        gemm_out.iter_mut().for_each(|x| *x = 0.0);
        matmul_into(wslice, &cols.data, &mut gemm_out, og, patch, npos);
        // scatter [og, n*ho*wo] -> [n, o, ho, wo]
        let hw = ho * wo;
        for oi in 0..og {
            let ochan = g * og + oi;
            let b = bias.map(|b| b[ochan]).unwrap_or(0.0);
            let src = &gemm_out[oi * npos..(oi + 1) * npos];
            for ni in 0..n {
                let dst = &mut out.data[((ni * o + ochan) * hw)..((ni * o + ochan + 1) * hw)];
                let s = &src[ni * hw..(ni + 1) * hw];
                for (d, v) in dst.iter_mut().zip(s) {
                    *d = v + b;
                }
            }
        }
    }
    out
}

/// Direct (naive) convolution — the test oracle for the im2col path.
pub fn conv2d_naive(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    p: Conv2dParams,
) -> Tensor {
    let (n, c, h, w) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    let o = weight.shape[0];
    let cg = c / p.groups;
    let og = o / p.groups;
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(w, p.k, p.stride, p.pad));
    let mut out = Tensor::zeros(&[n, o, ho, wo]);
    for ni in 0..n {
        for oc in 0..o {
            let g = oc / og;
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = bias.map(|b| b[oc]).unwrap_or(0.0) as f64;
                    for ci in 0..cg {
                        for ky in 0..p.k {
                            for kx in 0..p.k {
                                let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                                let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                                if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let iv = input.data
                                    [((ni * c + g * cg + ci) * h + iy as usize) * w + ix as usize];
                                let wv = weight.data
                                    [((oc * cg + ci) * p.k + ky) * p.k + kx];
                                acc += (iv * wv) as f64;
                            }
                        }
                    }
                    out.data[((ni * o + oc) * ho + oy) * wo + ox] = acc as f32;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{close, property};

    #[test]
    fn im2col_identity_1x1() {
        // 1x1 conv im2col is just a channel-major reshuffle
        let input = Tensor::from_vec(&[1, 2, 2, 2], (1..=8).map(|x| x as f32).collect());
        let p = Conv2dParams { k: 1, stride: 1, pad: 0, groups: 1 };
        let cols = im2col(&input, 0, p);
        assert_eq!(cols.shape, vec![2, 4]);
        assert_eq!(cols.data, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
    }

    #[test]
    fn conv_matches_naive_property() {
        property(21, 15, |g| {
            let n = g.int(1, 2);
            let groups = *g.choice(&[1usize, 2]);
            let cg = g.int(1, 3);
            let c = cg * groups;
            let og = g.int(1, 3);
            let o = og * groups;
            let k = *g.choice(&[1usize, 3]);
            let stride = *g.choice(&[1usize, 2]);
            let pad = k / 2;
            let h = g.int(4, 9);
            let w = g.int(4, 9);
            let input = Tensor::from_vec(&[n, c, h, w], g.vec_normal(n * c * h * w, 0.0, 1.0));
            let weight = Tensor::from_vec(&[o, cg, k, k], g.vec_normal(o * cg * k * k, 0.0, 0.5));
            let bias: Vec<f32> = g.vec_normal(o, 0.0, 0.1);
            let p = Conv2dParams { k, stride, pad, groups };
            let fast = conv2d(&input, &weight, Some(&bias), p);
            let slow = conv2d_naive(&input, &weight, Some(&bias), p);
            if fast.shape != slow.shape {
                return Err(format!("shape {:?} vs {:?}", fast.shape, slow.shape));
            }
            for (a, b) in fast.data.iter().zip(&slow.data) {
                close(*a, *b, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn depthwise_conv() {
        // groups == channels: each filter sees exactly one input channel
        let p = Conv2dParams { k: 3, stride: 1, pad: 1, groups: 4 };
        let input = Tensor::full(&[1, 4, 5, 5], 1.0);
        let mut weight = Tensor::zeros(&[4, 1, 3, 3]);
        for oc in 0..4 {
            weight.data[oc * 9 + 4] = (oc + 1) as f32; // center tap only
        }
        let out = conv2d(&input, &weight, None, p);
        for oc in 0..4 {
            let v = out.data[(oc * 5 + 2) * 5 + 2];
            assert!((v - (oc + 1) as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn stride_output_size() {
        assert_eq!(out_size(32, 3, 2, 1), 16);
        assert_eq!(out_size(32, 1, 1, 0), 32);
        assert_eq!(out_size(5, 3, 2, 1), 3);
    }
}
