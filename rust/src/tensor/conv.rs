//! Convolution via im2col + matmul (NCHW / OIHW, zero padding).
//!
//! im2col column layout matches the AOT shape buckets: a conv with
//! `cout` filters over `cin/groups`-channel k x k patches becomes
//! W[cout/g, cin/g*k*k] @ X[cin/g*k*k, N*Ho*Wo] per group — identical to
//! the geometry the Pallas/HLO artifacts were lowered for, so the same
//! im2col feeds both the native engine and the PJRT engine.
//!
//! Parallel structure (see [`crate::util::parallel`]): both im2col and the
//! GEMM parallelize over a FLAT index space that spans all groups — patch
//! rows `(group, channel-in-group, ky, kx)` for im2col, output channels
//! for the GEMM — so a conv with any `groups` value uses every core
//! (the former per-group fan-out idled cores whenever
//! `1 < groups < PALLAS_THREADS`, e.g. groups=2 on a 16-core box ran on 2
//! threads). The final scatter fans out per image. All splits are by item
//! index with serial per-item code, so outputs are bit-identical across
//! `PALLAS_THREADS` values.

use crate::util::parallel;

use super::{matmul::matmul_into, Tensor};

#[derive(Clone, Copy, Debug)]
pub struct Conv2dParams {
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
}

pub fn out_size(h: usize, k: usize, stride: usize, pad: usize) -> usize {
    (h + 2 * pad - k) / stride + 1
}

/// Reusable buffers for [`conv2d_with`]: the im2col matrix of ALL groups
/// and the GEMM output of ALL groups. Holding these across calls makes the
/// conv hot path allocation-free once shapes have been seen (the network
/// executor reuses one workspace for a whole forward pass).
#[derive(Default)]
pub struct Conv2dWorkspace {
    /// im2col columns, [groups * cg*k*k, N*Ho*Wo] stacked group-major
    cols: Vec<f32>,
    /// GEMM outputs, [O, N*Ho*Wo] (groups stacked along output channels)
    gemm: Vec<f32>,
}

impl Conv2dWorkspace {
    pub fn new() -> Conv2dWorkspace {
        Conv2dWorkspace::default()
    }

    /// Resize `v` to `len` without preserving contents (no memset needed
    /// beyond what `resize` does for the newly grown tail).
    fn ensure(v: &mut Vec<f32>, len: usize) {
        if v.len() != len {
            v.resize(len, 0.0);
        }
    }
}

/// Extract im2col patches for ONE group from input [N, C, H, W].
///
/// Returns [cg*k*k, N*Ho*Wo] where cg = channels per group; column order is
/// (n, ho, wo) fastest-last, matching the output scatter in [`conv2d`].
pub fn im2col(input: &Tensor, group: usize, p: Conv2dParams) -> Tensor {
    let (n, c) = (input.shape[0], input.shape[1]);
    let (h, w) = (input.shape[2], input.shape[3]);
    let cg = c / p.groups;
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(w, p.k, p.stride, p.pad));
    let npos = n * ho * wo;
    let rows = cg * p.k * p.k;
    let mut out = Tensor::zeros(&[rows, npos]);
    im2col_into(input, group, p, &mut out.data);
    out
}

/// im2col into a caller-provided buffer of len `cg*k*k * N*Ho*Wo`; writes
/// every element (zero padding included), so the buffer needs no clearing.
/// Parallel over patch rows.
pub fn im2col_into(input: &Tensor, group: usize, p: Conv2dParams, out: &mut [f32]) {
    let (n, c) = (input.shape[0], input.shape[1]);
    let (h, w) = (input.shape[2], input.shape[3]);
    let cg = c / p.groups;
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(w, p.k, p.stride, p.pad));
    let npos = n * ho * wo;
    let rows = cg * p.k * p.k;
    assert_eq!(out.len(), rows * npos);
    // a patch row is a pure copy: parallelize only when rows carry real work
    let grain = ((1 << 16) / npos.max(1)).max(1);
    parallel::par_chunks_mut(out, npos, grain, |r, orow| {
        im2col_row(input, group, p, r, orow);
    });
}

/// Serial extraction of ONE im2col patch row (f32, zero padding): the
/// per-item unit behind both [`im2col_into`] and the group-flat fan-out
/// in [`conv2d_with`].
fn im2col_row(input: &Tensor, group: usize, p: Conv2dParams, r: usize, orow: &mut [f32]) {
    im2col_row_any(&input.shape, &input.data, group, p, 0.0, r, orow);
}

/// The patch-row geometry shared by the f32 and u8 im2col paths: row `r`
/// (decoding to (channel-in-group, ky, kx)) of `group` from an NCHW
/// buffer, written into its `N*Ho*Wo`-long slice; out-of-image positions
/// get `pad` (0.0 for f32, the zero point for u8). ONE implementation so
/// the fake-quant simulation and the integer serving engine can never
/// disagree on indexing.
pub(crate) fn im2col_row_any<T: Copy>(
    shape: &[usize],
    data: &[T],
    group: usize,
    p: Conv2dParams,
    pad: T,
    r: usize,
    orow: &mut [T],
) {
    let (n, c) = (shape[0], shape[1]);
    let (h, w) = (shape[2], shape[3]);
    let cg = c / p.groups;
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(w, p.k, p.stride, p.pad));
    let c0 = group * cg;
    let ci = r / (p.k * p.k);
    let ky = (r / p.k) % p.k;
    let kx = r % p.k;
    let mut col = 0usize;
    for ni in 0..n {
        let base = ((ni * c + c0 + ci) * h) * w;
        for oy in 0..ho {
            let iy = (oy * p.stride + ky) as isize - p.pad as isize;
            if iy < 0 || iy >= h as isize {
                orow[col..col + wo].fill(pad);
                col += wo;
                continue;
            }
            let irow = base + iy as usize * w;
            for ox in 0..wo {
                let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                orow[col] = if ix >= 0 && ix < w as isize {
                    data[irow + ix as usize]
                } else {
                    pad
                };
                col += 1;
            }
        }
    }
}

/// conv2d: input [N,C,H,W], weight [O, C/g, k, k], bias [O] -> [N,O,Ho,Wo].
/// Convenience wrapper allocating a fresh workspace; hot callers (the
/// network executor) keep a [`Conv2dWorkspace`] and use [`conv2d_with`].
pub fn conv2d(input: &Tensor, weight: &Tensor, bias: Option<&[f32]>, p: Conv2dParams) -> Tensor {
    let mut ws = Conv2dWorkspace::new();
    conv2d_with(&mut ws, input, weight, bias, p)
}

/// conv2d using caller-owned scratch buffers (group/row-parallel).
pub fn conv2d_with(
    ws: &mut Conv2dWorkspace,
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    p: Conv2dParams,
) -> Tensor {
    let (n, h, w) = (input.shape[0], input.shape[2], input.shape[3]);
    let o = weight.shape[0];
    let og = o / p.groups;
    let patch = weight.shape[1] * weight.shape[2] * weight.shape[3];
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(w, p.k, p.stride, p.pad));
    let npos = n * ho * wo;
    let hw = ho * wo;

    // pass 1: im2col of every group into the stacked workspace, fanned
    // out over the FLAT patch-row index (group-major: row r belongs to
    // group r/patch), so any groups value saturates the cores
    Conv2dWorkspace::ensure(&mut ws.cols, p.groups * patch * npos);
    let input_ref = &*input;
    let grain = ((1 << 16) / npos.max(1)).max(1);
    parallel::par_chunks_mut(&mut ws.cols, npos, grain, |r, orow| {
        im2col_row(input_ref, r / patch, p, r % patch, orow);
    });

    // pass 2: grouped GEMM over the FLAT output-channel index. A unit's
    // row range may span group boundaries; it is cut at them so each
    // segment multiplies against its own group's im2col block. Per-element
    // accumulation stays ascending-k regardless of how rows are batched
    // into matmul_into calls, so outputs are bit-identical across thread
    // counts AND across the former per-group split.
    Conv2dWorkspace::ensure(&mut ws.gemm, o * npos);
    ws.gemm.fill(0.0); // matmul_into accumulates
    let cols_ref = &ws.cols;
    parallel::par_grouped_rows_mut(
        &mut ws.gemm,
        npos,
        og,
        super::matmul::row_grain(patch, npos),
        |g, rows, seg| {
            let wslice = &weight.data[rows.start * patch..rows.end * patch];
            let cslice = &cols_ref[g * patch * npos..(g + 1) * patch * npos];
            matmul_into(wslice, cslice, seg, rows.end - rows.start, patch, npos);
        },
    );

    // pass 3: scatter [O, n*ho*wo] -> [n, O, ho, wo] + bias, parallel over
    // images (each image's [O, hw] block is one contiguous output chunk)
    let mut out = Tensor::zeros(&[n, o, ho, wo]);
    let gemm_ref = &ws.gemm;
    let grain = ((1 << 16) / (o * hw).max(1)).max(1);
    parallel::par_chunks_mut(&mut out.data, o * hw, grain, |ni, dst| {
        for oc in 0..o {
            let b = bias.map(|b| b[oc]).unwrap_or(0.0);
            let src = &gemm_ref[oc * npos + ni * hw..oc * npos + (ni + 1) * hw];
            let drow = &mut dst[oc * hw..(oc + 1) * hw];
            for (d, v) in drow.iter_mut().zip(src) {
                *d = v + b;
            }
        }
    });
    out
}

/// Direct (naive) convolution — the test oracle for the im2col path.
pub fn conv2d_naive(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    p: Conv2dParams,
) -> Tensor {
    let (n, c, h, w) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    let o = weight.shape[0];
    let cg = c / p.groups;
    let og = o / p.groups;
    let (ho, wo) = (out_size(h, p.k, p.stride, p.pad), out_size(w, p.k, p.stride, p.pad));
    let mut out = Tensor::zeros(&[n, o, ho, wo]);
    for ni in 0..n {
        for oc in 0..o {
            let g = oc / og;
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = bias.map(|b| b[oc]).unwrap_or(0.0) as f64;
                    for ci in 0..cg {
                        for ky in 0..p.k {
                            for kx in 0..p.k {
                                let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                                let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                                if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let iv = input.data
                                    [((ni * c + g * cg + ci) * h + iy as usize) * w + ix as usize];
                                let wv = weight.data
                                    [((oc * cg + ci) * p.k + ky) * p.k + kx];
                                acc += (iv * wv) as f64;
                            }
                        }
                    }
                    out.data[((ni * o + oc) * ho + oy) * wo + ox] = acc as f32;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::with_threads;
    use crate::util::proptest::{close, property};

    #[test]
    fn im2col_identity_1x1() {
        // 1x1 conv im2col is just a channel-major reshuffle
        let input = Tensor::from_vec(&[1, 2, 2, 2], (1..=8).map(|x| x as f32).collect());
        let p = Conv2dParams { k: 1, stride: 1, pad: 0, groups: 1 };
        let cols = im2col(&input, 0, p);
        assert_eq!(cols.shape, vec![2, 4]);
        assert_eq!(cols.data, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
    }

    #[test]
    fn conv_matches_naive_property() {
        property(21, 15, |g| {
            let n = g.int(1, 2);
            let groups = *g.choice(&[1usize, 2]);
            let cg = g.int(1, 3);
            let c = cg * groups;
            let og = g.int(1, 3);
            let o = og * groups;
            let k = *g.choice(&[1usize, 3]);
            let stride = *g.choice(&[1usize, 2]);
            let pad = k / 2;
            let h = g.int(4, 9);
            let w = g.int(4, 9);
            let input = Tensor::from_vec(&[n, c, h, w], g.vec_normal(n * c * h * w, 0.0, 1.0));
            let weight = Tensor::from_vec(&[o, cg, k, k], g.vec_normal(o * cg * k * k, 0.0, 0.5));
            let bias: Vec<f32> = g.vec_normal(o, 0.0, 0.1);
            let p = Conv2dParams { k, stride, pad, groups };
            let fast = conv2d(&input, &weight, Some(&bias), p);
            let slow = conv2d_naive(&input, &weight, Some(&bias), p);
            if fast.shape != slow.shape {
                return Err(format!("shape {:?} vs {:?}", fast.shape, slow.shape));
            }
            for (a, b) in fast.data.iter().zip(&slow.data) {
                close(*a, *b, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn depthwise_conv() {
        // groups == channels: each filter sees exactly one input channel
        let p = Conv2dParams { k: 3, stride: 1, pad: 1, groups: 4 };
        let input = Tensor::full(&[1, 4, 5, 5], 1.0);
        let mut weight = Tensor::zeros(&[4, 1, 3, 3]);
        for oc in 0..4 {
            weight.data[oc * 9 + 4] = (oc + 1) as f32; // center tap only
        }
        let out = conv2d(&input, &weight, None, p);
        for oc in 0..4 {
            let v = out.data[(oc * 5 + 2) * 5 + 2];
            assert!((v - (oc + 1) as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn stride_output_size() {
        assert_eq!(out_size(32, 3, 2, 1), 16);
        assert_eq!(out_size(32, 1, 1, 0), 32);
        assert_eq!(out_size(5, 3, 2, 1), 3);
    }

    #[test]
    fn workspace_reuse_across_shapes() {
        // one workspace, several different conv geometries in sequence
        let mut ws = Conv2dWorkspace::new();
        let mut rng = crate::util::Rng::new(9);
        for (c, o, hw, k, g) in [(2usize, 4usize, 6usize, 3usize, 1usize), (4, 4, 5, 3, 4), (3, 2, 7, 1, 1)] {
            let input = Tensor::from_vec(
                &[2, c, hw, hw],
                (0..2 * c * hw * hw).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
            let weight = Tensor::from_vec(
                &[o, c / g, k, k],
                (0..o * (c / g) * k * k).map(|_| rng.normal_f32(0.0, 0.5)).collect(),
            );
            let p = Conv2dParams { k, stride: 1, pad: k / 2, groups: g };
            let a = conv2d_with(&mut ws, &input, &weight, None, p);
            let b = conv2d_naive(&input, &weight, None, p);
            assert_eq!(a.shape, b.shape);
            for (x, y) in a.data.iter().zip(&b.data) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn bit_identical_across_threads() {
        let mut rng = crate::util::Rng::new(17);
        // big enough that im2col, GEMM and scatter all cross their grains
        let input = Tensor::from_vec(
            &[4, 8, 16, 16],
            (0..4 * 8 * 256).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        // groups=2 exercises the flat two-level fan-out (row ranges cut at
        // group boundaries); 8 the pure per-group split; 1 the plain GEMM
        for groups in [1usize, 2, 8] {
            let weight = Tensor::from_vec(
                &[8, 8 / groups, 3, 3],
                (0..8 * (8 / groups) * 9).map(|_| rng.normal_f32(0.0, 0.5)).collect(),
            );
            let bias: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 0.1)).collect();
            let p = Conv2dParams { k: 3, stride: 1, pad: 1, groups };
            let y1 = with_threads(1, || conv2d(&input, &weight, Some(&bias), p));
            let y4 = with_threads(4, || conv2d(&input, &weight, Some(&bias), p));
            assert_eq!(y1.data, y4.data, "conv2d groups={groups} differs across thread counts");
        }
    }
}
