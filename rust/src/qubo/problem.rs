//! QUBO problem construction from a layer row + input Gram matrix.

use crate::quant::QuantGrid;
use crate::tensor::Tensor;

/// Minimize `r^T Q r + lin^T r + c0` over r in {0,1}^n.
/// Q is symmetric, stored dense row-major in f64.
#[derive(Clone, Debug)]
pub struct QuboProblem {
    pub n: usize,
    pub q: Vec<f64>,
    pub lin: Vec<f64>,
    pub c0: f64,
    /// fractional parts frac(w/s) — the paper's smart CEM initialization
    pub frac: Vec<f64>,
}

/// E[x x^T] from an im2col activation sample X [cols, batch].
pub fn gram(x: &Tensor) -> Vec<f64> {
    let (cols, batch) = (x.rows(), x.cols());
    let mut h = vec![0.0f64; cols * cols];
    for i in 0..cols {
        let xi = x.row(i);
        for j in i..cols {
            let xj = x.row(j);
            let mut acc = 0.0f64;
            for (a, b) in xi.iter().zip(xj) {
                acc += (*a as f64) * (*b as f64);
            }
            acc /= batch as f64;
            h[i * cols + j] = acc;
            h[j * cols + i] = acc;
        }
    }
    h
}

impl QuboProblem {
    /// Build the rounding QUBO for one weight row under a fixed grid.
    ///
    /// `h` is the `cols x cols` Gram matrix from [`gram`]; `row` indexes the
    /// grid's per-channel scale.
    pub fn from_row(w_row: &[f32], grid: &QuantGrid, row: usize, h: &[f64]) -> QuboProblem {
        let n = w_row.len();
        assert_eq!(h.len(), n * n);
        let s = grid.scale_for_row(row) as f64;
        let (lo, hi) = (grid.n as f64, grid.p as f64);
        // perturbations for down (r=0) and up (r=1)
        let mut a = vec![0.0f64; n];
        let mut d = vec![0.0f64; n];
        let mut frac = vec![0.0f64; n];
        for i in 0..n {
            let w = w_row[i] as f64;
            let f = (w / s).floor();
            let down = s * f.clamp(lo, hi);
            let up = s * (f + 1.0).clamp(lo, hi);
            a[i] = w - down;
            d[i] = down - up; // Δ(1) - Δ(0) = (w-up) - (w-down)
            frac[i] = (w / s - f).clamp(0.0, 1.0);
        }
        // cost(r) = (a + d.r)^T H (a + d.r)
        //         = a^T H a + sum_i 2 d_i (H a)_i r_i + sum_ij d_i d_j H_ij r_i r_j
        let mut ha = vec![0.0f64; n];
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += h[i * n + j] * a[j];
            }
            ha[i] = acc;
        }
        let mut c0 = 0.0;
        for i in 0..n {
            c0 += a[i] * ha[i];
        }
        let mut q = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                q[i * n + j] = d[i] * d[j] * h[i * n + j];
            }
        }
        let lin: Vec<f64> = (0..n).map(|i| 2.0 * d[i] * ha[i]).collect();
        QuboProblem { n, q, lin, c0, frac }
    }

    /// Full cost of an assignment.
    pub fn eval(&self, r: &[u8]) -> f64 {
        debug_assert_eq!(r.len(), self.n);
        let mut cost = self.c0;
        for i in 0..self.n {
            if r[i] == 0 {
                continue;
            }
            cost += self.lin[i];
            let qi = &self.q[i * self.n..(i + 1) * self.n];
            for j in 0..self.n {
                if r[j] != 0 {
                    cost += qi[j];
                }
            }
        }
        cost
    }

    /// Field cache g_i = sum_j Q_sym[i,j] r_j for O(1)-amortized flips,
    /// where Q_sym[i,j] = Q[i,j] + Q[j,i] (Q is symmetric so = 2 Q[i,j]).
    pub fn fields(&self, r: &[u8]) -> Vec<f64> {
        let mut g = vec![0.0f64; self.n];
        for i in 0..self.n {
            let qi = &self.q[i * self.n..(i + 1) * self.n];
            let mut acc = 0.0;
            for j in 0..self.n {
                if r[j] != 0 {
                    acc += qi[j];
                }
            }
            g[i] = 2.0 * acc;
        }
        g
    }

    /// Cost change from flipping bit i given the field cache.
    #[inline]
    pub fn flip_delta(&self, r: &[u8], g: &[f64], i: usize) -> f64 {
        let qii = self.q[i * self.n + i];
        if r[i] == 0 {
            self.lin[i] + g[i] + qii
        } else {
            -(self.lin[i] + g[i] - qii)
        }
    }

    /// Apply a flip, updating the field cache in O(n).
    pub fn apply_flip(&self, r: &mut [u8], g: &mut [f64], i: usize) {
        let sign = if r[i] == 0 { 1.0 } else { -1.0 };
        r[i] ^= 1;
        for j in 0..self.n {
            g[j] += sign * 2.0 * self.q[j * self.n + i];
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::Rng;

    pub(crate) fn random_problem(seed: u64, n: usize, batch: usize) -> (QuboProblem, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let x = Tensor::from_vec(
            &[n, batch],
            (0..n * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let h = gram(&x);
        let grid = QuantGrid::per_tensor(0.05, 4);
        (QuboProblem::from_row(&w, &grid, 0, &h), w)
    }

    /// Direct MSE evaluation: E[(Δ x)^2] for a given rounding — the oracle
    /// the QUBO expansion must match.
    fn direct_cost(w: &[f32], r: &[u8], x: &Tensor, grid: &QuantGrid) -> f64 {
        let n = w.len();
        let batch = x.cols();
        let s = grid.scale[0] as f64;
        let dq: Vec<f64> = (0..n)
            .map(|i| {
                let f = (w[i] as f64 / s).floor();
                let z = (f + r[i] as f64).clamp(grid.n as f64, grid.p as f64);
                w[i] as f64 - s * z
            })
            .collect();
        let mut acc = 0.0;
        for b in 0..batch {
            let mut dot = 0.0;
            for i in 0..n {
                dot += dq[i] * x.at2(i, b) as f64;
            }
            acc += dot * dot;
        }
        acc / batch as f64
    }

    #[test]
    fn qubo_matches_direct_mse() {
        property(61, 15, |g| {
            let n = g.int(2, 12);
            let batch = g.int(4, 30);
            let mut rng = Rng::new(g.case as u64 + 100);
            let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.3)).collect();
            let x = Tensor::from_vec(
                &[n, batch],
                (0..n * batch).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
            let grid = QuantGrid::per_tensor(0.05, 4);
            let h = gram(&x);
            let prob = QuboProblem::from_row(&w, &grid, 0, &h);
            for _ in 0..5 {
                let r: Vec<u8> = (0..n).map(|_| rng.bernoulli(0.5) as u8).collect();
                let c1 = prob.eval(&r);
                let c2 = direct_cost(&w, &r, &x, &grid);
                if (c1 - c2).abs() > 1e-6 * (1.0 + c2.abs()) {
                    return Err(format!("qubo {c1} vs direct {c2}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn flip_delta_consistent() {
        property(62, 10, |gen| {
            let (prob, _) = random_problem(gen.case as u64, gen.int(3, 15), 20);
            let mut rng = Rng::new(gen.case as u64 + 7);
            let mut r: Vec<u8> = (0..prob.n).map(|_| rng.bernoulli(0.5) as u8).collect();
            let mut g = prob.fields(&r);
            let mut cost = prob.eval(&r);
            for _ in 0..20 {
                let i = rng.below(prob.n);
                let delta = prob.flip_delta(&r, &g, i);
                prob.apply_flip(&mut r, &mut g, i);
                cost += delta;
                let fresh = prob.eval(&r);
                if (cost - fresh).abs() > 1e-6 * (1.0 + fresh.abs()) {
                    return Err(format!("incremental {cost} vs fresh {fresh}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Rng::new(5);
        let x = Tensor::from_vec(&[6, 40], (0..240).map(|_| rng.normal_f32(0.0, 1.0)).collect());
        let h = gram(&x);
        for i in 0..6 {
            assert!(h[i * 6 + i] >= 0.0);
            for j in 0..6 {
                assert!((h[i * 6 + j] - h[j * 6 + i]).abs() < 1e-12);
            }
        }
    }
}
