//! Cross-entropy method QUBO solver (Rubinstein, 1999) — the paper's
//! solver for eq. (13)/(20), with the sampling distribution initialized at
//! the stochastic-rounding probabilities (Gupta et al., 2015), i.e.
//! P(r_i = 1) = frac(w_i / s). See paper §5.1 and Appendix A.

use crate::util::Rng;

use super::problem::QuboProblem;

#[derive(Clone, Copy, Debug)]
pub struct CemParams {
    pub population: usize,
    pub elite_frac: f64,
    pub iters: usize,
    /// probability smoothing step
    pub alpha: f64,
}

impl Default for CemParams {
    fn default() -> Self {
        CemParams { population: 96, elite_frac: 0.125, iters: 60, alpha: 0.7 }
    }
}

/// Returns (best assignment, best cost).
pub fn solve_cem(prob: &QuboProblem, params: CemParams, rng: &mut Rng) -> (Vec<u8>, f64) {
    let n = prob.n;
    // smart init: stochastic-rounding probabilities
    let mut p: Vec<f64> = prob.frac.iter().map(|&f| f.clamp(0.02, 0.98)).collect();
    let mut best: Vec<u8> = p.iter().map(|&pi| (pi >= 0.5) as u8).collect();
    let mut best_cost = prob.eval(&best);
    let elite_n = ((params.population as f64 * params.elite_frac) as usize).max(2);

    let mut pop: Vec<(f64, Vec<u8>)> = Vec::with_capacity(params.population);
    for _ in 0..params.iters {
        pop.clear();
        for _ in 0..params.population {
            let r: Vec<u8> = p.iter().map(|&pi| rng.bernoulli(pi) as u8).collect();
            let cost = prob.eval(&r);
            pop.push((cost, r));
        }
        pop.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        if pop[0].0 < best_cost {
            best_cost = pop[0].0;
            best = pop[0].1.clone();
        }
        // update distribution towards the elite mean
        for i in 0..n {
            let mean = pop[..elite_n].iter().map(|(_, r)| r[i] as f64).sum::<f64>()
                / elite_n as f64;
            p[i] = ((1.0 - params.alpha) * p[i] + params.alpha * mean).clamp(0.01, 0.99);
        }
    }
    // local 1-flip polish on the best sample
    let mut g = prob.fields(&best);
    loop {
        let mut improved = false;
        for i in 0..n {
            let d = prob.flip_delta(&best, &g, i);
            if d < -1e-15 {
                prob.apply_flip(&mut best, &mut g, i);
                best_cost += d;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    (best, best_cost)
}

#[cfg(test)]
mod tests {
    use super::super::problem::tests::random_problem;
    use super::*;

    #[test]
    fn beats_nearest_rounding() {
        for seed in 0..5u64 {
            let (prob, _) = random_problem(seed, 24, 64);
            let nearest: Vec<u8> = prob.frac.iter().map(|&f| (f >= 0.5) as u8).collect();
            let mut rng = Rng::new(seed + 1);
            let (_, cost) = solve_cem(&prob, CemParams::default(), &mut rng);
            assert!(
                cost <= prob.eval(&nearest) + 1e-9,
                "seed {seed}: CEM {cost} worse than nearest {}",
                prob.eval(&nearest)
            );
        }
    }

    #[test]
    fn finds_optimum_on_small_problems() {
        for seed in 0..3u64 {
            let (prob, _) = random_problem(seed + 50, 10, 32);
            let (opt_r, opt_cost) = super::super::solve_exhaustive(&prob);
            let mut rng = Rng::new(seed);
            let (r, cost) = solve_cem(&prob, CemParams::default(), &mut rng);
            assert!(
                cost <= opt_cost * 1.02 + 1e-9,
                "seed {seed}: CEM {cost} vs optimum {opt_cost}"
            );
            // sanity: the reported cost matches the assignment
            assert!((prob.eval(&r) - cost).abs() < 1e-9);
            let _ = opt_r;
        }
    }
}
