//! Exhaustive QUBO enumeration — the ground-truth oracle for solver tests
//! (n <= 24; cost is evaluated incrementally over a Gray-code walk).

use super::problem::QuboProblem;

/// Returns (optimal assignment, optimal cost). Panics for n > 24.
pub fn solve_exhaustive(prob: &QuboProblem) -> (Vec<u8>, f64) {
    let n = prob.n;
    assert!(n <= 24, "exhaustive solver limited to 24 variables, got {n}");
    let mut r = vec![0u8; n];
    let mut g = prob.fields(&r);
    let mut cost = prob.eval(&r);
    let mut best_cost = cost;
    let mut best_code: u64 = 0;
    let mut code: u64 = 0;
    // Gray-code walk: step k flips bit = trailing zeros of k
    for k in 1u64..(1u64 << n) {
        let bit = k.trailing_zeros() as usize;
        cost += prob.flip_delta(&r, &g, bit);
        prob.apply_flip(&mut r, &mut g, bit);
        code ^= 1 << bit;
        if cost < best_cost {
            best_cost = cost;
            best_code = code;
        }
    }
    let best: Vec<u8> = (0..n).map(|i| ((best_code >> i) & 1) as u8).collect();
    (best, best_cost)
}

#[cfg(test)]
mod tests {
    use super::super::problem::tests::random_problem;
    use super::*;

    #[test]
    fn matches_bruteforce_eval() {
        let (prob, _) = random_problem(1, 8, 16);
        let (r, cost) = solve_exhaustive(&prob);
        // recompute from scratch
        assert!((prob.eval(&r) - cost).abs() < 1e-9);
        // verify optimality by naive loop
        for code in 0..(1u32 << prob.n) {
            let cand: Vec<u8> = (0..prob.n).map(|i| ((code >> i) & 1) as u8).collect();
            assert!(prob.eval(&cand) >= cost - 1e-9);
        }
    }

    #[test]
    fn beats_or_ties_nearest() {
        for seed in 0..4u64 {
            let (prob, _) = random_problem(seed + 70, 12, 24);
            let nearest: Vec<u8> = prob.frac.iter().map(|&f| (f >= 0.5) as u8).collect();
            let (_, opt) = solve_exhaustive(&prob);
            assert!(opt <= prob.eval(&nearest) + 1e-12);
        }
    }
}
