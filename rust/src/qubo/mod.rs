//! QUBO formulation of the rounding problem (paper §3.1-3.2) and solvers.
//!
//! Per output row k (eq. 20), with binary up/down variables r:
//!
//! ```text
//! Δ(r) = a + d ⊙ r,   a_i = w_i - floor-quant(w_i),  d_i = Δup - Δdown
//! cost(r) = Δ(r)^T H Δ(r),   H = E[x x^T]   (the layer-input Gram)
//! ```
//!
//! expanded into standard QUBO form `r^T Q r + lin^T r + c0`.
//! Solvers: cross-entropy method (the paper's choice), tabu search (the
//! qbsolv stand-in for Table 10), and exhaustive enumeration (test oracle).

pub mod cem;
pub mod exhaustive;
pub mod problem;
pub mod tabu;

pub use cem::{solve_cem, CemParams};
pub use exhaustive::solve_exhaustive;
pub use problem::{gram, QuboProblem};
pub use tabu::{solve_tabu, TabuParams};
