//! Tabu-search QUBO solver — the classical core of D-Wave's `qbsolv`
//! (Table 10's comparison baseline). Random restarts + greedy 1-flip with
//! a recency tabu list; deliberately *no* smart initialization, matching
//! the paper's observation that the qbsolv API does not accept one.

use crate::util::Rng;

use super::problem::QuboProblem;

#[derive(Clone, Copy, Debug)]
pub struct TabuParams {
    pub restarts: usize,
    pub iters_per_restart: usize,
    pub tenure: usize,
}

impl Default for TabuParams {
    fn default() -> Self {
        TabuParams { restarts: 6, iters_per_restart: 400, tenure: 12 }
    }
}

/// Returns (best assignment, best cost).
pub fn solve_tabu(prob: &QuboProblem, params: TabuParams, rng: &mut Rng) -> (Vec<u8>, f64) {
    let n = prob.n;
    let mut global_best: Option<(f64, Vec<u8>)> = None;

    for _ in 0..params.restarts {
        // random start (uniform — no smart init, see module docs)
        let mut r: Vec<u8> = (0..n).map(|_| rng.bernoulli(0.5) as u8).collect();
        let mut g = prob.fields(&r);
        let mut cost = prob.eval(&r);
        let mut best_cost = cost;
        let mut best_r = r.clone();
        let mut tabu_until = vec![0usize; n];

        for it in 0..params.iters_per_restart {
            // best admissible 1-flip (aspiration: always allow a new global best)
            let mut chosen: Option<(usize, f64)> = None;
            for i in 0..n {
                let d = prob.flip_delta(&r, &g, i);
                let admissible = tabu_until[i] <= it || cost + d < best_cost - 1e-15;
                if admissible && chosen.map(|(_, bd)| d < bd).unwrap_or(true) {
                    chosen = Some((i, d));
                }
            }
            let Some((i, d)) = chosen else { break };
            prob.apply_flip(&mut r, &mut g, i);
            cost += d;
            tabu_until[i] = it + params.tenure;
            if cost < best_cost {
                best_cost = cost;
                best_r = r.clone();
            }
        }
        if global_best.as_ref().map(|(c, _)| best_cost < *c).unwrap_or(true) {
            global_best = Some((best_cost, best_r));
        }
    }
    let (cost, r) = global_best.unwrap();
    (r, cost)
}

#[cfg(test)]
mod tests {
    use super::super::problem::tests::random_problem;
    use super::*;

    #[test]
    fn improves_over_random() {
        let (prob, _) = random_problem(3, 20, 48);
        let mut rng = Rng::new(4);
        let random: Vec<u8> = (0..prob.n).map(|_| rng.bernoulli(0.5) as u8).collect();
        let (_, cost) = solve_tabu(&prob, TabuParams::default(), &mut rng);
        assert!(cost <= prob.eval(&random) + 1e-12);
    }

    #[test]
    fn near_optimal_on_small() {
        for seed in 0..3u64 {
            let (prob, _) = random_problem(seed + 20, 10, 32);
            let (_, opt) = super::super::solve_exhaustive(&prob);
            let mut rng = Rng::new(seed);
            let (_, cost) = solve_tabu(&prob, TabuParams::default(), &mut rng);
            assert!(cost <= opt * 1.05 + 1e-9, "tabu {cost} vs opt {opt}");
        }
    }
}
