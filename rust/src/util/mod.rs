//! Dependency-free utility substrates.
//!
//! The build environment has no network access and only `xla` + `anyhow`
//! in its vendored registry, so the roles usually filled by `clap`,
//! `serde_json`, `rand` and `criterion` are implemented here from scratch
//! (see DESIGN.md §1).

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod topo;

pub use json::Json;
pub use rng::Rng;
pub use timer::Stopwatch;
