//! Mini property-testing harness — the `proptest` replacement.
//!
//! `property(seed, cases, |g| { ... })` runs a closure over `cases`
//! generated inputs. On failure the case index and generator seed are
//! reported so the exact case can be replayed. Shrinking is intentionally
//! omitted (inputs here are small enough to debug from the seed).

use super::rng::Rng;

/// Per-case generator handle.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    /// Integer in [lo, hi].
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal_f32(mean, std)).collect()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }
}

/// Run `f` over `cases` generated cases; panics with replay info on failure.
pub fn property<F: FnMut(&mut Gen) -> Result<(), String>>(
    seed: u64,
    cases: usize,
    mut f: F,
) {
    let mut base = Rng::new(seed);
    for case in 0..cases {
        let rng = base.fork(case as u64);
        let mut g = Gen { rng, case };
        if let Err(msg) = f(&mut g) {
            panic!("property failed at case {case} (seed {seed}): {msg}");
        }
    }
}

/// assert_close! for float comparisons inside properties.
pub fn close(a: f32, b: f32, tol: f32) -> Result<(), String> {
    let scale = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        property(1, 50, |g| {
            n += 1;
            let v = g.int(1, 10);
            if (1..=10).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        property(2, 10, |g| {
            let v = g.int(0, 100);
            if v < 1000 && g.case < 5 {
                Ok(())
            } else {
                Err("boom".into())
            }
        });
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5).is_ok());
        assert!(close(1.0, 1.1, 1e-5).is_err());
    }
}
