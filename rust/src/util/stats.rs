//! Small statistics helpers: mean/std over seeds, Pearson correlation,
//! percentiles — used by the experiment tables and the bench harness.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1); 0 for n < 2.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx * dy).sqrt()
}

/// Spearman rank correlation (ties get average-free dense ranks; adequate
/// for the Fig-1 cost-accuracy monotonicity check).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rank = |v: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
        let mut r = vec![0.0; v.len()];
        for (rank, &i) in idx.iter().enumerate() {
            r[i] = rank as f64;
        }
        r
    };
    pearson(&rank(xs), &rank(ys))
}

/// p-th percentile (0..100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[k.min(v.len() - 1)]
}

/// Estimated q-quantile (q in 0..=1) of a fixed-bucket histogram:
/// `bounds` are increasing bucket upper bounds, `counts` the per-bucket
/// observation counts with the overflow bucket last
/// (`counts.len() == bounds.len() + 1`). Linear interpolation within the
/// covering bucket (the Prometheus `histogram_quantile` convention);
/// observations past the last bound clamp to it. NaN for an empty
/// histogram — the serving telemetry layer's p50/p99 estimator.
pub fn histogram_quantile(bounds: &[f64], counts: &[u64], q: f64) -> f64 {
    assert_eq!(counts.len(), bounds.len() + 1, "need an overflow bucket");
    assert!((0.0..=1.0).contains(&q));
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return f64::NAN;
    }
    // rank of the target observation, 1-based, at least 1
    let rank = ((q * total as f64).ceil() as u64).max(1);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        if cum + c >= rank {
            if i == bounds.len() {
                return bounds[bounds.len() - 1]; // overflow: clamp
            }
            let lower = if i == 0 { 0.0 } else { bounds[i - 1] };
            let frac = (rank - cum) as f64 / c as f64;
            return lower + (bounds[i] - lower) * frac;
        }
        cum += c;
    }
    bounds[bounds.len() - 1]
}

/// Format "mean±std" the way the paper's tables do.
pub fn fmt_mean_std(xs: &[f64]) -> String {
    if xs.len() == 1 {
        format!("{:.2}", xs[0])
    } else {
        format!("{:.2}±{:.2}", mean(xs), std(xs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 10.0, 100.0, 1000.0]; // nonlinear but monotone
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantile_interpolates() {
        // buckets (0,1], (1,2], (2,4], overflow
        let bounds = [1.0, 2.0, 4.0];
        // 10 obs in (0,1], 10 in (1,2]
        let counts = [10u64, 10, 0, 0];
        assert!((histogram_quantile(&bounds, &counts, 0.5) - 1.0).abs() < 1e-12);
        assert!((histogram_quantile(&bounds, &counts, 0.75) - 1.5).abs() < 1e-12);
        // everything in one bucket: interpolate from its lower edge
        let one = [0u64, 4, 0, 0];
        assert!((histogram_quantile(&bounds, &one, 0.5) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantile_edges() {
        let bounds = [1.0, 2.0];
        assert!(histogram_quantile(&bounds, &[0, 0, 0], 0.5).is_nan());
        // overflow observations clamp to the last finite bound
        assert_eq!(histogram_quantile(&bounds, &[0, 0, 5], 0.99), 2.0);
        // q=0 still returns the first occupied bucket's estimate
        let counts = [3u64, 0, 0];
        assert!(histogram_quantile(&bounds, &counts, 0.0) > 0.0);
    }

    #[test]
    fn percentile_nearest() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
