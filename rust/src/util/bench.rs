//! Micro-benchmark harness — the `criterion` replacement.
//!
//! Adaptive warmup + timed iterations, reporting mean / p50 / p95 and
//! optional throughput. Used by `benches/*.rs` (built with
//! `harness = false`) and by the `adaround bench` subcommand.

use std::time::Instant;

use super::stats;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// items/sec if `throughput_items` was set
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn print(&self) {
        let tp = self
            .throughput
            .map(|t| format!("  {:>12.1} items/s", t))
            .unwrap_or_default();
        println!(
            "{:<44} {:>10.3} ms/iter  p50 {:>9.3}  p95 {:>9.3}  ({} iters){}",
            self.name, self.mean_ms, self.p50_ms, self.p95_ms, self.iters, tp
        );
    }
}

pub struct Bench {
    /// minimum total measurement time
    pub measure_secs: f64,
    pub warmup_secs: f64,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { measure_secs: 1.0, warmup_secs: 0.3, max_iters: 10_000 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { measure_secs: 0.3, warmup_secs: 0.1, max_iters: 2_000 }
    }

    /// Benchmark `f`, which performs ONE iteration of the workload.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        self.run_with_items(name, 0, &mut f)
    }

    /// Benchmark with a throughput denominator (items processed per iter).
    pub fn run_with_items<F: FnMut()>(
        &self,
        name: &str,
        items_per_iter: usize,
        f: &mut F,
    ) -> BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed().as_secs_f64() < self.warmup_secs {
            f();
        }
        // measure
        let mut samples_ms: Vec<f64> = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed().as_secs_f64() < self.measure_secs && samples_ms.len() < self.max_iters
        {
            let t = Instant::now();
            f();
            samples_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }
        let mean = stats::mean(&samples_ms);
        BenchResult {
            name: name.to_string(),
            iters: samples_ms.len(),
            mean_ms: mean,
            p50_ms: stats::percentile(&samples_ms, 50.0),
            p95_ms: stats::percentile(&samples_ms, 95.0),
            throughput: if items_per_iter > 0 {
                Some(items_per_iter as f64 / (mean / 1e3))
            } else {
                None
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench { measure_secs: 0.05, warmup_secs: 0.01, max_iters: 1000 };
        let mut acc = 0u64;
        let r = b.run("noop", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters > 0);
        assert!(r.mean_ms >= 0.0);
        assert!(r.p95_ms >= r.p50_ms * 0.5);
    }

    #[test]
    fn throughput_reported() {
        let b = Bench { measure_secs: 0.05, warmup_secs: 0.0, max_iters: 100 };
        let r = b.run_with_items("items", 100, &mut || {
            std::hint::black_box(vec![0u8; 64]);
        });
        assert!(r.throughput.unwrap() > 0.0);
    }
}
