//! Tiny CLI argument parser — the `clap` replacement.
//!
//! Grammar: `adaround <subcommand> [positional...] [--flag [value]]...`
//! A flag with no following value (or followed by another flag) is boolean.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    /// every value a flag appeared with, in order — `flags` keeps the
    /// last one (the historical behavior); repeatable flags
    /// (`--model a=1.qtz --model b=2.qtz`) read [`Args::all`] instead
    pub multi: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let is_val = it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                let val = if is_val { it.next().unwrap() } else { "true".to_string() };
                out.multi.entry(name.to_string()).or_default().push(val.clone());
                out.flags.insert(name.to_string(), val);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable flag, in command-line order
    /// (empty if absent).
    pub fn all(&self, name: &str) -> Vec<&str> {
        self.multi
            .get(name)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a float, got '{v}'")),
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("quantize --model micro18 --bits 4 --verbose");
        assert_eq!(a.subcommand, "quantize");
        assert_eq!(a.str("model", ""), "micro18");
        assert_eq!(a.usize("bits", 8).unwrap(), 4);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn positionals() {
        let a = parse("table 7 --seeds 3");
        assert_eq!(a.subcommand, "table");
        assert_eq!(a.positional, vec!["7"]);
        assert_eq!(a.usize("seeds", 1).unwrap(), 3);
    }

    #[test]
    fn repeated_flags_collect_in_order() {
        let a = parse("serve --model a=1.qtz --model b=2.qtz --shards 2");
        assert_eq!(a.all("model"), vec!["a=1.qtz", "b=2.qtz"]);
        assert_eq!(a.str("model", ""), "b=2.qtz"); // last wins, as before
        assert_eq!(a.all("shards"), vec!["2"]);
        assert!(a.all("absent").is_empty());
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("eval --bits x");
        assert_eq!(a.usize("iters", 100).unwrap(), 100);
        assert!(a.usize("bits", 4).is_err());
        assert_eq!(a.f32("lr", 0.01).unwrap(), 0.01);
    }
}
