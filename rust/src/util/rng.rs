//! Deterministic PRNG (xoshiro256++) — the `rand`-crate replacement.
//!
//! Every stochastic component in the framework (stochastic rounding, CEM
//! sampling, tabu restarts, data shuffling) takes an explicit `Rng` so runs
//! are reproducible from a single seed, matching the paper's protocol of
//! reporting mean ± std over seeds.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 expansion (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-layer / per-seed forks).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx = Vec::new();
        let k = self.sample_indices_into(n, k, &mut idx);
        idx.truncate(k);
        idx
    }

    /// Allocation-free `sample_indices`: resets `pool` to 0..n, runs the
    /// same partial Fisher-Yates (identical RNG stream and sample), and
    /// returns the sample size — the first `k` entries of `pool`. Reusing
    /// the pool across calls keeps the optimizer inner loop heap-free.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, pool: &mut Vec<usize>) -> usize {
        let k = k.min(n);
        pool.clear();
        pool.extend(0..n);
        for i in 0..k {
            let j = i + self.below(n - i);
            pool.swap(i, j);
        }
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let m: f64 = (0..20000).map(|_| r.f64()).sum::<f64>() / 20000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
    }

    #[test]
    fn sample_indices_into_matches_alloc_variant() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        let mut pool = Vec::new();
        for (n, k) in [(50usize, 10usize), (8, 8), (20, 30)] {
            let alloc = a.sample_indices(n, k);
            let kk = b.sample_indices_into(n, k, &mut pool);
            assert_eq!(&pool[..kk], &alloc[..]);
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
