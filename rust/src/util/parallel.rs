//! Zero-dependency data parallelism over `std::thread::scope`.
//!
//! The AdaRound hot paths (GEMM rows, conv groups, calibration chunks,
//! per-group rounding) are embarrassingly parallel, so this module provides
//! exactly one pattern: split a range of independent work items into
//! contiguous per-thread spans and run them on scoped threads.
//!
//! **Determinism.** Work is assigned by *item index* and every item is
//! computed by the same serial code regardless of the thread count, so
//! results are bit-identical for `PALLAS_THREADS=1` and `=N` (verified by
//! the `*_bit_identical_across_threads` tests in tensor/ and adaround/).
//! No atomics, no locks, no reduction-order dependence: threads only ever
//! write disjoint `&mut` sub-slices handed out via `split_at_mut`.
//!
//! **Thread count.** `PALLAS_THREADS` (clamped to [1, 256]) wins; otherwise
//! `std::thread::available_parallelism()`. Workers run their items with the
//! count forced to 1, so nested parallel calls (e.g. the row-parallel
//! matmul inside a group-parallel conv) never oversubscribe.
//!
//! Threads are spawned per call rather than kept in a static pool: spawn
//! cost (~10-40us) is amortized by the grain thresholds at each call site,
//! and scoped threads let workers borrow the caller's slices safely.

use std::cell::Cell;
use std::ops::Range;
use std::sync::OnceLock;

/// Hard cap on worker threads (sanity bound for absurd env values).
pub const MAX_THREADS: usize = 256;

thread_local! {
    static OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
}

fn env_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let n = match std::env::var("PALLAS_THREADS") {
            Ok(v) => v.trim().parse::<usize>().unwrap_or(0),
            Err(_) => 0,
        };
        let n = if n == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            n
        };
        n.clamp(1, MAX_THREADS)
    })
}

/// Effective worker count for the current thread (env / override).
pub fn num_threads() -> usize {
    OVERRIDE.with(|c| c.get()).unwrap_or_else(env_threads)
}

/// Run `f` with the thread count forced to `n` on this thread (restored on
/// exit, panic-safe). Used by tests to compare thread counts within one
/// process, and internally to serialize nested parallelism in workers.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Guard(Option<usize>);
    impl Drop for Guard {
        fn drop(&mut self) {
            OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = OVERRIDE.with(|c| c.replace(Some(n.clamp(1, MAX_THREADS))));
    let _g = Guard(prev);
    f()
}

/// Split `n` items into at most `parts` contiguous near-equal ranges
/// (the first `n % parts` ranges get one extra item). Deterministic and
/// independent of thread scheduling.
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut s = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        if len == 0 {
            break;
        }
        out.push(s..s + len);
        s += len;
    }
    out
}

/// Parallel split of `data` into per-thread spans of whole chunks: each
/// thread receives ONE contiguous range of chunk indices plus the matching
/// sub-slice, and `f(range, span)` processes it serially. This is the
/// primitive behind the K-blocked row-parallel GEMM, where a thread wants
/// its whole row range at once (to reuse cache blocks across rows) rather
/// than row-at-a-time callbacks.
///
/// `grain` is the minimum number of chunks per thread — below it the call
/// degrades to `f(0..nchunks, data)` on the caller thread (allocating
/// nothing), so tiny inputs never pay spawn cost.
///
/// Panics if `data.len()` is not a multiple of `chunk`.
pub fn par_ranges_mut<T, F>(data: &mut [T], chunk: usize, grain: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk must be positive");
    assert_eq!(data.len() % chunk, 0, "data.len() {} not a multiple of chunk {}", data.len(), chunk);
    let nchunks = data.len() / chunk;
    let want = nchunks / grain.max(1);
    let t = num_threads().min(want.max(1));
    if t <= 1 || nchunks <= 1 {
        f(0..nchunks, data);
        return;
    }
    let ranges = split_ranges(nchunks, t);
    // main thread takes ranges[0]; workers get the rest
    let (main_part, mut rest) = data.split_at_mut(ranges[0].end * chunk);
    std::thread::scope(|s| {
        for r in &ranges[1..] {
            let len = (r.end - r.start) * chunk;
            let (part, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            let range = r.clone();
            let fr = &f;
            s.spawn(move || with_threads(1, || fr(range, part)));
        }
        let r0 = ranges[0].clone();
        with_threads(1, || f(r0, main_part));
    });
}

/// Parallel iteration over the equal-size chunks of `data`: calls
/// `f(chunk_index, chunk)` for every `chunk`-sized piece, fanning
/// contiguous runs of chunks out to worker threads (see [`par_ranges_mut`]
/// for grain semantics and the determinism contract).
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, grain: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_ranges_mut(data, chunk, grain, |range, span| {
        for (j, c) in span.chunks_mut(chunk).enumerate() {
            f(range.start + j, c);
        }
    });
}

/// Lock-step parallel iteration over the chunks of TWO slices: calls
/// `f(i, a_chunk_i, b_chunk_i)` for every chunk index. Both slices must
/// contain the same number of chunks (`a.len()/ca == b.len()/cb`); chunk
/// sizes may differ — e.g. a per-row output plus a per-row f64 partial.
/// Grain/determinism semantics as in [`par_ranges_mut`].
pub fn par_chunks2_mut<T, U, F>(a: &mut [T], ca: usize, b: &mut [U], cb: usize, grain: usize, f: F)
where
    T: Send,
    U: Send,
    F: Fn(usize, &mut [T], &mut [U]) + Sync,
{
    assert!(ca > 0 && cb > 0, "chunk sizes must be positive");
    assert_eq!(a.len() % ca, 0, "a.len() {} not a multiple of {}", a.len(), ca);
    assert_eq!(b.len() % cb, 0, "b.len() {} not a multiple of {}", b.len(), cb);
    let nchunks = a.len() / ca;
    assert_eq!(nchunks, b.len() / cb, "slices disagree on chunk count");
    let serial = |off: usize, aspan: &mut [T], bspan: &mut [U]| {
        for (j, (ac, bc)) in aspan.chunks_mut(ca).zip(bspan.chunks_mut(cb)).enumerate() {
            f(off + j, ac, bc);
        }
    };
    let want = nchunks / grain.max(1);
    let t = num_threads().min(want.max(1));
    if t <= 1 || nchunks <= 1 {
        serial(0, a, b);
        return;
    }
    let ranges = split_ranges(nchunks, t);
    let (a_main, mut a_rest) = a.split_at_mut(ranges[0].end * ca);
    let (b_main, mut b_rest) = b.split_at_mut(ranges[0].end * cb);
    std::thread::scope(|s| {
        for r in &ranges[1..] {
            let (ap, at) = std::mem::take(&mut a_rest).split_at_mut((r.end - r.start) * ca);
            let (bp, bt) = std::mem::take(&mut b_rest).split_at_mut((r.end - r.start) * cb);
            a_rest = at;
            b_rest = bt;
            let start = r.start;
            let sr = &serial;
            s.spawn(move || with_threads(1, || sr(start, ap, bp)));
        }
        with_threads(1, || serial(0, a_main, b_main));
    });
}

/// Parallel map over `0..n`: returns `vec![f(0), f(1), ..]` in index order
/// regardless of scheduling. `grain` as in [`par_chunks_mut`].
pub fn par_map<R, F>(n: usize, grain: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    par_chunks_mut(&mut out, 1, grain, |i, slot| {
        slot[0] = Some(f(i));
    });
    out.into_iter().map(|r| r.expect("par_map slot filled")).collect()
}

/// [`par_map`] for stochastic work: item `i` draws from `rngs[i]`. Fork
/// the RNGs serially from one stream before calling (fork order = item
/// order), and the outcome is independent of the thread count — the
/// deterministic fan-out rule used by per-group rounding and per-chunk
/// calibration sampling.
pub fn par_map_rng<R, F>(rngs: &mut [crate::util::Rng], grain: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut crate::util::Rng) -> R + Sync,
{
    let n = rngs.len();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    par_chunks2_mut(&mut out, 1, rngs, 1, grain, |i, slot, rng| {
        slot[0] = Some(f(i, &mut rng[0]));
    });
    out.into_iter().map(|r| r.expect("par_map_rng slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_exactly() {
        for (n, p) in [(10, 3), (3, 10), (0, 4), (7, 1), (8, 8), (1, 1)] {
            let rs = split_ranges(n, p);
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next);
                assert!(r.end > r.start);
                next = r.end;
            }
            assert_eq!(next, n);
            assert!(rs.len() <= p.max(1));
            // near-equal: sizes differ by at most one
            if let (Some(a), Some(b)) = (
                rs.iter().map(|r| r.end - r.start).max(),
                rs.iter().map(|r| r.end - r.start).min(),
            ) {
                assert!(a - b <= 1);
            }
        }
    }

    #[test]
    fn par_chunks_mut_writes_every_chunk() {
        let mut data = vec![0u32; 7 * 13];
        with_threads(4, || {
            par_chunks_mut(&mut data, 13, 1, |i, c| {
                for (j, v) in c.iter_mut().enumerate() {
                    *v = (i * 13 + j) as u32;
                }
            });
        });
        for (k, v) in data.iter().enumerate() {
            assert_eq!(*v, k as u32);
        }
    }

    #[test]
    fn par_matches_serial() {
        let run = |threads: usize| {
            let mut data = vec![0.0f32; 101];
            with_threads(threads, || {
                par_chunks_mut(&mut data, 1, 1, |i, c| {
                    c[0] = (i as f32).sin();
                });
            });
            data
        };
        assert_eq!(run(1), run(5));
    }

    #[test]
    fn par_map_preserves_order() {
        let got = with_threads(3, || par_map(20, 1, |i| i * i));
        assert_eq!(got, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_rng_thread_count_independent() {
        let run = |threads: usize| {
            let mut base = crate::util::Rng::new(99);
            let mut rngs: Vec<crate::util::Rng> = (0..12).map(|i| base.fork(i)).collect();
            with_threads(threads, || par_map_rng(&mut rngs, 1, |i, r| (i, r.next_u64())))
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn nested_calls_serialize() {
        // inside a worker, num_threads() must report 1
        let inner: Vec<usize> = with_threads(4, || par_map(8, 1, |_| num_threads()));
        assert!(inner.iter().all(|&n| n == 1), "{inner:?}");
    }

    #[test]
    fn with_threads_restores() {
        let before = num_threads();
        with_threads(2, || {
            assert_eq!(num_threads(), 2);
            with_threads(7, || assert_eq!(num_threads(), 7));
            assert_eq!(num_threads(), 2);
        });
        assert_eq!(num_threads(), before);
    }

    #[test]
    fn par_chunks2_lockstep() {
        let rows = 9;
        let cols = 5;
        let mut grid = vec![0.0f32; rows * cols];
        let mut partial = vec![0.0f64; rows];
        with_threads(4, || {
            par_chunks2_mut(&mut grid, cols, &mut partial, 1, 1, |r, row, p| {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = (r * cols + j) as f32;
                }
                p[0] = row.iter().map(|&v| v as f64).sum();
            });
        });
        for (k, v) in grid.iter().enumerate() {
            assert_eq!(*v, k as f32);
        }
        let expect: f64 = (0..cols).map(|j| (8 * cols + j) as f64).sum();
        assert_eq!(partial[8], expect);
    }

    #[test]
    fn grain_degrades_to_serial() {
        // grain larger than the chunk count: must still process everything
        let mut data = vec![0u8; 6];
        par_chunks_mut(&mut data, 2, 100, |_, c| c.iter_mut().for_each(|v| *v = 1));
        assert!(data.iter().all(|&v| v == 1));
    }
}
